//! Cluster-simulation example: compare all systems on both paper testbeds
//! and print Figure-9/10-style speedup tables plus a Figure-12 breakdown.
//!
//! ```bash
//! cargo run --release --example simulate_cluster
//! ```

use hecate::config::ClusterPreset;
use hecate::sim::report;

fn main() {
    let opts = report::default_opts();

    println!("== Table 1 ==");
    print!("{}", report::table1().to_markdown());

    println!("\n== End-to-end speedups, Cluster A (4x8 V100, 100 Gbps) ==");
    print!("{}", report::end_to_end(ClusterPreset::A, 4, 8, &opts).to_markdown());

    println!("\n== End-to-end speedups, Cluster B (4x8 A100, 400 Gbps) ==");
    print!("{}", report::end_to_end(ClusterPreset::B, 4, 8, &opts).to_markdown());

    println!("\n== Critical-path breakdown (BERT-MoE-Deep @ B) ==");
    print!("{}", report::figure12(&opts).to_markdown());

    println!("\n== Peak MoE memory ==");
    print!("{}", report::figure13(&opts).to_markdown());
}
