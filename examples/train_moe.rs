//! End-to-end training driver (EXPERIMENTS.md §E2E): train the ~100M-param
//! GPT-MoE model (`e2e` artifacts; falls back to `tiny` with a warning)
//! for a few hundred steps on the synthetic Markov corpus through the PJRT
//! runtime, and log the loss curve to `train_loss.csv`.
//!
//! ```bash
//! make artifacts && cargo run --release --example train_moe -- [steps]
//! ```

use hecate::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(200);
    let rt = Runtime::open("artifacts")?;
    let tag = if rt.entry("e2e_train_step").is_ok() {
        "e2e"
    } else {
        eprintln!("warning: e2e artifacts missing, training tiny model instead");
        "tiny"
    };
    drop(rt);
    println!("training `{tag}` for {steps} steps…");
    hecate::train::run_training("artifacts", tag, steps, Some("train_loss.csv"))
}
