//! Numeric FSSDP demonstration: real FSSDP training of an MoE layer across
//! 8 simulated devices (2 nodes × 4), then the 1-device reference on the
//! same data, asserting the trained parameters match — the paper's §3
//! guarantee that placement freedom never changes the math. Both runs go
//! through the unified `Session` API (PJRT backend).
//!
//! ```bash
//! make artifacts && cargo run --release --example fssdp_numeric
//! ```

use hecate::fssdp::{Session, SessionConfig};
use hecate::testing::max_rel_err;
use hecate::topology::Topology;

fn main() -> anyhow::Result<()> {
    let iters = 6;
    let sources = 8;
    let session = |topo: Topology| -> anyhow::Result<Session> {
        Session::fresh(
            SessionConfig::builder()
                .pjrt("artifacts")
                .topology(topo)
                .seed(77)
                .data_shards(sources)
                .build()?,
        )
    };

    println!("=== distributed run: 2 nodes x 4 devices ===");
    let mut dist = session(Topology::cluster_a(2, 4))?;
    for (i, s) in dist.run(iters)?.iter().enumerate() {
        println!(
            "iter {i}  loss {:.5}  λ={:.2}  replicas {}  remote_tokens {}  straggler {:.2}",
            s.loss, s.spag_sparsity, s.replicas, s.remote_tokens, s.straggler
        );
    }

    println!("\n=== reference run: 1 device, same data ===");
    let mut reference = session(Topology::flat(1, 1e9))?;
    for (i, s) in reference.run(iters)?.iter().enumerate() {
        println!("iter {i}  loss {:.5}", s.loss);
    }

    println!("\n=== parameter equivalence ===");
    let mut worst = 0.0f32;
    for e in 0..dist.engine().dims.experts {
        let err =
            max_rel_err(dist.engine().expert_chunk(e), reference.engine().expert_chunk(e));
        worst = worst.max(err);
        println!("expert {e}: max rel err {err:.2e}");
    }
    anyhow::ensure!(worst < 2e-3, "equivalence violated: {worst}");
    println!("\nFSSDP(8 devices) == reference(1 device): OK (worst {worst:.2e})");
    Ok(())
}
