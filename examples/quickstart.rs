//! Quickstart: the three-layer stack in one page.
//!
//! 1. Load the AOT artifacts (Pallas kernels + JAX model, compiled to HLO
//!    by `make artifacts`) through the PJRT runtime.
//! 2. Run the gate and one expert through real executables.
//! 3. Plan a sparse materialization with Algorithm 1 and inspect the spAG.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use hecate::collectives::sparse::build_spag;
use hecate::materialize::{sparse_materialize, MatConstraints};
use hecate::placement::Placement;
use hecate::runtime::{HostTensor, Runtime};
use hecate::topology::Topology;

fn main() -> anyhow::Result<()> {
    // ---- L2/L1 through PJRT -------------------------------------------
    let mut rt = Runtime::open("artifacts")?;
    println!("artifacts: {:?}", rt.entry_names().collect::<Vec<_>>());

    let gate = rt.entry("gate_fwd")?.clone();
    let (t, dm) = (gate.inputs[0].shape[0], gate.inputs[0].shape[1]);
    let experts = gate.inputs[1].shape[1];
    let x = HostTensor::f32(vec![t, dm], (0..t * dm).map(|i| (i as f32 * 0.3).sin()).collect());
    let wg = HostTensor::f32(
        vec![dm, experts],
        (0..dm * experts).map(|i| (i as f32 * 0.17).cos()).collect(),
    );
    let out = rt.execute("gate_fwd", &[x, wg])?;
    let idx = out[2].as_i32()?;
    println!("gate: routed {t} tokens; first 4 top-2 pairs: {:?}", &idx[..8]);

    // ---- L3: FSSDP planning -------------------------------------------
    let topo = Topology::cluster_a(2, 4);
    let shards = Placement::round_robin(experts, topo.num_devices());
    // pretend expert 3 is hot
    let mut loads = vec![0.05; experts];
    loads[3] = 0.5;
    let plan = sparse_materialize(
        &topo,
        &shards,
        &loads,
        MatConstraints { overlap_degree: 4, mem_slots: 2 },
    );
    println!(
        "Algorithm 1: expert 3 materialized on {} devices (was 1)",
        plan.replication(3)
    );
    let spag = build_spag(&topo, &shards, &plan)?;
    println!(
        "spAG: {} transfers, λ = {:.2}, est. {:.3} ms on {}",
        spag.transfers.len(),
        spag.sparsity,
        spag.time(&topo, 4e6) * 1e3,
        topo.name
    );
    println!("quickstart OK");
    Ok(())
}
