"""AOT pipeline: lower every L2 entry point to HLO **text** + manifest.

HLO text (NOT serialized protos) is the interchange format: jax ≥ 0.5
emits 64-bit instruction ids that the Rust side's xla_extension 0.5.1
rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

Artifacts (written to ``--out``, default ``../artifacts``):

* ``e2e_init`` / ``e2e_train_step`` — the ~100M-param GPT-MoE model used by
  ``examples/train_moe``: full fwd/bwd/Adam in one executable.
* ``tiny_init`` / ``tiny_train_step`` — same entries at the TINY config for
  fast integration tests.
* ``gate_fwd`` — gate logits→softmax→Pallas top-2 (the L3 dispatcher's
  gate call in the numeric FSSDP engine).
* ``expert_ffn_fwd`` / ``expert_ffn_bwd`` — single-expert Pallas FFN
  forward and VJP at the engine's capacity tile, called per materialized
  expert by the numeric engine.
* ``manifest.json`` — shapes/dtypes/orderings for the Rust runtime.

Python runs ONCE (`make artifacts`); nothing here is on the training path.
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import gating, moe_ffn

jax.config.update("jax_platform_name", "cpu")


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec_of(x) -> dict:
    return {"shape": list(x.shape), "dtype": x.dtype.name}


# --------------------------------------------------------------------------
# Entry-point builders
# --------------------------------------------------------------------------

def flat_train_step(cfg: model.ModelCfg, adam: model.AdamCfg):
    """train_step over a flat arg list (stable ordering for the manifest).

    Args: [params…, m…, v…, t, tokens, targets] (params in param_order).
    Returns: (loss, nll, loads, params'…, m'…, v'…, t').
    """
    order = model.param_order(cfg)
    n = len(order)

    def fn(*flat):
        params = dict(zip(order, flat[:n]))
        m = dict(zip(order, flat[n : 2 * n]))
        v = dict(zip(order, flat[2 * n : 3 * n]))
        t = flat[3 * n]
        tokens, targets = flat[3 * n + 1], flat[3 * n + 2]
        opt = {"m": m, "v": v, "t": t}
        loss, nll, loads, new_p, new_o = model.train_step(
            params, opt, tokens, targets, cfg, adam
        )
        out = [loss, nll, loads]
        out += [new_p[k] for k in order]
        out += [new_o["m"][k] for k in order]
        out += [new_o["v"][k] for k in order]
        out += [new_o["t"]]
        return tuple(out)

    return fn, order


def flat_init(cfg: model.ModelCfg):
    """init over a scalar seed -> (params…, m…, v…, t) flat tuple."""
    order = model.param_order(cfg)

    def fn(seed):
        key = jax.random.PRNGKey(seed)
        params = model.init_params(cfg, key)
        opt = model.adam_init(params)
        out = [params[k] for k in order]
        out += [opt["m"][k] for k in order]
        out += [opt["v"][k] for k in order]
        out += [opt["t"]]
        return tuple(out)

    return fn, order


def expert_ffn_fwd_fn(x, w1, b1, w2, b2):
    """Single-expert FFN forward at the engine tile ([cap, dm])."""
    y = moe_ffn.grouped_ffn(x[None], w1[None], b1[None], w2[None], b2[None])
    return (y[0],)


def expert_ffn_bwd_fn(x, w1, b1, w2, b2, gy):
    """Single-expert FFN VJP: returns (gx, gw1, gb1, gw2, gb2)."""
    y, h = moe_ffn.grouped_ffn_fwd(x[None], w1[None], b1[None], w2[None], b2[None])
    del y
    gx, gw1, gb1, gw2, gb2 = moe_ffn.grouped_ffn_bwd_kernels(
        x[None], w1[None], b1[None], w2[None], b2[None], h, gy[None]
    )
    return gx[0], gw1[0], gb1[0], gw2[0], gb2[0]


def gate_fwd_fn(x, wg):
    return gating.gate_fwd(x, wg)


# --------------------------------------------------------------------------
# Driver
# --------------------------------------------------------------------------

def lower_entry(name, fn, example_args, out_dir, manifest, extra=None):
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    fname = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    outputs = jax.eval_shape(fn, *example_args)
    if not isinstance(outputs, (tuple, list)):
        outputs = (outputs,)
    entry = {
        "file": fname,
        "inputs": [spec_of(a) for a in example_args],
        "outputs": [spec_of(o) for o in outputs],
    }
    if extra:
        entry.update(extra)
    manifest["entries"][name] = entry
    print(f"  {name}: {len(text) / 1e6:.2f} MB, "
          f"{len(entry['inputs'])} in / {len(entry['outputs'])} out")


def model_entries(tag, cfg, batch, out_dir, manifest):
    adam = model.AdamCfg()
    order = model.param_order(cfg)

    init_fn, _ = flat_init(cfg)
    lower_entry(
        f"{tag}_init", init_fn,
        [jax.ShapeDtypeStruct((), jnp.int32)],
        out_dir, manifest,
        extra={"param_order": order, "config": cfg.__dict__},
    )

    step_fn, _ = flat_train_step(cfg, adam)
    params = jax.eval_shape(lambda s: flat_init(cfg)[0](s), jnp.int32(0))
    tokens = jax.ShapeDtypeStruct((batch, cfg.seq_len), jnp.int32)
    targets = jax.ShapeDtypeStruct((batch, cfg.seq_len), jnp.int32)
    args = list(params[:-1]) + [params[-1], tokens, targets]
    lower_entry(
        f"{tag}_train_step", step_fn, args, out_dir, manifest,
        extra={"param_order": order, "batch": batch, "config": cfg.__dict__},
    )


def engine_entries(out_dir, manifest, cfg=model.TINY, tokens=128, cap=64):
    """Artifacts for the numeric FSSDP engine (expert granularity)."""
    dm, dff, e = cfg.d_model, cfg.d_ffn, cfg.experts
    f32 = jnp.float32
    s = jax.ShapeDtypeStruct
    lower_entry(
        "gate_fwd", gate_fwd_fn,
        [s((tokens, dm), f32), s((dm, e), f32)],
        out_dir, manifest,
        extra={"tokens": tokens, "d_model": dm, "experts": e},
    )
    ffn_args = [
        s((cap, dm), f32), s((dm, dff), f32), s((dff,), f32),
        s((dff, dm), f32), s((dm,), f32),
    ]
    lower_entry(
        "expert_ffn_fwd", expert_ffn_fwd_fn, ffn_args, out_dir, manifest,
        extra={"cap": cap, "d_model": dm, "d_ffn": dff},
    )
    lower_entry(
        "expert_ffn_bwd", expert_ffn_bwd_fn,
        ffn_args + [s((cap, dm), f32)], out_dir, manifest,
        extra={"cap": cap, "d_model": dm, "d_ffn": dff},
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--skip-e2e", action="store_true",
                    help="skip the large e2e model (fast CI runs)")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    manifest = {"entries": {}, "format": "hlo-text", "version": 1}

    print("lowering engine entries (tiny)…")
    engine_entries(args.out, manifest)
    print("lowering tiny model…")
    model_entries("tiny", model.TINY, batch=2, out_dir=args.out, manifest=manifest)
    if not args.skip_e2e:
        print("lowering e2e 100M model…")
        model_entries("e2e", model.E2E_100M, batch=4, out_dir=args.out, manifest=manifest)

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"manifest: {len(manifest['entries'])} entries -> {args.out}/manifest.json")
    return 0


if __name__ == "__main__":
    sys.exit(main())
