"""L2: the JAX Transformer-MoE model (fwd/bwd + Adam) — build-time only.

The architecture follows the paper's §5.1 setup: GPT-style decoder blocks
whose FFNs are replaced by MoE layers (experts are FFNs with
``d_ffn = 2·d_model``), GShard top-2 gating with capacity factor and
auxiliary load-balancing loss. The expert compute runs through the L1
Pallas grouped-FFN kernel (``kernels.moe_ffn.grouped_ffn``); gating is
differentiable jnp, with the L1 ``top2_gate`` Pallas kernel exported
separately for the Rust dispatcher.

Everything here is AOT-lowered by ``aot.py`` to HLO text; Python never
runs at training time.
"""

import dataclasses
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels import moe_ffn


@dataclasses.dataclass(frozen=True)
class ModelCfg:
    """Mirror of the Rust `config::ModelConfig` (kept in sync via the
    manifest; Rust is the source of truth for Table 1 presets)."""

    vocab: int = 8192
    d_model: int = 512
    seq_len: int = 256
    layers: int = 4
    experts: int = 16
    n_heads: int = 8
    top_k: int = 2
    capacity_factor: float = 2.0
    aux_weight: float = 1e-2

    @property
    def d_ffn(self) -> int:
        return 2 * self.d_model

    def capacity(self, tokens: int) -> int:
        cap = int(self.capacity_factor * tokens * self.top_k / self.experts)
        # round up to a multiple of 8 for kernel block alignment
        return max(8, (cap + 7) // 8 * 8)


TINY = ModelCfg(vocab=512, d_model=64, seq_len=32, layers=2, experts=8, n_heads=4)
E2E_100M = ModelCfg(vocab=8192, d_model=512, seq_len=256, layers=4, experts=16, n_heads=8)


# --------------------------------------------------------------------------
# Parameters
# --------------------------------------------------------------------------

def init_params(cfg: ModelCfg, key: jax.Array) -> Dict[str, Any]:
    """Initialize the parameter pytree. Layer params are stacked on a
    leading L axis so the block loop is a `lax.scan` (small HLO)."""
    k = jax.random.split(key, 12)
    dm, dff, L, E = cfg.d_model, cfg.d_ffn, cfg.layers, cfg.experts
    s = lambda key, shape, scale: (jax.random.normal(key, shape, jnp.float32) * scale)
    return {
        "embed": s(k[0], (cfg.vocab, dm), 0.02),
        "pos": s(k[1], (cfg.seq_len, dm), 0.01),
        "ln1_g": jnp.ones((L, dm)),
        "ln1_b": jnp.zeros((L, dm)),
        "qkv_w": s(k[2], (L, dm, 3 * dm), dm ** -0.5),
        "qkv_b": jnp.zeros((L, 3 * dm)),
        "proj_w": s(k[3], (L, dm, dm), dm ** -0.5),
        "proj_b": jnp.zeros((L, dm)),
        "ln2_g": jnp.ones((L, dm)),
        "ln2_b": jnp.zeros((L, dm)),
        "gate_w": s(k[4], (L, dm, E), dm ** -0.5),
        "w1": s(k[5], (L, E, dm, dff), dm ** -0.5),
        "b1": jnp.zeros((L, E, dff)),
        "w2": s(k[6], (L, E, dff, dm), dff ** -0.5),
        "b2": jnp.zeros((L, E, dm)),
        "lnf_g": jnp.ones((dm,)),
        "lnf_b": jnp.zeros((dm,)),
    }


def param_order(cfg: ModelCfg) -> List[str]:
    """Canonical flattening order shared with the Rust runtime manifest."""
    del cfg
    return [
        "embed", "pos", "ln1_g", "ln1_b", "qkv_w", "qkv_b", "proj_w",
        "proj_b", "ln2_g", "ln2_b", "gate_w", "w1", "b1", "w2", "b2",
        "lnf_g", "lnf_b",
    ]


# --------------------------------------------------------------------------
# Blocks
# --------------------------------------------------------------------------

def layer_norm(x, g, b, eps=1e-5):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def attention(x, lp, cfg: ModelCfg):
    """Causal multi-head attention. x: [B, S, dm]."""
    b, s, dm = x.shape
    h = cfg.n_heads
    hd = dm // h
    qkv = x @ lp["qkv_w"] + lp["qkv_b"]  # [B, S, 3dm]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    scores = q @ k.transpose(0, 1, 3, 2) / jnp.sqrt(hd).astype(x.dtype)
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask, scores, -1e9)
    out = jax.nn.softmax(scores, axis=-1) @ v  # [B, H, S, hd]
    out = out.transpose(0, 2, 1, 3).reshape(b, s, dm)
    return out @ lp["proj_w"] + lp["proj_b"]


def moe_layer(x, lp, cfg: ModelCfg) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """GShard top-2 MoE layer over flattened tokens.

    x: [T, dm] (tokens = B*S). Returns (y [T, dm], aux_loss, expert_load
    fractions [E] — exported to the L3 load predictor)."""
    t, dm = x.shape
    e = cfg.experts
    cap = cfg.capacity(t)

    logits = x @ lp["gate_w"]  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)

    # top-2 (differentiable formulation; the Pallas top2_gate kernel is the
    # dispatcher-facing artifact and is ref-tested against this)
    p1 = jnp.max(probs, axis=-1)
    i1 = jnp.argmax(probs, axis=-1)
    masked = probs - jax.nn.one_hot(i1, e) * 1e9
    p2 = jnp.max(masked, axis=-1)
    i2 = jnp.argmax(masked, axis=-1)
    denom = p1 + p2
    w1g, w2g = p1 / denom, p2 / denom

    # capacity assignment: position of each token within its expert queue
    oh1 = jax.nn.one_hot(i1, e, dtype=jnp.float32)  # [T, E]
    oh2 = jax.nn.one_hot(i2, e, dtype=jnp.float32)
    pos1 = (jnp.cumsum(oh1, axis=0) - 1.0) * oh1  # [T, E]
    # second choices queue behind all first choices
    pos2 = (jnp.cumsum(oh2, axis=0) - 1.0 + oh1.sum(0, keepdims=True)) * oh2
    keep1 = (pos1 < cap) & (oh1 > 0)
    keep2 = (pos2 < cap) & (oh2 > 0)

    # dispatch/combine tensors [T, E, cap]
    d1 = jax.nn.one_hot(pos1.sum(-1), cap) [:, None, :] * (keep1 * oh1)[:, :, None]
    d2 = jax.nn.one_hot(pos2.sum(-1), cap)[:, None, :] * (keep2 * oh2)[:, :, None]
    dispatch = d1 + d2
    combine = d1 * w1g[:, None, None] + d2 * w2g[:, None, None]

    expert_in = jnp.einsum("tec,td->ecd", dispatch, x)  # [E, cap, dm]
    expert_out = moe_ffn.grouped_ffn(
        expert_in, lp["w1"], lp["b1"], lp["w2"], lp["b2"]
    )  # [E, cap, dm]
    y = jnp.einsum("tec,ecd->td", combine, expert_out)

    # GShard aux loss: E * mean_e(m_e * c_e)
    me = probs.mean(0)                       # mean gate prob per expert
    ce = oh1.mean(0)                         # fraction of tokens (1st choice)
    aux = e * jnp.sum(me * ce)
    load = (oh1.sum(0) + oh2.sum(0)) / (2.0 * t)
    return y, aux, load


def forward(params, tokens, cfg: ModelCfg):
    """Full model: tokens [B, S] int32 -> logits [B, S, V].

    Returns (logits, aux_total, loads [L, E])."""
    b, s = tokens.shape
    x = params["embed"][tokens] + params["pos"][None, :s, :]

    def block(carry, lp):
        x, aux = carry
        h = layer_norm(x, lp["ln1_g"], lp["ln1_b"])
        x = x + attention(h, lp, cfg)
        h = layer_norm(x, lp["ln2_g"], lp["ln2_b"])
        hflat = h.reshape(b * s, cfg.d_model)
        y, a, load = moe_layer(hflat, lp, cfg)
        x = x + y.reshape(b, s, cfg.d_model)
        return (x, aux + a), load

    layer_params = {
        k: params[k]
        for k in [
            "ln1_g", "ln1_b", "qkv_w", "qkv_b", "proj_w", "proj_b",
            "ln2_g", "ln2_b", "gate_w", "w1", "b1", "w2", "b2",
        ]
    }
    (x, aux), loads = jax.lax.scan(block, (x, 0.0), layer_params)
    x = layer_norm(x, params["lnf_g"], params["lnf_b"])
    logits = x @ params["embed"].T
    return logits, aux, loads


def loss_fn(params, tokens, targets, cfg: ModelCfg):
    """Mean cross-entropy + aux loss. targets [B, S] int32."""
    logits, aux, loads = forward(params, tokens, cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1).mean()
    return nll + cfg.aux_weight * aux, (nll, loads)


# --------------------------------------------------------------------------
# Adam (no optax in this environment — hand-rolled, matches Kingma & Ba)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AdamCfg:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8


def adam_init(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params), "t": jnp.zeros((), jnp.float32)}


def adam_update(params, grads, state, cfg: AdamCfg):
    t = state["t"] + 1.0
    m = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g, state["v"], grads)
    mhat_scale = 1.0 / (1.0 - cfg.b1 ** t)
    vhat_scale = 1.0 / (1.0 - cfg.b2 ** t)
    new_params = jax.tree.map(
        lambda p, m, v: p - cfg.lr * (m * mhat_scale) / (jnp.sqrt(v * vhat_scale) + cfg.eps),
        params, m, v,
    )
    return new_params, {"m": m, "v": v, "t": t}


def train_step(params, opt_state, tokens, targets, cfg: ModelCfg, adam: AdamCfg):
    """One full training step. Returns (loss, nll, loads, params', opt')."""
    (loss, (nll, loads)), grads = jax.value_and_grad(loss_fn, has_aux=True)(
        params, tokens, targets, cfg
    )
    new_params, new_state = adam_update(params, grads, opt_state, adam)
    return loss, nll, loads, new_params, new_state
