"""L1 Pallas kernels: the grouped expert FFN — the paper's compute hot-spot.

Hardware adaptation (DESIGN.md §3): instead of CUDA grouped-GEMM over
dynamically sized token groups, tokens are capacity-packed into fixed
``[E, cap, d_model]`` tiles (GShard-style) so the kernel is a static-shape
blocked matmul the MXU can stream. The grid iterates over (expert,
token-block); each program keeps one expert's ``w1/w2`` resident in VMEM
while token tiles stream through, which BlockSpec expresses via the
index maps below.

``interpret=True`` everywhere: the CPU PJRT client cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO so the same artifact runs
under the Rust runtime. Real-TPU efficiency is estimated structurally in
DESIGN.md §Perf (VMEM footprint / MXU utilization), not from CPU wallclock.

The backward pass is its own pair of Pallas kernels wired up via
``jax.custom_vjp`` so the L2 train step can differentiate through the
forward kernel.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _pick_block(cap: int) -> int:
    """Token-block size: multiples of 8 (fp32 sublane), at most 128."""
    for b in (128, 64, 32, 16, 8):
        if cap % b == 0:
            return b
    return cap


def _ffn_fwd_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, y_ref, h_ref):
    """One (expert, token-block) program: y = gelu(x@w1+b1)@w2 + b2.

    The activation ``h`` is also written out as the residual for the
    backward kernels (recompute-free bwd at the cost of cap×d_ffn VMEM).
    Accumulation happens in f32 regardless of input dtype.
    """
    x = x_ref[0].astype(jnp.float32)      # [blk, dm]
    w1 = w1_ref[0].astype(jnp.float32)    # [dm, dff]
    h = ref.gelu(jnp.dot(x, w1) + b1_ref[0].astype(jnp.float32))
    y = jnp.dot(h, w2_ref[0].astype(jnp.float32)) + b2_ref[0].astype(jnp.float32)
    h_ref[0] = h.astype(h_ref.dtype)
    y_ref[0] = y.astype(y_ref.dtype)


def grouped_ffn_fwd(x, w1, b1, w2, b2):
    """Forward grouped FFN, returning (y, h).

    x: [E, cap, dm]; w1: [E, dm, dff]; b1: [E, dff]; w2: [E, dff, dm];
    b2: [E, dm]. The grid is (E, cap // blk): expert weights are re-read
    per token-block (they stay VMEM-resident across the inner grid dim on
    TPU since the index map is constant in it).
    """
    e, cap, dm = x.shape
    dff = w1.shape[2]
    blk = _pick_block(cap)
    grid = (e, cap // blk)
    return pl.pallas_call(
        _ffn_fwd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, blk, dm), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, dm, dff), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, dff), lambda i, j: (i, 0)),
            pl.BlockSpec((1, dff, dm), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, dm), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, blk, dm), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, blk, dff), lambda i, j: (i, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((e, cap, dm), x.dtype),
            jax.ShapeDtypeStruct((e, cap, dff), x.dtype),
        ],
        interpret=True,
    )(x, w1, b1, w2, b2)


def _gelu_grad(s):
    """d/ds gelu(s) for the tanh approximation."""
    c = 0.7978845608028654
    t = jnp.tanh(c * (s + 0.044715 * s**3))
    return 0.5 * (1.0 + t) + 0.5 * s * (1.0 - t * t) * c * (1.0 + 3 * 0.044715 * s * s)


def _ffn_bwd_dw_kernel(x_ref, h_ref, gh_ref, gy_ref, gw1_ref, gb1_ref, gw2_ref, gb2_ref):
    """Backward weight-path program (one expert per program):
    gw1 = xᵀ gh, gb1 = Σ gh, gw2 = hᵀ gy, gb2 = Σ gy."""
    x = x_ref[0].astype(jnp.float32)
    h = h_ref[0].astype(jnp.float32)
    gh = gh_ref[0].astype(jnp.float32)
    gy = gy_ref[0].astype(jnp.float32)
    gw1_ref[...] = jnp.dot(x.T, gh)[None].astype(gw1_ref.dtype)
    gb1_ref[...] = jnp.sum(gh, axis=0)[None].astype(gb1_ref.dtype)
    gw2_ref[...] = jnp.dot(h.T, gy)[None].astype(gw2_ref.dtype)
    gb2_ref[...] = jnp.sum(gy, axis=0)[None].astype(gb2_ref.dtype)


def grouped_ffn_bwd_kernels(x, w1, b1, w2, b2, h, gy):
    """Run the two backward kernels; returns (gx, gw1, gb1, gw2, gb2)."""
    e, cap, dm = x.shape
    dff = w1.shape[2]
    blk = _pick_block(cap)

    def dx_kernel(gy_ref, h_ref, x_ref, w1_ref, b1_ref, w2_ref, gx_ref, gh_ref):
        """gh = (gy @ w2ᵀ) * gelu'(s) with s = x@w1+b1 recomputed; gx = gh @ w1ᵀ."""
        gy_ = gy_ref[0].astype(jnp.float32)
        x_ = x_ref[0].astype(jnp.float32)
        w1_ = w1_ref[0].astype(jnp.float32)
        w2_ = w2_ref[0].astype(jnp.float32)
        s = jnp.dot(x_, w1_) + b1_ref[0].astype(jnp.float32)
        gh = jnp.dot(gy_, w2_.T) * _gelu_grad(s)
        gx = jnp.dot(gh, w1_.T)
        gx_ref[0] = gx.astype(gx_ref.dtype)
        gh_ref[0] = gh.astype(gh_ref.dtype)
        del h_ref

    gx, gh = pl.pallas_call(
        dx_kernel,
        grid=(e, cap // blk),
        in_specs=[
            pl.BlockSpec((1, blk, dm), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, blk, dff), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, blk, dm), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, dm, dff), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, dff), lambda i, j: (i, 0)),
            pl.BlockSpec((1, dff, dm), lambda i, j: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, blk, dm), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, blk, dff), lambda i, j: (i, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((e, cap, dm), x.dtype),
            jax.ShapeDtypeStruct((e, cap, dff), x.dtype),
        ],
        interpret=True,
    )(gy, h, x, w1, b1, w2)

    gw1, gb1, gw2, gb2 = pl.pallas_call(
        _ffn_bwd_dw_kernel,
        grid=(e,),
        in_specs=[
            pl.BlockSpec((1, cap, dm), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, cap, dff), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, cap, dff), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, cap, dm), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, dm, dff), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, dff), lambda i: (i, 0)),
            pl.BlockSpec((1, dff, dm), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, dm), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(w1.shape, w1.dtype),
            jax.ShapeDtypeStruct(b1.shape, b1.dtype),
            jax.ShapeDtypeStruct(w2.shape, w2.dtype),
            jax.ShapeDtypeStruct(b2.shape, b2.dtype),
        ],
        interpret=True,
    )(x, h, gh, gy)
    return gx, gw1, gb1, gw2, gb2


@jax.custom_vjp
def grouped_ffn(x, w1, b1, w2, b2):
    """Differentiable grouped expert FFN (Pallas fwd + Pallas bwd)."""
    y, _ = grouped_ffn_fwd(x, w1, b1, w2, b2)
    return y


def _vjp_fwd(x, w1, b1, w2, b2):
    y, h = grouped_ffn_fwd(x, w1, b1, w2, b2)
    return y, (x, w1, b1, w2, b2, h)


def _vjp_bwd(res, gy):
    x, w1, b1, w2, b2, h = res
    return grouped_ffn_bwd_kernels(x, w1, b1, w2, b2, h, gy)


grouped_ffn.defvjp(_vjp_fwd, _vjp_bwd)


@functools.partial(jax.jit, static_argnames=())
def expert_ffn(x, w1, b1, w2, b2):
    """Single-expert convenience wrapper ([cap, dm] in/out)."""
    y = grouped_ffn(x[None], w1[None], b1[None], w2[None], b2[None])
    return y[0]
