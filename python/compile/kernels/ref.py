"""Pure-jnp reference oracles for the Pallas kernels.

Every Pallas kernel in this package has an oracle here; pytest asserts
allclose between kernel and oracle across shape/dtype sweeps (hypothesis).
These refs are also the semantics documentation: the kernels must match
them bit-for-bit up to float tolerance.
"""

import jax
import jax.numpy as jnp


def gelu(x):
    """tanh-approx GeLU (matches the kernel's VPU-friendly form)."""
    return 0.5 * x * (1.0 + jnp.tanh(0.7978845608028654 * (x + 0.044715 * x * x * x)))


def expert_ffn(x, w1, b1, w2, b2):
    """One expert FFN: ``gelu(x @ w1 + b1) @ w2 + b2``.

    x: [cap, d_model]; w1: [d_model, d_ffn]; w2: [d_ffn, d_model].
    """
    h = gelu(x @ w1 + b1)
    return h @ w2 + b2


def grouped_ffn(x, w1, b1, w2, b2):
    """All experts' FFN: x [E, cap, d_model], weights stacked on E."""
    return jax.vmap(expert_ffn)(x, w1, b1, w2, b2)


def grouped_ffn_bwd(x, w1, b1, w2, b2, gy):
    """VJP of grouped_ffn wrt (x, w1, b1, w2, b2) for cotangent gy."""
    _, vjp = jax.vjp(grouped_ffn, x, w1, b1, w2, b2)
    return vjp(gy)


def top2(probs):
    """Top-2 selection with GShard normalization.

    probs: [T, E] gate probabilities (rows sum to 1).
    Returns (w, idx): w [T, 2] normalized top-2 weights summing to 1,
    idx [T, 2] int32 expert ids. Ties broken toward the lower index
    (the kernel uses strict > for the second max).
    """
    idx1 = jnp.argmax(probs, axis=-1)
    p1 = jnp.take_along_axis(probs, idx1[:, None], axis=-1)[:, 0]
    masked = probs.at[jnp.arange(probs.shape[0]), idx1].set(-jnp.inf)
    idx2 = jnp.argmax(masked, axis=-1)
    p2 = jnp.take_along_axis(probs, idx2[:, None], axis=-1)[:, 0]
    denom = p1 + p2
    w = jnp.stack([p1 / denom, p2 / denom], axis=-1)
    idx = jnp.stack([idx1, idx2], axis=-1).astype(jnp.int32)
    return w, idx
