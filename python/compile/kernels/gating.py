"""L1 Pallas kernel: GShard top-2 gate selection.

One pass over the ``[T, E]`` probability matrix computes (max, argmax) and
(second-max, arg-second-max) per row without a sort — a VPU-friendly pair
of masked reductions — then normalizes the two weights to sum to 1
(GShard top-2 normalization, §5.1 of the paper).

The kernel grid blocks over tokens only; `E` is small (≤ 64 in the paper)
so a full row fits comfortably in VMEM.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _top2_kernel(p_ref, w_ref, idx_ref):
    p = p_ref[...].astype(jnp.float32)  # [blk, E]
    e = p.shape[-1]
    idx1 = jnp.argmax(p, axis=-1)
    p1 = jnp.max(p, axis=-1)
    # mask out the winner, then take the max again (ties -> lower index wins
    # first slot; strict masking matches ref.top2)
    onehot1 = jax.nn.one_hot(idx1, e, dtype=jnp.bool_)
    masked = jnp.where(onehot1, -jnp.inf, p)
    idx2 = jnp.argmax(masked, axis=-1)
    p2 = jnp.max(masked, axis=-1)
    denom = p1 + p2
    w_ref[...] = jnp.stack([p1 / denom, p2 / denom], axis=-1).astype(w_ref.dtype)
    idx_ref[...] = jnp.stack([idx1, idx2], axis=-1).astype(jnp.int32)


def top2_gate(probs):
    """Top-2 selection: probs [T, E] -> (w [T, 2], idx [T, 2] int32)."""
    t, e = probs.shape
    blk = t
    for b in (256, 128, 64, 32, 16, 8):
        if t % b == 0:
            blk = b
            break
    return pl.pallas_call(
        _top2_kernel,
        grid=(t // blk,),
        in_specs=[pl.BlockSpec((blk, e), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((blk, 2), lambda i: (i, 0)),
            pl.BlockSpec((blk, 2), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t, 2), probs.dtype),
            jax.ShapeDtypeStruct((t, 2), jnp.int32),
        ],
        interpret=True,
    )(probs)


def gate_fwd(x, wg):
    """Full gate for the Rust runtime: logits -> softmax -> Pallas top-2.

    x: [T, dm]; wg: [dm, E]. Returns (probs [T, E], w [T, 2], idx [T, 2]).
    Exported as an AOT artifact so the L3 dispatcher gets gate decisions
    from one executable call.
    """
    logits = x @ wg
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = top2_gate(probs)
    return probs, w, idx
