"""AOT pipeline tests: lowering produces loadable HLO text and a complete
manifest (the Rust runtime's contract)."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def engine_artifacts(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    manifest = {"entries": {}, "format": "hlo-text", "version": 1}
    aot.engine_entries(out, manifest)
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    return out, manifest


class TestHloText:
    def test_entries_written_as_hlo_modules(self, engine_artifacts):
        out, manifest = engine_artifacts
        assert set(manifest["entries"]) == {"gate_fwd", "expert_ffn_fwd", "expert_ffn_bwd"}
        for name, e in manifest["entries"].items():
            path = os.path.join(out, e["file"])
            text = open(path).read()
            assert text.startswith("HloModule"), f"{name} is not HLO text"
            assert "ENTRY" in text

    def test_manifest_shapes_match_jax(self, engine_artifacts):
        _, manifest = engine_artifacts
        e = manifest["entries"]["expert_ffn_fwd"]
        cap, dm, dff = e["cap"], e["d_model"], e["d_ffn"]
        assert e["inputs"][0]["shape"] == [cap, dm]
        assert e["inputs"][1]["shape"] == [dm, dff]
        assert e["outputs"][0]["shape"] == [cap, dm]
        assert all(i["dtype"] == "float32" for i in e["inputs"])

    def test_bwd_outputs_cover_all_params(self, engine_artifacts):
        _, manifest = engine_artifacts
        e = manifest["entries"]["expert_ffn_bwd"]
        # gx, gw1, gb1, gw2, gb2
        assert len(e["outputs"]) == 5
        in_shapes = [tuple(i["shape"]) for i in e["inputs"][:5]]
        out_shapes = [tuple(o["shape"]) for o in e["outputs"]]
        assert out_shapes == in_shapes


class TestFlatOrdering:
    def test_train_step_flat_roundtrip(self):
        cfg = model.TINY
        adam = model.AdamCfg()
        fn, order = aot.flat_train_step(cfg, adam)
        init_fn, _ = aot.flat_init(cfg)
        state = init_fn(jnp.int32(0))
        n = len(order)
        assert len(state) == 3 * n + 1, "params + m + v + t"
        tokens = jnp.zeros((2, cfg.seq_len), jnp.int32)
        out = fn(*state, tokens, tokens)
        # loss, nll, loads, params', m', v', t'
        assert len(out) == 3 + 3 * n + 1
        assert out[0].shape == ()
        assert out[2].shape == (cfg.layers, cfg.experts)
        # shapes preserved through the step
        for before, after in zip(state[:n], out[3 : 3 + n]):
            assert before.shape == after.shape

    def test_param_order_is_stable_contract(self):
        # the Rust runtime depends on this exact ordering
        assert model.param_order(model.TINY) == [
            "embed", "pos", "ln1_g", "ln1_b", "qkv_w", "qkv_b", "proj_w",
            "proj_b", "ln2_g", "ln2_b", "gate_w", "w1", "b1", "w2", "b2",
            "lnf_g", "lnf_b",
        ]
