"""L2 model tests: shapes, gradient flow, loss descent, MoE invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model

jax.config.update("jax_platform_name", "cpu")

CFG = model.TINY


@pytest.fixture(scope="module")
def params():
    return model.init_params(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def batch():
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    tokens = jax.random.randint(k1, (2, CFG.seq_len), 0, CFG.vocab)
    targets = jax.random.randint(k2, (2, CFG.seq_len), 0, CFG.vocab)
    return tokens, targets


class TestForward:
    def test_logits_shape(self, params, batch):
        tokens, _ = batch
        logits, aux, loads = model.forward(params, tokens, CFG)
        assert logits.shape == (2, CFG.seq_len, CFG.vocab)
        assert loads.shape == (CFG.layers, CFG.experts)
        assert float(aux) > 0.0

    def test_loads_are_fractions(self, params, batch):
        tokens, _ = batch
        _, _, loads = model.forward(params, tokens, CFG)
        # each layer's load sums to <= 1 (== 1 when no tokens dropped)
        sums = np.asarray(loads.sum(-1))
        assert (sums <= 1.0 + 1e-5).all()
        assert (sums > 0.5).all()

    def test_attention_is_causal(self, params):
        # NOTE: the full model is NOT strictly causal across MoE routing —
        # GShard second choices queue behind ALL first choices, so capacity
        # competition is batch-global (faithful to the paper's gating). The
        # attention path itself must be causal:
        lp = {k: params[k][0] for k in [
            "ln1_g", "ln1_b", "qkv_w", "qkv_b", "proj_w", "proj_b",
            "ln2_g", "ln2_b", "gate_w", "w1", "b1", "w2", "b2"]}
        x = jax.random.normal(jax.random.PRNGKey(4), (1, CFG.seq_len, CFG.d_model))
        y1 = model.attention(x, lp, CFG)
        x2 = x.at[0, -1].add(1.0)
        y2 = model.attention(x2, lp, CFG)
        np.testing.assert_allclose(
            y1[0, : CFG.seq_len - 1], y2[0, : CFG.seq_len - 1], rtol=1e-4, atol=1e-5
        )

    def test_routing_competition_is_batch_global(self, params):
        # documents the GShard property above: a future token CAN shift an
        # earlier token's second-choice slot when capacity is contended.
        tokens = jnp.zeros((1, CFG.seq_len), jnp.int32)
        l1, _, _ = model.forward(params, tokens, CFG)
        l2, _, _ = model.forward(params, tokens.at[0, -1].set(5), CFG)
        assert l1.shape == l2.shape  # smoke: both run; equality not required


class TestLossAndGrads:
    def test_loss_finite_and_near_uniform_at_init(self, params, batch):
        tokens, targets = batch
        loss, (nll, _) = model.loss_fn(params, tokens, targets, CFG)
        assert np.isfinite(float(loss))
        # at random init, nll ≈ ln(vocab)
        assert abs(float(nll) - np.log(CFG.vocab)) < 1.0

    def test_grads_flow_to_all_params(self, params, batch):
        tokens, targets = batch
        grads = jax.grad(lambda p: model.loss_fn(p, tokens, targets, CFG)[0])(params)
        for name, g in grads.items():
            norm = float(jnp.abs(g).max())
            assert np.isfinite(norm), name
            assert norm > 0.0, f"no gradient reaches {name}"


class TestAdam:
    def test_matches_closed_form_single_step(self):
        p = {"w": jnp.array([1.0, 2.0])}
        g = {"w": jnp.array([0.5, -0.5])}
        st = model.adam_init(p)
        cfg = model.AdamCfg(lr=0.1)
        new_p, new_st = model.adam_update(p, g, st, cfg)
        # after one step: m_hat = g, v_hat = g^2 -> update = lr * sign-ish
        expect = p["w"] - 0.1 * g["w"] / (jnp.abs(g["w"]) + 1e-8)
        np.testing.assert_allclose(new_p["w"], expect, rtol=1e-5)
        assert float(new_st["t"]) == 1.0

    def test_train_step_decreases_loss(self, batch):
        tokens, targets = batch
        params = model.init_params(CFG, jax.random.PRNGKey(2))
        opt = model.adam_init(params)
        adam = model.AdamCfg(lr=3e-3)
        step = jax.jit(
            lambda p, o, tk, tg: model.train_step(p, o, tk, tg, CFG, adam)
        )
        loss0, *_ = step(params, opt, tokens, targets)
        losses = [float(loss0)]
        for _ in range(8):
            loss, nll, loads, params, opt = step(params, opt, tokens, targets)
            losses.append(float(loss))
        assert losses[-1] < losses[0] - 0.5, f"no descent: {losses}"


class TestCapacity:
    def test_capacity_multiple_of_8(self):
        assert CFG.capacity(64) % 8 == 0
        assert model.E2E_100M.capacity(1024) % 8 == 0

    def test_moe_layer_conserves_when_underloaded(self, params):
        # tokens spread under capacity: every kept token contributes
        lp = {k: params[k][0] for k in [
            "ln1_g", "ln1_b", "qkv_w", "qkv_b", "proj_w", "proj_b",
            "ln2_g", "ln2_b", "gate_w", "w1", "b1", "w2", "b2"]}
        x = jax.random.normal(jax.random.PRNGKey(3), (32, CFG.d_model)) * 0.1
        y, aux, load = model.moe_layer(x, lp, CFG)
        assert y.shape == x.shape
        assert float(load.sum()) <= 1.0 + 1e-6
        assert np.isfinite(np.asarray(y)).all()
