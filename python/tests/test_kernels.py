"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles (ref.py).

This is the CORE correctness signal for the compute layer — hypothesis
sweeps shapes/dtypes and asserts allclose, including the custom-VJP
backward kernels against jax.grad of the reference.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gating, moe_ffn, ref

jax.config.update("jax_platform_name", "cpu")


def rand(key, *shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


def make_ffn_inputs(seed, e, cap, dm, dff, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = rand(ks[0], e, cap, dm, dtype=dtype)
    w1 = rand(ks[1], e, dm, dff, dtype=dtype) * 0.1
    b1 = rand(ks[2], e, dff, dtype=dtype) * 0.1
    w2 = rand(ks[3], e, dff, dm, dtype=dtype) * 0.1
    b2 = rand(ks[4], e, dm, dtype=dtype) * 0.1
    return x, w1, b1, w2, b2


class TestGroupedFfnForward:
    @settings(max_examples=20, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        e=st.sampled_from([1, 2, 4, 8]),
        cap=st.sampled_from([8, 16, 24, 64, 96, 128]),
        dm=st.sampled_from([8, 16, 32, 64]),
    )
    def test_matches_ref_fp32(self, seed, e, cap, dm):
        args = make_ffn_inputs(seed, e, cap, dm, 2 * dm)
        got = moe_ffn.grouped_ffn(*args)
        want = ref.grouped_ffn(*args)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_matches_ref_bf16(self, seed):
        args = make_ffn_inputs(seed, 2, 16, 32, 64, dtype=jnp.bfloat16)
        got = moe_ffn.grouped_ffn(*args).astype(jnp.float32)
        want = ref.grouped_ffn(*[a.astype(jnp.float32) for a in args])
        # bf16 storage, f32 accumulation in-kernel
        np.testing.assert_allclose(got, want, rtol=5e-2, atol=5e-2)

    def test_single_expert_wrapper(self):
        x, w1, b1, w2, b2 = make_ffn_inputs(0, 1, 16, 8, 16)
        got = moe_ffn.expert_ffn(x[0], w1[0], b1[0], w2[0], b2[0])
        want = ref.expert_ffn(x[0], w1[0], b1[0], w2[0], b2[0])
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    def test_jit_compiles(self):
        args = make_ffn_inputs(1, 2, 8, 8, 16)
        got = jax.jit(moe_ffn.grouped_ffn)(*args)
        want = ref.grouped_ffn(*args)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


class TestGroupedFfnBackward:
    @settings(max_examples=12, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        e=st.sampled_from([1, 2, 4]),
        cap=st.sampled_from([8, 16, 32]),
        dm=st.sampled_from([8, 16]),
    )
    def test_vjp_matches_ref_grad(self, seed, e, cap, dm):
        args = make_ffn_inputs(seed, e, cap, dm, 2 * dm)

        def loss_kernel(*a):
            return jnp.sum(moe_ffn.grouped_ffn(*a) ** 2)

        def loss_ref(*a):
            return jnp.sum(ref.grouped_ffn(*a) ** 2)

        g_kernel = jax.grad(loss_kernel, argnums=(0, 1, 2, 3, 4))(*args)
        g_ref = jax.grad(loss_ref, argnums=(0, 1, 2, 3, 4))(*args)
        for gk, gr, name in zip(g_kernel, g_ref, ["x", "w1", "b1", "w2", "b2"]):
            np.testing.assert_allclose(
                gk, gr, rtol=2e-4, atol=2e-4, err_msg=f"grad {name}"
            )

    def test_bwd_kernels_direct(self):
        args = make_ffn_inputs(7, 2, 16, 8, 16)
        y, h = moe_ffn.grouped_ffn_fwd(*args)
        gy = jnp.ones_like(y)
        gx, gw1, gb1, gw2, gb2 = moe_ffn.grouped_ffn_bwd_kernels(*args, h, gy)
        rx, rw1, rb1, rw2, rb2 = ref.grouped_ffn_bwd(*args, gy)
        for got, want, name in [
            (gx, rx, "gx"), (gw1, rw1, "gw1"), (gb1, rb1, "gb1"),
            (gw2, rw2, "gw2"), (gb2, rb2, "gb2"),
        ]:
            np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4, err_msg=name)

    def test_zero_padded_rows_contribute_nothing(self):
        # FSSDP packs variable token counts into fixed capacity tiles; rows
        # beyond the real count are zero and their gy is zeroed on the host.
        x, w1, b1, w2, b2 = make_ffn_inputs(3, 1, 16, 8, 16)
        x = x.at[0, 8:].set(0.0)
        y, h = moe_ffn.grouped_ffn_fwd(x, w1, b1, w2, b2)
        gy = jnp.ones_like(y).at[0, 8:].set(0.0)
        _, gw1, gb1, gw2, gb2 = moe_ffn.grouped_ffn_bwd_kernels(x, w1, b1, w2, b2, h, gy)
        # reference computed on the unpadded 8-row problem
        xs, gys = x[:, :8], gy[:, :8]
        _, rw1, rb1, rw2, rb2 = ref.grouped_ffn_bwd(xs, w1, b1, w2, b2, gys)
        np.testing.assert_allclose(gw1, rw1, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(gb1, rb1, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(gw2, rw2, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(gb2, rb2, rtol=1e-4, atol=1e-4)


class TestTop2Gate:
    @settings(max_examples=20, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        t=st.sampled_from([8, 16, 64, 128, 200]),
        e=st.sampled_from([4, 8, 16, 64]),
    )
    def test_matches_ref(self, seed, t, e):
        logits = jax.random.normal(jax.random.PRNGKey(seed), (t, e))
        probs = jax.nn.softmax(logits, axis=-1)
        w_got, i_got = gating.top2_gate(probs)
        w_want, i_want = ref.top2(probs)
        np.testing.assert_array_equal(i_got, i_want)
        np.testing.assert_allclose(w_got, w_want, rtol=1e-5, atol=1e-6)

    def test_weights_normalized(self):
        probs = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(0), (32, 8)))
        w, idx = gating.top2_gate(probs)
        np.testing.assert_allclose(w.sum(-1), np.ones(32), rtol=1e-5)
        assert (idx[:, 0] != idx[:, 1]).all()

    def test_gate_fwd_composite(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (16, 8))
        wg = jax.random.normal(jax.random.PRNGKey(2), (8, 4)) * 0.1
        probs, w, idx = gating.gate_fwd(x, wg)
        np.testing.assert_allclose(probs.sum(-1), np.ones(16), rtol=1e-5)
        # idx picks the argmax of probs
        np.testing.assert_array_equal(np.asarray(idx[:, 0]), np.argmax(probs, -1))
