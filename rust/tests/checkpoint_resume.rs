//! Integration: sharded checkpointing + elastic resume of the numeric
//! FSSDP engine.
//!
//! Runs hermetically on the pure-Rust reference backend (no artifacts /
//! PJRT needed):
//!
//! * save → restore at the **same** world size is **bit-identical** (the
//!   saved owner layout is reused, so every reduction order matches);
//! * an N=4 run checkpointed at step k and **elastically** resumed on M=2
//!   and M=8 devices reaches the same final parameters as the
//!   uninterrupted run, within the tolerance `tests/fssdp_equivalence.rs`
//!   uses (2e-3) — FSSDP placement freedom never changes the math;
//! * corruption and version mismatches are rejected at load time.

use std::path::PathBuf;

use hecate::checkpoint;
use hecate::fssdp::{reference_dims, FssdpEngine};
use hecate::testing::max_rel_err;
use hecate::topology::Topology;

/// Fixed logical data-shard count across every run in this file — elastic
/// resume changes the device count, never the data stream.
const SOURCES: usize = 4;
const SEED: u64 = 7;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("hecate-it-ckpt-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn final_chunks(e: &FssdpEngine) -> Vec<Vec<f32>> {
    (0..e.dims.experts).map(|x| e.expert_chunk(x).clone()).collect()
}

/// Uninterrupted reference run: `iters` steps on `topo`.
fn uninterrupted(topo: Topology, iters: u64) -> Vec<Vec<f32>> {
    let mut e = FssdpEngine::new_reference(reference_dims(), topo, SEED);
    for i in 0..iters {
        e.step(i, SOURCES).unwrap();
    }
    final_chunks(&e)
}

/// Run k1 steps on `topo_a`, checkpoint through disk, resume on `topo_b`,
/// run k2 more. Returns the final chunks and the number of moved experts.
fn interrupted(topo_a: Topology, topo_b: Topology, k1: u64, k2: u64, tag: &str) -> (Vec<Vec<f32>>, usize) {
    let dir = tmpdir(tag);
    let old_world = topo_a.num_devices();
    let mut e = FssdpEngine::new_reference(reference_dims(), topo_a, SEED);
    for i in 0..k1 {
        e.step(i, SOURCES).unwrap();
    }
    checkpoint::save(&dir, &e.snapshot(k1, SOURCES), &e.topo).unwrap();
    drop(e);

    let (state, saved) = checkpoint::load(&dir).unwrap();
    assert_eq!(saved.world(), old_world);
    assert_eq!(state.step, k1);
    assert_eq!(state.data_shards, SOURCES);
    let (mut r, plan) = FssdpEngine::resume_reference(topo_b, &state, saved.world()).unwrap();
    let mut step = state.step;
    for _ in 0..k2 {
        r.step(step, state.data_shards).unwrap();
        step += 1;
    }
    std::fs::remove_dir_all(&dir).unwrap();
    (final_chunks(&r), plan.moved_experts.len())
}

#[test]
fn same_world_restore_is_bit_identical() {
    let k1 = 2u64;
    let k2 = 2u64;
    let straight = uninterrupted(Topology::cluster_a(2, 2), k1 + k2);
    let (resumed, moved) = interrupted(
        Topology::cluster_a(2, 2),
        Topology::cluster_a(2, 2),
        k1,
        k2,
        "same-world",
    );
    assert_eq!(moved, 0, "same world size must reuse the saved layout");
    for (e, (a, b)) in resumed.iter().zip(straight.iter()).enumerate() {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "expert {e}[{i}]: {x} vs {y} — same-world resume must be bit-identical"
            );
        }
    }
}

#[test]
fn elastic_resume_shrink_matches_uninterrupted() {
    // N=4 checkpointed at step 2, resumed on M=2 — vs 4 uninterrupted steps.
    let straight = uninterrupted(Topology::cluster_a(2, 2), 4);
    let (resumed, moved) =
        interrupted(Topology::cluster_a(2, 2), Topology::cluster_a(1, 2), 2, 2, "shrink");
    assert!(moved > 0, "shrinking 4 -> 2 devices must move the dead ranks' experts");
    for (e, (a, b)) in resumed.iter().zip(straight.iter()).enumerate() {
        let err = max_rel_err(a, b);
        assert!(err < 2e-3, "expert {e}: max rel err {err} after shrink resume");
    }
}

#[test]
fn elastic_resume_grow_matches_uninterrupted() {
    // N=4 checkpointed at step 2, resumed on M=8 — vs 4 uninterrupted steps.
    let straight = uninterrupted(Topology::cluster_a(2, 2), 4);
    let (resumed, _) =
        interrupted(Topology::cluster_a(2, 2), Topology::cluster_a(2, 4), 2, 2, "grow");
    for (e, (a, b)) in resumed.iter().zip(straight.iter()).enumerate() {
        let err = max_rel_err(a, b);
        assert!(err < 2e-3, "expert {e}: max rel err {err} after grow resume");
    }
}

#[test]
fn elastic_resume_preserves_loss_trajectory() {
    // The loss of the resumed run tracks the uninterrupted one closely.
    let mut full = FssdpEngine::new_reference(reference_dims(), Topology::cluster_a(2, 2), SEED);
    let mut losses_full = Vec::new();
    for i in 0..4 {
        losses_full.push(full.step(i, SOURCES).unwrap().loss);
    }

    let dir = tmpdir("loss-traj");
    let mut head = FssdpEngine::new_reference(reference_dims(), Topology::cluster_a(2, 2), SEED);
    for i in 0..2 {
        head.step(i, SOURCES).unwrap();
    }
    checkpoint::save(&dir, &head.snapshot(2, SOURCES), &head.topo).unwrap();
    let (state, saved) = checkpoint::load(&dir).unwrap();
    let (mut tail, _) =
        FssdpEngine::resume_reference(Topology::cluster_a(1, 2), &state, saved.world()).unwrap();
    for (i, want) in losses_full.iter().enumerate().skip(2) {
        let got = tail.step(i as u64, SOURCES).unwrap().loss;
        let rel = (got - want).abs() / want.abs().max(1e-9);
        assert!(rel < 1e-2, "step {i}: loss {got} vs {want} (rel {rel})");
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn corrupted_checkpoint_is_rejected() {
    let dir = tmpdir("corrupt");
    let mut e = FssdpEngine::new_reference(reference_dims(), Topology::cluster_a(1, 2), SEED);
    e.step(0, SOURCES).unwrap();
    checkpoint::save(&dir, &e.snapshot(1, SOURCES), &e.topo).unwrap();

    let f = dir.join("global.bin");
    let mut bytes = std::fs::read(&f).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x10;
    std::fs::write(&f, &bytes).unwrap();
    assert!(checkpoint::load(&dir).is_err(), "tampered global blob must not load");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn missing_rank_file_is_rejected() {
    let dir = tmpdir("missing-rank");
    let mut e = FssdpEngine::new_reference(reference_dims(), Topology::cluster_a(1, 2), SEED);
    e.step(0, SOURCES).unwrap();
    checkpoint::save(&dir, &e.snapshot(1, SOURCES), &e.topo).unwrap();
    std::fs::remove_file(dir.join("rank-1.bin")).unwrap();
    assert!(checkpoint::load(&dir).is_err());
    std::fs::remove_dir_all(&dir).unwrap();
}
