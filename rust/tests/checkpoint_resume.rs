//! Integration: sharded checkpointing (format v2, multi-layer) + elastic
//! resume of the numeric FSSDP engine.
//!
//! Runs hermetically on the pure-Rust reference backend (no artifacts /
//! PJRT needed):
//!
//! * save → restore at the **same** world size is **bit-identical** (the
//!   saved owner layouts are reused, so every reduction order matches) —
//!   at L=1 and L=3;
//! * an N=4 run checkpointed at step k and **elastically** resumed on M=2
//!   and M=8 devices reaches the same final parameters as the
//!   uninterrupted run, within the tolerance `tests/fssdp_equivalence.rs`
//!   uses (2e-3) — FSSDP placement freedom never changes the math, and at
//!   L>1 the planner re-shards all layers jointly;
//! * corruption, v1-format blobs, and version mismatches are rejected at
//!   load time.

use std::path::PathBuf;

use hecate::checkpoint;
use hecate::fssdp::{reference_dims, FssdpEngine};
use hecate::testing::{all_chunks as final_chunks, max_rel_err};
use hecate::topology::Topology;

/// Fixed logical data-shard count across every run in this file — elastic
/// resume changes the device count, never the data stream.
const SOURCES: usize = 4;
const SEED: u64 = 7;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("hecate-it-ckpt-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Uninterrupted reference run: `iters` steps of an `layers`-deep stack.
fn uninterrupted(layers: usize, topo: Topology, iters: u64) -> Vec<Vec<f32>> {
    let mut e = FssdpEngine::new_reference_layers(reference_dims(), layers, topo, SEED);
    for i in 0..iters {
        e.step(i, SOURCES).unwrap();
    }
    final_chunks(&e)
}

/// Run k1 steps on `topo_a`, checkpoint through disk, resume on `topo_b`,
/// run k2 more. Returns the final chunks and the number of moved experts.
fn interrupted(
    layers: usize,
    topo_a: Topology,
    topo_b: Topology,
    k1: u64,
    k2: u64,
    tag: &str,
) -> (Vec<Vec<f32>>, usize) {
    let dir = tmpdir(tag);
    let old_world = topo_a.num_devices();
    let mut e = FssdpEngine::new_reference_layers(reference_dims(), layers, topo_a, SEED);
    for i in 0..k1 {
        e.step(i, SOURCES).unwrap();
    }
    checkpoint::save(&dir, &e.snapshot(k1, SOURCES), &e.topo).unwrap();
    drop(e);

    let (state, saved) = checkpoint::load(&dir).unwrap();
    assert_eq!(saved.world(), old_world);
    assert_eq!(state.step, k1);
    assert_eq!(state.data_shards, SOURCES);
    assert_eq!(state.num_layers(), layers);
    let (mut r, plan) = FssdpEngine::resume_reference(topo_b, &state, saved.world()).unwrap();
    let mut step = state.step;
    for _ in 0..k2 {
        r.step(step, state.data_shards).unwrap();
        step += 1;
    }
    std::fs::remove_dir_all(&dir).unwrap();
    (final_chunks(&r), plan.moved_experts.len())
}

#[test]
fn same_world_restore_is_bit_identical() {
    let k1 = 2u64;
    let k2 = 2u64;
    let straight = uninterrupted(1, Topology::cluster_a(2, 2), k1 + k2);
    let (resumed, moved) = interrupted(
        1,
        Topology::cluster_a(2, 2),
        Topology::cluster_a(2, 2),
        k1,
        k2,
        "same-world",
    );
    assert_eq!(moved, 0, "same world size must reuse the saved layout");
    for (e, (a, b)) in resumed.iter().zip(straight.iter()).enumerate() {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "expert {e}[{i}]: {x} vs {y} — same-world resume must be bit-identical"
            );
        }
    }
}

#[test]
fn multilayer_same_world_restore_is_bit_identical() {
    // Checkpoint v2 round-trip: an L=3 stack through disk at the same
    // world size is bit-identical to the uninterrupted run.
    let straight = uninterrupted(3, Topology::cluster_a(2, 2), 4);
    let (resumed, moved) = interrupted(
        3,
        Topology::cluster_a(2, 2),
        Topology::cluster_a(2, 2),
        2,
        2,
        "ml-same-world",
    );
    assert_eq!(moved, 0, "same world size must reuse every layer's saved layout");
    for (e, (a, b)) in resumed.iter().zip(straight.iter()).enumerate() {
        for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "chunk {e}[{i}] must be bit-identical");
        }
    }
}

#[test]
fn elastic_resume_shrink_matches_uninterrupted() {
    // N=4 checkpointed at step 2, resumed on M=2 — vs 4 uninterrupted steps.
    let straight = uninterrupted(1, Topology::cluster_a(2, 2), 4);
    let (resumed, moved) =
        interrupted(1, Topology::cluster_a(2, 2), Topology::cluster_a(1, 2), 2, 2, "shrink");
    assert!(moved > 0, "shrinking 4 -> 2 devices must move the dead ranks' experts");
    for (e, (a, b)) in resumed.iter().zip(straight.iter()).enumerate() {
        let err = max_rel_err(a, b);
        assert!(err < 2e-3, "expert {e}: max rel err {err} after shrink resume");
    }
}

#[test]
fn multilayer_elastic_resume_shrink_matches_uninterrupted() {
    // Checkpoint v2 elastic: L=3, N=4 → M=2, within the 2e-3 tolerance.
    let straight = uninterrupted(3, Topology::cluster_a(2, 2), 4);
    let (resumed, moved) =
        interrupted(3, Topology::cluster_a(2, 2), Topology::cluster_a(1, 2), 2, 2, "ml-shrink");
    assert!(moved > 0, "shrinking must move the dead ranks' experts in some layer");
    for (e, (a, b)) in resumed.iter().zip(straight.iter()).enumerate() {
        let err = max_rel_err(a, b);
        assert!(err < 2e-3, "chunk {e}: max rel err {err} after L=3 shrink resume");
    }
}

#[test]
fn elastic_resume_grow_matches_uninterrupted() {
    // N=4 checkpointed at step 2, resumed on M=8 — vs 4 uninterrupted steps.
    let straight = uninterrupted(1, Topology::cluster_a(2, 2), 4);
    let (resumed, _) =
        interrupted(1, Topology::cluster_a(2, 2), Topology::cluster_a(2, 4), 2, 2, "grow");
    for (e, (a, b)) in resumed.iter().zip(straight.iter()).enumerate() {
        let err = max_rel_err(a, b);
        assert!(err < 2e-3, "expert {e}: max rel err {err} after grow resume");
    }
}

#[test]
fn elastic_resume_preserves_loss_trajectory() {
    // The loss of the resumed run tracks the uninterrupted one closely.
    let mut full = FssdpEngine::new_reference(reference_dims(), Topology::cluster_a(2, 2), SEED);
    let mut losses_full = Vec::new();
    for i in 0..4 {
        losses_full.push(full.step(i, SOURCES).unwrap().loss);
    }

    let dir = tmpdir("loss-traj");
    let mut head = FssdpEngine::new_reference(reference_dims(), Topology::cluster_a(2, 2), SEED);
    for i in 0..2 {
        head.step(i, SOURCES).unwrap();
    }
    checkpoint::save(&dir, &head.snapshot(2, SOURCES), &head.topo).unwrap();
    let (state, saved) = checkpoint::load(&dir).unwrap();
    let (mut tail, _) =
        FssdpEngine::resume_reference(Topology::cluster_a(1, 2), &state, saved.world()).unwrap();
    for (i, want) in losses_full.iter().enumerate().skip(2) {
        let got = tail.step(i as u64, SOURCES).unwrap().loss;
        let rel = (got - want).abs() / want.abs().max(1e-9);
        assert!(rel < 1e-2, "step {i}: loss {got} vs {want} (rel {rel})");
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn reshard_every_survives_checkpoint_roundtrip() {
    // The Algorithm 2 cadence is part of the durable run config (format
    // v2): resume restores it without re-specifying the flag.
    let dir = tmpdir("reshard-cfg");
    let mut e =
        FssdpEngine::new_reference_layers(reference_dims(), 2, Topology::cluster_a(2, 2), SEED);
    e.reshard_every = 4;
    e.run_span(0, 2, SOURCES).unwrap();
    checkpoint::save(&dir, &e.snapshot(2, SOURCES), &e.topo).unwrap();
    let (state, saved) = checkpoint::load(&dir).unwrap();
    assert_eq!(state.reshard_every, 4);
    let (tail, _) =
        FssdpEngine::resume_reference(Topology::cluster_a(2, 2), &state, saved.world()).unwrap();
    assert_eq!(tail.reshard_every, 4);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn corrupted_checkpoint_is_rejected() {
    let dir = tmpdir("corrupt");
    let mut e = FssdpEngine::new_reference(reference_dims(), Topology::cluster_a(1, 2), SEED);
    e.step(0, SOURCES).unwrap();
    checkpoint::save(&dir, &e.snapshot(1, SOURCES), &e.topo).unwrap();

    let f = dir.join("global.bin");
    let mut bytes = std::fs::read(&f).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x10;
    std::fs::write(&f, &bytes).unwrap();
    assert!(checkpoint::load(&dir).is_err(), "tampered global blob must not load");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn v1_blob_is_rejected_with_migration_error() {
    // A global blob carrying the v1 version byte — re-sealed, with the
    // manifest checksum updated to match, so the blob's own version check
    // is what fires — must fail with the single-layer migration message.
    use hecate::checkpoint::format::fnv1a64;
    use hecate::util::json::Json;

    let dir = tmpdir("v1-blob");
    let mut e = FssdpEngine::new_reference(reference_dims(), Topology::cluster_a(1, 2), SEED);
    e.step(0, SOURCES).unwrap();
    checkpoint::save(&dir, &e.snapshot(1, SOURCES), &e.topo).unwrap();

    let f = dir.join("global.bin");
    let mut bytes = std::fs::read(&f).unwrap();
    bytes[4] = 1; // version byte, after the 4-byte magic
    let body_len = bytes.len() - 8;
    let sum = fnv1a64(&bytes[..body_len]);
    bytes[body_len..].copy_from_slice(&sum.to_le_bytes());
    std::fs::write(&f, &bytes).unwrap();

    let manifest = std::fs::read_to_string(dir.join("manifest.json")).unwrap();
    let mut doc = Json::parse(&manifest).unwrap();
    if let Json::Obj(map) = &mut doc {
        map.insert(
            "global_fnv".into(),
            Json::Str(format!("{:#018x}", fnv1a64(&std::fs::read(&f).unwrap()))),
        );
    }
    std::fs::write(dir.join("manifest.json"), doc.to_string_pretty()).unwrap();

    let err = checkpoint::load(&dir).unwrap_err().to_string();
    assert!(
        err.contains("v1") && err.contains("single-layer"),
        "v1 blob must get the migration error: {err}"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn missing_rank_file_is_rejected() {
    let dir = tmpdir("missing-rank");
    let mut e = FssdpEngine::new_reference(reference_dims(), Topology::cluster_a(1, 2), SEED);
    e.step(0, SOURCES).unwrap();
    checkpoint::save(&dir, &e.snapshot(1, SOURCES), &e.topo).unwrap();
    std::fs::remove_file(dir.join("rank-1.bin")).unwrap();
    assert!(checkpoint::load(&dir).is_err());
    std::fs::remove_dir_all(&dir).unwrap();
}
