//! Integration: sharded checkpointing (format v2, multi-layer) + elastic
//! resume of the numeric FSSDP engine, through the public `Session` API.
//!
//! Runs hermetically on the pure-Rust reference backend (no artifacts /
//! PJRT needed):
//!
//! * save → restore at the **same** world size is **bit-identical** (the
//!   saved owner layouts are reused, so every reduction order matches) —
//!   at L=1 and L=3;
//! * an N=4 run checkpointed at step k and **elastically** resumed on M=2
//!   and M=8 devices reaches the same final parameters as the
//!   uninterrupted run, within the tolerance `tests/fssdp_equivalence.rs`
//!   uses (2e-3) — FSSDP placement freedom never changes the math, and at
//!   L>1 the planner re-shards all layers jointly;
//! * corruption, v1-format blobs, and version mismatches are rejected at
//!   load time.

use std::path::PathBuf;

use hecate::checkpoint;
use hecate::fssdp::{Session, SessionConfig, SessionConfigBuilder};
use hecate::testing::{all_chunks as final_chunks, max_rel_err};
use hecate::topology::Topology;

/// Fixed logical data-shard count across every run in this file — elastic
/// resume changes the device count, never the data stream.
const SOURCES: usize = 4;
const SEED: u64 = 7;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("hecate-it-ckpt-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn cfg(layers: usize, topo: Topology) -> SessionConfigBuilder {
    SessionConfig::builder()
        .reference()
        .topology(topo)
        .layers(layers)
        .seed(SEED)
        .data_shards(SOURCES)
}

fn fresh(layers: usize, topo: Topology) -> Session {
    Session::fresh(cfg(layers, topo).build().unwrap()).unwrap()
}

/// Uninterrupted reference run: `iters` steps of a `layers`-deep stack.
fn uninterrupted(layers: usize, topo: Topology, iters: usize) -> Vec<Vec<f32>> {
    let mut s = fresh(layers, topo);
    s.run(iters).unwrap();
    final_chunks(s.engine())
}

/// Run k1 steps on `topo_a`, checkpoint through disk, resume on `topo_b`,
/// run k2 more. Returns the final chunks and the number of moved experts.
fn interrupted(
    layers: usize,
    topo_a: Topology,
    topo_b: Topology,
    k1: usize,
    k2: usize,
    tag: &str,
) -> (Vec<Vec<f32>>, usize) {
    let dir = tmpdir(tag);
    let old_world = topo_a.num_devices();
    let mut s = fresh(layers, topo_a);
    s.run(k1).unwrap();
    s.checkpoint_to(&dir).unwrap();
    drop(s);

    let (state, saved) = checkpoint::load(&dir).unwrap();
    assert_eq!(saved.world(), old_world);
    assert_eq!(state.step, k1 as u64);
    assert_eq!(state.data_shards, SOURCES);
    assert_eq!(state.num_layers(), layers);
    // The resume config names only the new topology; step, layer count and
    // data shards come from the checkpoint.
    let mut r = Session::resume(
        SessionConfig::builder().reference().topology(topo_b).build().unwrap(),
        &dir,
    )
    .unwrap();
    assert_eq!(r.step(), k1 as u64);
    assert_eq!(r.data_shards(), SOURCES);
    let moved = r.resume_report().unwrap().moved_experts;
    r.run(k2).unwrap();
    std::fs::remove_dir_all(&dir).unwrap();
    (final_chunks(r.engine()), moved)
}

#[test]
fn same_world_restore_is_bit_identical() {
    let k1 = 2;
    let k2 = 2;
    let straight = uninterrupted(1, Topology::cluster_a(2, 2), k1 + k2);
    let (resumed, moved) = interrupted(
        1,
        Topology::cluster_a(2, 2),
        Topology::cluster_a(2, 2),
        k1,
        k2,
        "same-world",
    );
    assert_eq!(moved, 0, "same world size must reuse the saved layout");
    for (e, (a, b)) in resumed.iter().zip(straight.iter()).enumerate() {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "expert {e}[{i}]: {x} vs {y} — same-world resume must be bit-identical"
            );
        }
    }
}

#[test]
fn multilayer_same_world_restore_is_bit_identical() {
    // Checkpoint v2 round-trip: an L=3 stack through disk at the same
    // world size is bit-identical to the uninterrupted run.
    let straight = uninterrupted(3, Topology::cluster_a(2, 2), 4);
    let (resumed, moved) = interrupted(
        3,
        Topology::cluster_a(2, 2),
        Topology::cluster_a(2, 2),
        2,
        2,
        "ml-same-world",
    );
    assert_eq!(moved, 0, "same world size must reuse every layer's saved layout");
    for (e, (a, b)) in resumed.iter().zip(straight.iter()).enumerate() {
        for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "chunk {e}[{i}] must be bit-identical");
        }
    }
}

#[test]
fn elastic_resume_shrink_matches_uninterrupted() {
    // N=4 checkpointed at step 2, resumed on M=2 — vs 4 uninterrupted steps.
    let straight = uninterrupted(1, Topology::cluster_a(2, 2), 4);
    let (resumed, moved) =
        interrupted(1, Topology::cluster_a(2, 2), Topology::cluster_a(1, 2), 2, 2, "shrink");
    assert!(moved > 0, "shrinking 4 -> 2 devices must move the dead ranks' experts");
    for (e, (a, b)) in resumed.iter().zip(straight.iter()).enumerate() {
        let err = max_rel_err(a, b);
        assert!(err < 2e-3, "expert {e}: max rel err {err} after shrink resume");
    }
}

#[test]
fn multilayer_elastic_resume_shrink_matches_uninterrupted() {
    // Checkpoint v2 elastic: L=3, N=4 → M=2, within the 2e-3 tolerance.
    let straight = uninterrupted(3, Topology::cluster_a(2, 2), 4);
    let (resumed, moved) =
        interrupted(3, Topology::cluster_a(2, 2), Topology::cluster_a(1, 2), 2, 2, "ml-shrink");
    assert!(moved > 0, "shrinking must move the dead ranks' experts in some layer");
    for (e, (a, b)) in resumed.iter().zip(straight.iter()).enumerate() {
        let err = max_rel_err(a, b);
        assert!(err < 2e-3, "chunk {e}: max rel err {err} after L=3 shrink resume");
    }
}

#[test]
fn elastic_resume_grow_matches_uninterrupted() {
    // N=4 checkpointed at step 2, resumed on M=8 — vs 4 uninterrupted steps.
    let straight = uninterrupted(1, Topology::cluster_a(2, 2), 4);
    let (resumed, _) =
        interrupted(1, Topology::cluster_a(2, 2), Topology::cluster_a(2, 4), 2, 2, "grow");
    for (e, (a, b)) in resumed.iter().zip(straight.iter()).enumerate() {
        let err = max_rel_err(a, b);
        assert!(err < 2e-3, "expert {e}: max rel err {err} after grow resume");
    }
}

#[test]
fn elastic_resume_preserves_loss_trajectory() {
    // The loss of the resumed run tracks the uninterrupted one closely.
    let mut full = fresh(1, Topology::cluster_a(2, 2));
    let losses_full: Vec<f64> = full.run(4).unwrap().iter().map(|s| s.loss).collect();

    let dir = tmpdir("loss-traj");
    let mut head = fresh(1, Topology::cluster_a(2, 2));
    head.run(2).unwrap();
    head.checkpoint_to(&dir).unwrap();
    let mut tail = Session::resume(
        SessionConfig::builder().reference().topology(Topology::cluster_a(1, 2)).build().unwrap(),
        &dir,
    )
    .unwrap();
    for (i, want) in losses_full.iter().enumerate().skip(2) {
        let got = tail.run(1).unwrap()[0].loss;
        let rel = (got - want).abs() / want.abs().max(1e-9);
        assert!(rel < 1e-2, "step {i}: loss {got} vs {want} (rel {rel})");
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn reshard_every_survives_checkpoint_roundtrip() {
    // The Algorithm 2 cadence is part of the durable run config (format
    // v2): resume restores it without re-specifying the flag.
    let dir = tmpdir("reshard-cfg");
    let mut s =
        Session::fresh(cfg(2, Topology::cluster_a(2, 2)).reshard_every(4).build().unwrap())
            .unwrap();
    s.run(2).unwrap();
    s.checkpoint_to(&dir).unwrap();
    let (state, _) = checkpoint::load(&dir).unwrap();
    assert_eq!(state.reshard_every, 4);
    let tail = Session::resume(
        SessionConfig::builder().reference().topology(Topology::cluster_a(2, 2)).build().unwrap(),
        &dir,
    )
    .unwrap();
    assert_eq!(tail.reshard_every(), 4);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn corrupted_checkpoint_is_rejected() {
    let dir = tmpdir("corrupt");
    let mut s = fresh(1, Topology::cluster_a(1, 2));
    s.run(1).unwrap();
    s.checkpoint_to(&dir).unwrap();

    let f = dir.join("global.bin");
    let mut bytes = std::fs::read(&f).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x10;
    std::fs::write(&f, &bytes).unwrap();
    assert!(checkpoint::load(&dir).is_err(), "tampered global blob must not load");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn v1_blob_is_rejected_with_migration_error() {
    // A global blob carrying the v1 version byte — re-sealed, with the
    // manifest checksum updated to match, so the blob's own version check
    // is what fires — must fail with the single-layer migration message.
    use hecate::checkpoint::format::fnv1a64;
    use hecate::util::json::Json;

    let dir = tmpdir("v1-blob");
    let mut s = fresh(1, Topology::cluster_a(1, 2));
    s.run(1).unwrap();
    s.checkpoint_to(&dir).unwrap();

    let f = dir.join("global.bin");
    let mut bytes = std::fs::read(&f).unwrap();
    bytes[4] = 1; // version byte, after the 4-byte magic
    let body_len = bytes.len() - 8;
    let sum = fnv1a64(&bytes[..body_len]);
    bytes[body_len..].copy_from_slice(&sum.to_le_bytes());
    std::fs::write(&f, &bytes).unwrap();

    let manifest = std::fs::read_to_string(dir.join("manifest.json")).unwrap();
    let mut doc = Json::parse(&manifest).unwrap();
    if let Json::Obj(map) = &mut doc {
        map.insert(
            "global_fnv".into(),
            Json::Str(format!("{:#018x}", fnv1a64(&std::fs::read(&f).unwrap()))),
        );
    }
    std::fs::write(dir.join("manifest.json"), doc.to_string_pretty()).unwrap();

    let err = checkpoint::load(&dir).unwrap_err().to_string();
    assert!(
        err.contains("v1") && err.contains("single-layer"),
        "v1 blob must get the migration error: {err}"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn missing_rank_file_is_rejected() {
    let dir = tmpdir("missing-rank");
    let mut s = fresh(1, Topology::cluster_a(1, 2));
    s.run(1).unwrap();
    s.checkpoint_to(&dir).unwrap();
    std::fs::remove_file(dir.join("rank-1.bin")).unwrap();
    assert!(checkpoint::load(&dir).is_err());
    std::fs::remove_dir_all(&dir).unwrap();
}
