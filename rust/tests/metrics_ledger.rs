//! Tier-1 integration locks for the memory ledger + load observatory.
//!
//! Contracts:
//! 1. Metering is purely observational — a metered run is bit-identical
//!    to an unmetered one on both executors, including across Algorithm 2
//!    re-shard boundaries.
//! 2. The measured ledger agrees with the analytic memory model: on a
//!    flat single-device placement every sample equals the replicated
//!    expectation exactly; on a sharded cluster every sample is a whole
//!    number of chunks bounded by the replicated baseline.
//! 3. Predictor-accuracy samples keep flowing across re-shard boundaries
//!    and stay in range.
//! 4. The export/report path round-trips: files written by
//!    `MetricsWriter` parse back into the exact in-memory ledger, and
//!    the Prometheus exposition survives its parser.

use hecate::fssdp::{reference_dims, Session, SessionConfig};
use hecate::metrics::meter::MemModel;
use hecate::metrics::registry::parse_prometheus;
use hecate::telemetry::metrics_io::{
    load_metrics, MetricsWriter, COUNTERS_FILE, METRICS_JSONL_FILE, METRICS_PROM_FILE,
};
use hecate::testing::all_chunks;
use hecate::topology::Topology;

fn builder() -> hecate::fssdp::SessionConfigBuilder {
    SessionConfig::builder().reference().topology(Topology::cluster_a(2, 2)).seed(23)
}

#[test]
fn metered_run_is_bit_identical_on_both_executors() {
    // Sequential executor, with Algorithm 2 firing mid-run.
    let seq = |metered: bool| -> Vec<Vec<f32>> {
        let mut b = builder().layers(2).data_shards(4).reshard_every(2);
        if metered {
            b = b.metrics(true);
        }
        let mut s = Session::fresh(b.build().unwrap()).unwrap();
        s.run(4).unwrap();
        all_chunks(s.engine())
    };
    assert_eq!(seq(false), seq(true), "sequential: metered == unmetered bitwise");

    // SPMD executor, same workload.
    let spmd = |metered: bool| -> Vec<Vec<f32>> {
        let mut b =
            builder().layers(2).data_shards(4).reshard_every(2).parallel(true).threads(4);
        if metered {
            b = b.metrics(true);
        }
        let mut s = Session::fresh(b.build().unwrap()).unwrap();
        s.run(4).unwrap();
        all_chunks(s.engine())
    };
    let plain = spmd(false);
    assert_eq!(plain, spmd(true), "spmd: metered == unmetered bitwise");
    assert_eq!(plain, seq(true), "and both executors agree");
}

#[test]
fn ledger_matches_analytic_model_on_a_flat_single_device() {
    // One device owns every expert: no replicas ever materialize beyond
    // the shards, so every sample must equal the analytic expectation
    // exactly — experts × chunk bytes, which is also the replicated
    // baseline.
    let dims = reference_dims();
    let cfg = SessionConfig::builder()
        .reference()
        .topology(Topology::flat(1, 150e9))
        .data_shards(1)
        .seed(23)
        .metrics(true)
        .build()
        .unwrap();
    let mut s = Session::fresh(cfg).unwrap();
    s.run(3).unwrap();
    let m = s.meter_samples().unwrap();
    assert_eq!(m.mem_samples().len(), 3, "3 iters x 1 layer x 1 device");
    let model = MemModel::per_device(dims.experts, dims.experts, dims.experts, dims.chunk_len());
    assert_eq!(model.fssdp_bytes, model.replicated_bytes);
    for sample in m.mem_samples() {
        assert_eq!(sample.resident_bytes, model.replicated_bytes, "{sample:?}");
        assert_eq!(sample.payload_idle_bytes, 0, "sequential executor has no wire");
    }
    for hw in m.high_water().values() {
        assert_eq!(*hw, model.replicated_bytes);
    }
}

#[test]
fn ledger_is_chunk_granular_and_bounded_on_a_sharded_cluster() {
    let dims = reference_dims();
    let chunk_bytes = dims.chunk_len() as u64 * 4;
    let replicated = dims.experts as u64 * chunk_bytes;
    for parallel in [false, true] {
        let mut b = builder().layers(2).data_shards(4).metrics(true);
        if parallel {
            b = b.parallel(true).threads(4);
        }
        let mut s = Session::fresh(b.build().unwrap()).unwrap();
        s.run(3).unwrap();
        let m = s.meter_samples().unwrap();
        assert_eq!(m.mem_samples().len(), 3 * 2 * 4, "3 iters x 2 layers x 4 devices");
        let ranks: std::collections::BTreeSet<u32> =
            m.mem_samples().iter().map(|s| s.rank).collect();
        assert_eq!(ranks.len(), 4, "every rank contributes to the ledger");
        let hw = m.high_water();
        for sample in m.mem_samples() {
            assert!(sample.resident_bytes > 0, "{sample:?}");
            assert_eq!(
                sample.resident_bytes % chunk_bytes,
                0,
                "resident memory is whole chunks: {sample:?}"
            );
            assert!(
                sample.resident_bytes <= replicated,
                "never above the replicated baseline: {sample:?}"
            );
            assert!(hw[&(sample.rank, sample.layer)] >= sample.resident_bytes);
        }
    }
}

#[test]
fn predictor_accuracy_samples_span_reshard_boundaries() {
    let cfg = builder()
        .layers(2)
        .data_shards(4)
        .parallel(true)
        .threads(4)
        .reshard_every(2)
        .metrics(true)
        .build()
        .unwrap();
    let mut s = Session::fresh(cfg).unwrap();
    s.run(5).unwrap();
    assert!(s.reshards_moved() > 0 || s.reshard_every() == 2, "Algorithm 2 was on");
    let m = s.meter_samples().unwrap();
    let load = m.load_samples();
    assert_eq!(load.len(), 5 * 2, "one load sample per iter per layer");
    let iters: std::collections::BTreeSet<u32> = load.iter().map(|s| s.iter).collect();
    assert_eq!(
        iters,
        (0..5).collect(),
        "accuracy keeps being sampled across the reshard boundaries at 2 and 4"
    );
    for sample in load {
        assert!(sample.mae.is_finite() && sample.mae >= 0.0 && sample.mae <= 2.0, "{sample:?}");
        assert!((-1.0..=1.0).contains(&sample.rank_corr), "{sample:?}");
        assert!(sample.imbalance >= 1.0, "{sample:?}");
        assert!(sample.entropy >= 0.0, "{sample:?}");
        assert!(sample.max_load > 0.0 && sample.max_load <= 1.0, "{sample:?}");
    }
}

#[test]
fn spmd_export_round_trips_files_prometheus_and_report_tables() {
    let dir = std::env::temp_dir().join(format!("hecate-ledger-exp-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let cfg = builder()
        .layers(2)
        .data_shards(4)
        .parallel(true)
        .threads(4)
        .metrics(true)
        .build()
        .unwrap();
    let mut s = Session::fresh(cfg).unwrap();
    let mut w = MetricsWriter::new(&dir);
    s.run_observed(3, &mut [&mut w]).unwrap();
    for f in [METRICS_JSONL_FILE, METRICS_PROM_FILE, COUNTERS_FILE] {
        assert!(dir.join(f).exists(), "missing {f}");
    }
    let log = load_metrics(&dir).unwrap();
    let m = s.meter_samples().unwrap();
    assert_eq!(log.mem, m.mem_samples(), "JSONL round-trips the exact ledger");
    assert_eq!(log.load, m.load_samples());
    assert_eq!(log.high_water(), m.high_water());
    // SPMD ranks recycle wire buffers, so payload idle bytes show up
    assert!(
        log.mem.iter().any(|s| s.payload_idle_bytes > 0),
        "payload free-list column is live on the SPMD executor"
    );

    // exposition parses, and its peak gauges equal the ledger's marks
    let text = std::fs::read_to_string(dir.join(METRICS_PROM_FILE)).unwrap();
    let samples = parse_prometheus(&text).unwrap();
    assert!(samples.iter().any(|p| p.name == "hecate_peak_resident_bytes"));
    assert!(samples.iter().any(|p| p.name == "hecate_imbalance_pct_bucket"));

    // the report tables carry one line per rank / per sample
    let peak = log.peak_memory_table();
    assert_eq!(peak.lines().count(), 2 + 4, "header rows + one per rank: {peak}");
    let tl = log.imbalance_timeline();
    assert_eq!(tl.lines().count(), 2 + 3 * 2, "header rows + one per load sample");
    std::fs::remove_dir_all(&dir).unwrap();
}
