//! Integration: the PJRT runtime loads and executes the AOT artifacts, and
//! the end-to-end trainer reduces loss on the tiny model.
//! Requires `artifacts/` (run `make artifacts`); tests self-skip otherwise.

use hecate::runtime::{HostTensor, Runtime};

fn have_artifacts() -> bool {
    let ok = std::path::Path::new("artifacts/manifest.json").exists();
    if !ok {
        eprintln!("skipping: artifacts/ not built");
    }
    ok
}

#[test]
fn manifest_lists_engine_entries() {
    if !have_artifacts() {
        return;
    }
    let rt = Runtime::open("artifacts").unwrap();
    let required = ["gate_fwd", "expert_ffn_fwd", "expert_ffn_bwd", "tiny_init", "tiny_train_step"];
    for entry in required {
        assert!(rt.entry(entry).is_ok(), "missing entry {entry}");
    }
}

#[test]
fn gate_fwd_produces_valid_top2() {
    if !have_artifacts() {
        return;
    }
    let mut rt = Runtime::open("artifacts").unwrap();
    let e = rt.entry("gate_fwd").unwrap().clone();
    let (t, dm) = (e.inputs[0].shape[0], e.inputs[0].shape[1]);
    let experts = e.inputs[1].shape[1];
    let x = HostTensor::f32(vec![t, dm], (0..t * dm).map(|i| (i as f32 * 0.37).sin()).collect());
    let wg = HostTensor::f32(
        vec![dm, experts],
        (0..dm * experts).map(|i| (i as f32 * 0.11).cos() * 0.3).collect(),
    );
    let out = rt.execute("gate_fwd", &[x, wg]).unwrap();
    assert_eq!(out.len(), 3);
    // probs rows sum to 1
    let probs = out[0].as_f32().unwrap();
    for row in probs.chunks(experts) {
        let s: f32 = row.iter().sum();
        assert!((s - 1.0).abs() < 1e-4, "row sum {s}");
    }
    // top-2 weights normalized, indices distinct and in range
    let w = out[1].as_f32().unwrap();
    let idx = out[2].as_i32().unwrap();
    for (wpair, ipair) in w.chunks(2).zip(idx.chunks(2)) {
        assert!((wpair[0] + wpair[1] - 1.0).abs() < 1e-4);
        assert!(wpair[0] >= wpair[1], "first choice has the larger weight");
        assert_ne!(ipair[0], ipair[1]);
        assert!((0..experts as i32).contains(&ipair[0]));
    }
}

#[test]
fn expert_ffn_bwd_matches_finite_difference() {
    if !have_artifacts() {
        return;
    }
    let mut rt = Runtime::open("artifacts").unwrap();
    let e = rt.entry("expert_ffn_fwd").unwrap().clone();
    let (cap, dm) = (e.inputs[0].shape[0], e.inputs[0].shape[1]);
    let dff = e.inputs[1].shape[1];
    let mk = |n: usize, f: f32| -> Vec<f32> {
        (0..n).map(|i| ((i as f32) * f).sin() * 0.1).collect()
    };
    let x = HostTensor::f32(vec![cap, dm], mk(cap * dm, 0.13));
    let w1 = HostTensor::f32(vec![dm, dff], mk(dm * dff, 0.07));
    let b1 = HostTensor::f32(vec![dff], mk(dff, 0.19));
    let w2 = HostTensor::f32(vec![dff, dm], mk(dff * dm, 0.05));
    let b2 = HostTensor::f32(vec![dm], mk(dm, 0.23));
    let gy = HostTensor::f32(vec![cap, dm], vec![1.0; cap * dm]);

    let bwd = rt
        .execute(
            "expert_ffn_bwd",
            &[x.clone(), w1.clone(), b1.clone(), w2.clone(), b2.clone(), gy],
        )
        .unwrap();
    let gb2 = bwd[4].as_f32().unwrap();
    // analytic: dL/db2 with gy=1 is cap (each row contributes 1)
    for &g in gb2 {
        assert!((g - cap as f32).abs() < 1e-3, "gb2 {g} vs {cap}");
    }

    // finite difference on one w1 element: L = sum(y)
    let run_loss = |rt: &mut Runtime, w1v: &[f32]| -> f32 {
        let w1t = HostTensor::f32(vec![dm, dff], w1v.to_vec());
        let y = rt
            .execute(
                "expert_ffn_fwd",
                &[x.clone(), w1t, b1.clone(), w2.clone(), b2.clone()],
            )
            .unwrap();
        y[0].as_f32().unwrap().iter().sum()
    };
    let mut w1v = mk(dm * dff, 0.07);
    let base_idx = 5;
    let eps = 1e-3;
    w1v[base_idx] += eps;
    let lp = run_loss(&mut rt, &w1v);
    w1v[base_idx] -= 2.0 * eps;
    let lm = run_loss(&mut rt, &w1v);
    let fd = (lp - lm) / (2.0 * eps);
    let analytic = bwd[1].as_f32().unwrap()[base_idx];
    assert!(
        (fd - analytic).abs() < 2e-2 * analytic.abs().max(1.0),
        "finite diff {fd} vs analytic {analytic}"
    );
}

#[test]
fn tiny_training_reduces_loss() {
    if !have_artifacts() {
        return;
    }
    // 60 steps at ~15 ms each; compare first/last quartile means (single
    // steps are noisy at batch 2 × seq 32 = 64 tokens).
    let report = hecate::train::train("artifacts", "tiny", 60, 3, |_, _, _, _| {}).unwrap();
    assert_eq!(report.losses.len(), 60);
    assert!(report.losses.iter().all(|l| l.is_finite()));
    let head: f32 = report.losses[..15].iter().sum::<f32>() / 15.0;
    let tail: f32 = report.losses[45..].iter().sum::<f32>() / 15.0;
    assert!(tail < head, "loss trend not decreasing: head {head:.4} tail {tail:.4}");
}

#[test]
fn execute_validates_shapes() {
    if !have_artifacts() {
        return;
    }
    let mut rt = Runtime::open("artifacts").unwrap();
    let bad = HostTensor::f32(vec![2, 2], vec![0.0; 4]);
    let err = rt.execute("gate_fwd", &[bad.clone(), bad]).unwrap_err().to_string();
    assert!(err.contains("shape"), "{err}");
}
