//! Integration: the static schedule verifier (`hecate analyze schedule`)
//! passes every shipped SPMD configuration, each seeded [`Injection`]
//! violation is caught with an actionable rank/iter/layer/tag diagnostic,
//! and — in debug builds — real SPMD spans on both transports assert
//! their audited traffic equals the model's predicted multiset (the
//! `verify_span_traffic` cross-check inside `spmd::run_span`). Hermetic:
//! reference backend, no artifacts or PJRT required.

use hecate::analysis::{analyze_config, Injection};
use hecate::fssdp::{Session, SessionConfig, SessionConfigBuilder};
use hecate::spmd::transport::TransportKind;

fn cfg(nodes: usize, devices: usize) -> SessionConfigBuilder {
    SessionConfig::builder().reference().cluster(nodes, devices).parallel(true).seed(42)
}

// ---------------------------------------------------------------------------
// Clean configurations: the analyzer must pass everything we ship.
// ---------------------------------------------------------------------------

#[test]
fn analyzer_passes_every_shipped_config() {
    // The `fssdp --parallel` smoke matrix: world sizes 2/4/8, one layer.
    for (nodes, devices) in [(1usize, 2usize), (2, 4), (2, 8)] {
        let rep = analyze_config(&cfg(nodes, devices).build().unwrap(), 4, None).unwrap();
        assert_eq!(rep.ranks, devices);
        assert!(rep.sends == rep.recvs && rep.sends > 0, "{rep:?}");
    }
    // Overlap off must be just as clean (same multiset, different order).
    let rep = analyze_config(&cfg(2, 4).overlap(false).build().unwrap(), 4, None).unwrap();
    assert!(rep.sends == rep.recvs && rep.sends > 0, "{rep:?}");
}

#[test]
fn analyzer_passes_racked_multilayer_resharding_config() {
    // The hardest shipped shape: 8 ranks over 4 nodes in 2 racks, 3 MoE
    // layers, Algorithm 2 resharding every 2 iterations, socket wire caps
    // on. The window spans two reshard boundaries.
    let c = cfg(4, 8)
        .layers(3)
        .racks(2)
        .reshard_every(2)
        .transport(TransportKind::Socket)
        .build()
        .unwrap();
    let rep = analyze_config(&c, 5, None).unwrap();
    assert_eq!((rep.ranks, rep.layers, rep.iters), (8, 3, 5));
    assert_eq!(rep.spans, 3, "5 iters at cadence 2 → spans of 2+2+1");
    assert_eq!(rep.reshards, 2);
    assert!(rep.sends == rep.recvs && rep.sends > 0, "{rep:?}");
    assert!(
        rep.max_frame_bytes <= hecate::spmd::transport::socket::MAX_FRAME_LEN,
        "largest modeled frame {} must fit the wire cap",
        rep.max_frame_bytes
    );
}

// ---------------------------------------------------------------------------
// Mutation coverage: every check catches what it claims to catch, with a
// diagnostic naming the rank and tag involved.
// ---------------------------------------------------------------------------

#[test]
fn dropped_recv_reports_the_orphan_send() {
    let err = analyze_config(&cfg(2, 4).build().unwrap(), 2, Some(Injection::DropRecv))
        .unwrap_err()
        .to_string();
    assert!(err.contains("schedule verification failed"), "{err}");
    assert!(err.contains("orphan send"), "{err}");
    assert!(err.contains("rank") && err.contains("iter"), "{err}");
}

#[test]
fn swapped_barrier_prints_the_deadlock_cycle() {
    let err = analyze_config(&cfg(2, 4).build().unwrap(), 2, Some(Injection::SwapBarrier))
        .unwrap_err()
        .to_string();
    assert!(err.contains("deadlock cycle"), "{err}");
    assert!(err.contains("waits for"), "{err}");
    assert!(err.contains("Barrier"), "{err}");
}

#[test]
fn oversized_frame_is_rejected_on_the_socket_transport() {
    // Frame caps only bind where a wire codec exists, so the injection is
    // exercised on a socket-transport config.
    let c = cfg(2, 4).transport(TransportKind::Socket).build().unwrap();
    let err = analyze_config(&c, 2, Some(Injection::OversizeFrame)).unwrap_err().to_string();
    assert!(err.contains("oversized frame"), "{err}");
    assert!(err.contains("rank"), "{err}");
}

#[test]
fn double_owned_chunk_after_reshard_breaks_the_partition() {
    // The injection fires at the first reshard boundary: the analyzer must
    // catch the shard map ceasing to be an exact partition mid-window.
    let c = cfg(2, 4).layers(2).reshard_every(2).build().unwrap();
    let err = analyze_config(&c, 4, Some(Injection::DoubleOwn)).unwrap_err().to_string();
    assert!(err.contains("must stay an exact partition"), "{err}");
    assert!(err.contains("chunk 0"), "{err}");
    // Clean run of the same window for contrast.
    analyze_config(&c, 4, None).unwrap();
}

// ---------------------------------------------------------------------------
// Debug cross-check: real SPMD spans audit their traffic against the
// model's multiset inside `run_span` (cfg!(debug_assertions) only — in
// release these are plain equivalence smokes).
// ---------------------------------------------------------------------------

fn run_spmd(b: SessionConfigBuilder, iters: usize) {
    let mut s = Session::fresh(b.build().unwrap()).unwrap();
    s.run(iters).unwrap();
}

#[test]
fn debug_spans_match_the_model_on_inproc() {
    // Multi-layer, overlapped, across a reshard boundary: any divergence
    // between audited traffic and the symbolic multiset fails run().
    run_spmd(cfg(2, 2).layers(3).overlap(true).reshard_every(2), 3);
    run_spmd(cfg(2, 2).layers(2).overlap(false), 2);
}

#[test]
fn debug_spans_match_the_model_on_socket() {
    run_spmd(cfg(2, 2).layers(2).overlap(true).transport(TransportKind::Socket), 2);
}

#[test]
fn debug_span_matches_the_model_on_eight_ranks() {
    run_spmd(cfg(2, 8).layers(2).overlap(true), 2);
}
