//! Integration: the socket transport is a drop-in replacement for the
//! in-process fabric — an 8-rank, 3-layer SPMD run over unix sockets
//! (versioned wire codec, reader threads, message-fallback barrier)
//! produces final expert parameters **bit-identical** to the in-process
//! backend at the same seed, and both collapse to the sequential oracle.
//! Also drives the real `hecate` binary end to end: the coordinator
//! launcher spawns one `hecate worker` process per rank over a UDS mesh
//! and `--verify-inproc` bit-compares the merged result in-process.
//! Hermetic: reference backend, localhost sockets only.

use std::process::Command;

use hecate::fssdp::{Session, SessionConfig, SessionConfigBuilder};
use hecate::spmd::transport::TransportKind;
use hecate::testing::all_chunks;
use hecate::topology::Topology;

#[allow(clippy::too_many_arguments)]
fn cfg(
    layers: usize,
    topo: Topology,
    threads: usize,
    overlap: bool,
    sources: usize,
    seed: u64,
    transport: TransportKind,
) -> SessionConfigBuilder {
    SessionConfig::builder()
        .reference()
        .topology(topo)
        .layers(layers)
        .seed(seed)
        .data_shards(sources)
        .parallel(true)
        .threads(threads)
        .overlap(overlap)
        .transport(transport)
}

#[allow(clippy::too_many_arguments)]
fn run(
    layers: usize,
    topo: Topology,
    threads: usize,
    overlap: bool,
    iters: usize,
    sources: usize,
    seed: u64,
    transport: TransportKind,
) -> Vec<Vec<f32>> {
    let b = cfg(layers, topo, threads, overlap, sources, seed, transport);
    let mut s = Session::fresh(b.build().unwrap()).unwrap();
    s.run(iters).unwrap();
    all_chunks(s.engine())
}

#[test]
fn socket_matches_inproc_bitwise_on_8_ranks_3_layers() {
    // The acceptance lock: 8 ranks, 3 MoE layers, overlap scheduler on,
    // same seed — the socket backend must not perturb a single bit.
    let inproc =
        run(3, Topology::cluster_a(2, 4), 8, true, 3, 8, 23, TransportKind::InProc);
    let socket =
        run(3, Topology::cluster_a(2, 4), 8, true, 3, 8, 23, TransportKind::Socket);
    assert_eq!(inproc, socket, "socket transport must be bit-identical to in-proc");
}

#[test]
fn socket_matches_the_sequential_oracle_with_overlap_off() {
    // Transitivity check through the other executor: a socket run with the
    // overlap scheduler off equals the sequential engine bit for bit.
    let mut s = Session::fresh(
        SessionConfig::builder()
            .reference()
            .topology(Topology::cluster_a(2, 2))
            .layers(2)
            .seed(19)
            .data_shards(4)
            .build()
            .unwrap(),
    )
    .unwrap();
    s.run(3).unwrap();
    let seq = all_chunks(s.engine());
    let socket =
        run(2, Topology::cluster_a(2, 2), 4, false, 3, 4, 19, TransportKind::Socket);
    assert_eq!(seq, socket, "socket SPMD must collapse to the sequential trajectory");
}

#[test]
fn racked_topology_runs_over_sockets_bit_identically() {
    // The hierarchical tiers change planning inputs, never numerics: a
    // 2-rack topology must agree across transports too.
    let topo = Topology::cluster_a(4, 2).with_racks(2);
    let inproc = run(2, topo.clone(), 8, true, 2, 8, 37, TransportKind::InProc);
    let socket = run(2, topo, 8, true, 2, 8, 37, TransportKind::Socket);
    assert_eq!(inproc, socket, "rack tiers must not perturb socket numerics");
}

#[test]
fn multiprocess_launcher_verifies_against_inproc() {
    // The real binary: coordinator spawns 4 `hecate worker` processes over
    // a UDS mesh, merges their state blobs, and bit-compares against an
    // in-process rerun (--verify-inproc). This is the CI smoke flow.
    let dir = std::env::temp_dir().join(format!("hecate-socket-cli-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let out = Command::new(env!("CARGO_BIN_EXE_hecate"))
        .args([
            "fssdp",
            "--reference",
            "--parallel",
            "--devices",
            "4",
            "--nodes",
            "2",
            "--layers",
            "2",
            "--iters",
            "2",
            "--transport",
            "socket",
            "--verify-inproc",
            "--worker-dir",
            dir.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "launcher failed:\n{stdout}\n{stderr}");
    assert!(
        stdout.contains("verify: socket run is bit-identical to the in-process executor"),
        "missing verification line:\n{stdout}"
    );
    assert!(stdout.contains("iter   0  loss"), "missing per-iteration lines:\n{stdout}");
    // per-rank logs and state blobs are kept for post-mortems / artifacts
    for r in 0..4 {
        assert!(dir.join(format!("worker-{r}.log")).exists(), "missing worker-{r}.log");
        assert!(dir.join(format!("state-{r}.bin")).exists(), "missing state-{r}.bin");
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn comm_failures_exit_with_code_2() {
    // A worker with an unusable listen address dies with a typed
    // communicator error, which `main` maps to exit code 2 — supervisors
    // can tell a dead fabric from a bad flag (exit 1).
    let out = Command::new(env!("CARGO_BIN_EXE_hecate"))
        .args([
            "worker", "--rank", "0", "--world", "4", "--listen", "carrier-pigeon:nest",
            "--peers", "a,b,c,d", "--devices", "4", "--out", "/tmp/hecate-unused-state.bin",
        ])
        .output()
        .unwrap();
    assert_eq!(out.status.code(), Some(2), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    // a plain flag error stays exit 1
    let out = Command::new(env!("CARGO_BIN_EXE_hecate"))
        .args(["fssdp", "--bogus", "1"])
        .output()
        .unwrap();
    assert_eq!(out.status.code(), Some(1));
}
