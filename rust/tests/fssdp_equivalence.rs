//! Integration: the numeric FSSDP engine across N devices produces the
//! SAME trained parameters as the 1-device reference (all experts local —
//! no sparse collectives, no cross-device dispatch). This is the numeric
//! proof of §3: FSSDP's placement freedom does not change the math.
//!
//! Requires `artifacts/` (run `make artifacts`); tests self-skip otherwise.
//! Runs go through the public `Session` API on the PJRT backend.

use hecate::fssdp::{Session, SessionConfig};
use hecate::testing::max_rel_err;
use hecate::topology::Topology;

fn artifacts() -> Option<&'static str> {
    if std::path::Path::new("artifacts/manifest.json").exists() {
        Some("artifacts")
    } else {
        eprintln!("skipping: artifacts/ not built");
        None
    }
}

fn session(topo: Topology, sources: usize, seed: u64) -> Session {
    Session::fresh(
        SessionConfig::builder()
            .pjrt(artifacts().unwrap())
            .topology(topo)
            .seed(seed)
            .data_shards(sources)
            .build()
            .unwrap(),
    )
    .unwrap()
}

fn train(topo: Topology, sources: usize, iters: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut s = session(topo, sources, seed);
    s.run(iters).unwrap();
    let e = s.engine();
    (0..e.dims.experts).map(|x| e.expert_chunk(x).to_vec()).collect()
}

#[test]
fn fssdp_matches_single_device_reference() {
    if artifacts().is_none() {
        return;
    }
    let sources = 8; // fixed data-shard count across both runs
    let distributed = train(Topology::cluster_a(2, 4), sources, 4, 7);
    let reference = train(Topology::flat(1, 1e9), sources, 4, 7);
    assert_eq!(distributed.len(), reference.len());
    for (e, (d, r)) in distributed.iter().zip(reference.iter()).enumerate() {
        let err = max_rel_err(d, r);
        assert!(err < 2e-3, "expert {e}: max rel err {err}");
    }
}

#[test]
fn fssdp_loss_decreases() {
    if artifacts().is_none() {
        return;
    }
    let mut s = session(Topology::cluster_a(2, 4), 8, 11);
    let losses: Vec<f64> = s.run(6).unwrap().iter().map(|st| st.loss).collect();
    let (first, last) = (losses[0], losses[5]);
    assert!(last < first * 0.9, "loss {first} -> {last}");
}

#[test]
fn fssdp_four_device_topology_also_matches() {
    if artifacts().is_none() {
        return;
    }
    let sources = 4;
    let a = train(Topology::cluster_a(4, 1), sources, 3, 13);
    let b = train(Topology::flat(1, 1e9), sources, 3, 13);
    for (e, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        let err = max_rel_err(x, y);
        assert!(err < 2e-3, "expert {e}: max rel err {err}");
    }
}
