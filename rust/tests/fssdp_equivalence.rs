//! Integration: the numeric FSSDP engine across N devices produces the
//! SAME trained parameters as the 1-device reference (all experts local —
//! no sparse collectives, no cross-device dispatch). This is the numeric
//! proof of §3: FSSDP's placement freedom does not change the math.
//!
//! Requires `artifacts/` (run `make artifacts`); tests self-skip otherwise.

use hecate::fssdp::FssdpEngine;
use hecate::testing::max_rel_err;
use hecate::topology::Topology;

fn artifacts() -> Option<&'static str> {
    if std::path::Path::new("artifacts/manifest.json").exists() {
        Some("artifacts")
    } else {
        eprintln!("skipping: artifacts/ not built");
        None
    }
}

fn train(topo: Topology, sources: usize, iters: u64, seed: u64) -> Vec<Vec<f32>> {
    let mut engine = FssdpEngine::new(artifacts().unwrap(), topo, seed).unwrap();
    for i in 0..iters {
        engine.step(i, sources).unwrap();
    }
    (0..engine.dims.experts).map(|e| engine.expert_chunk(e).clone()).collect()
}

#[test]
fn fssdp_matches_single_device_reference() {
    if artifacts().is_none() {
        return;
    }
    let sources = 8; // fixed data-shard count across both runs
    let distributed = train(Topology::cluster_a(2, 4), sources, 4, 7);
    let reference = train(Topology::flat(1, 1e9), sources, 4, 7);
    assert_eq!(distributed.len(), reference.len());
    for (e, (d, r)) in distributed.iter().zip(reference.iter()).enumerate() {
        let err = max_rel_err(d, r);
        assert!(err < 2e-3, "expert {e}: max rel err {err}");
    }
}

#[test]
fn fssdp_loss_decreases() {
    if artifacts().is_none() {
        return;
    }
    let mut engine = FssdpEngine::new("artifacts", Topology::cluster_a(2, 4), 11).unwrap();
    let first = engine.step(0, 8).unwrap().loss;
    let mut last = first;
    for i in 1..6 {
        last = engine.step(i, 8).unwrap().loss;
    }
    assert!(last < first * 0.9, "loss {first} -> {last}");
}

#[test]
fn fssdp_four_device_topology_also_matches() {
    if artifacts().is_none() {
        return;
    }
    let sources = 4;
    let a = train(Topology::cluster_a(4, 1), sources, 3, 13);
    let b = train(Topology::flat(1, 1e9), sources, 3, 13);
    for (e, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        let err = max_rel_err(x, y);
        assert!(err < 2e-3, "expert {e}: max rel err {err}");
    }
}
