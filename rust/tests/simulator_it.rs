//! Integration: full figure-reproduction pipeline shapes. These run the
//! simulator end-to-end at reduced iteration counts and assert the
//! *qualitative* results the paper reports (who wins, direction of
//! tradeoffs) — the quantitative rows land in EXPERIMENTS.md via
//! `hecate repro --all`.

use hecate::config::ClusterPreset;
use hecate::sim::engine::SimOptions;
use hecate::sim::report;

fn quick() -> SimOptions {
    SimOptions { iterations: 24, warmup: 6, seed: 42, balanced_loads: false }
}

#[test]
fn figure9_hecate_wins_all_models_16_and_32_gpus() {
    for (nodes, dpn) in [(2, 8), (4, 8)] {
        let t = report::end_to_end(ClusterPreset::A, nodes, dpn, &quick());
        for row in &t.rows {
            let hecate: f64 = row[6].parse().unwrap();
            assert!(hecate > 1.0, "{} @{}x{}: hecate {hecate}", row[0], nodes, dpn);
            let ratio: f64 = row[7].parse().unwrap();
            assert!(ratio >= 0.95, "{}: hecate/best {ratio}", row[0]);
        }
    }
}

#[test]
fn figure10_cluster_b_hecate_wins() {
    let t = report::figure10(&quick());
    for row in &t.rows {
        let hecate: f64 = row[6].parse().unwrap();
        assert!(hecate > 1.0, "{}: {hecate}", row[0]);
    }
}

#[test]
fn speedup_grows_with_scale_like_paper() {
    // §5.2: "the speedup exhibits an increasing trend with the number of
    // GPUs" — geo-mean Hecate speedup at 32 GPUs ≥ at 16 GPUs.
    let t16 = report::end_to_end(ClusterPreset::A, 2, 8, &quick());
    let t32 = report::end_to_end(ClusterPreset::A, 4, 8, &quick());
    let geo = |t: &hecate::metrics::Table| {
        let v: Vec<f64> = t.rows.iter().map(|r| r[6].parse::<f64>().unwrap()).collect();
        hecate::util::stats::geomean(&v)
    };
    let (g16, g32) = (geo(&t16), geo(&t32));
    assert!(
        g32 > g16 * 0.9,
        "speedup should not shrink with scale: 16GPU {g16:.2} vs 32GPU {g32:.2}"
    );
}

#[test]
fn figure12_a2a_dominates_ep_and_hecate_reduces_it() {
    let t = report::figure12(&quick());
    // row 0 is EP; A2A column is index 3
    let ep_a2a: f64 = t.rows[0][3].parse().unwrap();
    let ep_total: f64 = t.rows[0][5].parse().unwrap();
    assert!(ep_a2a > 0.3 * ep_total, "A2A should dominate EP: {ep_a2a} of {ep_total}");
    let hec_a2a: f64 = t.rows[4][3].parse().unwrap();
    assert!(hec_a2a < ep_a2a, "Hecate must reduce A2A: {hec_a2a} vs {ep_a2a}");
    // Hecate-RM slower than Hecate but faster than EP
    let hec_total: f64 = t.rows[4][5].parse().unwrap();
    let rm_total: f64 = t.rows[5][5].parse().unwrap();
    assert!(rm_total >= hec_total);
    assert!(rm_total < ep_total);
}

#[test]
fn figure13_memory_shape() {
    let t = report::figure13(&quick());
    let get = |name: &str, col: usize| -> f64 {
        t.rows.iter().find(|r| r[0] == name).unwrap()[col].parse().unwrap()
    };
    // SmartMoE ≈ EP; FlexMoE > Hecate; Hecate-RM param ≪ Hecate param
    assert!((get("SmartMoE", 5) - 1.0).abs() < 0.05);
    assert!(get("FlexMoE", 4) > get("Hecate", 4));
    let hec_param = get("Hecate", 3);
    let rm_param = get("Hecate-RM", 3);
    assert!(
        rm_param < 0.5 * hec_param,
        "RM param {rm_param} should be far below Hecate {hec_param}"
    );
    // Hecate uses more param memory than EP (the 5.73× effect direction)
    assert!(get("Hecate", 3) > get("EP", 3));
}

#[test]
fn figure14_oom_frontier() {
    let t = report::figure14(&quick());
    // at batch 6: Hecate-RM alive; Hecate OOMs before RM does overall
    let rm_oom = t.rows.iter().filter(|r| r[4] == "OOM").count();
    let hec_oom = t.rows.iter().filter(|r| r[3] == "OOM").count();
    assert!(rm_oom <= hec_oom, "RM OOMs ({rm_oom}) must not exceed Hecate's ({hec_oom})");
    assert_ne!(t.rows[5][4], "OOM", "Hecate-RM survives batch 6");
}

#[test]
fn figure15_ablation_directions() {
    let a = report::figure15a(&quick());
    let speed = |i: usize| -> f64 { a.rows[i][3].parse().unwrap() };
    // (sharding, mat): rows 0..4 = (f,f),(t,f),(f,t),(t,t)
    assert!(speed(3) >= speed(1), "full beats sharding-only");
    // our trace rewards sharding less than the paper's workloads; allow a
    // small margin vs mat-only (see EXPERIMENTS.md Figure 15 notes)
    assert!(speed(3) >= speed(0) * 0.95, "full beats neither");
    // materialization contributes more than sharding alone (paper: 3.32×
    // vs 1.27× gaps)
    assert!(speed(2) > speed(1), "mat-only should beat sharding-only");

    let b = report::figure15b(&quick());
    let speeds: Vec<f64> = b.rows.iter().map(|r| r[2].parse().unwrap()).collect();
    let max = speeds.iter().cloned().fold(f64::MIN, f64::max);
    let min = speeds.iter().cloned().fold(f64::MAX, f64::min);
    assert!(
        max / min < 1.25,
        "re-sharding interval insensitivity (paper §5.4): {speeds:?}"
    );
}

#[test]
fn claims_ep_slowdown_and_flexmoe_tradeoff() {
    let c = report::claims(&quick());
    // claim 0: EP slowdown > 1.5× under imbalance
    let slowdown: f64 = c[0].1.rows[1][2].parse().unwrap();
    assert!(slowdown > 1.5, "EP imbalance slowdown {slowdown}");
    // claim 1: FlexMoE memory grows monotonically with reserve
    let mems: Vec<f64> = c[1].1.rows.iter().map(|r| r[3].parse().unwrap()).collect();
    assert!(mems.windows(2).all(|w| w[1] >= w[0]), "{mems:?}");
}
