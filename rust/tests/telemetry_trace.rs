//! Integration: the telemetry subsystem is purely observational — traced
//! runs stay bit-identical to untraced ones on both executors — and its
//! exports are well-formed: per-rank timelines have non-decreasing span
//! end times, the Chrome trace carries one phase row per rank, identical
//! seeded runs record identical event multisets, and the analyzer reports
//! a defined §4.3 overlap efficiency on paced links. Hermetic: reference
//! backend, public `Session` API only.

use hecate::fssdp::{parse_pacing, Session, SessionConfig, SessionConfigBuilder};
use hecate::telemetry::analyze::{analyze, analyze_dir, load_events};
use hecate::telemetry::{
    Event, Phase, TraceWriter, CHROME_TRACE_FILE, COMM_TID_OFFSET, EVENTS_FILE,
};
use hecate::testing::all_chunks;
use hecate::topology::Topology;
use hecate::util::json::Json;

/// 2-layer reference session on 4 devices; `spmd` selects the parallel
/// executor, `trace` installs the recorder.
fn cfg(spmd: bool, trace: bool, seed: u64) -> SessionConfigBuilder {
    let mut b = SessionConfig::builder()
        .reference()
        .topology(Topology::cluster_a(2, 2))
        .layers(2)
        .seed(seed)
        .data_shards(4)
        .trace(trace);
    if spmd {
        b = b.parallel(true).threads(4);
    }
    b
}

fn run(spmd: bool, trace: bool, seed: u64) -> Session {
    let mut s = Session::fresh(cfg(spmd, trace, seed).build().unwrap()).unwrap();
    s.run(3).unwrap();
    s
}

/// The order- and timing-independent identity of an event.
fn key(e: &Event) -> (&'static str, u32, u32, u32, u64) {
    (e.phase.as_str(), e.iter, e.layer, e.rank, e.detail)
}

#[test]
fn tracing_is_observational_on_both_executors() {
    for spmd in [false, true] {
        let plain = run(spmd, false, 41);
        let traced = run(spmd, true, 41);
        assert!(plain.trace_events().is_none(), "tracing must be off by default");
        assert!(!traced.trace_events().unwrap().is_empty());
        assert_eq!(
            all_chunks(plain.engine()),
            all_chunks(traced.engine()),
            "traced run (spmd={spmd}) must be bit-identical to untraced"
        );
    }
}

#[test]
fn identical_seeded_runs_record_identical_event_multisets() {
    // Unpaced runs: spans, sends, and deliveries are all decided by the
    // deterministic plans, so the recorded (phase, iter, layer, rank,
    // detail) multiset must be reproducible; only timings may differ.
    for spmd in [false, true] {
        let mut a: Vec<_> = run(spmd, true, 43).trace_events().unwrap().iter().map(key).collect();
        let mut b: Vec<_> = run(spmd, true, 43).trace_events().unwrap().iter().map(key).collect();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a.len(), b.len(), "event count must be stable (spmd={spmd})");
        assert_eq!(a, b, "event multiset must be stable (spmd={spmd})");
    }
}

#[test]
fn per_rank_timelines_are_well_formed() {
    let s = run(true, true, 47);
    let events = s.trace_events().unwrap();
    for r in 0..4u32 {
        // spans are pushed at span *end* (nested issue spans close before
        // their parent), so the per-rank invariant is on end times
        let mut last_end = f64::NEG_INFINITY;
        let mut any = false;
        for e in events.iter().filter(|e| e.rank == r) {
            any = true;
            assert!(e.dur_us >= 0.0 && e.ts_us >= 0.0, "negative time: {e:?}");
            assert!(e.iter < 3 + 1, "iter out of range: {e:?}"); // +1: eager next-iter issue
            assert!(e.layer < 2, "layer out of range: {e:?}");
            let end = e.ts_us + e.dur_us;
            assert!(end >= last_end, "rank {r}: end times must be non-decreasing ({e:?})");
            last_end = end;
        }
        assert!(any, "rank {r} recorded nothing");
    }
}

#[test]
fn trace_writer_exports_chrome_trace_and_jsonl() {
    let dir = std::env::temp_dir().join(format!("hecate-trace-int-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();

    let mut s = Session::fresh(cfg(true, true, 53).build().unwrap()).unwrap();
    let mut writer = TraceWriter::new(&dir);
    s.run_observed(3, &mut [&mut writer]).unwrap();
    let n = s.trace_events().unwrap().len();
    assert_eq!(writer.exported(), n, "writer must drain the full timeline");

    // JSONL round-trips through the loader
    let loaded = load_events(&dir).unwrap();
    assert_eq!(loaded.len(), n);
    assert_eq!(loaded, s.trace_events().unwrap());

    // Chrome trace: valid JSON, one phase row + one comm row per rank
    let text = std::fs::read_to_string(dir.join(CHROME_TRACE_FILE)).unwrap();
    let doc = Json::parse(&text).unwrap();
    let entries = doc.get("traceEvents").unwrap().as_arr().unwrap();
    let thread_rows: Vec<f64> = entries
        .iter()
        .filter(|j| j.get("name").and_then(Json::as_str) == Some("thread_name"))
        .map(|j| j.get("tid").unwrap().as_f64().unwrap())
        .collect();
    let phase_rows = thread_rows.iter().filter(|&&t| t < COMM_TID_OFFSET as f64).count();
    let comm_rows = thread_rows.len() - phase_rows;
    assert_eq!(phase_rows, 4, "one named timeline row per rank");
    assert_eq!(comm_rows, 4, "one named comm row per rank");
    let spans = entries
        .iter()
        .filter(|j| j.get("ph").and_then(Json::as_str) == Some("X"))
        .count();
    assert_eq!(spans, n, "every event renders as one complete span");

    assert!(dir.join(EVENTS_FILE).exists());
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn paced_run_reports_defined_overlap_efficiency() {
    let dir = std::env::temp_dir().join(format!("hecate-trace-eff-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();

    // α–β paced links give deliveries a modeled in-flight time, so the
    // analyzer has wire time to compare against exposed waits.
    let mut s = Session::fresh(
        cfg(true, true, 59).pacing(parse_pacing("1e-4,1e-9").unwrap()).build().unwrap(),
    )
    .unwrap();
    let mut writer = TraceWriter::new(&dir);
    s.run_observed(2, &mut [&mut writer]).unwrap();

    let a = analyze_dir(&dir).unwrap();
    assert!(a.wire_us > 0.0, "paced deliveries must record wire time");
    let eff = a.overlap_efficiency.expect("efficiency defined when wire > 0");
    assert!((0.0..=1.0).contains(&eff), "efficiency in [0,1]: {eff}");
    assert!(!a.steps.is_empty() && a.ranks.len() == 4);
    assert!(a.summary().contains("overlap efficiency"), "{}", a.summary());

    // in-memory analysis agrees with the directory round-trip
    let b = analyze(s.trace_events().unwrap());
    assert_eq!(a, b);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn sequential_trace_covers_the_step_phases() {
    let s = run(false, true, 61);
    let events = s.trace_events().unwrap();
    for want in [
        Phase::Materialize,
        Phase::Gate,
        Phase::ExpertFwd,
        Phase::ExpertBwd,
        Phase::SprsWait,
        Phase::Adam,
        Phase::SpagIssue,
        Phase::SprsIssue,
    ] {
        assert!(events.iter().any(|e| e.phase == want), "missing {want:?}");
    }
    assert!(events.iter().all(|e| e.rank == 0), "sequential engine records as rank 0");
}
