//! Integration: the SPMD parallel executor (one OS thread per rank, an
//! in-process communicator, overlapped sparse collectives with the §4.3
//! cross-layer pipeline) produces final expert parameters **bit-identical**
//! to the sequential engine at the same seed — on 2/4/8 threads, at L=1 and
//! L=3, and across a checkpoint/resume boundary. Hermetic: reference
//! backend, no artifacts or PJRT required.

use hecate::fssdp::{reference_dims, Executor, FssdpEngine};
use hecate::testing::{all_chunks as chunks, max_rel_err};
use hecate::topology::Topology;

fn run_layers(
    layers: usize,
    topo: Topology,
    executor: Executor,
    iters: usize,
    sources: usize,
    seed: u64,
) -> Vec<Vec<f32>> {
    let mut e = FssdpEngine::new_reference_layers(reference_dims(), layers, topo, seed);
    e.executor = executor;
    e.run_span(0, iters, sources).unwrap();
    chunks(&e)
}

fn run(
    topo: Topology,
    executor: Executor,
    iters: usize,
    sources: usize,
    seed: u64,
) -> Vec<Vec<f32>> {
    run_layers(1, topo, executor, iters, sources, seed)
}

#[test]
fn parallel_matches_sequential_on_2_4_8_threads() {
    for (nodes, dpn) in [(1usize, 2usize), (2, 2), (2, 4)] {
        let d = nodes * dpn;
        let seq = run(Topology::cluster_a(nodes, dpn), Executor::Sequential, 4, d, 13);
        let par = run(
            Topology::cluster_a(nodes, dpn),
            Executor::Spmd { threads: d, overlap: true },
            4,
            d,
            13,
        );
        assert_eq!(seq, par, "{d}-thread SPMD must be bit-identical to sequential");
    }
}

#[test]
fn l3_parallel_matches_sequential_on_2_4_8_threads() {
    // The multi-layer lock: 3 MoE layers, cross-layer pipelined overlap
    // on, must be bit-identical to the sequential oracle on every thread
    // count.
    for (nodes, dpn) in [(1usize, 2usize), (2, 2), (2, 4)] {
        let d = nodes * dpn;
        let seq = run_layers(3, Topology::cluster_a(nodes, dpn), Executor::Sequential, 3, d, 17);
        let par = run_layers(
            3,
            Topology::cluster_a(nodes, dpn),
            Executor::Spmd { threads: d, overlap: true },
            3,
            d,
            17,
        );
        assert_eq!(seq, par, "L=3 {d}-thread SPMD must be bit-identical to sequential");
    }
}

#[test]
fn l1_multilayer_engine_matches_seed_trajectory_across_executors() {
    // The seed-behavior lock, executor edition: an L=1 engine must produce
    // one single trajectory regardless of executor or overlap mode (the
    // in-module test `fssdp::tests::l1_step_matches_seed_oracle_bitwise`
    // pins that trajectory to the seed engine's transcribed step body).
    let seq = run(Topology::cluster_a(2, 2), Executor::Sequential, 4, 4, 29);
    for overlap in [false, true] {
        let par =
            run(Topology::cluster_a(2, 2), Executor::Spmd { threads: 4, overlap }, 4, 4, 29);
        assert_eq!(seq, par, "L=1 SPMD (overlap={overlap}) must match the seed trajectory");
    }
}

#[test]
fn l3_parallel_with_resharding_matches_sequential() {
    // Algorithm 2 re-runs inside the numeric span (--reshard-every); the
    // re-shard happens on merged engine state, so both executors must stay
    // bit-identical through chunk migrations.
    let mk = |executor: Executor| -> Vec<Vec<f32>> {
        let mut e =
            FssdpEngine::new_reference_layers(reference_dims(), 3, Topology::cluster_a(2, 2), 31);
        e.reshard_every = 2;
        e.executor = executor;
        e.run_span(0, 5, 4).unwrap();
        chunks(&e)
    };
    let seq = mk(Executor::Sequential);
    let par = mk(Executor::Spmd { threads: 4, overlap: true });
    assert_eq!(seq, par, "re-sharded L=3 run must be bit-identical across executors");
}

#[test]
fn parallel_matches_single_device_reference_within_tolerance() {
    // The fssdp_equivalence guarantee carries over to the parallel
    // executor: 8 distributed ranks vs the all-local 1-device oracle at
    // the established 2e-3 tolerance (placement freedom, not bit-equality,
    // is what differs here — reduction orders depend on the placement).
    let par =
        run(Topology::cluster_a(2, 4), Executor::Spmd { threads: 8, overlap: true }, 3, 4, 7);
    let refr = run(Topology::flat(1, 1e9), Executor::Sequential, 3, 4, 7);
    assert_eq!(par.len(), refr.len());
    for (e, (d, r)) in par.iter().zip(refr.iter()).enumerate() {
        let err = max_rel_err(d, r);
        assert!(err < 2e-3, "expert {e}: max rel err {err}");
    }
}

#[test]
fn parallel_resume_from_checkpoint_is_bit_identical() {
    let dims = reference_dims();
    let sources = 4;
    let layers = 3;
    let spmd = Executor::Spmd { threads: 4, overlap: true };

    // uninterrupted parallel run, 4 iterations
    let mut full = FssdpEngine::new_reference_layers(dims, layers, Topology::cluster_a(2, 2), 33);
    full.executor = spmd;
    full.run_span(0, 4, sources).unwrap();

    // interrupted: 2 parallel iterations, checkpoint, restore, 2 more
    let dir = std::env::temp_dir().join(format!("hecate-spmd-ckpt-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut head = FssdpEngine::new_reference_layers(dims, layers, Topology::cluster_a(2, 2), 33);
    head.executor = spmd;
    head.run_span(0, 2, sources).unwrap();
    hecate::checkpoint::save(&dir, &head.snapshot(2, sources), &head.topo).unwrap();

    let (state, saved) = hecate::checkpoint::load(&dir).unwrap();
    assert_eq!(state.step, 2);
    assert_eq!(state.num_layers(), layers);
    let (mut tail, plan) =
        FssdpEngine::resume_reference(Topology::cluster_a(2, 2), &state, saved.world()).unwrap();
    assert!(plan.kept_saved_layout, "same world size must reuse the saved layout");
    tail.executor = spmd;
    tail.run_span(state.step, 2, state.data_shards).unwrap();

    assert_eq!(chunks(&full), chunks(&tail), "resumed parallel run must be bit-identical");
    // …and the whole family collapses to the sequential trajectory
    let seq = run_layers(layers, Topology::cluster_a(2, 2), Executor::Sequential, 4, sources, 33);
    assert_eq!(chunks(&full), seq);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn parallel_loss_decreases() {
    let mut e =
        FssdpEngine::new_reference_layers(reference_dims(), 2, Topology::cluster_a(2, 4), 11);
    e.executor = Executor::spmd_for(&e.topo);
    let stats = e.run_span(0, 6, 8).unwrap();
    assert_eq!(stats.len(), 6);
    assert!(
        stats[5].loss < stats[0].loss,
        "loss {} -> {}",
        stats[0].loss,
        stats[5].loss
    );
}
