//! Integration: the SPMD parallel executor (one OS thread per rank, an
//! in-process communicator, overlapped sparse collectives with the §4.3
//! cross-layer pipeline) produces final expert parameters **bit-identical**
//! to the sequential engine at the same seed — on 2/4/8 threads, at L=1 and
//! L=3, and across a checkpoint/resume boundary. Hermetic: reference
//! backend, no artifacts or PJRT required. All runs go through the public
//! `Session` API (the engine constructors are crate-private).

use hecate::fssdp::{ComputeMode, Session, SessionConfig, SessionConfigBuilder};
use hecate::testing::{all_chunks, max_rel_err};
use hecate::topology::Topology;

/// Builder for an L-layer reference session; `spmd = Some((threads,
/// overlap))` selects the parallel executor.
fn cfg(
    layers: usize,
    topo: Topology,
    spmd: Option<(usize, bool)>,
    sources: usize,
    seed: u64,
) -> SessionConfigBuilder {
    let mut b = SessionConfig::builder()
        .reference()
        .topology(topo)
        .layers(layers)
        .seed(seed)
        .data_shards(sources);
    if let Some((threads, overlap)) = spmd {
        b = b.parallel(true).threads(threads).overlap(overlap);
    }
    b
}

fn run_layers(
    layers: usize,
    topo: Topology,
    spmd: Option<(usize, bool)>,
    iters: usize,
    sources: usize,
    seed: u64,
) -> Vec<Vec<f32>> {
    let mut s = Session::fresh(cfg(layers, topo, spmd, sources, seed).build().unwrap()).unwrap();
    s.run(iters).unwrap();
    all_chunks(s.engine())
}

fn run(
    topo: Topology,
    spmd: Option<(usize, bool)>,
    iters: usize,
    sources: usize,
    seed: u64,
) -> Vec<Vec<f32>> {
    run_layers(1, topo, spmd, iters, sources, seed)
}

#[test]
fn parallel_matches_sequential_on_2_4_8_threads() {
    for (nodes, dpn) in [(1usize, 2usize), (2, 2), (2, 4)] {
        let d = nodes * dpn;
        let seq = run(Topology::cluster_a(nodes, dpn), None, 4, d, 13);
        let par = run(Topology::cluster_a(nodes, dpn), Some((d, true)), 4, d, 13);
        assert_eq!(seq, par, "{d}-thread SPMD must be bit-identical to sequential");
    }
}

#[test]
fn l3_parallel_matches_sequential_on_2_4_8_threads() {
    // The multi-layer lock: 3 MoE layers, cross-layer pipelined overlap
    // on, must be bit-identical to the sequential oracle on every thread
    // count.
    for (nodes, dpn) in [(1usize, 2usize), (2, 2), (2, 4)] {
        let d = nodes * dpn;
        let seq = run_layers(3, Topology::cluster_a(nodes, dpn), None, 3, d, 17);
        let par = run_layers(3, Topology::cluster_a(nodes, dpn), Some((d, true)), 3, d, 17);
        assert_eq!(seq, par, "L=3 {d}-thread SPMD must be bit-identical to sequential");
    }
}

#[test]
fn l1_multilayer_engine_matches_seed_trajectory_across_executors() {
    // The seed-behavior lock, executor edition: an L=1 engine must produce
    // one single trajectory regardless of executor or overlap mode (the
    // in-module test `fssdp::tests::l1_step_matches_seed_oracle_bitwise`
    // pins that trajectory to the seed engine's transcribed step body).
    let seq = run(Topology::cluster_a(2, 2), None, 4, 4, 29);
    for overlap in [false, true] {
        let par = run(Topology::cluster_a(2, 2), Some((4, overlap)), 4, 4, 29);
        assert_eq!(seq, par, "L=1 SPMD (overlap={overlap}) must match the seed trajectory");
    }
}

#[test]
fn l3_parallel_with_resharding_matches_sequential() {
    // Algorithm 2 re-runs inside the numeric span (--reshard-every); the
    // re-shard happens on merged engine state, so both executors must stay
    // bit-identical through chunk migrations.
    let mk = |spmd: Option<(usize, bool)>| -> Vec<Vec<f32>> {
        let mut s = Session::fresh(
            cfg(3, Topology::cluster_a(2, 2), spmd, 4, 31).reshard_every(2).build().unwrap(),
        )
        .unwrap();
        s.run(5).unwrap();
        all_chunks(s.engine())
    };
    let seq = mk(None);
    let par = mk(Some((4, true)));
    assert_eq!(seq, par, "re-sharded L=3 run must be bit-identical across executors");
}

#[test]
fn parallel_matches_single_device_reference_within_tolerance() {
    // The fssdp_equivalence guarantee carries over to the parallel
    // executor: 8 distributed ranks vs the all-local 1-device oracle at
    // the established 2e-3 tolerance (placement freedom, not bit-equality,
    // is what differs here — reduction orders depend on the placement).
    let par = run(Topology::cluster_a(2, 4), Some((8, true)), 3, 4, 7);
    let refr = run(Topology::flat(1, 1e9), None, 3, 4, 7);
    assert_eq!(par.len(), refr.len());
    for (e, (d, r)) in par.iter().zip(refr.iter()).enumerate() {
        let err = max_rel_err(d, r);
        assert!(err < 2e-3, "expert {e}: max rel err {err}");
    }
}

#[test]
fn parallel_resume_from_checkpoint_is_bit_identical() {
    let sources = 4;
    let layers = 3;
    let spmd = Some((4usize, true));

    // uninterrupted parallel run, 4 iterations
    let mut full =
        Session::fresh(cfg(layers, Topology::cluster_a(2, 2), spmd, sources, 33).build().unwrap())
            .unwrap();
    full.run(4).unwrap();

    // interrupted: 2 parallel iterations, checkpoint, restore, 2 more
    let dir = std::env::temp_dir().join(format!("hecate-spmd-ckpt-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut head =
        Session::fresh(cfg(layers, Topology::cluster_a(2, 2), spmd, sources, 33).build().unwrap())
            .unwrap();
    head.run(2).unwrap();
    head.checkpoint_to(&dir).unwrap();

    let (state, _) = hecate::checkpoint::load(&dir).unwrap();
    assert_eq!(state.step, 2);
    assert_eq!(state.num_layers(), layers);
    let mut tail = Session::resume(
        cfg(layers, Topology::cluster_a(2, 2), spmd, sources, 33).build().unwrap(),
        &dir,
    )
    .unwrap();
    let report = tail.resume_report().unwrap().clone();
    assert!(report.kept_saved_layout, "same world size must reuse the saved layout");
    assert_eq!(tail.step(), 2);
    assert_eq!(tail.data_shards(), sources);
    tail.run(2).unwrap();

    assert_eq!(
        all_chunks(full.engine()),
        all_chunks(tail.engine()),
        "resumed parallel run must be bit-identical"
    );
    // …and the whole family collapses to the sequential trajectory
    let seq = run_layers(layers, Topology::cluster_a(2, 2), None, 4, sources, 33);
    assert_eq!(all_chunks(full.engine()), seq);
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Bitwise comparison of two f32 buffers (plain `==` would conflate
/// `-0.0` and `0.0`, and the locks here are about *bits*).
fn same_bits(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

#[test]
fn rank_kernel_pools_preserve_params_moments_and_loss_bits() {
    // The per-rank kernel worker pool (compute_threads on the SPMD
    // executor) must be invisible in Reference mode: final parameters,
    // Adam moments, *and* the per-step loss bits all match the
    // single-threaded run at every pool width. The Adam moments come out
    // through a checkpoint snapshot — `all_chunks` only sees parameters.
    let snapshot = |kthreads: usize| {
        let mut s = Session::fresh(
            cfg(2, Topology::cluster_a(2, 2), Some((4, true)), 4, 41)
                .compute_threads(kthreads)
                .build()
                .unwrap(),
        )
        .unwrap();
        let stats = s.run(3).unwrap();
        let losses: Vec<u64> = stats.iter().map(|st| st.loss.to_bits()).collect();
        let dir = std::env::temp_dir()
            .join(format!("hecate-spmd-kpool-{}-{kthreads}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        s.checkpoint_to(&dir).unwrap();
        let (state, _) = hecate::checkpoint::load(&dir).unwrap();
        std::fs::remove_dir_all(&dir).unwrap();
        (losses, state)
    };

    let (base_losses, base) = snapshot(1);
    for kthreads in [2usize, 4] {
        let (losses, state) = snapshot(kthreads);
        assert_eq!(base_losses, losses, "loss bits must not depend on the pool width");
        assert_eq!(base.layers.len(), state.layers.len());
        for (l, (lb, ls)) in base.layers.iter().zip(state.layers.iter()).enumerate() {
            for (e, (eb, es)) in lb.experts.iter().zip(ls.experts.iter()).enumerate() {
                assert!(
                    same_bits(&eb.chunk, &es.chunk),
                    "layer {l} expert {e}: params drift at compute_threads={kthreads}"
                );
                assert!(
                    same_bits(&eb.m, &es.m) && same_bits(&eb.v, &es.v) && eb.t == es.t,
                    "layer {l} expert {e}: Adam moments drift at compute_threads={kthreads}"
                );
            }
        }
    }
}

#[test]
fn fast_mode_spmd_is_reproducible_across_runs_and_pool_widths() {
    // Fast-tier kernels reorder float accumulation vs Reference, but the
    // per-key work is self-contained and merged in expert order — so two
    // identical runs are bit-equal, and so are runs at different kernel
    // pool widths.
    let run_fast = |kthreads: usize| {
        let mut s = Session::fresh(
            cfg(2, Topology::cluster_a(2, 2), Some((4, true)), 4, 43)
                .compute_mode(ComputeMode::Fast)
                .compute_threads(kthreads)
                .build()
                .unwrap(),
        )
        .unwrap();
        s.run(3).unwrap();
        all_chunks(s.engine())
    };
    let a = run_fast(2);
    let b = run_fast(2);
    assert_eq!(a.len(), b.len());
    for (e, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        assert!(same_bits(x, y), "expert {e}: Fast SPMD must be run-to-run deterministic");
    }
    let c = run_fast(4);
    for (e, (x, y)) in a.iter().zip(c.iter()).enumerate() {
        assert!(same_bits(x, y), "expert {e}: Fast SPMD must be pool-width invariant");
    }
}

#[test]
fn parallel_loss_decreases() {
    let mut s =
        Session::fresh(cfg(2, Topology::cluster_a(2, 4), Some((8, true)), 8, 11).build().unwrap())
            .unwrap();
    let stats = s.run(6).unwrap();
    assert_eq!(stats.len(), 6);
    assert!(
        stats[5].loss < stats[0].loss,
        "loss {} -> {}",
        stats[0].loss,
        stats[5].loss
    );
}
