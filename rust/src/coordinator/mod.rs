//! L3 coordinator CLI: subcommand dispatch for the `hecate` binary.
//!
//! ```text
//! hecate repro     --figure 9|10|11|12|13|14|15a|15b | --table 1 | --claims | --all
//!                  [--numeric]                       (fig 11/15b: numeric-engine rows)
//! hecate simulate  --cluster a|b --model gpt-moe-s --system hecate [--nodes 4 --dpn 8]
//!                  [--fail-step K --fail-device D --checkpoint-every N]   (fault injection)
//! hecate train     --model e2e --steps 200 [--artifacts DIR]   (runs PJRT)
//!                  [--checkpoint-every N --checkpoint-dir DIR] [--resume DIR]
//! hecate fssdp     --devices 8 --iters 20                      (numeric engine)
//!                  [--layers L] [--reshard-every K]            (multi-layer stack)
//!                  [--checkpoint-every N --checkpoint-dir DIR] [--resume DIR] [--reference]
//!                  [--parallel [--threads N]] [--pacing a,b]   (SPMD executor)
//!                  [--racks R] [--pacing-topo SCALE]           (tiered topology + pacing)
//!                  [--transport inproc|socket] [--recv-timeout S]   (SPMD rank transport)
//!                  [--verify-inproc] [--worker-dir DIR]        (socket launcher extras)
//!                  [--compute-threads T]       (threaded expert loops, both executors)
//!                  [--compute-mode ref|fast]   (bitwise oracle vs fast-math kernels)
//!                  [--trace-out DIR]           (per-rank Chrome trace + JSONL events)
//!                  [--metrics-out DIR]         (memory ledger + load observatory export)
//! hecate worker    --rank R --world N --listen ADDR --peers A0,..,AN-1 --out FILE
//!                  (one SPMD rank as its own process; spawned by `fssdp --transport socket`)
//! hecate checkpoint --dir DIR [--devices N --iters K]          (hermetic snapshot demo)
//! hecate resume     --dir DIR [--devices M --iters K]          (elastic resume demo)
//! hecate trace analyze DIR                    (critical path / overlap / stragglers)
//! hecate metrics report DIR                   (peak memory / predictor accuracy / imbalance)
//! hecate analyze schedule [--devices N --nodes N --racks R --layers L --iters K]
//!                  [--reshard-every K] [--transport inproc|socket] [--overlap BOOL]
//!                  [--inject drop-recv|swap-barrier|oversize-frame|double-own]
//!                  (static deadlock/match/wire/resource verification, no execution)
//! hecate bench spmd [--iters N --quick] [--transport socket]   (thread scaling + overlap)
//!                  [--compute-mode ref|fast] [--compute-threads T]   (kernel tier + pool)
//! hecate bench step [--iters N --quick --json --compute-threads T]  (per-phase step times)
//!                  [--compute-mode ref|fast]   (tier to gate on; default fast)
//!                  [--check [--gate-tol F]]   (CI perf gate vs committed baseline)
//! ```
//!
//! The `fssdp`/`checkpoint`/`resume` subcommands are thin shells over the
//! library's [`Session`] API: flags map onto a
//! [`SessionConfig`](crate::fssdp::SessionConfig) builder (one shared
//! validation path — the CLI has no checks of its own), and the console
//! output is a [`PrintObserver`] attached to the run.

use std::path::Path;

use crate::checkpoint::faults::FaultSpec;
use crate::config::{ClusterPreset, ModelConfig, SystemConfig, SystemKind, TrainConfig};
use crate::fssdp::{self, Executor, PrintObserver, Session, SessionConfig, StepObserver};
use crate::sim::engine::{simulate, simulate_with_faults};
use crate::sim::report;
use crate::spmd::transport::TransportKind;
use crate::util::cli::Args;

/// Entry point called by `main`.
pub fn run(argv: Vec<String>) -> anyhow::Result<()> {
    crate::util::logging::init();
    let Some((cmd, rest)) = argv.split_first() else {
        print_usage();
        return Ok(());
    };
    let args = Args::parse(rest.iter().cloned());
    match cmd.as_str() {
        "repro" => cmd_repro(&args),
        "simulate" => cmd_simulate(&args),
        "train" => cmd_train(&args),
        "fssdp" => cmd_fssdp(&args),
        "worker" => crate::spmd::worker::cmd_worker(&args),
        "checkpoint" => cmd_checkpoint(&args),
        "resume" => cmd_resume(&args),
        "trace" => cmd_trace(&args),
        "metrics" => cmd_metrics(&args),
        "analyze" => cmd_analyze(&args),
        "bench" => cmd_bench(&args),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => {
            print_usage();
            anyhow::bail!("unknown command `{other}`")
        }
    }
}

fn print_usage() {
    eprintln!(
        "hecate — FSSDP MoE training (paper reproduction)\n\
         USAGE:\n  hecate repro    [--figure N | --table 1 | --claims | --all] [--iters N] [--numeric]\n  \
         hecate simulate --cluster a|b --model NAME --system NAME [--nodes N --dpn N --batch N]\n                  \
         [--fail-step K --fail-device D --checkpoint-every N --detect-s S --disk-gbps G]\n  \
         hecate train    [--steps N] [--artifacts DIR] [--model tiny|e2e] [--log FILE]\n                  \
         [--checkpoint-every N --checkpoint-dir DIR] [--resume DIR]\n  \
         hecate fssdp    [--devices N] [--iters N] [--artifacts DIR] [--reference]\n                  \
         [--layers L] [--reshard-every K]   (multi-layer MoE stack, Algorithm 2 cadence)\n                  \
         [--checkpoint-every N --checkpoint-dir DIR] [--resume DIR]\n                  \
         [--parallel [--threads N]]   (SPMD executor: one thread per rank)\n                  \
         [--pacing ALPHA,BETA]   (SPMD α–β link pacing: latency s, s/byte)\n                  \
         [--racks R] [--pacing-topo SCALE]   (rack tier + topology-derived pacing)\n                  \
         [--transport inproc|socket] [--recv-timeout S]   (SPMD rank transport)\n                  \
         [--verify-inproc] [--worker-dir DIR]   (socket: bit-compare vs in-proc, keep logs)\n                  \
         [--compute-threads T]   (threaded expert loops, both executors; Reference stays bit-identical)\n                  \
         [--compute-mode ref|fast]   (bitwise oracle vs fast-math kernels)\n                  \
         [--trace-out DIR]   (write per-rank Chrome trace + JSONL events to DIR)\n                  \
         [--metrics-out DIR]   (write the memory ledger + load observatory to DIR)\n  \
         hecate worker   --rank R --world N --listen ADDR --peers A0,..,AN-1 --out FILE\n                  \
         (one SPMD rank as its own process; spawned by `fssdp --transport socket`)\n  \
         hecate checkpoint --dir DIR [--nodes N --devices N --layers L --iters K --seed S]\n  \
         hecate resume     --dir DIR [--nodes N --devices M --iters K]\n  \
         hecate trace analyze DIR   (critical path, overlap efficiency, straggler report)\n  \
         hecate metrics report DIR   (peak-memory, predictor-accuracy, imbalance tables)\n  \
         hecate analyze schedule [--devices N] [--nodes N] [--racks R] [--layers L]\n                  \
         [--iters K] [--reshard-every K] [--transport inproc|socket] [--overlap BOOL]\n                  \
         [--inject drop-recv|swap-barrier|oversize-frame|double-own]\n                  \
         (static schedule verification: match completeness, deadlock freedom,\n                  \
         wire safety, resource discipline — nonzero exit on any violation)\n  \
         hecate bench spmd [--iters N] [--quick] [--transport socket]\n                  \
         [--compute-mode ref|fast] [--compute-threads T]   (thread scaling + overlap)\n  \
         hecate bench step [--iters N] [--quick] [--json] [--compute-threads T]\n                  \
         [--compute-mode ref|fast] [--check [--gate-tol F]]   (per-phase step times;\n                  \
         --json writes BENCH_runtime_step.json with the Fast-vs-Reference speedup\n                  \
         and divergence bound; --check gates on the committed baseline)"
    );
}

fn cmd_repro(args: &Args) -> anyhow::Result<()> {
    args.reject_unknown(&["figure", "table", "claims", "all", "iters", "numeric"])?;
    let mut opts = report::default_opts();
    opts.iterations = args.usize_or("iters", opts.iterations)?;
    let all = args.has("all");
    let numeric = args.bool_or("numeric", false)?;
    let fig = args.str_or("figure", "")?;
    let table = args.str_or("table", "")?;

    if all || table == "1" {
        println!("\n== Table 1: model architectures ==");
        print!("{}", report::table1().to_markdown());
    }
    if all || fig == "3" {
        println!("\n== Figure 3: expert load distribution over iterations ==");
        print!("{}", report::figure3(30).to_markdown());
    }
    if all || fig == "9" {
        println!("\n== Figure 9: end-to-end speedup vs EP, Cluster A ==");
        for (t, label) in report::figure9(&opts).into_iter().zip(["16 GPUs", "32 GPUs"]) {
            println!("-- {label} --");
            print!("{}", t.to_markdown());
        }
    }
    if all || fig == "10" {
        println!("\n== Figure 10: end-to-end speedup vs EP, Cluster B (32 GPUs) ==");
        print!("{}", report::figure10(&opts).to_markdown());
    }
    if all || fig == "11" {
        println!("\n== Figure 11: layer-wise MoE speedup (GPT-MoE-S, Cluster B) ==");
        print!("{}", report::figure11(&opts).to_markdown());
        if numeric || all {
            println!("\n== Figure 11 (numeric engine): per-layer exposed materialization ==");
            print!("{}", report::numeric_figure11(3, 3)?.to_markdown());
        }
    }
    if all || fig == "12" {
        println!("\n== Figure 12: critical-path breakdown (BERT-MoE-Deep, Cluster B) ==");
        print!("{}", report::figure12(&opts).to_markdown());
    }
    if all || fig == "13" {
        println!("\n== Figure 13: peak MoE memory per device ==");
        print!("{}", report::figure13(&opts).to_markdown());
    }
    if all || fig == "14" {
        println!("\n== Figure 14: batch-size scaling (GPT-MoE-S, Cluster A) ==");
        print!("{}", report::figure14(&opts).to_markdown());
    }
    if all || fig == "15a" || fig == "15" {
        println!("\n== Figure 15a: component ablation ==");
        print!("{}", report::figure15a(&opts).to_markdown());
    }
    if all || fig == "15b" || fig == "15" {
        println!("\n== Figure 15b: re-sharding interval sweep ==");
        print!("{}", report::figure15b(&opts).to_markdown());
        if numeric || all {
            println!("\n== Figure 15b (numeric engine): executed re-sharding interval sweep ==");
            print!("{}", report::numeric_figure15b(3, 6)?.to_markdown());
        }
    }
    if all || args.has("claims") {
        for (name, t) in report::claims(&opts) {
            println!("\n== Claim: {name} ==");
            print!("{}", t.to_markdown());
        }
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    args.reject_unknown(&[
        "cluster", "model", "system", "nodes", "dpn", "batch", "iters", "seed", "experts",
        "fail-step", "fail-device", "checkpoint-every", "detect-s", "disk-gbps",
    ])?;
    let cluster = ClusterPreset::parse(&args.str_or("cluster", "a")?)?;
    let nodes = args.usize_or("nodes", 4)?;
    let dpn = args.usize_or("dpn", 8)?;
    let topo = cluster.build(nodes, dpn);
    let mut model = ModelConfig::preset(&args.str_or("model", "gpt-moe-s")?)?;
    if let Some(e) = args.str_opt("experts")? {
        model = model.with_experts(e.parse()?);
    }
    let system = SystemKind::parse(&args.str_or("system", "hecate")?)?;
    let batch = args.usize_or("batch", report::paper_batch(&model))?;
    let train = TrainConfig { batch_per_device: batch, ..Default::default() };
    let mut opts = report::default_opts();
    opts.iterations = args.usize_or("iters", opts.iterations)?;
    opts.seed = args.usize_or("seed", opts.seed as usize)? as u64;

    let sys_cfg = SystemConfig::new(system);

    // Fault-injection mode: kill a device, restart, replay from snapshot.
    if args.has("fail-step") {
        // Clamp once here so the headline numbers, the printed failure
        // line, and the interval-sweep table all describe the same step.
        let spec = FaultSpec {
            fail_step: args
                .usize_or("fail-step", 50)?
                .min(opts.iterations.saturating_sub(1)),
            fail_device: args.usize_or("fail-device", 0)?,
            checkpoint_every: args.usize_or("checkpoint-every", 25)?,
            detect_time: args.f64_or("detect-s", 5.0)?,
            disk_bw: args.f64_or("disk-gbps", 2.0)? * 1e9,
        };
        let r = simulate_with_faults(&topo, &model, &sys_cfg, &train, &opts, &spec);
        println!("system     : {} (fault injection)", r.sim.system);
        println!("topology   : {}", topo.name);
        let every = if spec.checkpoint_every == 0 {
            "never".to_string()
        } else {
            spec.checkpoint_every.to_string()
        };
        println!(
            "failure    : device {} at step {} (snapshot every {})",
            spec.fail_device % topo.num_devices().max(1),
            spec.fail_step,
            every
        );
        println!("iter time  : {:.2} ms", r.sim.iter_time * 1e3);
        let rec = &r.recovery;
        println!(
            "snapshot   : {:.2} GB in {:.2} s ({:.2}% steady overhead)",
            rec.checkpoint_bytes / 1e9,
            rec.checkpoint_time,
            100.0 * rec.steady_overhead / r.sim.iter_time.max(1e-12)
        );
        println!(
            "MTTR       : {:.2} s = detect {:.2} + restore {:.2} + redistribute {:.2} + replay {:.2} ({} iters)",
            rec.mttr, rec.detect, rec.restore_io, rec.redistribute, rec.replay, rec.replay_iters
        );
        println!(
            "wall clock : {:.2} s vs ideal {:.2} s ({:.2}x)",
            r.total_wall_clock,
            r.ideal_wall_clock,
            r.slowdown()
        );
        println!("\n== Recovery time vs snapshot interval ==");
        let t = report::recovery_table(&topo, &model, r.sim.iter_time, &spec);
        print!("{}", t.to_markdown());
        return Ok(());
    }

    let r = simulate(&topo, &model, &sys_cfg, &train, &opts);
    println!("system     : {}", r.system);
    println!("topology   : {}", topo.name);
    println!("model      : {} ({} experts, batch {})", model.name, model.experts, batch);
    println!("iter time  : {:.2} ms", r.iter_time * 1e3);
    let b = &r.breakdown;
    println!(
        "breakdown  : attn {:.2} ms | expert {:.2} ms | a2a {:.2} ms | exposed-comm {:.2} ms | rearr {:.2} ms",
        b.attn * 1e3,
        b.expert * 1e3,
        b.a2a * 1e3,
        b.exposed_comm * 1e3,
        b.rearrange * 1e3
    );
    println!(
        "moe memory : params {:.2} GB | grads {:.2} GB | opt {:.2} GB",
        r.memory.params / 1e9,
        r.memory.grads / 1e9,
        r.memory.opt / 1e9
    );
    Ok(())
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    args.reject_unknown(&[
        "steps", "artifacts", "model", "log", "lr", "seed", "checkpoint-every",
        "checkpoint-dir", "resume",
    ])?;
    let steps = args.usize_or("steps", 200)?;
    let dir = args.str_or("artifacts", "artifacts")?;
    let tag = args.str_or("model", "tiny")?;
    let log = args.str_opt("log")?;
    let ckpt = crate::train::CkptOpts {
        every: args.usize_or("checkpoint-every", 0)?,
        dir: args.str_opt("checkpoint-dir")?,
        resume: args.str_opt("resume")?,
    };
    crate::train::run_training_with(&dir, &tag, steps, log.as_deref(), &ckpt)
}

fn cmd_fssdp(args: &Args) -> anyhow::Result<()> {
    args.reject_unknown(&[
        "devices", "iters", "artifacts", "nodes", "racks", "seed", "layers", "reshard-every",
        "checkpoint-every", "checkpoint-dir", "resume", "reference", "parallel", "threads",
        "pacing", "pacing-topo", "transport", "recv-timeout", "verify-inproc", "worker-dir",
        "compute-threads", "compute-mode", "trace-out", "metrics-out",
    ])?;
    let mut b = SessionConfig::builder()
        .cluster(args.usize_or("nodes", 2)?, args.usize_or("devices", 8)?)
        .seed(args.usize_or("seed", 42)? as u64)
        .parallel(args.bool_or("parallel", false)?)
        .checkpoint_every(args.usize_or("checkpoint-every", 0)?);
    b = if args.bool_or("reference", false)? {
        b.reference()
    } else {
        b.pjrt(&args.str_or("artifacts", "artifacts")?)
    };
    if args.has("threads") {
        b = b.threads(args.usize_or("threads", 0)?);
    }
    if args.has("compute-threads") {
        b = b.compute_threads(args.usize_or("compute-threads", 1)?);
    }
    if let Some(m) = args.str_opt("compute-mode")? {
        b = b.compute_mode(fssdp::parse_compute_mode(&m)?);
    }
    if args.has("layers") {
        b = b.layers(args.usize_or("layers", 1)?);
    }
    if args.has("reshard-every") {
        b = b.reshard_every(args.usize_or("reshard-every", 0)?);
    }
    if args.has("racks") {
        b = b.racks(args.usize_or("racks", 1)?);
    }
    if let Some(p) = args.str_opt("pacing")? {
        b = b.pacing(fssdp::parse_pacing(&p)?);
    }
    if let Some(s) = args.str_opt("pacing-topo")? {
        b = b.pacing_topo(fssdp::parse_pacing_scale(&s)?);
    }
    if let Some(t) = args.str_opt("transport")? {
        b = b.transport(fssdp::parse_transport(&t)?);
    }
    if let Some(t) = args.str_opt("recv-timeout")? {
        b = b.recv_timeout(fssdp::parse_recv_timeout(&t)?);
    }
    if let Some(d) = args.str_opt("checkpoint-dir")? {
        b = b.checkpoint_dir(d);
    }
    if let Some(d) = args.str_opt("trace-out")? {
        b = b.trace_out(d);
    }
    if let Some(d) = args.str_opt("metrics-out")? {
        b = b.metrics_out(d);
    }
    let resume = args.str_opt("resume")?;
    let iters = args.usize_or("iters", 10)?;
    let verify_inproc = args.bool_or("verify-inproc", false)?;
    let worker_dir = args.str_opt("worker-dir")?;
    let cfg = b.build()?;
    if cfg.transport() == TransportKind::Socket {
        // The process launcher runs fresh spans only: span-boundary logic
        // (checkpoints, resume, re-sharding, telemetry export) lives on the
        // coordinator engine, which the worker processes replace.
        anyhow::ensure!(
            resume.is_none(),
            "--resume is not supported with --transport socket (the process launcher runs \
             fresh spans only)"
        );
        anyhow::ensure!(
            cfg.checkpoint_every() == 0 && cfg.checkpoint_dir().is_none(),
            "--checkpoint-every/--checkpoint-dir are not supported with --transport socket"
        );
        anyhow::ensure!(
            cfg.telemetry().trace_dir.is_none() && cfg.telemetry().metrics_dir.is_none(),
            "--trace-out/--metrics-out are not supported with --transport socket \
             (telemetry export stays within one process)"
        );
        anyhow::ensure!(
            !args.has("reshard-every"),
            "--reshard-every is not supported with --transport socket (re-sharding is a \
             span-boundary operation on the coordinator engine)"
        );
        return crate::spmd::worker::launch_local(&cfg, iters, verify_inproc, worker_dir);
    }
    anyhow::ensure!(
        !verify_inproc && worker_dir.is_none(),
        "--verify-inproc/--worker-dir require --transport socket"
    );
    run_fssdp_session(cfg, resume, iters)
}

/// Shared driver of the `fssdp`/`checkpoint`/`resume` subcommands: enter a
/// [`Session`] (fresh or resumed), attach the console observer, run, and
/// print the run summary.
fn run_fssdp_session(
    cfg: SessionConfig,
    resume: Option<String>,
    iters: usize,
) -> anyhow::Result<()> {
    let trace_dir = cfg.telemetry().trace_dir.clone();
    let metrics_dir = cfg.telemetry().metrics_dir.clone();
    println!(
        "FSSDP numeric engine on {} ({} devices)",
        cfg.topology().name,
        cfg.topology().num_devices()
    );
    let mut session = match &resume {
        None => Session::fresh(cfg)?,
        Some(dir) => {
            let s = Session::resume(cfg, Path::new(dir))?;
            let r = s.resume_report().expect("resumed sessions carry a report");
            println!(
                "resumed step {} from {dir}: {} -> {} devices, {} layers, {} experts moved \
                 ({:.2} MB), {}",
                r.step,
                r.old_world,
                r.new_world,
                r.layers,
                r.moved_experts,
                r.bytes_moved as f64 / 1e6,
                if r.kept_saved_layout { "layout kept" } else { "re-sharded (Algorithm 2)" },
            );
            s
        }
    };
    let e = session.engine();
    println!(
        "stack: {} layer(s) x {} experts, d_model {}, d_ffn {}, {} tokens/source, cap {} \
         (backend: {}, {}, reshard every {})",
        e.num_layers(),
        e.dims.experts,
        e.dims.d_model,
        e.dims.d_ffn,
        e.dims.tokens,
        e.dims.cap,
        e.backend(),
        match session.executor() {
            Executor::Sequential => "sequential".to_string(),
            Executor::Spmd { threads, .. } => format!("spmd x{threads}"),
        },
        if session.reshard_every() == 0 {
            "never".to_string()
        } else {
            session.reshard_every().to_string()
        }
    );

    // Compose the observer set: console always, plus the trace and
    // metrics writers when their export directories are configured.
    let mut console = PrintObserver;
    let mut trace_writer = trace_dir.as_deref().map(crate::telemetry::TraceWriter::new);
    let mut metrics_writer =
        metrics_dir.as_deref().map(crate::telemetry::metrics_io::MetricsWriter::new);
    {
        let mut observers: Vec<&mut dyn StepObserver> = vec![&mut console];
        if let Some(w) = trace_writer.as_mut() {
            observers.push(w);
        }
        if let Some(w) = metrics_writer.as_mut() {
            observers.push(w);
        }
        session.run_observed(iters, &mut observers)?;
    }
    if let (Some(w), Some(dir)) = (&trace_writer, trace_dir.as_deref()) {
        println!(
            "trace: {} events -> {dir}/{{{}, {}}} (load {}/{} in Perfetto / \
             chrome://tracing; `hecate trace analyze {dir}` for the report)",
            w.exported(),
            crate::telemetry::CHROME_TRACE_FILE,
            crate::telemetry::EVENTS_FILE,
            dir,
            crate::telemetry::CHROME_TRACE_FILE,
        );
    }
    if let (Some(w), Some(dir)) = (&metrics_writer, metrics_dir.as_deref()) {
        println!(
            "metrics: {} samples -> {dir}/{{{}, {}, {}}} (`hecate metrics report {dir}` \
             for the tables)",
            w.exported(),
            crate::telemetry::metrics_io::METRICS_JSONL_FILE,
            crate::telemetry::metrics_io::METRICS_PROM_FILE,
            crate::telemetry::metrics_io::COUNTERS_FILE,
        );
    }
    if session.reshards_moved() > 0 {
        println!("re-shards moved {} expert(s) in total", session.reshards_moved());
    }
    if let Some(m) = session.spmd_metrics() {
        println!(
            "spmd: compute {:?} | spag wait {:?} | gate+exchange {:?} | combine {:?} | sprs {:?} (summed over ranks)",
            m.timer("spmd.compute"),
            m.timer("spmd.spag_wait"),
            m.timer("spmd.gate"),
            m.timer("spmd.combine"),
            m.timer("spmd.sprs")
        );
    }
    // Final snapshot when a checkpoint dir is configured and the boundary
    // loop has not just written one — printed with the legacy "final
    // checkpoint" marker rather than the periodic observer line.
    if let Some(info) = session.finish(&mut [])? {
        println!("final checkpoint @ step {} -> {}", session.step(), info.dir.display());
    }
    println!("done — parameters live on their shard owners (one global copy).");
    Ok(())
}

/// Measured-performance sweeps. `hecate bench spmd` runs the reference
/// engine sequentially and on the SPMD executor across thread counts
/// (modeled comm time next to measured wall clock), then sweeps the layer
/// stack with the §4.3 cross-layer overlap scheduler on vs off under α–β
/// link pacing.
fn cmd_bench(args: &Args) -> anyhow::Result<()> {
    let target = args
        .str_opt("target")?
        .or_else(|| args.positional.first().cloned())
        .unwrap_or_else(|| "spmd".to_string());
    match target.as_str() {
        "spmd" => {
            // per-target allow-list: step-only flags must error here, not
            // silently no-op
            args.reject_unknown(&[
                "iters", "quick", "target", "transport", "compute-mode", "compute-threads",
            ])?;
            let iters = args.usize_or("iters", 3)?;
            let quick = args.bool_or("quick", false)?;
            let transport = match args.str_opt("transport")? {
                Some(t) => fssdp::parse_transport(&t)?,
                None => TransportKind::InProc,
            };
            let mode = match args.str_opt("compute-mode")? {
                Some(m) => fssdp::parse_compute_mode(&m)?,
                None => fssdp::ComputeMode::Reference,
            };
            let kthreads = args.usize_or("compute-threads", 1)?;
            println!(
                "== SPMD thread scaling ({}, {} kernels): modeled comm vs measured wall \
                 clock ==",
                transport.as_str(),
                mode.as_str()
            );
            let t = report::spmd_scaling(iters, quick, transport, mode, kthreads)?;
            print!("{}", t.to_markdown());
            println!("\n== Cross-layer overlap (paced links): wall clock on vs off ==");
            let t = report::spmd_overlap(iters, quick)?;
            print!("{}", t.to_markdown());
            Ok(())
        }
        "step" => {
            args.reject_unknown(&[
                "iters", "quick", "target", "json", "compute-threads", "compute-mode", "check",
                "gate-tol",
            ])?;
            let iters = args.usize_or("iters", 8)?;
            let quick = args.bool_or("quick", false)?;
            let threads = args.usize_or("compute-threads", 4)?;
            // the bench's default tier under test is Fast — `bench step
            // --json` then reports the Fast-vs-Reference speedup and
            // divergence without extra flags, and `--check` gates the
            // Fast tier against the committed Reference baseline
            let mode = match args.str_opt("compute-mode")? {
                Some(m) => fssdp::parse_compute_mode(&m)?,
                None => fssdp::ComputeMode::Fast,
            };
            let json = args.bool_or("json", false)?;
            let check = if args.bool_or("check", false)? {
                Some(args.f64_or("gate-tol", 0.25)?)
            } else {
                None
            };
            if args.has("gate-tol") && check.is_none() {
                anyhow::bail!("--gate-tol requires --check");
            }
            println!(
                "== Runtime step (hermetic backends, 8 devices x 3 layers): per-phase =="
            );
            let t = report::bench_step(iters, quick, threads, mode, json, check)?;
            print!("{}", t.to_markdown());
            Ok(())
        }
        other => anyhow::bail!("unknown bench target `{other}` (available: spmd, step)"),
    }
}

/// Hermetic checkpoint demo: train the reference engine for `--iters`
/// steps and write a sharded checkpoint to `--dir`. No artifacts needed.
fn cmd_checkpoint(args: &Args) -> anyhow::Result<()> {
    args.reject_unknown(&["dir", "nodes", "devices", "layers", "iters", "seed"])?;
    let mut b = SessionConfig::builder()
        .reference()
        .cluster(args.usize_or("nodes", 2)?, args.usize_or("devices", 4)?)
        .seed(args.usize_or("seed", 42)? as u64)
        .checkpoint_dir(args.req("dir")?);
    if args.has("layers") {
        b = b.layers(args.usize_or("layers", 1)?);
    }
    run_fssdp_session(b.build()?, None, args.usize_or("iters", 4)?)
}

/// Hermetic elastic-resume demo: restore `--dir` onto `--devices` devices
/// (any count — the planner re-shards jointly over all layers) and
/// continue for `--iters` steps.
fn cmd_resume(args: &Args) -> anyhow::Result<()> {
    args.reject_unknown(&["dir", "nodes", "devices", "iters"])?;
    let dir = args.req("dir")?;
    let cfg = SessionConfig::builder()
        .reference()
        .cluster(args.usize_or("nodes", 1)?, args.usize_or("devices", 2)?)
        .build()?;
    run_fssdp_session(cfg, Some(dir), args.usize_or("iters", 4)?)
}

/// `hecate trace analyze DIR`: offline report over a `--trace-out`
/// directory — per-step critical path, §4.3 overlap efficiency, and the
/// per-rank straggler table.
fn cmd_trace(args: &Args) -> anyhow::Result<()> {
    args.reject_unknown(&["dir"])?;
    let action = args.positional.first().cloned().unwrap_or_default();
    anyhow::ensure!(
        action == "analyze",
        "unknown trace action `{action}` (usage: hecate trace analyze DIR)"
    );
    let dir = args
        .str_opt("dir")?
        .or_else(|| args.positional.get(1).cloned())
        .ok_or_else(|| {
            anyhow::anyhow!("trace analyze expects a directory (--trace-out of a previous run)")
        })?;
    let a = crate::telemetry::analyze::analyze_dir(Path::new(&dir))?;
    println!("== Trace analysis: {dir} ==");
    println!("\n-- per-step critical path --");
    print!("{}", a.steps_table().to_markdown());
    println!("\n-- per-rank straggler report --");
    print!("{}", a.straggler_table().to_markdown());
    println!("\n{}", a.summary());
    Ok(())
}

/// `hecate metrics report DIR`: offline report over a `--metrics-out`
/// directory — the per-rank peak-memory table (measured vs analytic
/// baselines), the predictor-accuracy table, and the imbalance timeline.
fn cmd_metrics(args: &Args) -> anyhow::Result<()> {
    args.reject_unknown(&["dir"])?;
    let action = args.positional.first().cloned().unwrap_or_default();
    anyhow::ensure!(
        action == "report",
        "unknown metrics action `{action}` (usage: hecate metrics report DIR)"
    );
    let dir = args
        .str_opt("dir")?
        .or_else(|| args.positional.get(1).cloned())
        .ok_or_else(|| {
            anyhow::anyhow!(
                "metrics report expects a directory (--metrics-out of a previous run)"
            )
        })?;
    let log = crate::telemetry::metrics_io::load_metrics(Path::new(&dir))?;
    println!("== Metrics report: {dir} ==\n");
    print!("{}", log.peak_memory_table());
    println!();
    print!("{}", log.predictor_table());
    println!();
    print!("{}", log.imbalance_timeline());
    // Round-trip the Prometheus exposition through the parser when it is
    // present — the export sanity check CI leans on.
    let prom = Path::new(&dir).join(crate::telemetry::metrics_io::METRICS_PROM_FILE);
    if let Ok(text) = std::fs::read_to_string(&prom) {
        let samples = crate::metrics::registry::parse_prometheus(&text)?;
        println!("\nprometheus exposition: {} samples ({})", samples.len(), prom.display());
    }
    Ok(())
}

/// `hecate analyze schedule`: statically verify a configuration's SPMD
/// communication schedule — replay plan building and resharding without
/// executing a kernel, then check match completeness, deadlock freedom,
/// wire safety, and resource discipline ([`crate::analysis`]). Exits
/// nonzero with a rank/iter/layer/tag diagnostic on any violation;
/// `--inject` seeds a deliberate violation to demonstrate the checks.
fn cmd_analyze(args: &Args) -> anyhow::Result<()> {
    args.reject_unknown(&[
        "devices", "nodes", "racks", "layers", "seed", "iters", "reshard-every", "transport",
        "overlap", "inject",
    ])?;
    let action = args.positional.first().cloned().unwrap_or_default();
    anyhow::ensure!(
        action == "schedule",
        "unknown analyze action `{action}` (usage: hecate analyze schedule [flags])"
    );
    // The same builder path as `hecate fssdp` validates the flags; the
    // analyzer reads the resulting config, it never runs the engine.
    let mut b = SessionConfig::builder()
        .reference()
        .cluster(args.usize_or("nodes", 2)?, args.usize_or("devices", 8)?)
        .seed(args.usize_or("seed", 42)? as u64)
        .parallel(true)
        .overlap(args.bool_or("overlap", true)?);
    if args.has("layers") {
        b = b.layers(args.usize_or("layers", 1)?);
    }
    let reshard_every = args.usize_or("reshard-every", 0)?;
    if args.has("reshard-every") {
        b = b.reshard_every(reshard_every);
    }
    if args.has("racks") {
        b = b.racks(args.usize_or("racks", 1)?);
    }
    if let Some(t) = args.str_opt("transport")? {
        b = b.transport(fssdp::parse_transport(&t)?);
    }
    let cfg = b.build()?;
    // Default window: past the first reshard boundary when resharding is
    // on, so the partition-migration checks actually see a migration.
    let iters = args.usize_or("iters", if reshard_every > 0 { reshard_every + 2 } else { 4 })?;
    let inject = match args.str_opt("inject")? {
        Some(s) => Some(crate::analysis::Injection::parse(&s).ok_or_else(|| {
            anyhow::anyhow!(
                "--inject expects drop-recv|swap-barrier|oversize-frame|double-own, got `{s}`"
            )
        })?),
        None => None,
    };
    let rep = crate::analysis::analyze_config(&cfg, iters, inject)?;
    println!(
        "schedule OK: {} ranks x {} layer(s), {} iteration(s) in {} span(s) \
         ({} reshard(s), {} expert(s) moved)",
        rep.ranks, rep.layers, rep.iters, rep.spans, rep.reshards, rep.experts_moved
    );
    println!(
        "  {} sends / {} recvs modeled; largest known frame {} bytes (wire cap {})",
        rep.sends,
        rep.recvs,
        rep.max_frame_bytes,
        crate::spmd::transport::socket::MAX_FRAME_LEN
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_command_errors() {
        assert!(run(vec!["bogus".into()]).is_err());
    }

    #[test]
    fn help_ok() {
        assert!(run(vec!["help".into()]).is_ok());
    }

    #[test]
    fn simulate_smoke() {
        let argv: Vec<String> = [
            "simulate", "--cluster", "a", "--model", "gpt-moe-s", "--system", "hecate",
            "--nodes", "2", "--dpn", "2", "--iters", "8", "--experts", "8",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        run(argv).unwrap();
    }

    #[test]
    fn repro_table1_smoke() {
        let argv: Vec<String> =
            ["repro", "--table", "1"].iter().map(|s| s.to_string()).collect();
        run(argv).unwrap();
    }

    fn argv(parts: &[&str]) -> Vec<String> {
        parts.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn simulate_fault_injection_smoke() {
        run(argv(&[
            "simulate", "--cluster", "a", "--model", "gpt-moe-s", "--system", "hecate",
            "--nodes", "2", "--dpn", "2", "--iters", "8", "--experts", "8",
            "--fail-step", "5", "--fail-device", "1", "--checkpoint-every", "2",
        ]))
        .unwrap();
    }

    #[test]
    fn checkpoint_then_elastic_resume_roundtrip() {
        let dir = std::env::temp_dir()
            .join(format!("hecate-coord-ckpt-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let d = dir.to_str().unwrap().to_string();
        // write a multi-layer checkpoint on 4 devices…
        run(argv(&[
            "checkpoint", "--iters", "2", "--nodes", "2", "--devices", "4", "--layers", "2",
            "--dir", &d,
        ]))
        .unwrap();
        assert!(dir.join("manifest.json").exists());
        assert!(dir.join("rank-3.bin").exists());
        // …resume on 2 (shrink) and then via the fssdp flag form on 8 (grow)
        run(argv(&["resume", "--iters", "2", "--nodes", "1", "--devices", "2", "--dir", &d]))
            .unwrap();
        run(argv(&[
            "fssdp", "--reference", "--iters", "1", "--nodes", "2", "--devices", "8",
            "--resume", &d,
        ]))
        .unwrap();
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn checkpoint_requires_dir() {
        assert!(run(argv(&["checkpoint", "--iters", "1"])).is_err());
        assert!(run(argv(&["resume", "--iters", "1"])).is_err());
    }

    #[test]
    fn subcommands_reject_unknown_flags() {
        assert!(run(argv(&["fssdp", "--bogus", "1"])).is_err());
        assert!(run(argv(&["simulate", "--fail-step", "5", "--nope", "1"])).is_err());
        assert!(run(argv(&["checkpoint", "--dir", "/tmp/x", "--nope", "1"])).is_err());
        assert!(run(argv(&["bench", "nope"])).is_err());
        assert!(run(argv(&["bench", "spmd", "--bogus", "1"])).is_err());
        // step-only flags must not silently no-op on the spmd target
        assert!(run(argv(&["bench", "spmd", "--json"])).is_err());
        // compute-mode must name a real tier
        let err = run(argv(&["bench", "step", "--quick", "--iters", "1", "--compute-mode", "turbo"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("--compute-mode expects"), "{err}");
    }

    #[test]
    fn bench_spmd_accepts_kernel_pool_flags() {
        // Regression: `bench spmd --compute-threads` used to be rejected as a
        // step-only flag; the SPMD ranks now run their own kernel pools, so
        // the combination is accepted and validated through SessionConfig.
        run(argv(&[
            "bench", "spmd", "--quick", "--iters", "1", "--compute-threads", "2",
            "--compute-mode", "fast",
        ]))
        .unwrap();
    }

    #[test]
    fn trailing_value_flag_is_an_error_not_a_panic() {
        // Regression for the CLI parser fix: a value-taking flag as the
        // final token must produce a parse error end-to-end.
        let err = run(argv(&["fssdp", "--reference", "--devices"])).unwrap_err().to_string();
        assert!(err.contains("expects a value"), "{err}");
        let err =
            run(argv(&["fssdp", "--reference", "--checkpoint-dir"])).unwrap_err().to_string();
        assert!(err.contains("expects a value"), "{err}");
    }

    #[test]
    fn threads_without_parallel_is_rejected() {
        let err = run(argv(&["fssdp", "--reference", "--threads", "4", "--iters", "1"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("--threads requires --parallel"), "{err}");
    }

    #[test]
    fn compute_threads_smoke_and_validation() {
        // threaded expert loops through the CLI, sequential executor
        run(argv(&[
            "fssdp", "--reference", "--devices", "4", "--nodes", "2", "--layers", "2",
            "--compute-threads", "2", "--iters", "2",
        ]))
        .unwrap();
        let err = run(argv(&[
            "fssdp", "--reference", "--devices", "4", "--compute-threads", "0", "--iters", "1",
        ]))
        .unwrap_err()
        .to_string();
        assert!(err.contains("--compute-threads must be at least 1"), "{err}");
    }

    #[test]
    fn bench_step_quick_smoke() {
        // no --json: must not write files from the test run
        run(argv(&["bench", "step", "--quick", "--iters", "1", "--compute-threads", "2"]))
            .unwrap();
        assert!(run(argv(&["bench", "step", "--bogus", "1"])).is_err());
    }

    #[test]
    fn bench_step_check_passes_against_the_committed_baseline() {
        // The committed BENCH_runtime_step.json now carries a non-null
        // baseline.step_ms (full bench shape), so the gate is armed; the
        // quick shape is far below it, so --check must pass with the
        // default tolerance. The failure path is locked by the
        // `perf_gate_known_answers` unit test and exercised end-to-end by
        // the CI injected-regression step. No --json, so nothing is
        // written.
        run(argv(&[
            "bench", "step", "--quick", "--iters", "1", "--compute-threads", "1", "--check",
        ]))
        .unwrap();
        // --gate-tol only makes sense under --check
        let err = run(argv(&["bench", "step", "--quick", "--gate-tol", "0.5"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("--gate-tol requires --check"), "{err}");
    }

    #[test]
    fn trace_out_writes_chrome_trace_and_analyze_reads_it() {
        let dir = std::env::temp_dir()
            .join(format!("hecate-coord-trace-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let d = dir.to_str().unwrap().to_string();
        run(argv(&[
            "fssdp", "--reference", "--parallel", "--devices", "4", "--nodes", "2",
            "--layers", "2", "--iters", "2", "--trace-out", &d,
        ]))
        .unwrap();
        let chrome = dir.join(crate::telemetry::CHROME_TRACE_FILE);
        assert!(chrome.exists(), "missing {}", chrome.display());
        assert!(dir.join(crate::telemetry::EVENTS_FILE).exists());
        let text = std::fs::read_to_string(&chrome).unwrap();
        let doc = crate::util::json::Json::parse(&text).unwrap();
        assert!(!doc.get("traceEvents").unwrap().as_arr().unwrap().is_empty());
        // both argument spellings of the analyzer work on the result
        run(argv(&["trace", "analyze", &d])).unwrap();
        run(argv(&["trace", "analyze", "--dir", &d])).unwrap();
        std::fs::remove_dir_all(&dir).unwrap();
        // a missing directory is a clear error, as is a bogus action
        assert!(run(argv(&["trace", "analyze", &d])).is_err());
        assert!(run(argv(&["trace", "export", &d])).is_err());
        assert!(run(argv(&["trace"])).is_err());
    }

    #[test]
    fn metrics_out_writes_exports_and_report_reads_them() {
        let dir = std::env::temp_dir()
            .join(format!("hecate-coord-metrics-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let d = dir.to_str().unwrap().to_string();
        run(argv(&[
            "fssdp", "--reference", "--parallel", "--devices", "4", "--nodes", "2",
            "--layers", "2", "--iters", "2", "--metrics-out", &d,
        ]))
        .unwrap();
        assert!(dir.join(crate::telemetry::metrics_io::METRICS_JSONL_FILE).exists());
        assert!(dir.join(crate::telemetry::metrics_io::METRICS_PROM_FILE).exists());
        assert!(dir.join(crate::telemetry::metrics_io::COUNTERS_FILE).exists());
        // both argument spellings of the report work on the result
        run(argv(&["metrics", "report", &d])).unwrap();
        run(argv(&["metrics", "report", "--dir", &d])).unwrap();
        std::fs::remove_dir_all(&dir).unwrap();
        // a missing directory exits with a clear typed error; so do a
        // bogus action and a missing argument
        let err = run(argv(&["metrics", "report", &d])).unwrap_err().to_string();
        assert!(err.contains("does not exist"), "{err}");
        assert!(run(argv(&["metrics", "export", &d])).is_err());
        assert!(run(argv(&["metrics"])).is_err());
    }

    #[test]
    fn trace_and_metrics_together_put_counter_rows_in_the_chrome_trace() {
        let dir = std::env::temp_dir()
            .join(format!("hecate-coord-trmet-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let d = dir.to_str().unwrap().to_string();
        run(argv(&[
            "fssdp", "--reference", "--parallel", "--devices", "4", "--nodes", "2",
            "--layers", "2", "--iters", "2", "--trace-out", &d, "--metrics-out", &d,
        ]))
        .unwrap();
        let text =
            std::fs::read_to_string(dir.join(crate::telemetry::CHROME_TRACE_FILE)).unwrap();
        let doc = crate::util::json::Json::parse(&text).unwrap();
        let rows = doc.req("traceEvents").unwrap().as_arr().unwrap().to_vec();
        let ph = |row: &crate::util::json::Json| {
            row.get("ph").and_then(|p| p.as_str()).map(str::to_string)
        };
        assert!(
            rows.iter().any(|r| ph(r).as_deref() == Some("C")),
            "counter tracks render next to the spans"
        );
        assert!(rows.iter().any(|r| ph(r).as_deref() == Some("X")), "span rows still present");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn threads_must_match_devices() {
        let err = run(argv(&[
            "fssdp", "--reference", "--parallel", "--devices", "4", "--threads", "3",
            "--iters", "1",
        ]))
        .unwrap_err()
        .to_string();
        assert!(err.contains("one OS thread per rank"), "{err}");
    }

    #[test]
    fn parallel_requires_reference_backend() {
        // --parallel without --reference would put PJRT handles on rank
        // threads; it must fail fast (before any engine is built).
        let err = run(argv(&["fssdp", "--parallel", "--devices", "4", "--iters", "1"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("--reference"), "{err}");
    }

    #[test]
    fn zero_layers_is_rejected() {
        let err = run(argv(&[
            "fssdp", "--reference", "--devices", "4", "--nodes", "2", "--layers", "0",
            "--iters", "1",
        ]))
        .unwrap_err()
        .to_string();
        assert!(err.contains("--layers"), "{err}");
    }

    #[test]
    fn parallel_smoke_runs_and_matches_flagless_defaults() {
        run(argv(&[
            "fssdp", "--reference", "--parallel", "--devices", "4", "--nodes", "2",
            "--iters", "2",
        ]))
        .unwrap();
        // explicit matching thread count is also accepted
        run(argv(&[
            "fssdp", "--reference", "--parallel", "--devices", "4", "--nodes", "2",
            "--threads", "4", "--iters", "1",
        ]))
        .unwrap();
    }

    #[test]
    fn pacing_flag_parses_and_runs() {
        // α–β link pacing wired through the config: a paced 1-iteration
        // SPMD run (tiny α/β so the smoke stays fast).
        run(argv(&[
            "fssdp", "--reference", "--parallel", "--devices", "4", "--nodes", "2",
            "--iters", "1", "--pacing", "1e-6,1e-12",
        ]))
        .unwrap();
    }

    #[test]
    fn bad_pacing_is_a_parse_error() {
        let err = run(argv(&["fssdp", "--reference", "--iters", "1", "--pacing", "fast"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("--pacing expects"), "{err}");
        assert!(err.contains("got `fast`"), "{err}");
    }

    #[test]
    fn socket_transport_flag_validation() {
        // socket without --parallel fails in the shared config validation
        let err = run(argv(&[
            "fssdp", "--reference", "--iters", "1", "--transport", "socket",
        ]))
        .unwrap_err()
        .to_string();
        assert!(err.contains("--transport socket requires --parallel"), "{err}");
        // a bogus transport names the value
        let err = run(argv(&[
            "fssdp", "--reference", "--iters", "1", "--transport", "telegraph",
        ]))
        .unwrap_err()
        .to_string();
        assert!(err.contains("got `telegraph`"), "{err}");
        // launcher extras are socket-only
        let err = run(argv(&[
            "fssdp", "--reference", "--parallel", "--devices", "4", "--iters", "1",
            "--verify-inproc",
        ]))
        .unwrap_err()
        .to_string();
        assert!(err.contains("require --transport socket"), "{err}");
        // span-boundary features stay on the in-process coordinator
        for extra in [
            ["--resume", "/tmp/nowhere"],
            ["--reshard-every", "2"],
            ["--trace-out", "/tmp/nowhere"],
        ] {
            let mut a = argv(&[
                "fssdp", "--reference", "--parallel", "--devices", "4", "--iters", "1",
                "--transport", "socket",
            ]);
            a.extend(extra.iter().map(|s| s.to_string()));
            let err = run(a).unwrap_err().to_string();
            assert!(err.contains("not supported with --transport socket"), "{err}");
        }
    }

    #[test]
    fn rack_and_pacing_topo_flags_reach_validation() {
        let err = run(argv(&[
            "fssdp", "--reference", "--devices", "8", "--nodes", "4", "--racks", "3",
            "--iters", "1",
        ]))
        .unwrap_err()
        .to_string();
        assert!(err.contains("--racks 3 must evenly divide --nodes 4"), "{err}");
        // tiered pacing derived from a racked topology runs end to end
        run(argv(&[
            "fssdp", "--reference", "--parallel", "--devices", "8", "--nodes", "4",
            "--racks", "2", "--pacing-topo", "1e6", "--iters", "1",
        ]))
        .unwrap();
        let err = run(argv(&[
            "fssdp", "--reference", "--parallel", "--devices", "4", "--iters", "1",
            "--pacing", "1e-6,1e-12", "--pacing-topo", "1e6",
        ]))
        .unwrap_err()
        .to_string();
        assert!(err.contains("mutually exclusive"), "{err}");
    }

    #[test]
    fn bench_spmd_rejects_bad_transport_before_running() {
        let err = run(argv(&["bench", "spmd", "--transport", "smoke-signal"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("got `smoke-signal`"), "{err}");
    }

    #[test]
    fn worker_subcommand_is_dispatched() {
        // full socket runs live in tests/socket_equivalence.rs (they need
        // the real binary); here we check dispatch + flag validation.
        let err = run(argv(&["worker", "--world", "4"])).unwrap_err().to_string();
        assert!(err.contains("missing required option --rank"), "{err}");
    }

    #[test]
    fn analyze_schedule_smoke_and_validation() {
        // a clean config verifies end to end through the CLI
        run(argv(&[
            "analyze", "schedule", "--devices", "4", "--nodes", "2", "--iters", "2",
        ]))
        .unwrap();
        // a seeded violation surfaces as an error with its diagnostic
        let err = run(argv(&[
            "analyze", "schedule", "--devices", "4", "--nodes", "2", "--iters", "2",
            "--inject", "drop-recv",
        ]))
        .unwrap_err()
        .to_string();
        assert!(err.contains("orphan send"), "{err}");
        // bad action, bad injection name, unknown flag
        let err = run(argv(&["analyze", "verify"])).unwrap_err().to_string();
        assert!(err.contains("unknown analyze action"), "{err}");
        let err = run(argv(&["analyze", "schedule", "--inject", "gremlins"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("got `gremlins`"), "{err}");
        assert!(run(argv(&["analyze", "schedule", "--bogus", "1"])).is_err());
    }

    #[test]
    fn multilayer_parallel_smoke_with_resharding() {
        // The CI smoke flow: 3 layers, SPMD executor, Algorithm 2 every 2
        // iterations inside the numeric run.
        run(argv(&[
            "fssdp", "--reference", "--parallel", "--devices", "4", "--nodes", "2",
            "--layers", "3", "--reshard-every", "2", "--iters", "3",
        ]))
        .unwrap();
    }
}
