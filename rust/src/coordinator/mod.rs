//! L3 coordinator CLI: subcommand dispatch for the `hecate` binary.
//!
//! ```text
//! hecate repro   --figure 9|10|11|12|13|14|15a|15b | --table 1 | --claims | --all
//! hecate simulate --cluster a|b --model gpt-moe-s --system hecate [--nodes 4 --dpn 8]
//! hecate train   --model e2e --steps 200 [--artifacts DIR]   (runs PJRT)
//! hecate fssdp   --devices 8 --iters 20                      (numeric engine)
//! ```

use crate::config::{ClusterPreset, ModelConfig, SystemConfig, SystemKind, TrainConfig};
use crate::sim::engine::simulate;
use crate::sim::report;
use crate::util::cli::Args;

/// Entry point called by `main`.
pub fn run(argv: Vec<String>) -> anyhow::Result<()> {
    crate::util::logging::init();
    let Some((cmd, rest)) = argv.split_first() else {
        print_usage();
        return Ok(());
    };
    let args = Args::parse(rest.iter().cloned());
    match cmd.as_str() {
        "repro" => cmd_repro(&args),
        "simulate" => cmd_simulate(&args),
        "train" => cmd_train(&args),
        "fssdp" => cmd_fssdp(&args),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => {
            print_usage();
            anyhow::bail!("unknown command `{other}`")
        }
    }
}

fn print_usage() {
    eprintln!(
        "hecate — FSSDP MoE training (paper reproduction)\n\
         USAGE:\n  hecate repro    [--figure N | --table 1 | --claims | --all] [--iters N]\n  \
         hecate simulate --cluster a|b --model NAME --system NAME [--nodes N --dpn N --batch N]\n  \
         hecate train    [--steps N] [--artifacts DIR] [--model tiny|e2e] [--log FILE]\n  \
         hecate fssdp    [--devices N] [--iters N] [--artifacts DIR]"
    );
}

fn cmd_repro(args: &Args) -> anyhow::Result<()> {
    args.reject_unknown(&["figure", "table", "claims", "all", "iters"])?;
    let mut opts = report::default_opts();
    opts.iterations = args.usize_or("iters", opts.iterations)?;
    let all = args.has("all");
    let fig = args.str_or("figure", "");
    let table = args.str_or("table", "");

    if all || table == "1" {
        println!("\n== Table 1: model architectures ==");
        print!("{}", report::table1().to_markdown());
    }
    if all || fig == "3" {
        println!("\n== Figure 3: expert load distribution over iterations ==");
        print!("{}", report::figure3(30).to_markdown());
    }
    if all || fig == "9" {
        println!("\n== Figure 9: end-to-end speedup vs EP, Cluster A ==");
        for (t, label) in report::figure9(&opts).into_iter().zip(["16 GPUs", "32 GPUs"]) {
            println!("-- {label} --");
            print!("{}", t.to_markdown());
        }
    }
    if all || fig == "10" {
        println!("\n== Figure 10: end-to-end speedup vs EP, Cluster B (32 GPUs) ==");
        print!("{}", report::figure10(&opts).to_markdown());
    }
    if all || fig == "11" {
        println!("\n== Figure 11: layer-wise MoE speedup (GPT-MoE-S, Cluster B) ==");
        print!("{}", report::figure11(&opts).to_markdown());
    }
    if all || fig == "12" {
        println!("\n== Figure 12: critical-path breakdown (BERT-MoE-Deep, Cluster B) ==");
        print!("{}", report::figure12(&opts).to_markdown());
    }
    if all || fig == "13" {
        println!("\n== Figure 13: peak MoE memory per device ==");
        print!("{}", report::figure13(&opts).to_markdown());
    }
    if all || fig == "14" {
        println!("\n== Figure 14: batch-size scaling (GPT-MoE-S, Cluster A) ==");
        print!("{}", report::figure14(&opts).to_markdown());
    }
    if all || fig == "15a" || fig == "15" {
        println!("\n== Figure 15a: component ablation ==");
        print!("{}", report::figure15a(&opts).to_markdown());
    }
    if all || fig == "15b" || fig == "15" {
        println!("\n== Figure 15b: re-sharding interval sweep ==");
        print!("{}", report::figure15b(&opts).to_markdown());
    }
    if all || args.has("claims") {
        for (name, t) in report::claims(&opts) {
            println!("\n== Claim: {name} ==");
            print!("{}", t.to_markdown());
        }
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    args.reject_unknown(&[
        "cluster", "model", "system", "nodes", "dpn", "batch", "iters", "seed", "experts",
    ])?;
    let cluster = ClusterPreset::parse(&args.str_or("cluster", "a"))?;
    let nodes = args.usize_or("nodes", 4)?;
    let dpn = args.usize_or("dpn", 8)?;
    let topo = cluster.build(nodes, dpn);
    let mut model = ModelConfig::preset(&args.str_or("model", "gpt-moe-s"))?;
    if let Some(e) = args.get("experts") {
        model = model.with_experts(e.parse()?);
    }
    let system = SystemKind::parse(&args.str_or("system", "hecate"))?;
    let batch = args.usize_or("batch", report::paper_batch(&model))?;
    let train = TrainConfig { batch_per_device: batch, ..Default::default() };
    let mut opts = report::default_opts();
    opts.iterations = args.usize_or("iters", opts.iterations)?;
    opts.seed = args.usize_or("seed", opts.seed as usize)? as u64;

    let r = simulate(&topo, &model, &SystemConfig::new(system), &train, &opts);
    println!("system     : {}", r.system);
    println!("topology   : {}", topo.name);
    println!("model      : {} ({} experts, batch {})", model.name, model.experts, batch);
    println!("iter time  : {:.2} ms", r.iter_time * 1e3);
    let b = &r.breakdown;
    println!(
        "breakdown  : attn {:.2} ms | expert {:.2} ms | a2a {:.2} ms | exposed-comm {:.2} ms | rearr {:.2} ms",
        b.attn * 1e3,
        b.expert * 1e3,
        b.a2a * 1e3,
        b.exposed_comm * 1e3,
        b.rearrange * 1e3
    );
    println!(
        "moe memory : params {:.2} GB | grads {:.2} GB | opt {:.2} GB",
        r.memory.params / 1e9,
        r.memory.grads / 1e9,
        r.memory.opt / 1e9
    );
    Ok(())
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    args.reject_unknown(&["steps", "artifacts", "model", "log", "lr", "seed"])?;
    let steps = args.usize_or("steps", 200)?;
    let dir = args.str_or("artifacts", "artifacts");
    let tag = args.str_or("model", "tiny");
    let log = args.get("log").map(|s| s.to_string());
    crate::train::run_training(&dir, &tag, steps, log.as_deref())
}

fn cmd_fssdp(args: &Args) -> anyhow::Result<()> {
    args.reject_unknown(&["devices", "iters", "artifacts", "nodes", "seed"])?;
    let devices = args.usize_or("devices", 8)?;
    let nodes = args.usize_or("nodes", 2)?;
    let iters = args.usize_or("iters", 10)?;
    let dir = args.str_or("artifacts", "artifacts");
    let seed = args.usize_or("seed", 42)? as u64;
    crate::fssdp::run_demo(&dir, nodes, devices, iters, seed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_command_errors() {
        assert!(run(vec!["bogus".into()]).is_err());
    }

    #[test]
    fn help_ok() {
        assert!(run(vec!["help".into()]).is_ok());
    }

    #[test]
    fn simulate_smoke() {
        let argv: Vec<String> = [
            "simulate", "--cluster", "a", "--model", "gpt-moe-s", "--system", "hecate",
            "--nodes", "2", "--dpn", "2", "--iters", "8", "--experts", "8",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        run(argv).unwrap();
    }

    #[test]
    fn repro_table1_smoke() {
        let argv: Vec<String> =
            ["repro", "--table", "1"].iter().map(|s| s.to_string()).collect();
        run(argv).unwrap();
    }
}
