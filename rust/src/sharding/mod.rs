//! Heterogeneous sharding — the paper's **Algorithm 2** (§4.3).
//!
//! FSSDP shards every MoE layer across all devices with the *expert* as the
//! atomic unit. Homogeneous (even) sharding is the initialization; Hecate
//! periodically re-shards *heterogeneously*: a device may hold anywhere
//! from 0 to |E| experts of a given layer, as long as the **total** slot
//! count per device stays balanced across all layers (unified memory space,
//! §4.3 / Figure 8).
//!
//! The algorithm places *underloaded* ("non-overlappable") experts first —
//! they are the ones whose tokens cannot be absorbed by replicas, so
//! spreading them evens out each node's inbound All-to-All traffic — then
//! fills the remaining slots with the overloaded (overlappable) experts.

use crate::materialize::top_by_load;
use crate::placement::Placement;
use crate::topology::{DeviceId, Topology};

/// Sharding plan for all MoE layers: `plans[l]` is a partition placement of
/// layer `l`'s experts.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardingPlan {
    pub layers: Vec<Placement>,
}

impl ShardingPlan {
    /// Total expert slots used on a device across all layers.
    pub fn slots_used(&self, d: DeviceId) -> usize {
        self.layers.iter().map(|p| p.load_of(d)).sum()
    }

    /// Max - min slot usage across devices (memory imbalance; 0 = balanced).
    pub fn slot_imbalance(&self, num_devices: usize) -> usize {
        let used: Vec<usize> = (0..num_devices).map(|d| self.slots_used(DeviceId(d))).collect();
        used.iter().max().unwrap() - used.iter().min().unwrap()
    }
}

/// Homogeneous (even) sharding: layer-wise round-robin. This is both the
/// initialization of Hecate and the static placement of EP.
pub fn homogeneous(num_layers: usize, experts: usize, num_devices: usize) -> ShardingPlan {
    ShardingPlan {
        layers: (0..num_layers)
            .map(|_| Placement::round_robin(experts, num_devices))
            .collect(),
    }
}

/// Algorithm 2: heterogeneous sharding.
///
/// * `loads[l][e]` — load distribution `F^g` across all MoE layers;
/// * `t` — overlap degree (top-`t` experts per layer are "overlappable" and
///   placed last, since sparse materialization will replicate them anyway).
///
/// All load comparisons use `f64::total_cmp`: a degenerate predictor window
/// (all-zero history → 0/0 normalization) can yield NaN loads, and the
/// planner must degrade to a deterministic (if arbitrary) placement rather
/// than panic mid-training.
pub fn heterogeneous(topo: &Topology, loads: &[Vec<f64>], t: usize) -> ShardingPlan {
    heterogeneous_sticky(topo, loads, t, None)
}

/// Algorithm 2 with *stickiness*: prefer each expert's previous owner when
/// the balance objective is indifferent. The paper places overlappable
/// experts "arbitrarily" (line 16) and observes that underloaded experts'
/// loads change slowly (§4.3) — so successive re-shards should move few
/// experts, keeping re-shard traffic off the critical path ("executing
/// only when shards change", §5.1). Without stickiness a greedy packer
/// reshuffles wholesale on every trigger and pays ~full-model movement.
pub fn heterogeneous_sticky(
    topo: &Topology,
    loads: &[Vec<f64>],
    t: usize,
    prev: Option<&ShardingPlan>,
) -> ShardingPlan {
    let num_layers = loads.len();
    assert!(num_layers > 0);
    let experts = loads[0].len();
    let nd = topo.num_devices();

    // line 1-2: J = top-t per layer (overlappable), J' = the rest.
    let overlappable: Vec<Vec<usize>> = loads
        .iter()
        .map(|f| top_by_load(f, t.min(experts)))
        .collect();

    // line 3: available slots per device — even share of ALL layers' experts.
    let total_experts = num_layers * experts;
    let base = total_experts / nd;
    let rem = total_experts % nd;
    // first `rem` devices take one extra slot when not divisible.
    let mut slots: Vec<usize> = (0..nd).map(|d| base + usize::from(d < rem)).collect();

    let mut plans: Vec<Placement> = (0..num_layers)
        .map(|_| Placement::empty(experts, nd))
        .collect();

    // Per-layer, per-node/device accumulated load (for least-loaded search).
    let mut node_load = vec![vec![0.0f64; topo.nodes]; num_layers];
    let mut dev_load = vec![vec![0.0f64; nd]; num_layers];

    // lines 6-14: place underloaded experts first, layers ordered by their
    // hottest underloaded expert, experts by load descending.
    let mut layer_order: Vec<usize> = (0..num_layers).collect();
    let layer_max: Vec<f64> = (0..num_layers)
        .map(|l| {
            loads[l]
                .iter()
                .enumerate()
                .filter(|(e, _)| !overlappable[l].contains(e))
                .map(|(_, &f)| f)
                .fold(0.0, f64::max)
        })
        .collect();
    layer_order.sort_by(|&a, &b| layer_max[b].total_cmp(&layer_max[a]));

    for &l in &layer_order {
        let mut under: Vec<usize> =
            (0..experts).filter(|e| !overlappable[l].contains(e)).collect();
        under.sort_by(|&a, &b| loads[l][b].total_cmp(&loads[l][a]));
        for e in under {
            // line 10: least-loaded node; tie -> fewer available slots.
            let node = topo
                .all_nodes()
                .filter(|&n| topo.devices_on(n).any(|d| slots[d.0] > 0))
                .min_by(|&a, &b| {
                    let la = node_load[l][a.0];
                    let lb = node_load[l][b.0];
                    la.total_cmp(&lb).then_with(|| {
                        let sa: usize = topo.devices_on(a).map(|d| slots[d.0]).sum();
                        let sb: usize = topo.devices_on(b).map(|d| slots[d.0]).sum();
                        sa.cmp(&sb)
                    })
                })
                .expect("ran out of slots — total slots must equal total experts");
            // line 11: least-loaded device on that node; tie -> fewer slots.
            let mut dev = topo
                .devices_on(node)
                .filter(|d| slots[d.0] > 0)
                .min_by(|a, b| {
                    dev_load[l][a.0]
                        .total_cmp(&dev_load[l][b.0])
                        .then(slots[a.0].cmp(&slots[b.0]))
                })
                .unwrap();
            // stickiness: keep the previous owner when the balance penalty
            // is at most this expert's own load (loads of underloaded
            // experts drift slowly — §4.3).
            if let Some(prev_dev) = prev
                .and_then(|p| p.layers.get(l))
                .and_then(|pl| pl.holders(e).next())
            {
                if prev_dev != dev
                    && slots[prev_dev.0] > 0
                    && dev_load[l][prev_dev.0] <= dev_load[l][dev.0] + loads[l][e]
                {
                    dev = prev_dev;
                }
            }
            // lines 12-13
            plans[l].add(e, dev);
            slots[dev.0] -= 1;
            node_load[l][topo.node_of(dev).0] += loads[l][e];
            dev_load[l][dev.0] += loads[l][e];
        }
    }

    // line 16: fill remaining slots with the overlappable experts. The paper
    // places these "arbitrarily" — sparse materialization will replicate
    // them anyway — so we keep each on its previous owner when possible
    // (zero movement on re-shard), falling back to least-loaded.
    for l in 0..num_layers {
        let mut over = overlappable[l].clone();
        over.sort_by(|&a, &b| loads[l][b].total_cmp(&loads[l][a]));
        for e in over {
            let prev_dev = prev
                .and_then(|p| p.layers.get(l))
                .and_then(|pl| pl.holders(e).next())
                .filter(|d| slots[d.0] > 0);
            let dev = prev_dev.unwrap_or_else(|| {
                topo.all_devices()
                    .filter(|d| slots[d.0] > 0)
                    .min_by(|a, b| {
                        dev_load[l][a.0]
                            .total_cmp(&dev_load[l][b.0])
                            .then(a.0.cmp(&b.0))
                    })
                    .expect("slot arithmetic violated")
            });
            plans[l].add(e, dev);
            slots[dev.0] -= 1;
            dev_load[l][dev.0] += loads[l][e];
            node_load[l][topo.node_of(dev).0] += loads[l][e];
        }
    }

    ShardingPlan { layers: plans }
}

/// Bytes a re-shard must move: experts whose owner changed carry parameters
/// *and* optimizer states (this is the cost §4.3 amortizes by re-sharding
/// rarely).
pub fn reshard_bytes(
    old: &ShardingPlan,
    new: &ShardingPlan,
    expert_param_bytes: usize,
    expert_opt_bytes: usize,
) -> usize {
    let mut moved = 0usize;
    for (po, pn) in old.layers.iter().zip(new.layers.iter()) {
        for e in 0..po.num_chunks() {
            let o: Vec<_> = po.holders(e).collect();
            let n: Vec<_> = pn.holders(e).collect();
            if o != n {
                moved += 1;
            }
        }
    }
    moved * (expert_param_bytes + expert_opt_bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing;
    use crate::util::rng::Rng;
    use crate::util::stats;

    fn gen_loads(rng: &mut Rng, layers: usize, experts: usize) -> Vec<Vec<f64>> {
        (0..layers).map(|_| rng.dirichlet(0.2, experts)).collect()
    }

    #[test]
    fn homogeneous_is_balanced_partition() {
        let plan = homogeneous(12, 64, 8);
        assert_eq!(plan.layers.len(), 12);
        for p in &plan.layers {
            assert!(p.is_partition());
        }
        assert_eq!(plan.slot_imbalance(8), 0);
        assert_eq!(plan.slots_used(DeviceId(0)), 12 * 8);
    }

    #[test]
    fn heterogeneous_places_every_expert_once() {
        let topo = Topology::cluster_a(2, 4);
        let mut rng = Rng::new(5);
        let loads = gen_loads(&mut rng, 6, 16);
        let plan = heterogeneous(&topo, &loads, 4);
        for p in &plan.layers {
            assert!(p.is_partition(), "each expert exactly one owner");
        }
    }

    #[test]
    fn heterogeneous_keeps_memory_balance() {
        // Figure 8's point: shard counts per layer may differ wildly, but
        // total memory per device stays even.
        let topo = Topology::cluster_a(4, 8);
        let mut rng = Rng::new(6);
        let loads = gen_loads(&mut rng, 12, 64);
        let plan = heterogeneous(&topo, &loads, 8);
        assert_eq!(plan.slot_imbalance(32), 0, "12*64 divisible by 32");
        // ...and is genuinely heterogeneous: some layer has an uneven split.
        let uneven = plan.layers.iter().any(|p| {
            let per_dev: Vec<usize> = (0..32).map(|d| p.load_of(DeviceId(d))).collect();
            per_dev.iter().max() != per_dev.iter().min()
        });
        assert!(uneven, "expected at least one heterogeneous layer");
    }

    #[test]
    fn heterogeneous_balances_underloaded_traffic_better_than_homogeneous() {
        // The node-level inbound load of underloaded experts should be more
        // even under Algorithm 2 than under a pathological static layout.
        let topo = Topology::cluster_a(4, 2);
        let mut rng = Rng::new(9);
        let loads = gen_loads(&mut rng, 8, 16);
        let t = 4;
        let hetero = heterogeneous(&topo, &loads, t);
        let homo = homogeneous(8, 16, topo.num_devices());
        let mean_cv = |plan: &ShardingPlan| {
            let mut cvs = Vec::new();
            for (l, p) in plan.layers.iter().enumerate() {
                let over = top_by_load(&loads[l], t);
                let mut node_load = vec![0.0; topo.nodes];
                for e in 0..16 {
                    if over.contains(&e) {
                        continue;
                    }
                    let d = p.holders(e).next().unwrap();
                    node_load[topo.node_of(d).0] += loads[l][e];
                }
                cvs.push(stats::cv(&node_load));
            }
            stats::mean(&cvs)
        };
        let (h, o) = (mean_cv(&hetero), mean_cv(&homo));
        assert!(h < o, "heterogeneous node CV {h:.3} should beat homogeneous {o:.3}");
    }

    #[test]
    fn indivisible_totals_balance_within_one() {
        let topo = Topology::cluster_a(1, 3);
        let mut rng = Rng::new(10);
        let loads = gen_loads(&mut rng, 2, 8); // 16 experts over 3 devices
        let plan = heterogeneous(&topo, &loads, 2);
        assert!(plan.slot_imbalance(3) <= 1);
        for p in &plan.layers {
            assert!(p.is_partition());
        }
    }

    #[test]
    fn reshard_bytes_counts_moves() {
        let a = homogeneous(2, 4, 2);
        let mut b = a.clone();
        // move expert 0 of layer 0 from device 0 to 1
        b.layers[0].remove(0, DeviceId(0));
        b.layers[0].add(0, DeviceId(1));
        assert_eq!(reshard_bytes(&a, &b, 10, 60), 70);
        assert_eq!(reshard_bytes(&a, &a, 10, 60), 0);
    }

    #[test]
    fn sticky_resharding_moves_few_experts_on_small_drift() {
        let topo = Topology::cluster_a(4, 8);
        let mut rng = Rng::new(31);
        let loads = gen_loads(&mut rng, 12, 64);
        let plan = heterogeneous(&topo, &loads, 8);
        // small multiplicative drift on every load
        let drifted: Vec<Vec<f64>> = loads
            .iter()
            .map(|f| {
                let nudged: Vec<f64> =
                    f.iter().map(|&x| x * (1.0 + 0.05 * rng.normal())).collect();
                let s: f64 = nudged.iter().sum();
                nudged.iter().map(|x| x / s).collect()
            })
            .collect();
        let sticky = heterogeneous_sticky(&topo, &drifted, 8, Some(&plan));
        let fresh = heterogeneous(&topo, &drifted, 8);
        let moved = |new: &ShardingPlan| {
            reshard_bytes(&plan, new, 1, 0) // 1 byte/expert => count of moves
        };
        let (ms, mf) = (moved(&sticky), moved(&fresh));
        assert!(
            ms * 4 < mf.max(1),
            "sticky should move far fewer experts: sticky {ms} vs fresh {mf}"
        );
        // and stay a valid balanced partition
        for p in &sticky.layers {
            assert!(p.is_partition());
        }
        assert!(sticky.slot_imbalance(topo.num_devices()) <= 1);
    }

    #[test]
    fn nan_load_rows_do_not_panic_the_planner() {
        // Regression: NaN in any layer's load row (degenerate predictor
        // window) must not panic any of the planner's sorts; the result is
        // still a balanced partition.
        let topo = Topology::cluster_a(2, 2);
        let mut rng = Rng::new(17);
        let mut loads = gen_loads(&mut rng, 3, 8);
        loads[1][2] = f64::NAN;
        loads[1][5] = f64::NAN;
        let plan = heterogeneous(&topo, &loads, 2);
        for p in &plan.layers {
            assert!(p.is_partition());
        }
        assert_eq!(plan.slot_imbalance(4), 0, "3*8 divisible by 4");

        // worst case: one layer entirely NaN, plus sticky re-shard over it
        loads[2] = vec![f64::NAN; 8];
        let plan2 = heterogeneous_sticky(&topo, &loads, 2, Some(&plan));
        for p in &plan2.layers {
            assert!(p.is_partition());
        }
        assert_eq!(plan2.slot_imbalance(4), 0);
    }

    #[test]
    fn prop_heterogeneous_invariants() {
        testing::check(
            |rng: &mut Rng, size| {
                let topo = Topology::cluster_a(1 + rng.below(3), 1 + rng.below(4));
                let layers = 1 + rng.below(size.max(1) * 2);
                let experts = topo.num_devices() * (1 + rng.below(4));
                let loads = gen_loads(rng, layers, experts);
                let t = rng.below(experts / 2 + 1);
                (topo, loads, t)
            },
            |(topo, loads, t)| {
                let plan = heterogeneous(topo, loads, *t);
                for (l, p) in plan.layers.iter().enumerate() {
                    if !p.is_partition() {
                        return Err(format!("layer {l} not a partition"));
                    }
                }
                let nd = topo.num_devices();
                let total: usize = loads.len() * loads[0].len();
                if plan.slot_imbalance(nd) > usize::from(total % nd != 0) {
                    return Err(format!(
                        "memory imbalance {} with total={total} devices={nd}",
                        plan.slot_imbalance(nd)
                    ));
                }
                Ok(())
            },
        );
    }
}
