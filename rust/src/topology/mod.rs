//! Cluster topology model: nodes × devices with heterogeneous interconnects.
//!
//! Reproduces the paper's two testbeds (§5.1):
//! * **Cluster A** — 4 nodes × 8 V100-32G, 300 GB/s NVLink intra-node,
//!   100 Gbps inter-node network.
//! * **Cluster B** — 4 nodes × 8 A100-40G, 600 GB/s NVSwitch intra-node,
//!   400 Gbps inter-node network.
//!
//! Link transfers follow the standard α–β model: `time = α + bytes / β`,
//! with separate (α, β) per link tier. The default hierarchy is two-tier
//! (intra-node / inter-node); [`Topology::with_racks`] adds a third tier
//! for clusters whose nodes are grouped into racks behind an oversubscribed
//! spine, giving cross-rack hops their own (α, β). The collectives cost
//! models in [`crate::collectives`] are built on the per-device
//! inbound/outbound bottleneck analysis the paper uses in §3.1.

/// Identifier of a device (global index across the cluster).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct DeviceId(pub usize);

/// Identifier of a node (host).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct NodeId(pub usize);

/// Identifier of a rack (group of nodes behind one spine uplink).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct RackId(pub usize);

/// Which tier of the interconnect hierarchy a point-to-point hop crosses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinkTier {
    /// Both devices share a node (NVLink/NVSwitch).
    IntraNode,
    /// Different nodes, same rack (NIC + top-of-rack switch).
    InterNode,
    /// Different racks (NIC + oversubscribed spine).
    InterRack,
}

/// Physical cluster description.
#[derive(Debug, Clone)]
pub struct Topology {
    pub nodes: usize,
    pub devices_per_node: usize,
    /// Rack groups the nodes split into (1 = single rack, no third tier).
    pub racks: usize,
    /// Intra-node per-direction bandwidth, bytes/s (NVLink/NVSwitch).
    pub intra_bw: f64,
    /// Inter-node per-direction bandwidth, bytes/s (NIC, per node).
    pub inter_bw: f64,
    /// Cross-rack per-direction bandwidth, bytes/s (spine share per node).
    pub rack_bw: f64,
    /// Intra-node link latency, seconds.
    pub intra_lat: f64,
    /// Inter-node link latency, seconds.
    pub inter_lat: f64,
    /// Cross-rack link latency, seconds.
    pub rack_lat: f64,
    /// Dense compute throughput per device, flop/s (for the simulator).
    pub device_flops: f64,
    /// Device memory capacity, bytes.
    pub device_mem: f64,
    /// Human-readable name.
    pub name: String,
}

impl Topology {
    /// Paper Cluster A: 4× AWS p3dn.24xlarge (8× V100-32G, NVLink 300 GB/s,
    /// 100 Gbps network). V100 fp16 peak ≈ 112 TFLOP/s with a realistic
    /// ~40% achievable efficiency for transformer workloads.
    pub fn cluster_a(nodes: usize, devices_per_node: usize) -> Topology {
        Topology {
            nodes,
            devices_per_node,
            racks: 1,
            intra_bw: 150e9, // per-direction share of 300 GB/s aggregate
            inter_bw: 100e9 / 8.0, // 100 Gbps = 12.5 GB/s per node
            rack_bw: 100e9 / 8.0,
            intra_lat: 3e-6,
            inter_lat: 15e-6,
            rack_lat: 15e-6,
            device_flops: 112e12 * 0.4,
            device_mem: 32e9,
            name: format!("ClusterA[{}x{} V100]", nodes, devices_per_node),
        }
    }

    /// Paper Cluster B: 4× AWS p4d.24xlarge (8× A100-40G, NVSwitch 600 GB/s,
    /// 400 Gbps network). A100 bf16 peak ≈ 312 TFLOP/s, ~45% achievable.
    pub fn cluster_b(nodes: usize, devices_per_node: usize) -> Topology {
        Topology {
            nodes,
            devices_per_node,
            racks: 1,
            intra_bw: 300e9,
            inter_bw: 400e9 / 8.0, // 400 Gbps = 50 GB/s per node
            rack_bw: 400e9 / 8.0,
            intra_lat: 2e-6,
            inter_lat: 10e-6,
            rack_lat: 10e-6,
            device_flops: 312e12 * 0.45,
            device_mem: 40e9,
            name: format!("ClusterB[{}x{} A100]", nodes, devices_per_node),
        }
    }

    /// Homogeneous single-switch topology (for unit tests and the numeric
    /// engine, where topology awareness is irrelevant).
    pub fn flat(devices: usize, bw: f64) -> Topology {
        Topology {
            nodes: 1,
            devices_per_node: devices,
            racks: 1,
            intra_bw: bw,
            inter_bw: bw,
            rack_bw: bw,
            intra_lat: 1e-6,
            inter_lat: 1e-6,
            rack_lat: 1e-6,
            device_flops: 100e12,
            device_mem: 32e9,
            name: format!("Flat[{devices}]"),
        }
    }

    /// Group the nodes into `racks` racks, deriving a conservatively
    /// oversubscribed spine: half the NIC bandwidth, triple the inter-node
    /// latency. Use [`Topology::with_rack_links`] afterwards to override.
    pub fn with_racks(mut self, racks: usize) -> Topology {
        assert!(racks >= 1, "a topology needs at least one rack");
        assert!(self.nodes % racks == 0, "racks must evenly divide the node count");
        self.racks = racks;
        if racks > 1 {
            self.rack_bw = self.inter_bw / 2.0;
            self.rack_lat = self.inter_lat * 3.0;
        }
        self
    }

    /// Override the cross-rack α–β parameters.
    pub fn with_rack_links(mut self, bw: f64, lat: f64) -> Topology {
        self.rack_bw = bw;
        self.rack_lat = lat;
        self
    }

    /// Total number of devices.
    pub fn num_devices(&self) -> usize {
        self.nodes * self.devices_per_node
    }

    /// Nodes per rack (all of them when the cluster is single-rack).
    pub fn nodes_per_rack(&self) -> usize {
        self.nodes / self.racks
    }

    /// Node that hosts a device.
    pub fn node_of(&self, d: DeviceId) -> NodeId {
        debug_assert!(d.0 < self.num_devices());
        NodeId(d.0 / self.devices_per_node)
    }

    /// Devices on a node, in global-id order.
    pub fn devices_on(&self, n: NodeId) -> impl Iterator<Item = DeviceId> + '_ {
        let start = n.0 * self.devices_per_node;
        (start..start + self.devices_per_node).map(DeviceId)
    }

    /// All device ids.
    pub fn all_devices(&self) -> impl Iterator<Item = DeviceId> + '_ {
        (0..self.num_devices()).map(DeviceId)
    }

    /// All node ids.
    pub fn all_nodes(&self) -> impl Iterator<Item = NodeId> + '_ {
        (0..self.nodes).map(NodeId)
    }

    /// Whether two devices share a node.
    pub fn same_node(&self, a: DeviceId, b: DeviceId) -> bool {
        self.node_of(a) == self.node_of(b)
    }

    /// Rack that hosts a device.
    pub fn rack_of(&self, d: DeviceId) -> RackId {
        RackId(self.node_of(d).0 / self.nodes_per_rack())
    }

    /// Whether two devices share a rack.
    pub fn same_rack(&self, a: DeviceId, b: DeviceId) -> bool {
        self.rack_of(a) == self.rack_of(b)
    }

    /// The interconnect tier a hop between two devices crosses.
    pub fn tier(&self, a: DeviceId, b: DeviceId) -> LinkTier {
        if self.same_node(a, b) {
            LinkTier::IntraNode
        } else if self.same_rack(a, b) {
            LinkTier::InterNode
        } else {
            LinkTier::InterRack
        }
    }

    /// Point-to-point bandwidth between two devices (bytes/s).
    pub fn bw(&self, a: DeviceId, b: DeviceId) -> f64 {
        match self.tier(a, b) {
            LinkTier::IntraNode => self.intra_bw,
            LinkTier::InterNode => self.inter_bw,
            LinkTier::InterRack => self.rack_bw,
        }
    }

    /// Point-to-point latency between two devices (seconds).
    pub fn lat(&self, a: DeviceId, b: DeviceId) -> f64 {
        match self.tier(a, b) {
            LinkTier::IntraNode => self.intra_lat,
            LinkTier::InterNode => self.inter_lat,
            LinkTier::InterRack => self.rack_lat,
        }
    }

    /// α–β transfer time for `bytes` between two devices.
    pub fn xfer_time(&self, a: DeviceId, b: DeviceId, bytes: f64) -> f64 {
        if a == b {
            0.0
        } else {
            self.lat(a, b) + bytes / self.bw(a, b)
        }
    }

    /// The effective bandwidth used for the overlap-degree computation in
    /// Algorithm 1: the *slowest* tier's bandwidth when the interconnect is
    /// heterogeneous (the algorithm minimizes traffic over the narrowest
    /// links first), otherwise the uniform bandwidth.
    pub fn planning_bw(&self) -> f64 {
        if self.racks > 1 {
            self.rack_bw
        } else if self.nodes > 1 {
            self.inter_bw
        } else {
            self.intra_bw
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn device_node_mapping() {
        let t = Topology::cluster_a(4, 8);
        assert_eq!(t.num_devices(), 32);
        assert_eq!(t.node_of(DeviceId(0)), NodeId(0));
        assert_eq!(t.node_of(DeviceId(7)), NodeId(0));
        assert_eq!(t.node_of(DeviceId(8)), NodeId(1));
        assert_eq!(t.node_of(DeviceId(31)), NodeId(3));
        let on2: Vec<_> = t.devices_on(NodeId(2)).collect();
        assert_eq!(on2.first(), Some(&DeviceId(16)));
        assert_eq!(on2.len(), 8);
    }

    #[test]
    fn bandwidth_hierarchy() {
        let t = Topology::cluster_a(4, 8);
        assert!(t.bw(DeviceId(0), DeviceId(1)) > t.bw(DeviceId(0), DeviceId(8)));
        assert!(t.same_node(DeviceId(0), DeviceId(7)));
        assert!(!t.same_node(DeviceId(7), DeviceId(8)));
    }

    #[test]
    fn xfer_time_alpha_beta() {
        let t = Topology::flat(4, 1e9);
        let d = t.xfer_time(DeviceId(0), DeviceId(1), 1e9);
        assert!((d - (1e-6 + 1.0)).abs() < 1e-9);
        assert_eq!(t.xfer_time(DeviceId(2), DeviceId(2), 1e9), 0.0);
    }

    #[test]
    fn cluster_b_faster_than_a() {
        let a = Topology::cluster_a(4, 8);
        let b = Topology::cluster_b(4, 8);
        assert!(b.inter_bw > a.inter_bw);
        assert!(b.device_flops > a.device_flops);
    }

    #[test]
    fn planning_bw_uses_internode_when_multinode() {
        let a = Topology::cluster_a(4, 8);
        assert_eq!(a.planning_bw(), a.inter_bw);
        let f = Topology::flat(8, 5e9);
        assert_eq!(f.planning_bw(), 5e9);
    }

    #[test]
    fn single_rack_topologies_have_two_tiers() {
        let t = Topology::cluster_a(4, 8);
        assert_eq!(t.racks, 1);
        assert_eq!(t.nodes_per_rack(), 4);
        assert_eq!(t.rack_of(DeviceId(0)), t.rack_of(DeviceId(31)));
        assert_eq!(t.tier(DeviceId(0), DeviceId(8)), LinkTier::InterNode);
        assert_eq!(t.rack_bw, t.inter_bw);
        assert_eq!(t.rack_lat, t.inter_lat);
    }

    #[test]
    fn rack_tier_maps_devices_and_routes_links() {
        let t = Topology::cluster_a(4, 2).with_racks(2);
        assert_eq!(t.nodes_per_rack(), 2);
        assert_eq!(t.rack_of(DeviceId(0)), RackId(0));
        assert_eq!(t.rack_of(DeviceId(3)), RackId(0));
        assert_eq!(t.rack_of(DeviceId(4)), RackId(1));
        assert!(t.same_rack(DeviceId(1), DeviceId(2)));
        assert!(!t.same_rack(DeviceId(3), DeviceId(4)));
        assert_eq!(t.tier(DeviceId(0), DeviceId(1)), LinkTier::IntraNode);
        assert_eq!(t.tier(DeviceId(0), DeviceId(2)), LinkTier::InterNode);
        assert_eq!(t.tier(DeviceId(0), DeviceId(4)), LinkTier::InterRack);
        assert_eq!(t.bw(DeviceId(0), DeviceId(4)), t.rack_bw);
        assert_eq!(t.lat(DeviceId(0), DeviceId(4)), t.rack_lat);
    }

    #[test]
    fn with_racks_derives_an_oversubscribed_spine() {
        let t = Topology::cluster_b(4, 8).with_racks(2);
        assert_eq!(t.rack_bw, t.inter_bw / 2.0);
        assert_eq!(t.rack_lat, t.inter_lat * 3.0);
        assert_eq!(t.planning_bw(), t.rack_bw);
        let custom = Topology::cluster_b(4, 8).with_racks(2).with_rack_links(7e9, 1e-4);
        assert_eq!(custom.rack_bw, 7e9);
        assert_eq!(custom.rack_lat, 1e-4);
        let d = custom.xfer_time(DeviceId(0), DeviceId(16), 7e9);
        assert!((d - (1e-4 + 1.0)).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "racks must evenly divide")]
    fn with_racks_rejects_nondividing_counts() {
        let _ = Topology::cluster_a(4, 8).with_racks(3);
    }
}
