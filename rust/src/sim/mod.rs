//! Discrete-event per-iteration simulator (placeholder — filled by the
//! systems/simulator milestone).

pub mod engine;
pub mod report;
