//! Discrete-event per-iteration cluster simulator.
//!
//! * [`engine`] — prices one training iteration of a system/model/cluster
//!   combination (compute, AllToAll, sparse collectives, rearrangement)
//!   via the α–β topology model, including fault-injection replay
//!   (`simulate_with_faults`).
//! * [`report`] — figure/table drivers reproducing the paper's artifacts
//!   (Table 1, Figures 3 and 9–15, §1 claims), the recovery/MTTR sweep,
//!   and the SPMD thread-scaling sweep that pairs the modeled per-iteration
//!   times with measured wall clock from the parallel executor.

pub mod engine;
pub mod report;
