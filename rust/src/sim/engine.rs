//! Per-iteration discrete-event simulation of MoE training.
//!
//! For each iteration the engine: draws the realized expert loads from the
//! [`crate::loadsim`] trace, lets the system under test plan placements
//! (seeing only *predicted* loads where the real system would), dispatches
//! tokens with [`crate::dispatch`], and accumulates the timeline:
//!
//! ```text
//!  per layer:  attn fwd ───── MoE: A2A → expert fwd → A2A ── … ──
//!              attn bwd(2×) ─ MoE bwd (2× fwd) ─ grad-sync ──
//!  overlap:    spAG hides under attn fwd; spRS (+re-mat spAG) and grad
//!              AllReduce hide under attn bwd; leftovers are exposed.
//! ```
//!
//! The cost model reproduces the paper's §3.1 bottleneck analysis: A2A is
//! bound by the busiest device port / node NIC, expert compute by the most
//! loaded device, and collective times come from the α–β models in
//! [`crate::collectives`].

use crate::checkpoint::faults::{recover, FaultSpec, RecoveryStats};
use crate::collectives::dense;
use crate::config::{ModelConfig, SystemConfig, TrainConfig};
use crate::dispatch::dispatch;
use crate::loadsim::{LoadPredictor, ModelLoadTrace};
use crate::systems::{build_system, GradSync, MatComm, MoeMemory, PlanCtx};
use crate::topology::Topology;
use crate::util::stats;

/// Timing breakdown of one iteration (seconds).
#[derive(Debug, Clone, Default)]
pub struct IterationStats {
    /// Dense attention compute, fwd + bwd, all layers.
    pub attn: f64,
    /// Expert compute (straggler-bound), fwd + bwd, all layers.
    pub expert: f64,
    /// All-to-All dispatch + combine, fwd + bwd, all layers.
    pub a2a: f64,
    /// Sparse/dense materialization + grad-sync time NOT hidden by overlap.
    pub exposed_comm: f64,
    /// Critical-path rearrangement traffic (incl. re-shard / transitions).
    pub rearrange: f64,
    /// Per-layer MoE time (a2a + expert + exposed) for Figure 11.
    pub per_layer_moe: Vec<f64>,
}

impl IterationStats {
    pub fn total(&self) -> f64 {
        self.attn + self.expert + self.a2a + self.exposed_comm + self.rearrange
    }
}

/// Aggregated simulation outcome.
#[derive(Debug, Clone)]
pub struct SimResult {
    pub system: String,
    /// Mean iteration time over the measured window.
    pub iter_time: f64,
    pub breakdown: IterationStats,
    pub memory: MoeMemory,
    /// Mean per-layer MoE time.
    pub per_layer_moe: Vec<f64>,
}

/// Simulation-wide knobs.
#[derive(Debug, Clone)]
pub struct SimOptions {
    pub iterations: usize,
    pub warmup: usize,
    /// Load-trace skew (Dirichlet α per layer family; see `ModelLoadTrace`).
    pub seed: u64,
    /// Override: force perfectly balanced loads (the §1 EP contrast).
    pub balanced_loads: bool,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions { iterations: 60, warmup: 10, seed: 42, balanced_loads: false }
    }
}

/// Simulate one system on one workload. Returns the averaged result.
pub fn simulate(
    topo: &Topology,
    model: &ModelConfig,
    sys_cfg: &SystemConfig,
    train: &TrainConfig,
    opts: &SimOptions,
) -> SimResult {
    let tokens_per_device = train.batch_per_device * model.seq_len;
    let attn_fwd = model.attention_fwd_flops(tokens_per_device) / topo.device_flops;
    let ctx = PlanCtx {
        topo: topo.clone(),
        model: model.clone(),
        tokens_per_device,
        attn_fwd_time: attn_fwd,
    };
    let mut system = build_system(sys_cfg);
    let mut trace = ModelLoadTrace::new(model.layers, model.experts, opts.seed);
    let mut predictors: Vec<LoadPredictor> = (0..model.layers)
        .map(|_| LoadPredictor::new(model.experts, train.predict_window))
        .collect();

    let nd = topo.num_devices();
    let token_bytes = (model.d_model * model.param_bytes) as f64;
    let mut measured: Vec<IterationStats> = Vec::new();
    let mut memory = MoeMemory::default();

    for iter in 0..opts.iterations {
        let realized: Vec<Vec<f64>> = if opts.balanced_loads {
            vec![vec![1.0 / model.experts as f64; model.experts]; model.layers]
        } else {
            trace.step()
        };
        let predicted: Vec<Vec<f64>> =
            predictors.iter().map(|p| p.predict()).collect();
        let plan = system.plan(iter, &ctx, &predicted, &realized);

        let mut it = IterationStats {
            rearrange: plan.global_critical_time,
            ..Default::default()
        };
        for (l, lp) in plan.layers.iter().enumerate() {
            // ---- dense attention (fwd + 2× bwd) ----
            let attn = 3.0 * attn_fwd;
            it.attn += attn;

            // ---- token dispatch / All-to-All ----
            // every device sees the same load distribution (iid data
            // parallel batches), realized[l]
            let asg: Vec<Vec<usize>> = (0..nd)
                .map(|_| {
                    realized[l]
                        .iter()
                        .map(|f| (f * tokens_per_device as f64 * model.top_k as f64).round()
                            as usize)
                        .collect()
                })
                .collect();
            let dplan = dispatch(&ctx.topo, &lp.placement, &asg);
            let matrix = dense::tokens_to_matrix(&dplan.sends, token_bytes);
            // dispatch + combine in fwd, and again in bwd: 4 one-way A2As
            let a2a = 4.0 * dense::alltoall_time(&ctx.topo, &matrix);
            it.a2a += a2a;

            // ---- expert compute (straggler-bound) ----
            let per_dev = dplan.device_compute_tokens();
            let max_tokens = per_dev.iter().copied().max().unwrap_or(0);
            let fwd = model.expert_fwd_flops(max_tokens) / topo.device_flops;
            let expert = 3.0 * fwd; // fwd + 2× bwd
            it.expert += expert;

            // ---- parameter collectives & overlap accounting ----
            let window_fwd = attn_fwd;
            let window_bwd = 2.0 * attn_fwd;
            let (mut exposed, mut used_bwd) = (0.0, 0.0);
            match &lp.mat_comm {
                MatComm::None => {}
                MatComm::Spag { time, remat } => {
                    // split: spAG ~ half the pair cost (Eq. 1 symmetry)
                    let spag = time * 0.5;
                    let sprs = time * 0.5;
                    exposed += (spag - window_fwd).max(0.0);
                    let bwd_comm = sprs + if *remat { spag } else { 0.0 };
                    used_bwd = bwd_comm.min(window_bwd);
                    exposed += (bwd_comm - window_bwd).max(0.0);
                }
                MatComm::DenseAg { time } => {
                    // AG before fwd, AG before bwd (re-gather), RS after bwd
                    exposed += (time - window_fwd).max(0.0);
                    let bwd_comm = 2.0 * time;
                    used_bwd = bwd_comm.min(window_bwd);
                    exposed += (bwd_comm - window_bwd).max(0.0);
                }
                MatComm::Critical { time } => {
                    it.rearrange += time;
                }
            }
            // gradient sync of replicas overlaps with what's left of bwd
            if let GradSync::AllReduceReplicas = lp.grad_sync {
                let mut ar = 0.0;
                for e in 0..lp.placement.num_chunks() {
                    let group: Vec<_> = lp.placement.holders(e).collect();
                    if group.len() > 1 {
                        ar += dense::allreduce_time(&ctx.topo, &group, ctx.expert_bytes());
                    }
                }
                let leftover = (window_bwd - used_bwd).max(0.0);
                exposed += (ar - leftover).max(0.0);
            }
            it.exposed_comm += exposed;
            it.per_layer_moe.push(a2a + expert + exposed);
        }

        // feed the predictors AFTER planning (next iteration sees this one)
        for (p, r) in predictors.iter_mut().zip(realized.iter()) {
            p.observe(r);
        }

        if iter >= opts.warmup {
            measured.push(it);
        }
        if iter + 1 == opts.iterations {
            memory = system.memory(&ctx, &plan);
        }
    }

    let n = measured.len().max(1) as f64;
    let mut agg = IterationStats::default();
    let mut per_layer = vec![0.0; model.layers];
    for it in &measured {
        agg.attn += it.attn / n;
        agg.expert += it.expert / n;
        agg.a2a += it.a2a / n;
        agg.exposed_comm += it.exposed_comm / n;
        agg.rearrange += it.rearrange / n;
        for (l, t) in it.per_layer_moe.iter().enumerate() {
            per_layer[l] += t / n;
        }
    }
    SimResult {
        system: sys_cfg.kind.name().to_string(),
        iter_time: agg.total(),
        breakdown: agg,
        memory,
        per_layer_moe: per_layer,
    }
}

/// Outcome of a fault-injected simulation run.
#[derive(Debug, Clone)]
pub struct FaultRunResult {
    /// The fault-free steady state (iteration time, breakdown, memory).
    pub sim: SimResult,
    /// Recovery cost breakdown of the injected failure.
    pub recovery: RecoveryStats,
    /// Wall-clock of the whole timeline: `iterations` productive steps +
    /// snapshot overhead + the failure's MTTR (replay included).
    pub total_wall_clock: f64,
    /// Wall-clock of the same timeline had nothing failed and nothing been
    /// checkpointed (the lower bound).
    pub ideal_wall_clock: f64,
}

impl FaultRunResult {
    /// Effective slowdown of the faulty run vs the ideal one.
    pub fn slowdown(&self) -> f64 {
        self.total_wall_clock / self.ideal_wall_clock.max(1e-12)
    }
}

/// Fault-injection mode: run the simulation, kill device
/// `spec.fail_device` at step `spec.fail_step`, restart from the last
/// snapshot, and replay. The numeric equivalence of replay is proven by
/// `rust/tests/checkpoint_resume.rs`; here the *time* of the whole
/// timeline is accounted:
///
/// ```text
/// |-- productive steps (+ snapshot overhead every k) --| X |-- MTTR --|
/// ```
pub fn simulate_with_faults(
    topo: &Topology,
    model: &ModelConfig,
    sys_cfg: &SystemConfig,
    train: &TrainConfig,
    opts: &SimOptions,
    spec: &FaultSpec,
) -> FaultRunResult {
    let sim = simulate(topo, model, sys_cfg, train, opts);
    let iter_time = sim.iter_time;
    let spec = FaultSpec {
        fail_step: spec.fail_step.min(opts.iterations.saturating_sub(1)),
        fail_device: spec.fail_device % topo.num_devices().max(1),
        ..*spec
    };
    let recovery = recover(topo, model, iter_time, &spec);

    // Timeline: every iteration runs once productively; iterations since
    // the last snapshot run again as replay (inside recovery.replay);
    // snapshots before the failure (and after, until the horizon) each pay
    // their write time.
    let n = opts.iterations as f64;
    let snapshots = if spec.checkpoint_every == 0 {
        0.0
    } else {
        (opts.iterations / spec.checkpoint_every) as f64
    };
    let ideal = n * iter_time;
    let total = ideal + snapshots * recovery.checkpoint_time + recovery.mttr;
    FaultRunResult {
        sim,
        recovery,
        total_wall_clock: total,
        ideal_wall_clock: ideal,
    }
}

/// Convenience: speedups of `systems` relative to the first entry (EP in
/// the paper's figures).
pub fn relative_speedups(results: &[SimResult]) -> Vec<f64> {
    let base = results[0].iter_time;
    results.iter().map(|r| base / r.iter_time).collect()
}

/// Geo-mean speedup of `a` over `b` across paired workload results.
pub fn geomean_speedup(a: &[f64], b: &[f64]) -> f64 {
    let ratios: Vec<f64> = a.iter().zip(b.iter()).map(|(x, y)| y / x).collect();
    stats::geomean(&ratios)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterPreset, SystemKind};

    fn quick_opts() -> SimOptions {
        SimOptions { iterations: 20, warmup: 5, seed: 7, balanced_loads: false }
    }

    fn setup() -> (Topology, ModelConfig, TrainConfig) {
        let topo = ClusterPreset::A.build(2, 4);
        let model = ModelConfig::preset("gpt-moe-s").unwrap().with_experts(16);
        let train = TrainConfig { batch_per_device: 1, ..Default::default() };
        (topo, model, train)
    }

    #[test]
    fn ep_imbalanced_slower_than_balanced() {
        // §1: imbalanced loads slow EP down by up to 5.18×.
        let (topo, model, train) = setup();
        let cfg = SystemConfig::new(SystemKind::Ep);
        let imb = simulate(&topo, &model, &cfg, &train, &quick_opts());
        let bal = simulate(
            &topo,
            &model,
            &cfg,
            &train,
            &SimOptions { balanced_loads: true, ..quick_opts() },
        );
        let slowdown = imb.iter_time / bal.iter_time;
        assert!(slowdown > 1.5, "EP slowdown under imbalance: {slowdown:.2}");
    }

    #[test]
    fn hecate_beats_ep_under_imbalance() {
        let (topo, model, train) = setup();
        let ep = simulate(&topo, &model, &SystemConfig::new(SystemKind::Ep), &train, &quick_opts());
        let hec = simulate(
            &topo,
            &model,
            &SystemConfig::new(SystemKind::Hecate),
            &train,
            &quick_opts(),
        );
        let speedup = ep.iter_time / hec.iter_time;
        assert!(speedup > 1.2, "Hecate speedup over EP: {speedup:.2}");
    }

    #[test]
    fn hecate_rm_slower_but_leaner_than_hecate() {
        let (topo, model, train) = setup();
        let hec = simulate(
            &topo,
            &model,
            &SystemConfig::new(SystemKind::Hecate),
            &train,
            &quick_opts(),
        );
        let rm = simulate(
            &topo,
            &model,
            &SystemConfig::new(SystemKind::HecateRm),
            &train,
            &quick_opts(),
        );
        assert!(rm.iter_time >= hec.iter_time, "RM pays re-materialization");
        assert!(rm.memory.params < hec.memory.params, "RM frees parameter memory");
    }

    #[test]
    fn fsdp_exposed_comm_dominates() {
        // §2.4: FSDP's |E|× communication cannot hide under attention.
        let (topo, model, train) = setup();
        let fsdp = simulate(
            &topo,
            &model,
            &SystemConfig::new(SystemKind::Fsdp),
            &train,
            &quick_opts(),
        );
        assert!(
            fsdp.breakdown.exposed_comm > fsdp.breakdown.attn,
            "exposed {} vs attn {}",
            fsdp.breakdown.exposed_comm,
            fsdp.breakdown.attn
        );
    }

    #[test]
    fn breakdown_sums_to_total() {
        let (topo, model, train) = setup();
        let r = simulate(
            &topo,
            &model,
            &SystemConfig::new(SystemKind::Hecate),
            &train,
            &quick_opts(),
        );
        let b = &r.breakdown;
        assert!((b.total() - r.iter_time).abs() < 1e-12);
        assert!(b.attn > 0.0 && b.a2a > 0.0 && b.expert > 0.0);
        assert_eq!(r.per_layer_moe.len(), model.layers);
    }

    #[test]
    fn memory_ordering_matches_figure13() {
        // SmartMoE ≈ EP ≤ Hecate-RM < Hecate < FlexMoE.
        let (topo, model, train) = setup();
        let o = quick_opts();
        let mem = |k: SystemKind| {
            simulate(&topo, &model, &SystemConfig::new(k), &train, &o).memory.total()
        };
        let ep = mem(SystemKind::Ep);
        let smart = mem(SystemKind::SmartMoe);
        let hec = mem(SystemKind::Hecate);
        let rm = mem(SystemKind::HecateRm);
        let flex = mem(SystemKind::FlexMoe);
        assert!((smart - ep).abs() < 1e-6 * ep, "SmartMoE ≈ EP");
        assert!(rm < hec, "RM below Hecate");
        assert!(flex > hec, "FlexMoE above Hecate (replicated opt)");
    }

    #[test]
    fn fault_injection_accounts_recovery() {
        let (topo, model, train) = setup();
        let cfg = SystemConfig::new(SystemKind::Hecate);
        let spec = FaultSpec { fail_step: 13, checkpoint_every: 5, ..Default::default() };
        let r = simulate_with_faults(&topo, &model, &cfg, &train, &quick_opts(), &spec);
        assert_eq!(r.recovery.replay_iters, 13 % 5);
        assert!(r.total_wall_clock > r.ideal_wall_clock);
        assert!(r.slowdown() > 1.0);
        assert!(r.recovery.mttr >= r.recovery.detect);

        // No checkpointing: every step since 0 replays.
        let none = FaultSpec { fail_step: 13, checkpoint_every: 0, ..Default::default() };
        let r0 = simulate_with_faults(&topo, &model, &cfg, &train, &quick_opts(), &none);
        assert_eq!(r0.recovery.replay_iters, 13);
        assert!(r0.recovery.replay > r.recovery.replay);
        assert_eq!(r0.recovery.restore_io, 0.0);
    }

    #[test]
    fn speedup_helpers() {
        let (topo, model, train) = setup();
        let o = quick_opts();
        let results = vec![
            simulate(&topo, &model, &SystemConfig::new(SystemKind::Ep), &train, &o),
            simulate(&topo, &model, &SystemConfig::new(SystemKind::Hecate), &train, &o),
        ];
        let sp = relative_speedups(&results);
        assert_eq!(sp[0], 1.0);
        assert!(sp[1] > 1.0);
    }
}
