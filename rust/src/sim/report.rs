//! Figure/table reproduction drivers. Each function regenerates one paper
//! artifact (Table 1, Figures 3 and 9–15, plus the §1 claims) as a
//! [`Table`], printed by `hecate repro` and recorded in EXPERIMENTS.md.

use crate::checkpoint::faults::{recover, FaultSpec};
use crate::config::{ClusterPreset, ModelConfig, SystemConfig, SystemKind, TrainConfig};
use crate::fssdp::{ComputeMode, StepPhases};
use crate::loadsim::ModelLoadTrace;
use crate::metrics::Table;
use crate::sim::engine::{simulate, SimOptions, SimResult};
use crate::topology::Topology;
use crate::util::stats;

fn fmt(x: f64) -> String {
    format!("{x:.2}")
}

fn ms(x: f64) -> String {
    format!("{:.1}", x * 1e3)
}

fn gb(x: f64) -> String {
    format!("{:.2}", x / 1e9)
}

/// Default measured window for figure reproduction.
pub fn default_opts() -> SimOptions {
    SimOptions { iterations: 60, warmup: 10, seed: 42, balanced_loads: false }
}

/// Paper methodology (§5.1): "the largest batch size that did not cause an
/// OOM error in any system" — short-sequence models fit proportionally
/// larger batches. We target ~8k tokens per device.
pub fn paper_batch(model: &ModelConfig) -> usize {
    (8192 / model.seq_len).max(1)
}

/// Table 1: model architectures.
pub fn table1() -> Table {
    let mut t = Table::new(&["Model", "d_model", "SeqLen", "Layers", "Experts", "Params"]);
    for m in ModelConfig::all_paper_models() {
        t.row(vec![
            m.name.clone(),
            m.d_model.to_string(),
            m.seq_len.to_string(),
            m.layers.to_string(),
            m.experts.to_string(),
            format!("{:.2}B", m.total_params() as f64 / 1e9),
        ]);
    }
    t
}

/// Figure 3: expert-load distribution over iterations (token proportion of
/// the hottest/median/coldest expert, plus straggler factor).
pub fn figure3(iterations: usize) -> Table {
    let mut t = Table::new(&["iter", "max_frac", "p50_frac", "min_frac", "straggler"]);
    let mut gen = ModelLoadTrace::new(1, 64, 42);
    for i in 0..iterations {
        let f = &gen.step()[0];
        let mut sorted = f.clone();
        sorted.sort_by(|a, b| a.total_cmp(b));
        t.row(vec![
            i.to_string(),
            format!("{:.3}", sorted[63]),
            format!("{:.4}", sorted[32]),
            format!("{:.5}", sorted[0]),
            fmt(stats::straggler_factor(f)),
        ]);
    }
    t
}

/// Shared worker for Figures 9 & 10: speedup vs EP for all systems, all
/// four models, at `gpus` devices on `cluster`.
pub fn end_to_end(cluster: ClusterPreset, nodes: usize, dpn: usize, opts: &SimOptions) -> Table {
    let topo = cluster.build(nodes, dpn);
    let gpus = topo.num_devices();
    // weak scaling: 32 experts at 16 GPUs, 64 at 32 (paper §5.2)
    let experts = if gpus <= 16 { 32 } else { 64 };
    let cols = ["Model", "GPUs", "EP", "FasterMoE", "SmartMoE", "FlexMoE", "Hecate", "Hecate/best"];
    let mut t = Table::new(&cols);
    for model in ModelConfig::all_paper_models() {
        let model = model.with_experts(experts);
        let train = TrainConfig { batch_per_device: paper_batch(&model), ..Default::default() };
        let results: Vec<SimResult> = SystemKind::paper_lineup()
            .iter()
            .map(|&k| simulate(&topo, &model, &SystemConfig::new(k), &train, opts))
            .collect();
        let ep_time = results[0].iter_time;
        let speedups: Vec<f64> = results.iter().map(|r| ep_time / r.iter_time).collect();
        let best_baseline = speedups[..4].iter().cloned().fold(f64::MIN, f64::max);
        let hecate = speedups[4];
        t.row(vec![
            model.name.clone(),
            gpus.to_string(),
            fmt(speedups[0]),
            fmt(speedups[1]),
            fmt(speedups[2]),
            fmt(speedups[3]),
            fmt(hecate),
            fmt(hecate / best_baseline),
        ]);
    }
    t
}

/// Figure 9: Cluster A (16 and 32 GPUs).
pub fn figure9(opts: &SimOptions) -> Vec<Table> {
    vec![
        end_to_end(ClusterPreset::A, 2, 8, opts),
        end_to_end(ClusterPreset::A, 4, 8, opts),
    ]
}

/// Figure 10: Cluster B (32 GPUs).
pub fn figure10(opts: &SimOptions) -> Table {
    end_to_end(ClusterPreset::B, 4, 8, opts)
}

/// Figure 11: layer-wise MoE speedup of Hecate over EP (GPT-MoE-S, B).
pub fn figure11(opts: &SimOptions) -> Table {
    let topo = ClusterPreset::B.build(4, 8);
    let model = ModelConfig::preset("gpt-moe-s").unwrap();
    let train = TrainConfig { batch_per_device: paper_batch(&model), ..Default::default() };
    let ep = simulate(&topo, &model, &SystemConfig::new(SystemKind::Ep), &train, opts);
    let hec = simulate(&topo, &model, &SystemConfig::new(SystemKind::Hecate), &train, opts);
    let mut t = Table::new(&["layer", "EP_moe_ms", "Hecate_moe_ms", "speedup"]);
    let mut speedups = Vec::new();
    for l in 0..model.layers {
        let s = ep.per_layer_moe[l] / hec.per_layer_moe[l];
        speedups.push(s);
        t.row(vec![l.to_string(), ms(ep.per_layer_moe[l]), ms(hec.per_layer_moe[l]), fmt(s)]);
    }
    t.row(vec!["geomean".into(), "".into(), "".into(), fmt(stats::geomean(&speedups))]);
    t
}

/// Figure 12: critical-path breakdown (BERT-MoE-Deep, Cluster B).
pub fn figure12(opts: &SimOptions) -> Table {
    let topo = ClusterPreset::B.build(4, 8);
    let model = ModelConfig::preset("bert-moe-deep").unwrap();
    let train = TrainConfig { batch_per_device: paper_batch(&model), ..Default::default() };
    let mut t = Table::new(&[
        "System", "Attn_ms", "ExpertComp_ms", "A2A_ms", "SparseColl/Rearr_ms", "Total_ms",
    ]);
    let mut kinds = SystemKind::paper_lineup();
    kinds.push(SystemKind::HecateRm);
    for k in kinds {
        let r = simulate(&topo, &model, &SystemConfig::new(k), &train, opts);
        let b = &r.breakdown;
        t.row(vec![
            r.system.clone(),
            ms(b.attn),
            ms(b.expert),
            ms(b.a2a),
            ms(b.exposed_comm + b.rearrange),
            ms(r.iter_time),
        ]);
    }
    t
}

/// Figure 13: peak MoE memory (opt / grad / param) per system.
pub fn figure13(opts: &SimOptions) -> Table {
    let topo = ClusterPreset::B.build(4, 8);
    let model = ModelConfig::preset("bert-moe-deep").unwrap();
    let train = TrainConfig { batch_per_device: paper_batch(&model), ..Default::default() };
    let mut t = Table::new(&["System", "Opt_GB", "Grad_GB", "Param_GB", "Total_GB", "vs_EP"]);
    let mut kinds = SystemKind::paper_lineup();
    kinds.push(SystemKind::HecateRm);
    let ep_total = simulate(&topo, &model, &SystemConfig::new(SystemKind::Ep), &train, opts)
        .memory
        .total();
    for k in kinds {
        let r = simulate(&topo, &model, &SystemConfig::new(k), &train, opts);
        let m = &r.memory;
        t.row(vec![
            r.system.clone(),
            gb(m.opt),
            gb(m.grads),
            gb(m.params),
            gb(m.total()),
            fmt(m.total() / ep_total),
        ]);
    }
    t
}

/// Figure 14: GPT-MoE-S across batch sizes 1..6; iteration time and OOM
/// frontier (activation memory grows with batch; Hecate-RM survives
/// longest).
pub fn figure14(opts: &SimOptions) -> Table {
    let topo = ClusterPreset::A.build(4, 8);
    let model = ModelConfig::preset("gpt-moe-s").unwrap();
    let mut t = Table::new(&["batch", "EP_ms", "FlexMoE_ms", "Hecate_ms", "HecateRM_ms"]);
    for batch in 1..=6usize {
        let train = TrainConfig { batch_per_device: batch, ..Default::default() };
        // activation estimate per device: tokens × d_model × layers ×
        // ~24 bytes (fwd activations kept for bwd, fp16 + ln/attn temps)
        let act = (batch * model.seq_len * model.d_model * model.layers * 24) as f64;
        let dense_base = 2e9; // dense params/opt/grads (DP-replicated)
        let mut row = vec![batch.to_string()];
        for k in [SystemKind::Ep, SystemKind::FlexMoe, SystemKind::Hecate, SystemKind::HecateRm] {
            let r = simulate(&topo, &model, &SystemConfig::new(k), &train, opts);
            let mem = r.memory.total() + act + dense_base;
            if mem > topo.device_mem {
                row.push("OOM".to_string());
            } else {
                row.push(ms(r.iter_time));
            }
        }
        t.row(row);
    }
    t
}

/// Figure 15a: component ablation (sharding × materialization).
pub fn figure15a(opts: &SimOptions) -> Table {
    let topo = ClusterPreset::A.build(4, 8);
    let model = ModelConfig::preset("gpt-moe-s").unwrap();
    let train = TrainConfig { batch_per_device: paper_batch(&model), ..Default::default() };
    let ep = simulate(&topo, &model, &SystemConfig::new(SystemKind::Ep), &train, opts);
    let mut t = Table::new(&["Sharding", "Materialization", "iter_ms", "speedup_vs_EP"]);
    for (sh, mat) in [(false, false), (true, false), (false, true), (true, true)] {
        let mut cfg = SystemConfig::new(SystemKind::Hecate);
        cfg.hetero_sharding = sh;
        cfg.sparse_materialization = mat;
        let r = simulate(&topo, &model, &cfg, &train, opts);
        t.row(vec![
            sh.to_string(),
            mat.to_string(),
            ms(r.iter_time),
            fmt(ep.iter_time / r.iter_time),
        ]);
    }
    t
}

/// Figure 15b: re-sharding interval sweep.
pub fn figure15b(opts: &SimOptions) -> Table {
    let topo = ClusterPreset::A.build(4, 8);
    let model = ModelConfig::preset("gpt-moe-s").unwrap();
    let ep_train = TrainConfig { batch_per_device: paper_batch(&model), ..Default::default() };
    let ep = simulate(&topo, &model, &SystemConfig::new(SystemKind::Ep), &ep_train, opts);
    let mut t = Table::new(&["reshard_interval", "iter_ms", "speedup_vs_EP"]);
    for interval in [10usize, 25, 50, 100] {
        let mut cfg = SystemConfig::new(SystemKind::Hecate);
        cfg.reshard_interval = interval;
        let train = TrainConfig {
            batch_per_device: 4,
            reshard_interval: interval,
            ..Default::default()
        };
        let r = simulate(&topo, &model, &cfg, &train, opts);
        t.row(vec![interval.to_string(), ms(r.iter_time), fmt(ep.iter_time / r.iter_time)]);
    }
    t
}

/// Recovery-time / MTTR table for the fault-injection mode: each row
/// sweeps the snapshot interval (0 = checkpointing disabled) for a device
/// failure at `base.fail_step`. `iter_time` is the fault-free steady-state
/// iteration time (the caller already simulated it — see
/// `simulate_with_faults` — so no second simulation runs here).
///
/// Columns: interval, snapshot size/time, steady-state overhead (% of an
/// iteration), then the MTTR breakdown (detect + restore + redistribute +
/// replay) of the injected failure.
pub fn recovery_table(
    topo: &Topology,
    model: &ModelConfig,
    iter_time: f64,
    base: &FaultSpec,
) -> Table {
    let mut t = Table::new(&[
        "ckpt_every",
        "ckpt_GB",
        "ckpt_s",
        "overhead_%",
        "detect_s",
        "restore_s",
        "redistr_s",
        "replay_iters",
        "replay_s",
        "MTTR_s",
    ]);
    for interval in [0usize, 10, 25, 50, 100] {
        let spec = FaultSpec { checkpoint_every: interval, ..*base };
        let r = recover(topo, model, iter_time, &spec);
        t.row(vec![
            if interval == 0 { "none".into() } else { interval.to_string() },
            gb(r.checkpoint_bytes),
            fmt(r.checkpoint_time),
            fmt(100.0 * r.steady_overhead / iter_time.max(1e-12)),
            fmt(r.detect),
            fmt(r.restore_io),
            fmt(r.redistribute),
            r.replay_iters.to_string(),
            fmt(r.replay),
            fmt(r.mttr),
        ]);
    }
    t
}

/// SPMD thread-scaling sweep (`hecate bench spmd`): the reference numeric
/// engine run sequentially and on the SPMD executor at 1/2/4/8 ranks.
/// The `modeled_comm_ms` column is the α–β bottleneck prediction (Eq. 1)
/// for the first iteration's spAG+spRS; the `*_ms_per_iter` columns are
/// **measured wall clock** on this host — the simulator's modeled times
/// paired with physically executed ones, per the SPMD milestone.
///
/// `transport` picks the fabric under the SPMD column: the in-process mpsc
/// backend, or (`--transport socket`) real unix sockets speaking the wire
/// codec — the modeled α–β comm then sits next to measured socket wall
/// clock, framing/syscall overhead included. `mode` selects the compute
/// tier both columns run at (`--compute-mode fast` benches the SIMD
/// kernels) and `compute_threads` sizes each rank's kernel worker pool
/// (`--compute-threads`, also applied to the sequential column so the
/// speedup stays like-for-like).
pub fn spmd_scaling(
    iters: usize,
    quick: bool,
    transport: crate::spmd::transport::TransportKind,
    mode: ComputeMode,
    compute_threads: usize,
) -> anyhow::Result<Table> {
    use crate::fssdp::{build_iter_plan, LayerDims, Session, SessionConfig};
    use crate::materialize::MatConstraints;
    use std::time::Instant;

    let dims = if quick {
        crate::fssdp::reference_dims()
    } else {
        // big enough that expert compute dominates thread overhead
        LayerDims { tokens: 128, d_model: 64, d_ffn: 128, experts: 16, cap: 32 }
    };
    let iters = iters.max(1);
    let mut t = Table::new(&[
        "threads", "modeled_comm_ms", "seq_ms_per_iter", "spmd_ms_per_iter", "speedup",
        "straggler_skew", "peak_resident_kb", "imbalance",
    ]);
    for &d in &[1usize, 2, 4, 8] {
        let topo =
            if d == 1 { Topology::flat(1, 150e9) } else { Topology::cluster_a(2, d / 2) };
        // weak scaling: one logical data shard per rank
        let session = |parallel: bool| -> anyhow::Result<Session> {
            let mut b = SessionConfig::builder()
                .reference()
                .dims(dims)
                .topology(topo.clone())
                .seed(11)
                .data_shards(d)
                .compute_mode(mode)
                .compute_threads(compute_threads.max(1));
            if parallel {
                // trace + meter the SPMD run so the table can report
                // realized compute skew, peak resident memory, and load
                // imbalance next to the wall clock
                b = b.parallel(true).threads(d).trace(true).metrics(true).transport(transport);
            }
            Session::fresh(b.build()?)
        };
        // modeled: first-iteration collectives under the cold-start
        // (uniform) prediction, priced by the bottleneck analysis
        let mut probe = session(false)?;
        let uniform = vec![1.0 / dims.experts as f64; dims.experts];
        let plan = build_iter_plan(
            &topo,
            probe.engine().shards(),
            &uniform,
            MatConstraints {
                overlap_degree: probe.engine().overlap_degree,
                mem_slots: probe.engine().mem_slots,
            },
        )?;
        let chunk_bytes = dims.chunk_len() as f64 * 4.0;
        let modeled = plan.spag.time(&topo, chunk_bytes) + plan.sprs.time(&topo, chunk_bytes);
        // measured: same workload, both executors
        let t0 = Instant::now();
        probe.run(iters)?;
        let seq = t0.elapsed().as_secs_f64() / iters as f64;
        let mut par = session(true)?;
        let t0 = Instant::now();
        par.run(iters)?;
        let spmd = t0.elapsed().as_secs_f64() / iters as f64;
        let skew =
            crate::telemetry::analyze::analyze(par.trace_events().unwrap_or(&[])).max_skew();
        let (peak_kb, imbalance) = meter_columns(par.meter_samples());
        t.row(vec![
            d.to_string(),
            format!("{:.4}", modeled * 1e3),
            ms(seq),
            ms(spmd),
            fmt(seq / spmd.max(1e-12)),
            format!("{skew:.2}"),
            format!("{peak_kb:.1}"),
            format!("{imbalance:.2}"),
        ]);
    }
    Ok(t)
}

/// Figure 11 on the **numeric engine**: per-layer exposed materialization
/// time of an L-layer SPMD run with the §4.3 cross-layer pipeline off vs
/// on, under α–β link pacing (so spAG wire time is physically on the
/// clock). The `hidden_%` column is how much of each layer's spAG wait the
/// pipeline removed — the executed counterpart of the simulator's
/// layer-wise speedup bars.
pub fn numeric_figure11(layers: usize, iters: usize) -> anyhow::Result<Table> {
    use crate::fssdp::{reference_dims, Session, SessionConfig};
    use crate::spmd::comm::Pacing;

    let dims = reference_dims();
    let chunk_bytes = dims.chunk_len() as f64 * 4.0;
    // pace links so one chunk transfer costs ~0.3 ms of wall clock
    let pacing = Pacing::uniform(chunk_bytes / 300e-6, 20e-6);
    let run = |overlap: bool| -> anyhow::Result<Session> {
        let cfg = SessionConfig::builder()
            .reference()
            .dims(dims)
            .topology(Topology::cluster_a(2, 2))
            .layers(layers)
            .seed(11)
            .data_shards(4)
            .parallel(true)
            .threads(4)
            .overlap(overlap)
            .pacing(pacing)
            .build()?;
        let mut s = Session::fresh(cfg)?;
        s.run(iters.max(1))?;
        Ok(s)
    };
    let off = run(false)?;
    let on = run(true)?;
    let mut t = Table::new(&[
        "layer", "compute_ms", "spag_wait_off_ms", "spag_wait_on_ms", "hidden_%",
    ]);
    for l in 0..layers {
        let m_on = on.spmd_metrics().expect("spmd span ran");
        let m_off = off.spmd_metrics().expect("spmd span ran");
        let comp = m_on.timer(&format!("spmd.compute.l{l}")).as_secs_f64();
        let woff = m_off.timer(&format!("spmd.spag_wait.l{l}")).as_secs_f64();
        let won = m_on.timer(&format!("spmd.spag_wait.l{l}")).as_secs_f64();
        let hidden = if woff > 0.0 { 100.0 * (1.0 - won / woff) } else { 0.0 };
        t.row(vec![l.to_string(), ms(comp), ms(woff), ms(won), fmt(hidden)]);
    }
    Ok(t)
}

/// Figure 15b on the **numeric engine**: the re-sharding interval sweep
/// executed rather than modeled — Algorithm 2 actually re-runs inside the
/// run every K iterations, chunks migrate, and the loss keeps training.
pub fn numeric_figure15b(layers: usize, iters: usize) -> anyhow::Result<Table> {
    use crate::fssdp::{reference_dims, Session, SessionConfig};
    use std::time::Instant;

    let dims = reference_dims();
    let mut t =
        Table::new(&["reshard_every", "wall_ms_per_iter", "final_loss", "experts_moved"]);
    for &k in &[0usize, 2, 4, 8] {
        let cfg = SessionConfig::builder()
            .reference()
            .dims(dims)
            .topology(Topology::cluster_a(2, 2))
            .layers(layers)
            .seed(11)
            .data_shards(4)
            .reshard_every(k)
            .build()?;
        let mut s = Session::fresh(cfg)?;
        let t0 = Instant::now();
        let stats = s.run(iters)?;
        let wall = t0.elapsed().as_secs_f64() / iters.max(1) as f64;
        t.row(vec![
            if k == 0 { "never".into() } else { k.to_string() },
            ms(wall),
            format!("{:.5}", stats.last().map(|st| st.loss).unwrap_or(0.0)),
            s.reshards_moved().to_string(),
        ]);
    }
    Ok(t)
}

/// Cross-layer overlap sweep (`hecate bench spmd`): L-layer SPMD runs with
/// the §4.3 pipeline on vs off under α–β link pacing. At L ≥ 2 the
/// pipeline issues layer `l+1`'s spAG under layer `l`'s compute and sinks
/// layer `l+1`'s spRS under layer `l`'s backward, so the on-column should
/// win wall clock on any host.
pub fn spmd_overlap(iters: usize, quick: bool) -> anyhow::Result<Table> {
    use crate::fssdp::{reference_dims, LayerDims, Session, SessionConfig};
    use crate::spmd::comm::Pacing;
    use std::time::Instant;

    let dims = if quick {
        reference_dims()
    } else {
        LayerDims { tokens: 64, d_model: 32, d_ffn: 64, experts: 8, cap: 32 }
    };
    let iters = iters.max(1);
    let chunk_bytes = dims.chunk_len() as f64 * 4.0;
    let pacing = Pacing::uniform(chunk_bytes / 400e-6, 20e-6);
    let mut t = Table::new(&[
        "layers", "overlap_off_ms_per_iter", "overlap_on_ms_per_iter", "speedup",
        "overlap_eff_off_%", "overlap_eff_on_%", "peak_resident_kb", "imbalance",
    ]);
    let pct = |eff: Option<f64>| eff.map(|p| format!("{p:.1}")).unwrap_or_else(|| "n/a".into());
    for &nl in &[1usize, 2, 3] {
        // traced + metered runs: the §4.3 overlap efficiency, peak
        // resident memory, and realized load imbalance land next to the
        // wall clock
        let run = |overlap: bool| -> anyhow::Result<(f64, Option<f64>, f64, f64)> {
            let cfg = SessionConfig::builder()
                .reference()
                .dims(dims)
                .topology(Topology::cluster_a(2, 2))
                .layers(nl)
                .seed(11)
                .data_shards(4)
                .parallel(true)
                .threads(4)
                .overlap(overlap)
                .pacing(pacing)
                .trace(true)
                .metrics(true)
                .build()?;
            let mut s = Session::fresh(cfg)?;
            let t0 = Instant::now();
            s.run(iters)?;
            let wall = t0.elapsed().as_secs_f64() / iters as f64;
            let eff =
                crate::telemetry::analyze::analyze(s.trace_events().unwrap_or(&[])).overlap_pct();
            let (peak_kb, imbalance) = meter_columns(s.meter_samples());
            Ok((wall, eff, peak_kb, imbalance))
        };
        let (off, eff_off, _, _) = run(false)?;
        let (on, eff_on, peak_kb, imbalance) = run(true)?;
        t.row(vec![
            nl.to_string(),
            ms(off),
            ms(on),
            fmt(off / on.max(1e-12)),
            pct(eff_off),
            pct(eff_on),
            format!("{peak_kb:.1}"),
            format!("{imbalance:.2}"),
        ]);
    }
    Ok(t)
}

/// The step-meter columns shared by the SPMD bench tables: worst-rank
/// peak resident expert memory (KB) and mean realized load imbalance
/// across the run's load samples (`1.00` when unmetered or no samples).
fn meter_columns(meter: Option<&crate::metrics::meter::StepMeter>) -> (f64, f64) {
    let Some(m) = meter else {
        return (0.0, 1.0);
    };
    let peak = m.mem_samples().iter().map(|s| s.resident_bytes).max().unwrap_or(0);
    let load = m.load_samples();
    let imbalance = if load.is_empty() {
        1.0
    } else {
        load.iter().map(|s| s.imbalance).sum::<f64>() / load.len() as f64
    };
    (peak as f64 / 1024.0, imbalance)
}

/// Per-phase deltas between two cumulative [`StepPhases`] samples
/// (monotone accumulation, so `b >= a` component-wise).
fn phase_delta(a: StepPhases, b: StepPhases) -> StepPhases {
    StepPhases {
        materialize: b.materialize - a.materialize,
        gate: b.gate - a.gate,
        expert_fwd: b.expert_fwd - a.expert_fwd,
        expert_bwd: b.expert_bwd - a.expert_bwd,
        sprs: b.sprs - a.sprs,
        adam: b.adam - a.adam,
        steps: b.steps - a.steps,
    }
}

/// `hecate bench step`: the hermetic 8-device, 3-layer training step
/// timed end-to-end and per phase (materialize/spAG, gate, expert fwd,
/// expert bwd, spRS, Adam+release) — the zero-copy hot path's acceptance
/// benchmark. Always measures the Reference tier; measures the Fast tier
/// next to it when `mode` selects it or a JSON report is requested, and
/// with `compute_threads > 1` also the scoped-thread kernel split of each
/// tier (bit-identical results in Reference mode, different wall clock).
/// With `write_json`, writes `BENCH_runtime_step.json` in the working
/// directory so CI can track the perf trajectory as an artifact — the
/// `current` entry records the selected `mode`'s numbers plus the
/// Fast-vs-Reference speedup and the measured parameter-divergence bound
/// ([`crate::fssdp::diverge`]); an existing `baseline` entry in that file
/// is preserved so before/after stays visible across runs. With
/// `check = Some(tolerance)`, the freshly measured step time of the
/// selected mode is run through [`perf_gate`] against that committed
/// baseline and the call fails on a regression beyond the tolerance.
pub fn bench_step(
    iters: usize,
    quick: bool,
    compute_threads: usize,
    mode: ComputeMode,
    write_json: bool,
    check: Option<f64>,
) -> anyhow::Result<Table> {
    use crate::fssdp::{reference_dims, LayerDims, Session, SessionConfig, WorkspaceStats};
    use crate::util::json::{obj, Json};
    use std::time::Instant;

    let dims = if quick {
        reference_dims()
    } else {
        // big enough that expert compute and buffer traffic both matter
        LayerDims { tokens: 64, d_model: 48, d_ffn: 96, experts: 8, cap: 32 }
    };
    let iters = iters.max(1);
    let layers = 3usize;

    let measure =
        |threads: usize, m: ComputeMode| -> anyhow::Result<(f64, StepPhases, WorkspaceStats)> {
            let mut s = Session::fresh(
                SessionConfig::builder()
                    .reference()
                    .dims(dims)
                    .topology(Topology::cluster_a(2, 4))
                    .layers(layers)
                    .seed(5)
                    .data_shards(8)
                    .compute_threads(threads)
                    .compute_mode(m)
                    .build()?,
            )?;
            s.run(2)?; // warm the workspace, pool, and predictors
            let p0 = s.engine().phases();
            let t0 = Instant::now();
            s.run(iters)?;
            let wall = t0.elapsed().as_secs_f64() / iters as f64;
            let phases = phase_delta(p0, s.engine().phases());
            Ok((wall, phases, s.engine().workspace_stats()))
        };

    let per_iter = |d: std::time::Duration| d.as_secs_f64() / iters as f64;
    let mut t = Table::new(&[
        "variant",
        "step_ms",
        "materialize_ms",
        "gate_ms",
        "expert_fwd_ms",
        "expert_bwd_ms",
        "sprs_ms",
        "adam_ms",
    ]);
    let mut push_row = |t: &mut Table, label: String, w: f64, p: &StepPhases| {
        t.row(vec![
            label,
            ms(w),
            ms(per_iter(p.materialize)),
            ms(per_iter(p.gate)),
            ms(per_iter(p.expert_fwd)),
            ms(per_iter(p.expert_bwd)),
            ms(per_iter(p.sprs)),
            ms(per_iter(p.adam)),
        ]);
    };
    let (ref_wall, ref_phases, ref_ws) = measure(1, ComputeMode::Reference)?;
    push_row(&mut t, "reference".into(), ref_wall, &ref_phases);
    if compute_threads > 1 {
        let (w, p, _) = measure(compute_threads, ComputeMode::Reference)?;
        push_row(&mut t, format!("reference threads={compute_threads}"), w, &p);
    }
    let want_fast = mode == ComputeMode::Fast || write_json;
    let mut fast: Option<(f64, StepPhases, WorkspaceStats)> = None;
    if want_fast {
        let f = measure(1, ComputeMode::Fast)?;
        push_row(&mut t, "fast".into(), f.0, &f.1);
        if compute_threads > 1 {
            let (w, p, _) = measure(compute_threads, ComputeMode::Fast)?;
            push_row(&mut t, format!("fast threads={compute_threads}"), w, &p);
        }
        fast = Some(f);
    }
    // the tier under test: what the JSON `current` entry and the perf
    // gate see
    let (cur_wall, cur_phases, cur_ws) = match (mode, &fast) {
        (ComputeMode::Fast, Some((w, p, ws))) => (*w, *p, *ws),
        _ => (ref_wall, ref_phases, ref_ws),
    };
    // Fast-vs-Reference correctness evidence for the JSON report: the
    // divergence harness trains both tiers in lockstep on this shape
    let divergence = if want_fast {
        Some(crate::fssdp::diverge::measure(
            dims,
            layers,
            Topology::cluster_a(2, 4),
            5,
            if quick { 4 } else { 8 },
            8,
            ComputeMode::Fast,
        )?)
    } else {
        None
    };

    let path = "BENCH_runtime_step.json";
    // keep a committed/previous baseline entry visible across runs — it is
    // also what the perf gate compares against
    let baseline = std::fs::read_to_string(path)
        .ok()
        .and_then(|text| Json::parse(&text).ok())
        .and_then(|j| j.get("baseline").cloned())
        .unwrap_or(Json::Null);

    if write_json {
        let phases_json = |p: &StepPhases| {
            obj([
                ("materialize", Json::num(per_iter(p.materialize) * 1e3)),
                ("gate", Json::num(per_iter(p.gate) * 1e3)),
                ("expert_fwd", Json::num(per_iter(p.expert_fwd) * 1e3)),
                ("expert_bwd", Json::num(per_iter(p.expert_bwd) * 1e3)),
                ("sprs", Json::num(per_iter(p.sprs) * 1e3)),
                ("adam", Json::num(per_iter(p.adam) * 1e3)),
            ])
        };
        let divergence_json = match &divergence {
            None => Json::Null,
            Some(d) => obj([
                ("max_abs", Json::num(d.max_abs)),
                ("max_rel", Json::num(d.max_rel)),
                ("bound_rel", Json::num(crate::fssdp::diverge::FAST_REL_BOUND)),
                ("iters", Json::num(d.per_step.len() as f64)),
            ]),
        };
        let speedup = fast
            .as_ref()
            .map(|(w, _, _)| Json::num(ref_wall / w.max(1e-12)))
            .unwrap_or(Json::Null);
        let doc = obj([
            ("bench", Json::Str("runtime_step".into())),
            (
                "config",
                obj([
                    ("devices", Json::num(8.0)),
                    ("layers", Json::num(layers as f64)),
                    ("tokens", Json::num(dims.tokens as f64)),
                    ("d_model", Json::num(dims.d_model as f64)),
                    ("d_ffn", Json::num(dims.d_ffn as f64)),
                    ("experts", Json::num(dims.experts as f64)),
                    ("cap", Json::num(dims.cap as f64)),
                    ("iters", Json::num(iters as f64)),
                    ("quick", Json::Bool(quick)),
                ]),
            ),
            ("baseline", baseline.clone()),
            (
                "reference",
                obj([
                    ("step_ms", Json::num(ref_wall * 1e3)),
                    ("phases_ms", phases_json(&ref_phases)),
                ]),
            ),
            (
                "current",
                obj([
                    ("mode", Json::Str(mode.as_str().into())),
                    ("step_ms", Json::num(cur_wall * 1e3)),
                    ("speedup_vs_reference", speedup),
                    ("phases_ms", phases_json(&cur_phases)),
                    ("divergence", divergence_json),
                    (
                        "workspace",
                        obj([
                            ("pool_allocated", Json::num(cur_ws.pool_allocated as f64)),
                            ("pool_reused", Json::num(cur_ws.pool_reused as f64)),
                        ]),
                    ),
                ]),
            ),
            (
                "note",
                Json::Str(
                    "per-iteration milliseconds; regenerate with `hecate bench step --json \
                     --compute-mode fast`; baseline = Reference tier, current = the selected \
                     --compute-mode tier; `bench step --check` gates CI on baseline.step_ms \
                     (bootstrap-pass while it is null — fill it from a toolchain host's \
                     reference.step_ms to arm the gate, default tolerance 25%, override with \
                     --gate-tol); divergence is the Fast-vs-Reference ∞-norm parameter drift \
                     measured by the diverge harness"
                        .into(),
                ),
            ),
        ]);
        std::fs::write(path, doc.to_string_pretty())?;
        crate::log_info!("wrote {path}");
    }
    if let Some(d) = &divergence {
        println!(
            "divergence fast-vs-ref: max_abs {:.3e}, max_rel {:.3e} (bound {})",
            d.max_abs,
            d.max_rel,
            crate::fssdp::diverge::FAST_REL_BOUND
        );
    }
    if let Some(tolerance) = check {
        println!("{}", perf_gate(&baseline, cur_wall * 1e3, tolerance)?);
    }
    Ok(t)
}

/// The CI perf gate: compare a freshly measured per-iteration step time
/// (ms) against the committed `baseline.step_ms` of
/// `BENCH_runtime_step.json`. A null/absent baseline is a **bootstrap
/// pass** — the gate arms itself once a baseline is committed — and a
/// regression beyond `tolerance` (fractional, e.g. 0.25 = +25%) is an
/// error, which `hecate bench step --check` turns into a non-zero exit.
pub fn perf_gate(
    baseline: &crate::util::json::Json,
    current_step_ms: f64,
    tolerance: f64,
) -> anyhow::Result<String> {
    use crate::util::json::Json;
    anyhow::ensure!(
        tolerance >= 0.0 && tolerance.is_finite(),
        "perf gate tolerance must be a non-negative fraction, got {tolerance}"
    );
    let base_ms = match baseline {
        Json::Null => None,
        j => j.get("step_ms").and_then(Json::as_f64),
    };
    let Some(base_ms) = base_ms else {
        return Ok(format!(
            "perf gate: no baseline step_ms recorded — bootstrap pass at {current_step_ms:.3} \
             ms (commit a baseline in BENCH_runtime_step.json to arm the gate)"
        ));
    };
    anyhow::ensure!(base_ms > 0.0, "perf gate baseline step_ms must be positive, got {base_ms}");
    let limit = base_ms * (1.0 + tolerance);
    anyhow::ensure!(
        current_step_ms <= limit,
        "perf gate FAILED: step {current_step_ms:.3} ms exceeds baseline {base_ms:.3} ms + \
         {:.0}% tolerance (limit {limit:.3} ms)",
        tolerance * 100.0
    );
    Ok(format!(
        "perf gate OK: step {current_step_ms:.3} ms vs baseline {base_ms:.3} ms (limit \
         {limit:.3} ms at {:.0}% tolerance)",
        tolerance * 100.0
    ))
}

/// §1 claims: EP imbalance slowdown; FlexMoE reserve-vs-speedup; SmartMoE
/// rearrangement-frequency tradeoff.
pub fn claims(opts: &SimOptions) -> Vec<(String, Table)> {
    let topo = ClusterPreset::A.build(4, 8);
    let model = ModelConfig::preset("gpt-moe-s").unwrap();
    let train = TrainConfig { batch_per_device: paper_batch(&model), ..Default::default() };
    let mut out = Vec::new();

    // EP: imbalanced vs balanced
    let imb = simulate(&topo, &model, &SystemConfig::new(SystemKind::Ep), &train, opts);
    let bal = simulate(
        &topo,
        &model,
        &SystemConfig::new(SystemKind::Ep),
        &train,
        &SimOptions { balanced_loads: true, ..opts.clone() },
    );
    let mut t = Table::new(&["loads", "iter_ms", "slowdown"]);
    t.row(vec!["balanced".into(), ms(bal.iter_time), fmt(1.0)]);
    t.row(vec!["imbalanced".into(), ms(imb.iter_time), fmt(imb.iter_time / bal.iter_time)]);
    out.push(("EP slowdown under imbalance (paper: up to 5.18x)".to_string(), t));

    // FlexMoE: reserved memory vs speedup
    let mut t = Table::new(&["reserved_slots", "iter_ms", "speedup_vs_EP", "mem_GB"]);
    for slots in [1usize, 2, 4, 8] {
        let mut cfg = SystemConfig::new(SystemKind::FlexMoe);
        cfg.reserved_slots = slots;
        let r = simulate(&topo, &model, &cfg, &train, opts);
        t.row(vec![
            slots.to_string(),
            ms(r.iter_time),
            fmt(imb.iter_time / r.iter_time),
            gb(r.memory.total()),
        ]);
    }
    out.push(("FlexMoE reserve-for-speedup (paper: 4x mem for 2.65x)".to_string(), t));

    // SmartMoE: rearrangement frequency tradeoff
    let mut t = Table::new(&["interval", "iter_ms", "speedup_vs_EP"]);
    for interval in [10usize, 25, 50, 100] {
        let mut cfg = SystemConfig::new(SystemKind::SmartMoe);
        cfg.rearrange_interval = interval;
        let r = simulate(&topo, &model, &cfg, &train, opts);
        t.row(vec![interval.to_string(), ms(r.iter_time), fmt(imb.iter_time / r.iter_time)]);
    }
    out.push(("SmartMoE frequency tradeoff (paper: optimum at moderate interval)".to_string(), t));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick() -> SimOptions {
        SimOptions { iterations: 16, warmup: 4, seed: 7, balanced_loads: false }
    }

    #[test]
    fn table1_matches_paper_shapes() {
        let t = table1();
        assert_eq!(t.rows.len(), 4);
        assert!(t.rows[0][5].contains('B'));
    }

    #[test]
    fn figure3_rows() {
        let t = figure3(10);
        assert_eq!(t.rows.len(), 10);
    }

    #[test]
    fn end_to_end_hecate_wins() {
        let t = end_to_end(ClusterPreset::A, 2, 4, &quick());
        for row in &t.rows {
            let hecate: f64 = row[6].parse().unwrap();
            let others: Vec<f64> =
                (2..6).map(|i| row[i].parse::<f64>().unwrap()).collect();
            let best = others.iter().cloned().fold(f64::MIN, f64::max);
            assert!(
                hecate >= best * 0.95,
                "{}: Hecate {hecate} vs best baseline {best}",
                row[0]
            );
            assert!(hecate > 1.0, "{}: Hecate must beat EP", row[0]);
        }
    }

    #[test]
    fn figure11_layer_speedups_positive_and_varied() {
        let t = figure11(&quick());
        let speedups: Vec<f64> = t.rows[..t.rows.len() - 1]
            .iter()
            .map(|r| r[3].parse::<f64>().unwrap())
            .collect();
        assert!(speedups.iter().all(|&s| s > 1.0));
        let max = speedups.iter().cloned().fold(f64::MIN, f64::max);
        let min = speedups.iter().cloned().fold(f64::MAX, f64::min);
        assert!(max / min > 1.3, "per-layer variation expected: {speedups:?}");
    }

    #[test]
    fn figure13_shape() {
        let t = figure13(&quick());
        assert_eq!(t.rows.len(), 6);
        // EP row has ratio 1.0
        assert_eq!(t.rows[0][5], "1.00");
    }

    #[test]
    fn figure14_rm_survives_largest_batch() {
        let t = figure14(&quick());
        let last = &t.rows[5];
        assert_eq!(last[0], "6");
        assert_ne!(last[4], "OOM", "Hecate-RM must survive batch 6");
    }

    #[test]
    fn figure15a_combination_is_best() {
        let t = figure15a(&quick());
        let full: f64 = t.rows[3][3].parse().unwrap();
        for r in &t.rows[..3] {
            let s: f64 = r[3].parse().unwrap();
            assert!(full >= s * 0.98, "full Hecate {full} vs partial {s}");
        }
    }

    #[test]
    fn recovery_table_shape_and_directions() {
        let topo = ClusterPreset::A.build(2, 4);
        let model = ModelConfig::preset("gpt-moe-s").unwrap().with_experts(16);
        let spec = FaultSpec { fail_step: 57, ..Default::default() };
        let t = recovery_table(&topo, &model, 0.1, &spec);
        assert_eq!(t.rows.len(), 5);
        assert_eq!(t.rows[0][0], "none");
        // no-checkpoint row replays all 57 steps and pays zero overhead
        assert_eq!(t.rows[0][7], "57");
        assert_eq!(t.rows[0][3].parse::<f64>().unwrap(), 0.0);
        // checkpointed rows replay fail_step % interval
        for (row, interval) in t.rows[1..].iter().zip([10usize, 25, 50, 100]) {
            assert_eq!(row[7].parse::<usize>().unwrap(), 57 % interval);
            assert!(row[3].parse::<f64>().unwrap() >= 0.0);
        }
        // tighter cadence costs more steady-state overhead
        let ov = |i: usize| t.rows[i][3].parse::<f64>().unwrap();
        assert!(ov(1) >= ov(4), "every-10 {} vs every-100 {}", ov(1), ov(4));
    }

    #[test]
    fn claims_tables_render() {
        let c = claims(&quick());
        assert_eq!(c.len(), 3);
        for (name, t) in &c {
            assert!(!t.rows.is_empty(), "{name}");
        }
    }

    #[test]
    fn spmd_scaling_smoke() {
        let t = spmd_scaling(
            1,
            true,
            crate::spmd::transport::TransportKind::InProc,
            ComputeMode::Reference,
            1,
        )
        .unwrap();
        assert_eq!(t.header[1], "modeled_comm_ms");
        assert_eq!(t.header[5], "straggler_skew");
        assert_eq!(t.header[6], "peak_resident_kb");
        assert_eq!(t.header[7], "imbalance");
        assert_eq!(t.rows.len(), 4);
        for row in &t.rows {
            assert!(row[4].parse::<f64>().unwrap() > 0.0, "speedup column: {row:?}");
            assert!(row[5].parse::<f64>().unwrap() >= 1.0, "skew column: {row:?}");
            assert!(row[6].parse::<f64>().unwrap() > 0.0, "peak memory column: {row:?}");
            assert!(row[7].parse::<f64>().unwrap() >= 1.0, "imbalance column: {row:?}");
        }
    }

    #[test]
    fn spmd_scaling_socket_smoke() {
        // the socket arm: same table, SPMD column measured over real unix
        // sockets (modeled α–β comm next to framed syscall wall clock)
        let t = spmd_scaling(
            1,
            true,
            crate::spmd::transport::TransportKind::Socket,
            ComputeMode::Fast,
            2,
        )
        .unwrap();
        assert_eq!(t.rows.len(), 4);
        for row in &t.rows {
            assert!(row[4].parse::<f64>().unwrap() > 0.0, "speedup column: {row:?}");
        }
    }

    #[test]
    fn spmd_overlap_smoke() {
        let t = spmd_overlap(1, true).unwrap();
        assert_eq!(t.rows.len(), 3);
        assert_eq!(t.header[5], "overlap_eff_on_%");
        assert_eq!(t.header[7], "imbalance");
        for row in &t.rows {
            assert!(row[3].parse::<f64>().unwrap() > 0.0, "speedup column: {row:?}");
            // paced links → wire time is recorded, so the efficiency
            // columns must be defined percentages, not "n/a"
            for eff in &row[4..6] {
                let v = eff.parse::<f64>().unwrap();
                assert!((0.0..=100.0).contains(&v), "efficiency column: {row:?}");
            }
            assert!(row[6].parse::<f64>().unwrap() > 0.0, "peak memory column: {row:?}");
            assert!(row[7].parse::<f64>().unwrap() >= 1.0, "imbalance column: {row:?}");
        }
    }

    #[test]
    fn perf_gate_known_answers() {
        use crate::util::json::{obj, Json};
        // bootstrap: no baseline recorded yet
        let msg = perf_gate(&Json::Null, 12.0, 0.25).unwrap();
        assert!(msg.contains("bootstrap pass"), "{msg}");
        // within tolerance passes, beyond it fails
        let base = obj([("step_ms", Json::num(10.0))]);
        assert!(perf_gate(&base, 12.4, 0.25).unwrap().contains("perf gate OK"));
        let err = perf_gate(&base, 12.6, 0.25).unwrap_err().to_string();
        assert!(err.contains("perf gate FAILED"), "{err}");
        assert!(err.contains("limit 12.500"), "{err}");
        // malformed baselines are bootstrap (missing key) or hard errors
        let msg = perf_gate(&obj([("other", Json::num(1.0))]), 5.0, 0.25).unwrap();
        assert!(msg.contains("bootstrap pass"), "{msg}");
        assert!(perf_gate(&obj([("step_ms", Json::num(0.0))]), 5.0, 0.25).is_err());
        assert!(perf_gate(&base, 5.0, -1.0).is_err(), "negative tolerance rejected");
    }

    #[test]
    fn numeric_figure11_smoke() {
        let t = numeric_figure11(2, 1).unwrap();
        assert_eq!(t.rows.len(), 2);
        assert_eq!(t.header[0], "layer");
    }

    #[test]
    fn numeric_figure15b_smoke() {
        let t = numeric_figure15b(2, 4).unwrap();
        assert_eq!(t.rows.len(), 4);
        assert_eq!(t.rows[0][0], "never");
        // the never row moves nothing; the k=2 row must actually re-shard
        assert_eq!(t.rows[0][3], "0");
        for row in &t.rows {
            assert!(row[2].parse::<f64>().unwrap().is_finite());
        }
    }
}
