//! # Hecate — Fully Sharded Sparse Data Parallelism (FSSDP) for MoE training
//!
//! Reproduction of *"Hecate: Unlocking Efficient Sparse Model Training via
//! Fully Sharded Sparse Data Parallelism"* (2025) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the coordinator: heterogeneous sharding
//!   (Algorithm 2), sparse materialization (Algorithm 1), topology-aware
//!   token dispatch, the [`collectives`] `SparseAllGather` /
//!   `SparseReduceScatter`, baseline systems (EP, FasterMoE, SmartMoE,
//!   FlexMoE, FSDP), a discrete-event cluster simulator reproducing the
//!   paper's figures, and a numeric FSSDP engine running real HLO compute
//!   via PJRT.
//! * **L2 (python/compile)** — the JAX Transformer-MoE model, AOT-lowered to
//!   HLO text in `artifacts/`.
//! * **L1 (python/compile/kernels)** — Pallas kernels for the expert FFN and
//!   top-2 gating, verified against pure-jnp oracles.
//!
//! Python never runs at training time: the Rust binary loads compiled
//! artifacts through [`runtime`].
//!
//! Training state is durable: the [`checkpoint`] subsystem snapshots the
//! complete FSSDP state (per-rank shard blobs + JSON manifest) and resumes
//! it **elastically** — an N-device run restarts on M devices by re-running
//! the sharding planner, with numerically identical training.
//!
//! Execution is pluggable: the numeric engine runs either sequentially
//! (the oracle) or on the [`spmd`] parallel executor — one OS thread per
//! simulated rank over an in-process communicator, with overlapped sparse
//! collectives — producing bit-identical expert parameters
//! (`hecate fssdp --reference --parallel`).
//!
//! See `DESIGN.md` for the full system inventory and the experiment index
//! mapping every paper table/figure to a module and bench target.

// Style lints that conflict with the codebase's explicit-index numerical
// style (CI runs `cargo clippy -D warnings`; correctness lints stay on).
#![allow(
    unknown_lints,
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::type_complexity,
    clippy::inherent_to_string_shadow_display,
    clippy::manual_div_ceil,
    clippy::new_without_default
)]

pub mod analysis;
pub mod bench;
pub mod checkpoint;
pub mod collectives;
pub mod config;
pub mod coordinator;
pub mod dispatch;
pub mod fssdp;
pub mod loadsim;
pub mod materialize;
pub mod metrics;
pub mod placement;
pub mod runtime;
pub mod sharding;
pub mod sim;
pub mod spmd;
pub mod systems;
pub mod telemetry;
pub mod testing;
pub mod topology;
pub mod train;
pub mod util;
