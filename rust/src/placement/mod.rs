//! Chunk placements — the core abstraction of FSSDP's sparse collectives
//! (§3.1).
//!
//! A logical buffer is split into equal-sized chunks `C = {C_0, C_1, …}`
//! (one chunk per expert). A *chunk placement* `P ⊆ C × D` records which
//! chunk is available on which device. The two sparse collectives are
//! defined by a (pre, post) placement pair:
//!
//! * `spAG(P0, P1)` requires `P0` surjective (every chunk somewhere) and
//!   `P0 ⊆ P1`;
//! * `spRS(P0, P1)` requires `P1` surjective and `P1 ⊆ P0`.
//!
//! [`Placement`] is stored as a per-chunk sorted device list, which is the
//! access pattern every planner and both collectives need.

use std::collections::BTreeSet;

use crate::topology::{DeviceId, Topology};

/// Index of a chunk (== expert index within an MoE layer).
pub type ChunkId = usize;

/// A chunk placement `P ⊆ C × D`.
///
/// Perf note (EXPERIMENTS.md §Perf): holders are stored as small *sorted
/// vectors*, not `BTreeSet`s — placements are cloned per layer per
/// simulated iteration and replication counts are tiny (1–32), so linear
/// probes on a contiguous Vec beat tree nodes and halve simulator time.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Placement {
    /// `holders[c]` = sorted list of devices holding chunk `c`.
    holders: Vec<Vec<DeviceId>>,
    /// Number of devices in the group (for validation).
    num_devices: usize,
}

impl Placement {
    /// Empty placement over `chunks` chunks and `num_devices` devices.
    pub fn empty(chunks: usize, num_devices: usize) -> Placement {
        Placement { holders: vec![Vec::new(); chunks], num_devices }
    }

    /// The canonical EP/sharded placement: chunk `c` on device
    /// `c % num_devices` (round-robin; even when `chunks % devices == 0`).
    pub fn round_robin(chunks: usize, num_devices: usize) -> Placement {
        let mut p = Placement::empty(chunks, num_devices);
        for c in 0..chunks {
            p.add(c, DeviceId(c % num_devices));
        }
        p
    }

    /// Fully-replicated placement (every chunk on every device).
    pub fn full(chunks: usize, num_devices: usize) -> Placement {
        let mut p = Placement::empty(chunks, num_devices);
        for c in 0..chunks {
            for d in 0..num_devices {
                p.add(c, DeviceId(d));
            }
        }
        p
    }

    /// Build from an explicit list of `(chunk, device)` pairs.
    pub fn from_pairs(
        chunks: usize,
        num_devices: usize,
        pairs: impl IntoIterator<Item = (ChunkId, DeviceId)>,
    ) -> Placement {
        let mut p = Placement::empty(chunks, num_devices);
        for (c, d) in pairs {
            p.add(c, d);
        }
        p
    }

    pub fn num_chunks(&self) -> usize {
        self.holders.len()
    }

    pub fn num_devices(&self) -> usize {
        self.num_devices
    }

    /// Add `(c, d)` to the placement.
    pub fn add(&mut self, c: ChunkId, d: DeviceId) {
        assert!(d.0 < self.num_devices, "device {} out of range", d.0);
        if let Err(pos) = self.holders[c].binary_search(&d) {
            self.holders[c].insert(pos, d);
        }
    }

    /// Remove `(c, d)`.
    pub fn remove(&mut self, c: ChunkId, d: DeviceId) {
        if let Ok(pos) = self.holders[c].binary_search(&d) {
            self.holders[c].remove(pos);
        }
    }

    /// Devices holding chunk `c`.
    pub fn holders(&self, c: ChunkId) -> impl Iterator<Item = DeviceId> + '_ {
        self.holders[c].iter().copied()
    }

    /// Number of replicas of chunk `c`.
    pub fn replication(&self, c: ChunkId) -> usize {
        self.holders[c].len()
    }

    pub fn contains(&self, c: ChunkId, d: DeviceId) -> bool {
        self.holders[c].binary_search(&d).is_ok()
    }

    /// Chunks held by device `d`, without materializing a `Vec` — the hot
    /// loops (per-rank gradient-buffer setup, release scans) iterate this
    /// once per layer per iteration.
    pub fn chunks_on_iter(&self, d: DeviceId) -> impl Iterator<Item = ChunkId> + '_ {
        (0..self.num_chunks()).filter(move |&c| self.contains(c, d))
    }

    /// Chunks held by device `d`, collected (cold paths; prefer
    /// [`Placement::chunks_on_iter`] in loops).
    pub fn chunks_on(&self, d: DeviceId) -> Vec<ChunkId> {
        self.chunks_on_iter(d).collect()
    }

    /// Number of chunks held by device `d` (its memory slots in use).
    pub fn load_of(&self, d: DeviceId) -> usize {
        (0..self.num_chunks()).filter(|&c| self.contains(c, d)).count()
    }

    /// Every chunk is held by at least one device (`P` surjective onto `C`).
    pub fn is_surjective(&self) -> bool {
        self.holders.iter().all(|h| !h.is_empty())
    }

    /// Every chunk is held by *exactly* one device — a sharding.
    pub fn is_partition(&self) -> bool {
        self.holders.iter().all(|h| h.len() == 1)
    }

    /// `self ⊆ other`.
    pub fn is_subset_of(&self, other: &Placement) -> bool {
        if self.num_chunks() != other.num_chunks() {
            return false;
        }
        // sorted-merge subset check per chunk
        self.holders.iter().zip(other.holders.iter()).all(|(a, b)| {
            let mut j = 0;
            'outer: for &x in a {
                while j < b.len() {
                    match b[j].cmp(&x) {
                        std::cmp::Ordering::Less => j += 1,
                        std::cmp::Ordering::Equal => {
                            j += 1;
                            continue 'outer;
                        }
                        std::cmp::Ordering::Greater => return false,
                    }
                }
                return false;
            }
            true
        })
    }

    /// Union of two placements over the same chunk/device space.
    pub fn union(&self, other: &Placement) -> Placement {
        assert_eq!(self.num_chunks(), other.num_chunks());
        assert_eq!(self.num_devices, other.num_devices);
        let mut out = self.clone();
        for c in 0..other.num_chunks() {
            for d in other.holders(c) {
                out.add(c, d);
            }
        }
        out
    }

    /// Pairs in `self` but not in `base` — the chunks a collective must move.
    pub fn diff(&self, base: &Placement) -> Vec<(ChunkId, DeviceId)> {
        let mut out = Vec::new();
        for c in 0..self.num_chunks() {
            for d in self.holders(c) {
                if !base.contains(c, d) {
                    out.push((c, d));
                }
            }
        }
        out
    }

    /// Total number of `(chunk, device)` pairs.
    pub fn len(&self) -> usize {
        self.holders.iter().map(|h| h.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The sparsity λ = |Ĉ|/|C| from §3.1: fraction of chunks that require
    /// any inter-device communication to reach this placement from `base`.
    pub fn sparsity(&self, base: &Placement) -> f64 {
        if self.num_chunks() == 0 {
            return 0.0;
        }
        let moved: BTreeSet<ChunkId> = self.diff(base).into_iter().map(|(c, _)| c).collect();
        moved.len() as f64 / self.num_chunks() as f64
    }

    /// Replicas of chunk `c` on a given node.
    pub fn holders_on_node(
        &self,
        topo: &Topology,
        c: ChunkId,
        node: crate::topology::NodeId,
    ) -> Vec<DeviceId> {
        self.holders(c).filter(|&d| topo.node_of(d) == node).collect()
    }
}

/// Validated spAG precondition pair: `pre` surjective, `pre ⊆ post`.
pub fn validate_spag(pre: &Placement, post: &Placement) -> anyhow::Result<()> {
    if !pre.is_surjective() {
        anyhow::bail!("spAG precondition must be surjective (every chunk owned somewhere)");
    }
    if !pre.is_subset_of(post) {
        anyhow::bail!("spAG requires pre ⊆ post");
    }
    Ok(())
}

/// Validated spRS precondition pair: `post` surjective, `post ⊆ pre`.
pub fn validate_sprs(pre: &Placement, post: &Placement) -> anyhow::Result<()> {
    if !post.is_surjective() {
        anyhow::bail!("spRS postcondition must be surjective");
    }
    if !post.is_subset_of(pre) {
        anyhow::bail!("spRS requires post ⊆ pre");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing;
    use crate::util::rng::Rng;

    #[test]
    fn round_robin_is_partition() {
        let p = Placement::round_robin(64, 8);
        assert!(p.is_partition());
        assert!(p.is_surjective());
        assert_eq!(p.len(), 64);
        assert_eq!(p.load_of(DeviceId(0)), 8);
        assert!(p.contains(9, DeviceId(1)));
    }

    #[test]
    fn full_replication() {
        let p = Placement::full(4, 3);
        assert_eq!(p.len(), 12);
        assert_eq!(p.replication(2), 3);
        assert!(!p.is_partition());
        assert!(p.is_surjective());
    }

    #[test]
    fn subset_union_diff() {
        let base = Placement::round_robin(8, 4);
        let mut post = base.clone();
        post.add(0, DeviceId(1));
        post.add(5, DeviceId(0));
        assert!(base.is_subset_of(&post));
        assert!(!post.is_subset_of(&base));
        let d = post.diff(&base);
        assert_eq!(d, vec![(0, DeviceId(1)), (5, DeviceId(0))]);
        assert_eq!(base.union(&post), post);
    }

    #[test]
    fn sparsity_counts_moved_chunks() {
        let base = Placement::round_robin(10, 5);
        let mut post = base.clone();
        assert_eq!(post.sparsity(&base), 0.0);
        post.add(0, DeviceId(3));
        post.add(0, DeviceId(4)); // same chunk — still one moved chunk
        post.add(7, DeviceId(0));
        assert!((post.sparsity(&base) - 0.2).abs() < 1e-12);
    }

    #[test]
    fn validation_rules() {
        let pre = Placement::round_robin(8, 4);
        let mut post = pre.clone();
        post.add(3, DeviceId(0));
        assert!(validate_spag(&pre, &post).is_ok());
        assert!(validate_sprs(&post, &pre).is_ok());
        // broken: pre not surjective
        let mut bad = pre.clone();
        bad.remove(2, DeviceId(2));
        assert!(validate_spag(&bad, &post).is_err());
        // broken: post missing pre pair
        let mut bad_post = pre.clone();
        bad_post.remove(1, DeviceId(1));
        bad_post.add(1, DeviceId(0));
        assert!(validate_spag(&pre, &bad_post).is_err());
        // (1, D0) ∈ bad_post but ∉ pre, so bad_post ⊄ pre
        assert!(validate_sprs(&pre, &bad_post).is_err());
    }

    #[test]
    fn prop_union_superset_and_diff_inverse() {
        testing::check(
            |rng: &mut Rng, size| {
                let chunks = 1 + rng.below(4 * size);
                let devices = 1 + rng.below(8);
                let base = Placement::round_robin(chunks, devices);
                let mut post = base.clone();
                let extra = rng.below(chunks * devices / 2 + 1);
                for _ in 0..extra {
                    post.add(rng.below(chunks), DeviceId(rng.below(devices)));
                }
                (base, post)
            },
            |(base, post)| {
                if !base.is_subset_of(post) {
                    return Err("base ⊄ post after union-building".into());
                }
                // post == base ∪ diff(post, base)
                let rebuilt = Placement::from_pairs(
                    base.num_chunks(),
                    base.num_devices(),
                    base.diff(&Placement::empty(base.num_chunks(), base.num_devices()))
                        .into_iter()
                        .chain(post.diff(base)),
                );
                if &rebuilt != post {
                    return Err("base ∪ diff != post".into());
                }
                validate_spag(base, post).map_err(|e| e.to_string())?;
                validate_sprs(post, base).map_err(|e| e.to_string())
            },
        );
    }

    #[test]
    fn chunks_on_iter_matches_collected_form() {
        let mut p = Placement::round_robin(10, 4);
        p.add(7, DeviceId(1));
        for d in 0..4 {
            let dev = DeviceId(d);
            assert_eq!(p.chunks_on_iter(dev).collect::<Vec<_>>(), p.chunks_on(dev));
        }
        assert_eq!(p.chunks_on_iter(DeviceId(1)).collect::<Vec<_>>(), vec![1, 5, 7, 9]);
    }

    #[test]
    fn holders_on_node_filters() {
        let topo = Topology::cluster_a(2, 4);
        let mut p = Placement::empty(2, 8);
        p.add(0, DeviceId(0));
        p.add(0, DeviceId(5));
        let n0 = p.holders_on_node(&topo, 0, crate::topology::NodeId(0));
        assert_eq!(n0, vec![DeviceId(0)]);
        let n1 = p.holders_on_node(&topo, 0, crate::topology::NodeId(1));
        assert_eq!(n1, vec![DeviceId(5)]);
    }
}
