//! Host tensors: the CPU-side data the coordinator moves between the
//! collectives (f32 buffers) and PJRT executables (Literals), plus the
//! borrowed [`TensorView`]/[`TensorViewMut`] types the zero-copy hot path
//! is built on — shape metadata over a `[f32]` someone else owns, so the
//! reference kernels can read parameter chunks and write activations
//! without a single intermediate allocation.

/// Borrowed row-major 2-D f32 tensor (vectors are `1 × n`). The shape is
/// metadata only — no data is owned, cloned, or moved; a view is two
/// `usize`s and a slice pointer.
#[derive(Debug, Clone, Copy)]
pub struct TensorView<'a> {
    rows: usize,
    cols: usize,
    data: &'a [f32],
}

impl<'a> TensorView<'a> {
    /// View `data` as a `rows × cols` matrix. Panics on a shape/len
    /// mismatch — a view never guesses.
    pub fn new(rows: usize, cols: usize, data: &'a [f32]) -> TensorView<'a> {
        assert_eq!(rows * cols, data.len(), "view shape {rows}x{cols} vs len {}", data.len());
        TensorView { rows, cols, data }
    }

    /// View a slice as a row vector (`1 × n`).
    pub fn vector(data: &'a [f32]) -> TensorView<'a> {
        TensorView { rows: 1, cols: data.len(), data }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// The whole backing slice, row-major.
    pub fn data(&self) -> &'a [f32] {
        self.data
    }

    /// One row as a slice.
    pub fn row(&self, r: usize) -> &'a [f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }
}

/// Mutable counterpart of [`TensorView`]: shape metadata over a caller-
/// provided output slice the kernels write into.
#[derive(Debug)]
pub struct TensorViewMut<'a> {
    rows: usize,
    cols: usize,
    data: &'a mut [f32],
}

impl<'a> TensorViewMut<'a> {
    /// View `data` as a mutable `rows × cols` matrix. Panics on a
    /// shape/len mismatch.
    pub fn new(rows: usize, cols: usize, data: &'a mut [f32]) -> TensorViewMut<'a> {
        assert_eq!(rows * cols, data.len(), "view shape {rows}x{cols} vs len {}", data.len());
        TensorViewMut { rows, cols, data }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut *self.data
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Reborrow as an immutable view.
    pub fn as_view(&self) -> TensorView<'_> {
        TensorView { rows: self.rows, cols: self.cols, data: &*self.data }
    }

    pub fn fill(&mut self, v: f32) {
        self.data.fill(v);
    }
}

/// Dense host tensor (f32 or i32), row-major.
#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl HostTensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        HostTensor::F32 { shape, data }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        HostTensor::I32 { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> HostTensor {
        let n = shape.iter().product();
        HostTensor::F32 { shape, data: vec![0.0; n] }
    }

    pub fn scalar_i32(v: i32) -> HostTensor {
        HostTensor::I32 { shape: vec![], data: vec![v] }
    }

    pub fn scalar_f32(v: f32) -> HostTensor {
        HostTensor::F32 { shape: vec![], data: vec![v] }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. } | HostTensor::I32 { shape, .. } => shape,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32 { data, .. } => data.len(),
            HostTensor::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Borrow as f32 slice (errors on i32 tensors).
    pub fn as_f32(&self) -> anyhow::Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            HostTensor::I32 { .. } => anyhow::bail!("tensor is i32, expected f32"),
        }
    }

    pub fn as_f32_mut(&mut self) -> anyhow::Result<&mut [f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            HostTensor::I32 { .. } => anyhow::bail!("tensor is i32, expected f32"),
        }
    }

    pub fn as_i32(&self) -> anyhow::Result<&[i32]> {
        match self {
            HostTensor::I32 { data, .. } => Ok(data),
            HostTensor::F32 { .. } => anyhow::bail!("tensor is f32, expected i32"),
        }
    }

    /// Borrow a rank-1 or rank-2 f32 tensor as a [`TensorView`]
    /// (rank-1 becomes a `1 × n` row vector).
    pub fn view2(&self) -> anyhow::Result<TensorView<'_>> {
        let data = self.as_f32()?;
        match self.shape() {
            [r, c] => Ok(TensorView::new(*r, *c, data)),
            [n] => Ok(TensorView::new(1, *n, data)),
            other => anyhow::bail!("view2: expected rank 1 or 2, got shape {other:?}"),
        }
    }

    /// Scalar f32 value.
    pub fn item_f32(&self) -> anyhow::Result<f32> {
        let d = self.as_f32()?;
        anyhow::ensure!(d.len() == 1, "not a scalar (len {})", d.len());
        Ok(d[0])
    }

    /// Convert to an XLA literal.
    pub fn to_literal(&self) -> anyhow::Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            HostTensor::F32 { data, .. } => xla::Literal::vec1(data).reshape(&dims)?,
            HostTensor::I32 { data, .. } => xla::Literal::vec1(data).reshape(&dims)?,
        };
        Ok(lit)
    }

    /// Convert from an XLA literal (f32/i32 arrays; other types error).
    pub fn from_literal(lit: xla::Literal) -> anyhow::Result<HostTensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => {
                Ok(HostTensor::F32 { shape: dims, data: lit.to_vec::<f32>()? })
            }
            xla::ElementType::S32 => {
                Ok(HostTensor::I32 { shape: dims, data: lit.to_vec::<i32>()? })
            }
            other => anyhow::bail!("unsupported literal element type {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_accessors() {
        let t = HostTensor::f32(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.len(), 6);
        assert!(t.as_f32().is_ok());
        assert!(t.as_i32().is_err());
        let s = HostTensor::scalar_f32(4.0);
        assert_eq!(s.item_f32().unwrap(), 4.0);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn shape_mismatch_panics() {
        HostTensor::f32(vec![2, 2], vec![0.0; 3]);
    }

    #[test]
    fn literal_roundtrip_f32() {
        let t = HostTensor::f32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(lit).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn literal_roundtrip_i32() {
        let t = HostTensor::i32(vec![3], vec![7, 8, 9]);
        let back = HostTensor::from_literal(t.to_literal().unwrap()).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn literal_roundtrip_scalar() {
        let t = HostTensor::scalar_i32(5);
        let back = HostTensor::from_literal(t.to_literal().unwrap()).unwrap();
        assert_eq!(back.as_i32().unwrap(), &[5]);
    }

    #[test]
    fn views_are_shape_metadata_over_the_same_slice() {
        let data: Vec<f32> = (0..6).map(|i| i as f32).collect();
        let v = TensorView::new(2, 3, &data);
        assert_eq!(v.rows(), 2);
        assert_eq!(v.cols(), 3);
        assert_eq!(v.row(1), &[3.0, 4.0, 5.0]);
        // same memory, not a copy
        assert_eq!(v.data().as_ptr(), data.as_ptr());
        let rv = TensorView::vector(&data);
        assert_eq!((rv.rows(), rv.cols()), (1, 6));
    }

    #[test]
    fn mut_views_write_through_to_the_owner() {
        let mut data = vec![0.0f32; 4];
        {
            let mut v = TensorViewMut::new(2, 2, &mut data);
            v.row_mut(1).copy_from_slice(&[7.0, 8.0]);
            assert_eq!(v.as_view().row(1), &[7.0, 8.0]);
            v.fill(1.0);
        }
        assert_eq!(data, vec![1.0; 4]);
    }

    #[test]
    #[should_panic(expected = "view shape")]
    fn view_shape_mismatch_panics() {
        TensorView::new(2, 4, &[0.0; 6]);
    }

    #[test]
    fn host_tensor_view2() {
        let t = HostTensor::f32(vec![2, 3], vec![0.0; 6]);
        let v = t.view2().unwrap();
        assert_eq!((v.rows(), v.cols()), (2, 3));
        let r1 = HostTensor::f32(vec![4], vec![0.0; 4]);
        assert_eq!(r1.view2().unwrap().rows(), 1);
        assert!(HostTensor::scalar_f32(1.0).view2().is_err());
        assert!(HostTensor::i32(vec![2], vec![1, 2]).view2().is_err());
    }
}
