//! Host tensors: the CPU-side data the coordinator moves between the
//! collectives (f32 buffers) and PJRT executables (Literals).

/// Dense host tensor (f32 or i32), row-major.
#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl HostTensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        HostTensor::F32 { shape, data }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        HostTensor::I32 { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> HostTensor {
        let n = shape.iter().product();
        HostTensor::F32 { shape, data: vec![0.0; n] }
    }

    pub fn scalar_i32(v: i32) -> HostTensor {
        HostTensor::I32 { shape: vec![], data: vec![v] }
    }

    pub fn scalar_f32(v: f32) -> HostTensor {
        HostTensor::F32 { shape: vec![], data: vec![v] }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. } | HostTensor::I32 { shape, .. } => shape,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32 { data, .. } => data.len(),
            HostTensor::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Borrow as f32 slice (errors on i32 tensors).
    pub fn as_f32(&self) -> anyhow::Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            HostTensor::I32 { .. } => anyhow::bail!("tensor is i32, expected f32"),
        }
    }

    pub fn as_f32_mut(&mut self) -> anyhow::Result<&mut [f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            HostTensor::I32 { .. } => anyhow::bail!("tensor is i32, expected f32"),
        }
    }

    pub fn as_i32(&self) -> anyhow::Result<&[i32]> {
        match self {
            HostTensor::I32 { data, .. } => Ok(data),
            HostTensor::F32 { .. } => anyhow::bail!("tensor is f32, expected i32"),
        }
    }

    /// Scalar f32 value.
    pub fn item_f32(&self) -> anyhow::Result<f32> {
        let d = self.as_f32()?;
        anyhow::ensure!(d.len() == 1, "not a scalar (len {})", d.len());
        Ok(d[0])
    }

    /// Convert to an XLA literal.
    pub fn to_literal(&self) -> anyhow::Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            HostTensor::F32 { data, .. } => xla::Literal::vec1(data).reshape(&dims)?,
            HostTensor::I32 { data, .. } => xla::Literal::vec1(data).reshape(&dims)?,
        };
        Ok(lit)
    }

    /// Convert from an XLA literal (f32/i32 arrays; other types error).
    pub fn from_literal(lit: xla::Literal) -> anyhow::Result<HostTensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(HostTensor::F32 { shape: dims, data: lit.to_vec::<f32>()? }),
            xla::ElementType::S32 => Ok(HostTensor::I32 { shape: dims, data: lit.to_vec::<i32>()? }),
            other => anyhow::bail!("unsupported literal element type {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_accessors() {
        let t = HostTensor::f32(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.len(), 6);
        assert!(t.as_f32().is_ok());
        assert!(t.as_i32().is_err());
        let s = HostTensor::scalar_f32(4.0);
        assert_eq!(s.item_f32().unwrap(), 4.0);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn shape_mismatch_panics() {
        HostTensor::f32(vec![2, 2], vec![0.0; 3]);
    }

    #[test]
    fn literal_roundtrip_f32() {
        let t = HostTensor::f32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(lit).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn literal_roundtrip_i32() {
        let t = HostTensor::i32(vec![3], vec![7, 8, 9]);
        let back = HostTensor::from_literal(t.to_literal().unwrap()).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn literal_roundtrip_scalar() {
        let t = HostTensor::scalar_i32(5);
        let back = HostTensor::from_literal(t.to_literal().unwrap()).unwrap();
        assert_eq!(back.as_i32().unwrap(), &[5]);
    }
}
