//! PJRT runtime: load AOT artifacts (`artifacts/*.hlo.txt` + manifest) and
//! execute them from the Rust hot path. Python is never invoked here.
//!
//! The interchange format is HLO **text** (see `python/compile/aot.py` and
//! /opt/xla-example/README.md): `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `PjRtClient::compile` → `execute`.
//! Executables return 1-tuples-of-N (lowered with `return_tuple=True`),
//! unpacked with `Literal::to_tuple`.

pub mod tensor;

pub use tensor::{HostTensor, TensorView, TensorViewMut};

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// Shape + dtype of one executable input/output.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> anyhow::Result<TensorSpec> {
        let shape = j
            .req("shape")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("shape must be an array"))?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| anyhow::anyhow!("bad dim")))
            .collect::<anyhow::Result<Vec<_>>>()?;
        let dtype = j.req("dtype")?.as_str().unwrap_or("float32").to_string();
        Ok(TensorSpec { shape, dtype })
    }
}

/// One AOT-compiled entry point.
#[derive(Debug, Clone)]
pub struct EntrySpec {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// Raw manifest entry (for extras like `param_order`, `config`).
    pub raw: Json,
}

impl EntrySpec {
    /// `param_order` extra (model entries).
    pub fn param_order(&self) -> Option<Vec<String>> {
        self.raw.get("param_order").and_then(Json::as_arr).map(|a| {
            a.iter().filter_map(|v| v.as_str().map(str::to_string)).collect()
        })
    }

    pub fn extra_usize(&self, key: &str) -> Option<usize> {
        self.raw.get(key).and_then(Json::as_usize)
    }
}

/// Loaded manifest + PJRT client + compiled executables.
pub struct Runtime {
    client: xla::PjRtClient,
    entries: BTreeMap<String, EntrySpec>,
    executables: BTreeMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Open `dir/manifest.json`, create the CPU PJRT client. Executables
    /// compile lazily on first use (compile-on-demand keeps `train` fast
    /// when only one entry is needed).
    pub fn open(dir: impl AsRef<Path>) -> anyhow::Result<Runtime> {
        let dir = dir.as_ref();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            anyhow::anyhow!(
                "cannot read {} (run `make artifacts` first): {e}",
                manifest_path.display()
            )
        })?;
        let manifest = Json::parse(&text).map_err(|e| anyhow::anyhow!("manifest: {e}"))?;
        let mut entries = BTreeMap::new();
        for (name, e) in manifest
            .req("entries")?
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("entries must be an object"))?
        {
            let file = dir.join(
                e.req("file")?
                    .as_str()
                    .ok_or_else(|| anyhow::anyhow!("file must be a string"))?,
            );
            let inputs = e
                .req("inputs")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(TensorSpec::from_json)
                .collect::<anyhow::Result<Vec<_>>>()?;
            let outputs = e
                .req("outputs")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(TensorSpec::from_json)
                .collect::<anyhow::Result<Vec<_>>>()?;
            entries.insert(
                name.clone(),
                EntrySpec { name: name.clone(), file, inputs, outputs, raw: e.clone() },
            );
        }
        let client = xla::PjRtClient::cpu()?;
        crate::log_info!(
            "pjrt client up: platform={} entries={}",
            client.platform_name(),
            entries.len()
        );
        Ok(Runtime { client, entries, executables: BTreeMap::new() })
    }

    pub fn entry(&self, name: &str) -> anyhow::Result<&EntrySpec> {
        self.entries
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("no artifact entry `{name}` in manifest"))
    }

    pub fn entry_names(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(|s| s.as_str())
    }

    /// Compile (idempotent) and cache an executable.
    pub fn compile(&mut self, name: &str) -> anyhow::Result<()> {
        if self.executables.contains_key(name) {
            return Ok(());
        }
        let entry = self.entry(name)?.clone();
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            entry
                .file
                .to_str()
                .ok_or_else(|| anyhow::anyhow!("non-utf8 artifact path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        crate::log_info!("compiled `{name}` in {:.2}s", t0.elapsed().as_secs_f64());
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an entry with host tensors; returns the unpacked outputs.
    /// Inputs are validated against the manifest specs.
    pub fn execute(
        &mut self,
        name: &str,
        inputs: &[HostTensor],
    ) -> anyhow::Result<Vec<HostTensor>> {
        self.compile(name)?;
        let entry = self.entry(name)?;
        anyhow::ensure!(
            inputs.len() == entry.inputs.len(),
            "`{name}` expects {} inputs, got {}",
            entry.inputs.len(),
            inputs.len()
        );
        for (i, (t, spec)) in inputs.iter().zip(entry.inputs.iter()).enumerate() {
            anyhow::ensure!(
                t.shape() == spec.shape,
                "`{name}` input {i}: shape {:?} != manifest {:?}",
                t.shape(),
                spec.shape
            );
        }
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(HostTensor::to_literal)
            .collect::<anyhow::Result<Vec<_>>>()?;
        let exe = self.executables.get(name).expect("compiled above");
        let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // jax lowers with return_tuple=True: a single tuple of outputs.
        let parts = result.to_tuple()?;
        parts.into_iter().map(HostTensor::from_literal).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Runtime tests that need artifacts live in rust/tests/runtime_it.rs
    // (integration, gated on artifacts/ existing). Here: manifest parsing.

    #[test]
    fn tensor_spec_from_json() {
        let j = Json::parse(r#"{"shape": [2, 3], "dtype": "float32"}"#).unwrap();
        let s = TensorSpec::from_json(&j).unwrap();
        assert_eq!(s.shape, vec![2, 3]);
        assert_eq!(s.elements(), 6);
    }

    #[test]
    fn open_missing_dir_errors_helpfully() {
        let Err(err) = Runtime::open("/nonexistent-dir").map(|_| ()) else {
            panic!("expected error");
        };
        assert!(err.to_string().contains("make artifacts"), "{err}");
    }
}
