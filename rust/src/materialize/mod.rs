//! Sparse materialization — the paper's **Algorithm 1** (§4.2) plus the
//! post-gate *calibration* stage and the overlap-degree computation.
//!
//! Given the sharded placement `P`, a (predicted) expert-load distribution
//! `F`, the overlap degree `t` (how many expert materializations can hide
//! under the attention layer) and the per-device memory headroom `m` (in
//! expert slots), the scheduler returns a materialization plan `P' ⊇ P`:
//!
//! * `t ≤ m`  — replicate the top-`t` loaded experts on **all** devices
//!   (communication is the binding constraint; memory is plentiful);
//! * `t > m`  — hand out `|D|·m` replica slots to the top-`t` experts
//!   proportionally to load, spreading each expert's replicas across nodes
//!   that do not yet hold it (topology-aware, mitigating inter-node
//!   All-to-All congestion).

use crate::placement::Placement;
use crate::topology::{DeviceId, Topology};

/// System constraints for Algorithm 1.
#[derive(Debug, Clone, Copy)]
pub struct MatConstraints {
    /// Overlap degree `t`: max expert materializations hideable under the
    /// preceding non-MoE computation.
    pub overlap_degree: usize,
    /// Memory capacity `m`: expert slots of headroom per device.
    pub mem_slots: usize,
}

/// `t = T_non-MoE · bw / expert_size` (§4.2). `bw` must be
/// [`Topology::planning_bw`] — inter-node bandwidth on heterogeneous
/// clusters, since the algorithm minimizes cross-node traffic first.
pub fn overlap_degree(t_non_moe: f64, bw: f64, expert_bytes: f64) -> usize {
    if expert_bytes <= 0.0 {
        return 0;
    }
    (t_non_moe * bw / expert_bytes).floor() as usize
}

/// Indices of the top-`t` experts by load, descending.
///
/// Uses `f64::total_cmp`, never `partial_cmp(..).unwrap()`: a degenerate
/// predictor window (all-zero history normalized 0/0) can surface NaN
/// loads, and a planner panic mid-training is far worse than a NaN expert
/// sorting deterministically (total order puts NaN above +inf, so it is
/// simply treated as hottest).
pub fn top_by_load(loads: &[f64], t: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..loads.len()).collect();
    idx.sort_by(|&a, &b| loads[b].total_cmp(&loads[a]).then(a.cmp(&b)));
    idx.truncate(t);
    idx
}

/// Algorithm 1: sparse materialization plan.
///
/// `shards` is the pre-condition `P` (must be surjective), `loads` the
/// per-expert (predicted) fractions `F`.
pub fn sparse_materialize(
    topo: &Topology,
    shards: &Placement,
    loads: &[f64],
    cons: MatConstraints,
) -> Placement {
    let num_experts = shards.num_chunks();
    assert_eq!(loads.len(), num_experts);
    let num_devices = shards.num_devices();

    // line 1: t <- min(t, |E|), m <- min(m, t)
    let t = cons.overlap_degree.min(num_experts);
    let m = cons.mem_slots.min(t);
    // line 2: P' <- P
    let mut plan = shards.clone();
    if t == 0 || m == 0 {
        return plan;
    }

    let top_t = top_by_load(loads, t);

    if t <= m {
        // lines 4-5: replicate all top-t experts on every device.
        for &e in &top_t {
            for d in 0..num_devices {
                plan.add(e, DeviceId(d));
            }
        }
        return plan;
    }

    // lines 7-11: proportional slot assignment under memory pressure.
    let tot_slots = num_devices * m;
    let mut free_slots: Vec<usize> = vec![m; num_devices];
    let top_load_sum: f64 = top_t.iter().map(|&e| loads[e]).sum();
    let mut remaining = tot_slots;

    for &e in &top_t {
        if remaining == 0 {
            break;
        }
        // line 9: slots by load share (at least 1 for a top-t expert).
        let share = if top_load_sum > 0.0 { loads[e] / top_load_sum } else { 0.0 };
        let n = ((share * tot_slots as f64).round() as usize)
            .clamp(1, remaining)
            .min(num_devices);
        // line 10: distribute n replicas across nodes, prioritizing nodes
        // that do not yet hold the expert, then devices with free slots.
        let placed = distribute_replicas(topo, &mut plan, &mut free_slots, e, n);
        remaining = remaining.saturating_sub(placed);
    }
    plan
}

/// Place up to `n` new replicas of expert `e`, preferring (1) nodes without
/// any replica, (2) nodes with more free slots, then within a node the
/// device with most free slots. Returns how many replicas were placed.
fn distribute_replicas(
    topo: &Topology,
    plan: &mut Placement,
    free_slots: &mut [usize],
    e: usize,
    n: usize,
) -> usize {
    let mut placed = 0;
    while placed < n {
        // Rank nodes: without-expert first, then most free slots.
        let best_node = topo
            .all_nodes()
            .filter(|&node| {
                topo.devices_on(node).any(|d| free_slots[d.0] > 0 && !plan.contains(e, d))
            })
            .min_by_key(|&node| {
                let has = !plan.holders_on_node(topo, e, node).is_empty();
                let free: usize = topo.devices_on(node).map(|d| free_slots[d.0]).sum();
                (has, usize::MAX - free, node.0)
            });
        let Some(node) = best_node else { break };
        let dev = topo
            .devices_on(node)
            .filter(|d| free_slots[d.0] > 0 && !plan.contains(e, *d))
            .max_by_key(|d| (free_slots[d.0], usize::MAX - d.0))
            .unwrap();
        plan.add(e, dev);
        free_slots[dev.0] -= 1;
        placed += 1;
    }
    placed
}

/// Post-gate calibration (§4.2): once the real token assignment is known,
/// re-run Algorithm 1 with the realized loads and remaining memory, and
/// accept the extra materialization only if the *estimated* MoE latency
/// reduction exceeds the additional on-critical-path communication cost.
///
/// Returns `Some(new_plan)` when calibration pays off.
pub struct CalibrationResult {
    pub plan: Placement,
    /// Extra spAG time placed on the critical path.
    pub extra_comm: f64,
    /// Estimated MoE latency before/after.
    pub est_before: f64,
    pub est_after: f64,
}

pub fn calibrate(
    topo: &Topology,
    _shards: &Placement,
    current_plan: &Placement,
    realized_loads: &[f64],
    remaining_mem_slots: usize,
    expert_bytes: f64,
    moe_latency_est: impl Fn(&Placement, &[f64]) -> f64,
) -> Option<CalibrationResult> {
    let cons = MatConstraints {
        // Calibration traffic is *not* overlapped, so the overlap degree no
        // longer binds; memory is the only constraint.
        overlap_degree: usize::MAX,
        mem_slots: remaining_mem_slots,
    };
    // Re-run Algorithm 1 seeded from the current materialized placement.
    let candidate = sparse_materialize(topo, current_plan, realized_loads, cons);
    if &candidate == current_plan {
        return None;
    }
    let extra = crate::collectives::sparse::build_spag(topo, current_plan, &candidate).ok()?;
    let extra_comm = extra.time(topo, expert_bytes);
    let est_before = moe_latency_est(current_plan, realized_loads);
    let est_after = moe_latency_est(&candidate, realized_loads);
    if est_after + extra_comm < est_before {
        Some(CalibrationResult { plan: candidate, extra_comm, est_before, est_after })
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing;
    use crate::util::rng::Rng;

    fn skewed_loads(n: usize, hot: usize) -> Vec<f64> {
        let mut f = vec![0.5 / (n - 1) as f64; n];
        f[hot] = 0.5;
        f
    }

    #[test]
    fn overlap_degree_formula() {
        // t = T_nonMoE * bw / expert_size
        assert_eq!(overlap_degree(0.01, 12.5e9, 25e6), 5);
        assert_eq!(overlap_degree(0.0, 12.5e9, 25e6), 0);
        assert_eq!(overlap_degree(1.0, 1e9, 0.0), 0);
    }

    #[test]
    fn plentiful_memory_replicates_top_t_everywhere() {
        let topo = Topology::cluster_a(2, 4);
        let shards = Placement::round_robin(16, 8);
        let loads = skewed_loads(16, 3);
        let plan = sparse_materialize(
            &topo,
            &shards,
            &loads,
            MatConstraints { overlap_degree: 2, mem_slots: 8 },
        );
        // hottest expert (3) on all 8 devices
        assert_eq!(plan.replication(3), 8);
        // exactly top-2 experts are fully replicated
        let fully: Vec<usize> = (0..16).filter(|&e| plan.replication(e) == 8).collect();
        assert_eq!(fully.len(), 2);
        assert!(fully.contains(&3));
        assert!(shards.is_subset_of(&plan));
    }

    #[test]
    fn memory_pressure_respects_slots() {
        let topo = Topology::cluster_a(2, 4);
        let shards = Placement::round_robin(16, 8);
        let loads = skewed_loads(16, 0);
        let m = 1;
        let plan = sparse_materialize(
            &topo,
            &shards,
            &loads,
            MatConstraints { overlap_degree: 8, mem_slots: m },
        );
        // no device gained more than m new experts
        for d in topo.all_devices() {
            let extra = plan.load_of(d) - shards.load_of(d);
            assert!(extra <= m, "device {} gained {extra} > m={m}", d.0);
        }
        // hottest expert got the most replicas
        let r0 = plan.replication(0);
        for e in 1..16 {
            assert!(plan.replication(e) <= r0);
        }
        assert!(r0 > 1);
    }

    #[test]
    fn replicas_spread_across_nodes_first() {
        let topo = Topology::cluster_a(4, 2); // 4 nodes × 2 devices
        let mut shards = Placement::empty(8, 8);
        for e in 0..8 {
            shards.add(e, DeviceId(e % 8));
        }
        let loads = skewed_loads(8, 0); // expert 0 hot, lives on node 0
        let plan = sparse_materialize(
            &topo,
            &shards,
            &loads,
            MatConstraints { overlap_degree: 8, mem_slots: 1 },
        );
        // expert 0's replicas should touch multiple nodes, not pile on node 0
        let nodes: std::collections::BTreeSet<usize> =
            plan.holders(0).map(|d| topo.node_of(d).0).collect();
        assert!(nodes.len() >= 3, "expert 0 replicas on nodes {nodes:?}");
    }

    #[test]
    fn nan_loads_do_not_panic_and_stay_deterministic() {
        // Regression: a degenerate predictor window (0/0 normalization) can
        // hand the planner NaN loads; sorting must not panic.
        let loads = vec![0.1, f64::NAN, 0.3, 0.0, f64::NAN, 0.2];
        let top = top_by_load(&loads, 3);
        assert_eq!(top.len(), 3);
        // total_cmp puts NaN above every finite value; ties by index.
        assert_eq!(top, vec![1, 4, 2]);
        // ...and Algorithm 1 still produces a valid spAG target.
        let topo = Topology::cluster_a(2, 2);
        let shards = Placement::round_robin(6, 4);
        let plan = sparse_materialize(
            &topo,
            &shards,
            &loads,
            MatConstraints { overlap_degree: 3, mem_slots: 2 },
        );
        assert!(shards.is_subset_of(&plan));
        crate::placement::validate_spag(&shards, &plan).unwrap();
        // an all-NaN row is the worst case of the degenerate window
        let all_nan = vec![f64::NAN; 6];
        assert_eq!(top_by_load(&all_nan, 2), vec![0, 1]);
        let plan2 = sparse_materialize(
            &topo,
            &shards,
            &all_nan,
            MatConstraints { overlap_degree: 4, mem_slots: 1 },
        );
        assert!(shards.is_subset_of(&plan2));
    }

    #[test]
    fn zero_constraints_are_noop() {
        let topo = Topology::flat(4, 1e9);
        let shards = Placement::round_robin(8, 4);
        let loads = vec![1.0 / 8.0; 8];
        for cons in [
            MatConstraints { overlap_degree: 0, mem_slots: 4 },
            MatConstraints { overlap_degree: 4, mem_slots: 0 },
        ] {
            assert_eq!(sparse_materialize(&topo, &shards, &loads, cons), shards);
        }
    }

    #[test]
    fn prop_plan_is_valid_spag_target() {
        testing::check(
            |rng: &mut Rng, size| {
                let topo = Topology::cluster_a(1 + rng.below(3), 1 + rng.below(4));
                let nd = topo.num_devices();
                let experts = (1 + rng.below(4 * size.max(1))).max(nd.min(4));
                let shards = Placement::round_robin(experts, nd);
                let loads = rng.dirichlet(0.2, experts);
                let cons = MatConstraints {
                    overlap_degree: rng.below(experts + 2),
                    mem_slots: rng.below(6),
                };
                (topo, shards, loads, cons)
            },
            |(topo, shards, loads, cons)| {
                let plan = sparse_materialize(topo, shards, loads, *cons);
                if !shards.is_subset_of(&plan) {
                    return Err("P ⊄ P'".into());
                }
                crate::placement::validate_spag(shards, &plan).map_err(|e| e.to_string())?;
                // memory bound: every device gains at most min(m, t) slots
                let bound = cons.mem_slots.min(cons.overlap_degree);
                for d in topo.all_devices() {
                    let extra = plan.load_of(d) - shards.load_of(d);
                    // in the t<=m branch the gain is top-t (≤ t ≤ bound
                    // only when t ≤ m); overall gain ≤ max(t, m) ≤ experts
                    let t = cons.overlap_degree.min(plan.num_chunks());
                    let m = cons.mem_slots.min(t);
                    let limit = if t <= m { t } else { bound };
                    if extra > limit {
                        return Err(format!("device {} gained {extra} > {limit}", d.0));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn calibration_accepts_only_when_profitable() {
        let topo = Topology::cluster_a(2, 4);
        let shards = Placement::round_robin(16, 8);
        let mut realized = vec![0.02; 16];
        realized[5] = 0.7; // unexpectedly hot
        let current = shards.clone(); // predictor missed it entirely
        // latency estimator: straggler factor of per-device load under the plan
        let est = |p: &Placement, loads: &[f64]| {
            let mut dev_load = vec![0.0; 8];
            for e in 0..16 {
                let reps: Vec<_> = p.holders(e).collect();
                for d in &reps {
                    dev_load[d.0] += loads[e] / reps.len() as f64;
                }
            }
            dev_load.iter().cloned().fold(0.0, f64::max)
        };
        let r = calibrate(&topo, &shards, &current, &realized, 4, 1e6, est);
        assert!(r.is_some(), "hot miss should trigger calibration");
        let r = r.unwrap();
        assert!(r.est_after < r.est_before);
        assert!(r.plan.replication(5) > 1);

        // balanced realized loads: nothing to gain
        let balanced = vec![1.0 / 16.0; 16];
        let r2 = calibrate(&topo, &shards, &current, &balanced, 4, 1e6, est);
        if let Some(r2) = r2 {
            assert!(r2.est_after + r2.extra_comm < r2.est_before);
        }
    }
}
