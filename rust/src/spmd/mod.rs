//! The SPMD parallel executor: run the numeric FSSDP engine with **one OS
//! thread per simulated rank**, connected by an in-process communicator.
//!
//! The sequential engine ([`FssdpEngine::step`]) is the oracle: it walks
//! all N device memories in one loop. This module executes the *same*
//! iteration — the same plans, the same kernels, the same floating-point
//! orders — as N true SPMD programs:
//!
//! * [`comm`] — per-link mailboxes over `std::sync::mpsc` with MPI-style
//!   tag matching, barrier, nonblocking `isend`/`irecv` + completion
//!   handles, and optional α–β link pacing.
//! * [`exec`] — per-rank spAG/spRS execution ([`exec::run_spag_rank`],
//!   [`exec::run_sprs_rank`]), staged exactly as the compiled
//!   [`SparsePlan`](crate::collectives::sparse::SparsePlan) dictates.
//! * [`sched`] — the overlap scheduler: lazy replica materialization
//!   during expert compute plus eager issue of the *next* iteration's
//!   spAG right after each owner's Adam update (§4.3 re-materialization
//!   overlap), with iteration-tagged messages instead of barriers.
//!
//! ## Determinism contract
//!
//! The parallel executor produces **bit-identical** expert parameters to
//! the sequential engine at the same seed because:
//!
//! 1. All control-plane state (predictor window, shard map, gate weights)
//!    is replicated and updated deterministically from globally exchanged
//!    gate decisions — every rank computes the same
//!    [`IterPlan`](crate::fssdp) and route map redundantly.
//! 2. Token batches are deterministic in `(iter, source)`, so ranks
//!    regenerate remote tokens locally; only gate decisions and chunk
//!    buffers cross the wire.
//! 3. Every floating-point accumulation order is preserved: gradient
//!    buffers accumulate per `(device, expert)` in route order, spRS
//!    reduces in plan order per destination, Adam is per-expert local.
//!    (The global *loss* stat is a cross-rank f64 sum and may differ in
//!    the last ulps; parameters never do.)
//!
//! `rust/tests/spmd_equivalence.rs` locks the contract, including resume
//! from a checkpoint written under the other executor.

pub mod comm;
pub mod exec;
pub(crate) mod sched;

use std::collections::BTreeMap;
use std::time::Instant;

use crate::collectives::exec::{ChunkStore, ClusterMem};
use crate::dispatch::dispatch;
use crate::fssdp::adam::{AdamCfg, AdamState};
use crate::fssdp::compute::{Compute, Reference};
use crate::fssdp::{
    assignment_matrix, batch_for, build_iter_plan, compute_expert_key, realized_loads,
    routes_from_gates, EngineStats, FssdpEngine, LayerDims,
};
use crate::loadsim::LoadPredictor;
use crate::materialize::MatConstraints;
use crate::metrics::Metrics;
use crate::placement::Placement;
use crate::runtime::HostTensor;
use crate::topology::{DeviceId, Topology};

use comm::{MsgKind, RankComm};
use exec::{run_sprs_rank, RankSpag};
use sched::{order_resident_first, Overlap};

/// Everything one rank thread owns or borrows for a span.
struct RankCtx<'a> {
    me: usize,
    nd: usize,
    sources: usize,
    start: u64,
    iters: usize,
    dims: LayerDims,
    topo: &'a Topology,
    shards: &'a Placement,
    gate_w: &'a [f32],
    adam: AdamCfg,
    cons: MatConstraints,
    overlap: bool,
    /// This rank's expert-parameter shard (plus transient replicas).
    store: ChunkStore,
    /// Adam states of the experts this rank owns.
    opt: BTreeMap<usize, AdamState>,
    /// Replicated predictor clone (deterministically identical on every
    /// rank; rank 0's copy is synced back to the engine).
    predictor: LoadPredictor,
    comm: RankComm,
}

/// Global per-iteration stats, computed redundantly on rank 0 only.
struct GlobalStats {
    sparsity: f64,
    replicas: usize,
    remote_tokens: usize,
    straggler: f64,
}

/// What a rank thread hands back at span exit.
struct RankOut {
    store: ChunkStore,
    opt: BTreeMap<usize, AdamState>,
    predictor: LoadPredictor,
    metrics: Metrics,
    /// Per-iteration partial loss (this rank's route groups).
    loss: Vec<f64>,
    /// Rank 0 only; empty elsewhere.
    global: Vec<GlobalStats>,
}

/// Run `iters` iterations of the engine on one thread per rank and sync
/// the (bit-identical) state back into `engine`. Called through
/// [`FssdpEngine::run_span`] with `Executor::Spmd`.
pub fn run_span(
    engine: &mut FssdpEngine,
    start: u64,
    iters: usize,
    sources: usize,
    threads: usize,
    overlap: bool,
) -> anyhow::Result<Vec<EngineStats>> {
    let nd = engine.topo.num_devices();
    anyhow::ensure!(
        threads == nd,
        "SPMD executor runs one OS thread per rank: {threads} threads != {nd} devices"
    );
    anyhow::ensure!(
        matches!(engine.compute, Compute::Reference(_)),
        "SPMD executor requires the hermetic reference backend \
         (PJRT client handles cannot be shared across rank threads)"
    );
    if iters == 0 {
        return Ok(Vec::new());
    }

    // Split the engine state per rank: each thread owns its device's chunk
    // store and the Adam states of the experts it owns; replicated state
    // is cloned (gate weights are frozen, the predictor evolves
    // deterministically and identically on every rank).
    let topo = engine.topo.clone();
    let shards = engine.shards.clone();
    let gate_w = engine.gate_w.clone();
    let dims = engine.dims;
    let adam = engine.adam;
    let cons = MatConstraints { overlap_degree: engine.overlap_degree, mem_slots: engine.mem_slots };
    let predictor = engine.predictor.clone();

    // Rank threads get *copies* of the device memories and optimizer
    // states, not the originals: if any rank fails, the engine keeps its
    // pre-span state intact (a span either commits whole or not at all).
    // One parameter-set copy per span is noise next to a span of steps.
    let stores: Vec<ChunkStore> = engine.params.devices.clone();
    anyhow::ensure!(stores.len() == nd, "engine memory does not match the topology");
    let mut opts: Vec<BTreeMap<usize, AdamState>> = (0..nd).map(|_| BTreeMap::new()).collect();
    for (e, st) in &engine.opt {
        let owner = shards.holders(*e).next().expect("every expert has an owner");
        opts[owner.0].insert(*e, st.clone());
    }
    let comms = comm::fabric(nd, None);

    let mut ctxs: Vec<RankCtx> = Vec::with_capacity(nd);
    for (me, ((store, opt), comm)) in
        stores.into_iter().zip(opts).zip(comms).enumerate()
    {
        ctxs.push(RankCtx {
            me,
            nd,
            sources,
            start,
            iters,
            dims,
            topo: &topo,
            shards: &shards,
            gate_w: &gate_w,
            adam,
            cons,
            overlap,
            store,
            opt,
            predictor: predictor.clone(),
            comm,
        });
    }

    let results: Vec<std::thread::Result<anyhow::Result<RankOut>>> =
        std::thread::scope(|sc| {
            let mut handles = Vec::with_capacity(nd);
            for ctx in ctxs {
                handles.push(sc.spawn(move || rank_main(ctx)));
            }
            handles.into_iter().map(|h| h.join()).collect()
        });

    // Surface the most informative failure: a rank's own error beats the
    // secondary "link closed" errors its death caused on its peers.
    let mut outs: Vec<RankOut> = Vec::with_capacity(nd);
    let mut primary: Option<anyhow::Error> = None;
    let mut secondary: Option<anyhow::Error> = None;
    for (r, res) in results.into_iter().enumerate() {
        match res {
            Err(payload) => {
                if primary.is_none() {
                    let msg = payload
                        .downcast_ref::<String>()
                        .cloned()
                        .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                        .unwrap_or_else(|| "non-string panic payload".to_string());
                    primary = Some(anyhow::anyhow!("SPMD rank {r} panicked: {msg}"));
                }
            }
            Ok(Err(e)) => {
                if e.to_string().contains("closed") {
                    if secondary.is_none() {
                        secondary = Some(e);
                    }
                } else if primary.is_none() {
                    primary = Some(e);
                }
            }
            Ok(Ok(o)) => outs.push(o),
        }
    }
    if let Some(e) = primary.or(secondary) {
        return Err(e);
    }
    anyhow::ensure!(outs.len() == nd, "SPMD span lost rank outputs");

    // Merge per-rank state back into the engine.
    let mut stats = vec![EngineStats::default(); iters];
    let mut devices: Vec<ChunkStore> = Vec::with_capacity(nd);
    let mut opt_all: BTreeMap<usize, AdamState> = BTreeMap::new();
    let mut merged = Metrics::new();
    for (r, out) in outs.into_iter().enumerate() {
        let RankOut { store, opt, predictor, metrics, loss, global } = out;
        anyhow::ensure!(loss.len() == iters, "rank {r} returned {} loss entries", loss.len());
        for (i, l) in loss.iter().enumerate() {
            stats[i].loss += *l;
        }
        if r == 0 {
            engine.predictor = predictor;
            for (i, g) in global.iter().enumerate() {
                stats[i].spag_sparsity = g.sparsity;
                stats[i].replicas = g.replicas;
                stats[i].remote_tokens = g.remote_tokens;
                stats[i].straggler = g.straggler;
            }
        }
        devices.push(store);
        opt_all.extend(opt);
        merged.merge(&metrics);
    }
    merged.add("spmd.ranks", nd as f64);
    engine.params = ClusterMem { devices };
    engine.opt = opt_all;
    engine.spmd_metrics = Some(merged);
    Ok(stats)
}

/// The rank program: the body of [`FssdpEngine::step`], restricted to one
/// rank's slice of the work, with communicator exchanges where the
/// sequential engine touches other devices' memory.
fn rank_main(mut ctx: RankCtx) -> anyhow::Result<RankOut> {
    let me = ctx.me;
    let nd = ctx.nd;
    let dims = ctx.dims;
    let mut compute = Compute::Reference(Reference);
    let mut ov = Overlap::new(ctx.overlap);
    let mut metrics = Metrics::new();
    let mut losses: Vec<f64> = Vec::with_capacity(ctx.iters);
    let mut global: Vec<GlobalStats> = Vec::new();
    let gate_wt = HostTensor::f32(vec![dims.d_model, dims.experts], ctx.gate_w.to_vec());

    for k in 0..ctx.iters {
        let iter = ctx.start + k as u64;
        let last = k + 1 == ctx.iters;

        // ---- plan (replicated): predict → Algorithm 1 → spAG/spRS ----
        let t0 = Instant::now();
        let plan = match ov.next_plan.take() {
            Some(p) => p,
            None => build_iter_plan(ctx.topo, ctx.shards, &ctx.predictor.predict(), ctx.cons)?,
        };
        metrics.add_duration("spmd.plan", t0.elapsed());

        // ---- spAG: issue our sends now; completion is lazy (overlap) or
        //      immediate (synchronous collectives) ----
        let pre_issued = std::mem::take(&mut ov.pre_issued);
        let mut spag =
            RankSpag::begin(&plan.spag, me, iter, &ctx.store, &ctx.comm, &pre_issued)?;
        if !ov.enabled {
            let t0 = Instant::now();
            spag.finish(&mut ctx.store, &mut ctx.comm)?;
            metrics.add_duration("spmd.spag_wait", t0.elapsed());
        }

        // ---- gate our sources; exchange decisions with every rank ----
        let t0 = Instant::now();
        let mut batches: Vec<Vec<f32>> = Vec::with_capacity(ctx.sources);
        for s in 0..ctx.sources {
            batches.push(batch_for(&dims, iter, s));
        }
        let mut gate_idx: Vec<Vec<i32>> = vec![Vec::new(); ctx.sources];
        let mut gate_w_out: Vec<Vec<f32>> = vec![Vec::new(); ctx.sources];
        let mut payload: Vec<f32> = Vec::new();
        for s in 0..ctx.sources {
            if s % nd != me {
                continue;
            }
            let xt = HostTensor::f32(vec![dims.tokens, dims.d_model], batches[s].clone());
            let out = compute.execute("gate_fwd", &[xt, gate_wt.clone()])?;
            let w = out[1].as_f32()?.to_vec();
            let idx = out[2].as_i32()?.to_vec();
            payload.push(s as f32);
            payload.extend_from_slice(&w);
            payload.extend(idx.iter().map(|&v| v as f32));
            gate_w_out[s] = w;
            gate_idx[s] = idx;
        }
        let gathered = ctx.comm.allgather(iter, MsgKind::Gate, payload)?;
        let rec = 1 + 4 * dims.tokens; // source id + 2T weights + 2T indices
        for (r, buf) in gathered.iter().enumerate() {
            if r == me {
                continue;
            }
            anyhow::ensure!(buf.len() % rec == 0, "gate payload misaligned from rank {r}");
            for record in buf.chunks(rec) {
                let s = record[0] as usize;
                anyhow::ensure!(s < ctx.sources && s % nd == r, "bogus gate source {s}");
                gate_w_out[s] = record[1..1 + 2 * dims.tokens].to_vec();
                gate_idx[s] =
                    record[1 + 2 * dims.tokens..].iter().map(|&v| v as i32).collect();
            }
        }
        metrics.add_duration("spmd.gate", t0.elapsed());

        // ---- predictor update; next iteration's plan is now knowable,
        //      which is what makes eager re-materialization sound ----
        let realized = realized_loads(dims.experts, &gate_idx);
        ctx.predictor.observe(&realized);
        if ov.enabled && !last {
            let t0 = Instant::now();
            ov.next_plan =
                Some(build_iter_plan(ctx.topo, ctx.shards, &ctx.predictor.predict(), ctx.cons)?);
            metrics.add_duration("spmd.plan", t0.elapsed());
        }

        // ---- routing (replicated) + rank-0 global stats ----
        let routes =
            routes_from_gates(ctx.topo, &plan.placement, nd, dims.experts, &gate_idx, &gate_w_out);
        if me == 0 {
            let asg = assignment_matrix(nd, dims.experts, &gate_idx);
            let dplan = dispatch(ctx.topo, &plan.placement, &asg);
            let toks: Vec<f64> =
                dplan.device_compute_tokens().iter().map(|&t| t as f64).collect();
            global.push(GlobalStats {
                sparsity: plan.spag.sparsity,
                replicas: plan.placement.len() - ctx.shards.len(),
                remote_tokens: dplan.remote_tokens(),
                straggler: crate::util::stats::straggler_factor(&toks),
            });
        }

        // ---- expert compute on our route keys, shards-resident first;
        //      replicas are pulled as compute reaches them ----
        let mut grads = ChunkStore::new();
        for e in 0..dims.experts {
            if plan.placement.contains(e, DeviceId(me)) {
                grads.insert(e, vec![0.0f32; dims.chunk_len()]);
            }
        }
        let my_keys: Vec<usize> =
            routes.keys().filter(|(d, _)| *d == me).map(|(_, e)| *e).collect();
        let order = order_resident_first(&my_keys, &ctx.store);
        let inv_t = 1.0f32 / (dims.tokens * ctx.sources) as f32;
        let mut loss = 0.0f64;
        for e in order {
            if !ctx.store.contains(e) {
                let t0 = Instant::now();
                spag.ensure(&mut ctx.store, &mut ctx.comm, e)?;
                metrics.add_duration("spmd.spag_wait", t0.elapsed());
                metrics.add("spmd.lazy_chunks", 1.0);
            }
            let toks = routes.get(&(me, e)).expect("key from this map");
            let chunk = ctx.store.get(e).expect("ensured above").clone();
            let acc = grads.get_mut(e).expect("grads cover the placement");
            let t0 = Instant::now();
            loss += compute_expert_key(&mut compute, &dims, &chunk, toks, &batches, inv_t, acc)?;
            metrics.add_duration("spmd.compute", t0.elapsed());
            metrics.add("spmd.groups", toks.chunks(dims.cap).len() as f64);
        }
        losses.push(loss);

        // Remaining receives + fan-out duties before the reduce phase.
        let t0 = Instant::now();
        spag.finish(&mut ctx.store, &mut ctx.comm)?;
        metrics.add_duration("spmd.spag_wait", t0.elapsed());

        // ---- spRS: reduce gradients to the shard owners ----
        let t0 = Instant::now();
        run_sprs_rank(&mut grads, &plan.sprs, ctx.shards, me, iter, &mut ctx.comm)?;
        metrics.add_duration("spmd.sprs", t0.elapsed());

        // ---- Adam on owned experts; eagerly re-materialize for i+1 ----
        let t0 = Instant::now();
        for e in 0..dims.experts {
            if !ctx.shards.contains(e, DeviceId(me)) {
                continue;
            }
            let g = grads
                .get(e)
                .ok_or_else(|| anyhow::anyhow!("owner {me} of expert {e} lost its gradient"))?
                .clone();
            let p = ctx.store.get_mut(e).expect("owner holds its shard");
            let st = ctx.opt.get_mut(&e).expect("owner holds the optimizer state");
            st.update(&ctx.adam, p, &g);
            let sent = ov.eager_issue(e, me, iter + 1, &ctx.store, &ctx.comm)?;
            metrics.add("spmd.eager_sends", sent as f64);
        }
        metrics.add_duration("spmd.adam", t0.elapsed());

        // ---- re-materialization: drop non-shard replicas (§4) ----
        let resident: Vec<usize> = ctx.store.chunks().collect();
        for c in resident {
            if !ctx.shards.contains(c, DeviceId(me)) {
                ctx.store.remove(c);
            }
        }
    }

    Ok(RankOut {
        store: ctx.store,
        opt: ctx.opt,
        predictor: ctx.predictor,
        metrics,
        loss: losses,
        global,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fssdp::{reference_dims, Executor};

    fn final_chunks(e: &FssdpEngine) -> Vec<Vec<f32>> {
        (0..e.dims.experts).map(|x| e.expert_chunk(x).clone()).collect()
    }

    #[test]
    fn spmd_span_matches_sequential_bitwise() {
        let dims = reference_dims();
        let sources = 4;
        let mut seq = FssdpEngine::new_reference(dims, Topology::cluster_a(2, 2), 21);
        let seq_stats = seq.run_span(0, 3, sources).unwrap();

        let mut par = FssdpEngine::new_reference(dims, Topology::cluster_a(2, 2), 21);
        par.executor = Executor::Spmd { threads: 4, overlap: true };
        let par_stats = par.run_span(0, 3, sources).unwrap();

        assert_eq!(final_chunks(&seq), final_chunks(&par), "parameters must be bit-identical");
        for (s, p) in seq_stats.iter().zip(par_stats.iter()) {
            assert!((s.loss - p.loss).abs() <= 1e-9 * s.loss.abs().max(1.0));
            assert_eq!(s.replicas, p.replicas);
            assert_eq!(s.remote_tokens, p.remote_tokens);
        }
        assert!(par.spmd_metrics().is_some());
    }

    #[test]
    fn overlap_off_is_also_bitwise_identical() {
        let dims = reference_dims();
        let mut a = FssdpEngine::new_reference(dims, Topology::cluster_a(2, 2), 5);
        a.executor = Executor::Spmd { threads: 4, overlap: false };
        a.run_span(0, 3, 4).unwrap();
        let mut b = FssdpEngine::new_reference(dims, Topology::cluster_a(2, 2), 5);
        b.executor = Executor::Spmd { threads: 4, overlap: true };
        b.run_span(0, 3, 4).unwrap();
        assert_eq!(final_chunks(&a), final_chunks(&b));
    }

    #[test]
    fn thread_count_must_match_devices() {
        let mut e = FssdpEngine::new_reference(reference_dims(), Topology::cluster_a(2, 2), 1);
        e.executor = Executor::Spmd { threads: 3, overlap: true };
        let err = e.run_span(0, 1, 4).unwrap_err().to_string();
        assert!(err.contains("one OS thread per rank"), "{err}");
    }

    #[test]
    fn empty_span_is_a_noop() {
        let mut e = FssdpEngine::new_reference(reference_dims(), Topology::cluster_a(2, 2), 1);
        e.executor = Executor::spmd_for(&e.topo);
        assert!(e.run_span(0, 0, 4).unwrap().is_empty());
    }
}
