//! The SPMD parallel executor: run the numeric FSSDP engine with **one
//! program per rank** — OS threads over the in-process transport, or
//! separate `hecate worker` processes over the socket transport.
//!
//! The sequential engine ([`FssdpEngine::step`]) is the oracle: it walks
//! all N device memories in one loop, layer by layer. This module executes
//! the *same* iteration — the same plans, the same kernels, the same
//! floating-point orders — as N true SPMD programs:
//!
//! * [`comm`] — the communicator: MPI-style tag matching (tags carry
//!   iteration **and layer**), barrier, nonblocking `isend`/`irecv` +
//!   completion handles, payload recycling, and optional α–β link pacing.
//! * [`transport`] — the pluggable byte-moving layer under the
//!   communicator: in-process mpsc mailboxes or TCP/UDS sockets with a
//!   versioned wire codec (rank programs can be threads or processes
//!   without the executor noticing).
//! * [`exec`] — per-rank spAG/spRS execution ([`exec::RankSpag`],
//!   [`exec::RankSprs`]), staged exactly as the compiled
//!   [`SparsePlan`](crate::collectives::sparse::SparsePlan) dictates.
//! * `sched` (crate-private) — the overlap scheduler: lazy replica
//!   materialization
//!   during expert compute, the §4.3 **cross-layer pipeline** (layer
//!   `l+1`'s spAG issued while layer `l` computes; layer `l+1`'s spRS
//!   finished under layer `l`'s backward), and eager issue of the *next*
//!   iteration's spAG right after each owner's Adam update, with
//!   (iteration, layer)-tagged messages instead of barriers.
//!
//! Layer boundaries add two data-plane exchanges the single-layer engine
//! never needed: after an inner layer's expert compute, every rank
//! broadcasts its routed tokens' **combine contributions** (`w·y` rows) so
//! all ranks assemble the next layer's activations identically, and during
//! backward the **input cotangents** (`gx` rows) flow the same way. Both
//! are assembled in the sequential engine's exact `(device, expert)` scan
//! order, so every f32 add lands in the same order on every rank.
//!
//! ## Determinism contract
//!
//! The parallel executor produces **bit-identical** expert parameters to
//! the sequential engine at the same seed because:
//!
//! 1. All control-plane state (predictor windows, shard maps, gate
//!    weights) is replicated and updated deterministically from globally
//!    exchanged gate decisions — every rank computes the same per-layer
//!    [`IterPlan`](crate::fssdp) and route maps redundantly.
//! 2. Layer-0 token batches are deterministic in `(iter, source)`, and
//!    deeper activations are assembled from broadcast combine rows in a
//!    fixed order — every rank holds identical activations at every layer.
//! 3. Every floating-point accumulation order is preserved: gradient
//!    buffers accumulate per `(device, expert)` in route order, spRS
//!    reduces in plan order per destination, Adam is per-expert local,
//!    combine/cotangent scatters run in `(device, expert)` order.
//!    (The global *loss* stat is a cross-rank f64 sum and may differ in
//!    the last ulps; parameters never do.)
//! 4. Per-rank kernel worker pools (`compute_threads > 1`) split each
//!    rank's expert-key loops across `std::thread::scope` workers, but
//!    every key accumulates into its own zeroed buffer and results merge
//!    on the rank thread in ascending-expert order — so Reference-mode
//!    parameters and losses carry the same bits at any thread count, and
//!    Fast-mode ([`ComputeMode::Fast`]) runs are deterministic run-to-run
//!    and across thread counts.
//!
//! `rust/tests/spmd_equivalence.rs` locks the contract at L=1 (including
//! bit-identity to the seed engine) and L=3, plus resume from a checkpoint
//! written under the other executor.

pub mod comm;
pub mod exec;
pub(crate) mod sched;
pub mod transport;
pub(crate) mod worker;

use std::collections::BTreeMap;
use std::time::Instant;

use crate::collectives::exec::{BufferPool, ChunkStore, ClusterMem};
use crate::dispatch::dispatch;
use crate::fssdp::adam::{AdamCfg, AdamState};
use crate::fssdp::compute::{Compute, ComputeMode};
use crate::fssdp::{
    assignment_matrix, backward_expert_key, batch_for, build_iter_plan, compute_expert_key,
    forward_expert_rows, realized_loads, routes_from_gates, scatter_rows, zero_acts,
    EngineStats, FssdpEngine, IterPlan, KeyMode, KeyOut, KeyScratch, LayerDims, Routes,
};
use crate::loadsim::LoadPredictor;
use crate::materialize::MatConstraints;
use crate::metrics::meter::StepMeter;
use crate::metrics::Metrics;
use crate::placement::Placement;
use crate::telemetry::{Phase as TracePhase, TraceRecorder};
use crate::topology::{DeviceId, Topology};

use comm::{AuditEvent, MsgKind, RankComm};
use exec::{RankSpag, RankSprs};
use sched::{order_resident_first, Overlap};
use transport::{CommError, TransportKind};

/// One layer's slice of a rank's state for a span.
struct RankLayerState {
    /// This rank's expert-parameter shard of the layer (plus transient
    /// replicas).
    store: ChunkStore,
    /// Adam states of the layer's experts this rank owns.
    opt: BTreeMap<usize, AdamState>,
    /// Replicated predictor clone (deterministically identical on every
    /// rank; rank 0's copy is synced back to the engine).
    predictor: LoadPredictor,
}

/// Everything one rank thread owns or borrows for a span.
struct RankCtx<'a> {
    me: usize,
    nd: usize,
    sources: usize,
    start: u64,
    iters: usize,
    dims: LayerDims,
    topo: &'a Topology,
    /// Per-layer owner partitions (replicated).
    shards: &'a [Placement],
    /// Per-layer gate weights (replicated, frozen).
    gate_w: &'a [Vec<f32>],
    adam: AdamCfg,
    cons: MatConstraints,
    overlap: bool,
    /// Kernel tier every gate/expert kernel on this rank runs at
    /// (Reference is the bit-exact oracle, Fast the SIMD tier).
    kernel_mode: ComputeMode,
    /// Kernel worker threads for this rank's expert-key loops (1 =
    /// in-line on the rank thread).
    kthreads: usize,
    layers: Vec<RankLayerState>,
    comm: RankComm,
    /// `Some(epoch)` when the engine is metered: each rank builds a local
    /// [`StepMeter`] on the shared epoch so memory/load samples line up
    /// with the trace timeline.
    meter_epoch: Option<Instant>,
}

/// Global per-iteration stats, computed redundantly on rank 0 only,
/// aggregated over layers exactly like the sequential engine's.
struct GlobalStats {
    sparsity: f64,
    replicas: usize,
    remote_tokens: usize,
    straggler: f64,
}

/// What a rank thread hands back at span exit.
struct RankOut {
    layers: Vec<RankLayerState>,
    metrics: Metrics,
    /// Per-iteration partial loss (this rank's route groups, last layer).
    loss: Vec<f64>,
    /// Rank 0 only; empty elsewhere.
    global: Vec<GlobalStats>,
    /// This rank's telemetry timeline (None when tracing is off).
    tracer: Option<TraceRecorder>,
    /// This rank's memory/load samples (None when metering is off).
    meter: Option<StepMeter>,
    /// Communicator audit log (debug builds only; empty in release). Fed
    /// to the static schedule model's drift cross-check.
    audit: Vec<AuditEvent>,
    /// Realized load fractions `[iter][layer]` (rank 0 only; empty
    /// elsewhere). The drift cross-check replays plan building from them.
    realized: Vec<Vec<Vec<f64>>>,
}

/// Clone one rank's per-layer state slice out of the engine: its device's
/// chunk store, the Adam states of the experts it owns, and a replicated
/// predictor clone. Shared by the in-process span split and the
/// `hecate worker` process entry ([`worker`]), so both build ranks from
/// the identical deterministic recipe.
fn split_rank_state(engine: &FssdpEngine, r: usize) -> anyhow::Result<Vec<RankLayerState>> {
    let nd = engine.topo.num_devices();
    let mut out = Vec::with_capacity(engine.layers.len());
    for ls in &engine.layers {
        anyhow::ensure!(
            ls.params.devices.len() == nd,
            "engine memory does not match the topology"
        );
        let store = ls.params.devices[r].clone();
        let mut opt = BTreeMap::new();
        for (e, st) in &ls.opt {
            let owner = ls.shards.holders(*e).next().expect("every expert has an owner");
            if owner.0 == r {
                opt.insert(*e, st.clone());
            }
        }
        out.push(RankLayerState { store, opt, predictor: ls.predictor.clone() });
    }
    Ok(out)
}

/// Run `iters` iterations of the engine on one thread per rank and sync
/// the (bit-identical) state back into `engine`. Called through
/// [`FssdpEngine::run_span`] with `Executor::Spmd`.
pub fn run_span(
    engine: &mut FssdpEngine,
    start: u64,
    iters: usize,
    sources: usize,
    threads: usize,
    overlap: bool,
) -> anyhow::Result<Vec<EngineStats>> {
    let nd = engine.topo.num_devices();
    anyhow::ensure!(
        threads == nd,
        "SPMD executor runs one OS thread per rank: {threads} threads != {nd} devices"
    );
    let kernel_mode = engine.compute.mode().ok_or_else(|| {
        anyhow::anyhow!(
            "SPMD executor requires a hermetic compute backend \
             (PJRT client handles cannot be shared across rank threads)"
        )
    })?;
    let kthreads = engine.compute_threads.max(1);
    if iters == 0 {
        return Ok(Vec::new());
    }
    let nl = engine.layers.len();

    // Split the engine state per rank and per layer: each thread owns its
    // device's chunk stores and the Adam states of the experts it owns;
    // replicated state is cloned (gate weights are frozen, the predictors
    // evolve deterministically and identically on every rank).
    let topo = engine.topo.clone();
    let shards_v: Vec<Placement> = engine.layers.iter().map(|ls| ls.shards.clone()).collect();
    let gate_w_v: Vec<Vec<f32>> = engine.layers.iter().map(|ls| ls.gate_w.clone()).collect();
    let dims = engine.dims;
    let adam = engine.adam;
    let cons =
        MatConstraints { overlap_degree: engine.overlap_degree, mem_slots: engine.mem_slots };

    // Rank threads get *copies* of the device memories and optimizer
    // states, not the originals: if any rank fails, the engine keeps its
    // pre-span state intact (a span either commits whole or not at all).
    // One parameter-set copy per span is noise next to a span of steps.
    // Debug builds cross-check the span's actual traffic against the
    // static schedule model (`crate::analysis`); that replay needs the
    // predictor state as of span entry.
    let predictors_snapshot: Option<Vec<LoadPredictor>> = if cfg!(debug_assertions) {
        Some(engine.layers.iter().map(|ls| ls.predictor.clone()).collect())
    } else {
        None
    };

    let rank_layers: Vec<Vec<RankLayerState>> =
        (0..nd).map(|r| split_rank_state(engine, r)).collect::<anyhow::Result<_>>()?;
    let comms = match engine.transport {
        TransportKind::InProc => comm::fabric(nd, engine.pacing),
        // Real sockets between rank threads: a private UDS mesh. Pacing
        // models wire time and only applies to the in-proc backend (the
        // config layer rejects the combination); socket wall-clock is real.
        TransportKind::Socket => transport::socket::local_fabric(nd, engine.recv_timeout)?,
    };
    // Tracing on: give every rank endpoint a recorder sharing the engine
    // recorder's epoch, so all ranks' timestamps are directly comparable.
    if let Some(tr) = &engine.tracer {
        let epoch = tr.epoch();
        for (r, c) in comms.iter().enumerate() {
            c.set_tracer(TraceRecorder::with_epoch(epoch, r));
        }
    }

    let meter_epoch = engine.meter.as_ref().map(|m| m.epoch());

    let mut ctxs: Vec<RankCtx> = Vec::with_capacity(nd);
    for (me, (layers, comm)) in rank_layers.into_iter().zip(comms).enumerate() {
        ctxs.push(RankCtx {
            me,
            nd,
            sources,
            start,
            iters,
            dims,
            topo: &topo,
            shards: &shards_v,
            gate_w: &gate_w_v,
            adam,
            cons,
            overlap,
            kernel_mode,
            kthreads,
            layers,
            comm,
            meter_epoch,
        });
    }

    let results: Vec<std::thread::Result<anyhow::Result<RankOut>>> =
        std::thread::scope(|sc| {
            let mut handles = Vec::with_capacity(nd);
            for ctx in ctxs {
                handles.push(sc.spawn(move || rank_main(ctx)));
            }
            handles.into_iter().map(|h| h.join()).collect()
        });

    // Surface the most informative failure: a rank's own error beats the
    // secondary "link closed" errors its death caused on its peers.
    let mut outs: Vec<RankOut> = Vec::with_capacity(nd);
    let mut primary: Option<anyhow::Error> = None;
    let mut secondary: Option<anyhow::Error> = None;
    for (r, res) in results.into_iter().enumerate() {
        match res {
            Err(payload) => {
                if primary.is_none() {
                    let msg = payload
                        .downcast_ref::<String>()
                        .cloned()
                        .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                        .unwrap_or_else(|| "non-string panic payload".to_string());
                    primary = Some(anyhow::anyhow!("SPMD rank {r} panicked: {msg}"));
                }
            }
            Ok(Err(e)) => {
                // A closed link / receive timeout is the *symptom* of a
                // peer dying, not the cause — demote it behind whatever
                // error killed the peer.
                if CommError::is_peer_loss_msg(&e.to_string()) {
                    if secondary.is_none() {
                        secondary = Some(e);
                    }
                } else if primary.is_none() {
                    primary = Some(e);
                }
            }
            Ok(Ok(o)) => outs.push(o),
        }
    }
    if let Some(e) = primary.or(secondary) {
        return Err(e);
    }
    anyhow::ensure!(outs.len() == nd, "SPMD span lost rank outputs");

    // Merge per-rank state back into the engine, layer by layer.
    let mut stats = vec![EngineStats::default(); iters];
    let mut devices_by_layer: Vec<Vec<ChunkStore>> =
        (0..nl).map(|_| Vec::with_capacity(nd)).collect();
    let mut opt_by_layer: Vec<BTreeMap<usize, AdamState>> =
        (0..nl).map(|_| BTreeMap::new()).collect();
    let mut merged = Metrics::new();
    let mut audits: Vec<Vec<AuditEvent>> = Vec::with_capacity(nd);
    let mut realized0: Vec<Vec<Vec<f64>>> = Vec::new();
    for (r, out) in outs.into_iter().enumerate() {
        let RankOut { layers, metrics, loss, global, tracer, meter, audit, realized } = out;
        audits.push(audit);
        if r == 0 {
            realized0 = realized;
        }
        if let Some(rank_tl) = tracer {
            if let Some(main) = &mut engine.tracer {
                main.absorb(rank_tl);
            }
        }
        if let Some(rank_meter) = meter {
            if let Some(main) = &mut engine.meter {
                main.absorb(rank_meter);
            }
        }
        anyhow::ensure!(loss.len() == iters, "rank {r} returned {} loss entries", loss.len());
        for (i, l) in loss.iter().enumerate() {
            stats[i].loss += *l;
        }
        if r == 0 {
            for (i, g) in global.iter().enumerate() {
                stats[i].spag_sparsity = g.sparsity;
                stats[i].replicas = g.replicas;
                stats[i].remote_tokens = g.remote_tokens;
                stats[i].straggler = g.straggler;
            }
        }
        anyhow::ensure!(layers.len() == nl, "rank {r} returned {} layers", layers.len());
        for (l, rls) in layers.into_iter().enumerate() {
            let RankLayerState { store, opt, predictor } = rls;
            if r == 0 {
                engine.layers[l].predictor = predictor;
            }
            devices_by_layer[l].push(store);
            opt_by_layer[l].extend(opt);
        }
        merged.merge(&metrics);
    }
    merged.set("spmd.ranks", nd as f64);
    for (l, (devices, opt)) in devices_by_layer.into_iter().zip(opt_by_layer).enumerate() {
        engine.layers[l].params = ClusterMem { devices };
        engine.layers[l].opt = opt;
    }
    engine.spmd_metrics = Some(merged);

    // Drift guard (debug builds): the communicator audit logs must carry
    // exactly the multiset of tagged transfers the static schedule model
    // predicts from this span's inputs — if the executor and the analyzer
    // ever disagree, every debug-build SPMD test fails loudly here.
    if let Some(mut preds) = predictors_snapshot {
        let spec = crate::analysis::model::SpanSpec {
            topo: &topo,
            dims,
            shards: &shards_v,
            cons,
            sources,
            start,
            iters,
            overlap,
        };
        crate::analysis::model::verify_span_traffic(&spec, &mut preds, &realized0, &audits)?;
    }
    Ok(stats)
}

/// All-to-all row exchange at a layer boundary: every rank broadcasts its
/// computed rows (combine contributions `w·y` on forward, input cotangents
/// `gx` on backward) for its route keys, flattened in expert order; every
/// rank then assembles the full per-source buffers by scanning `routes` in
/// the sequential engine's `(device, expert)` order — so each f32 add
/// happens in the same order on every rank, bit-identical to the
/// sequential scatter.
#[allow(clippy::too_many_arguments)]
fn exchange_rows(
    comm: &mut RankComm,
    iter: u64,
    kind: MsgKind,
    layer: usize,
    routes: &Routes,
    mine: &BTreeMap<usize, Vec<f32>>,
    nd: usize,
    sources: usize,
    dims: &LayerDims,
) -> anyhow::Result<Vec<Vec<f32>>> {
    let mut payload: Vec<f32> = Vec::new();
    for rows in mine.values() {
        payload.extend_from_slice(rows);
    }
    let gathered = comm.allgather(iter, kind, layer, &payload)?;
    let mut out = zero_acts(sources, dims);
    for (dev, buf) in gathered.iter().enumerate() {
        if dev >= nd {
            break;
        }
        let mut off = 0;
        for (&(d, e), toks) in routes.iter() {
            if d != dev {
                continue;
            }
            let n = toks.len() * dims.d_model;
            anyhow::ensure!(
                off + n <= buf.len(),
                "row payload from rank {dev} truncated (layer {layer}, expert {e})"
            );
            scatter_rows(dims, toks, &buf[off..off + n], &mut out);
            off += n;
        }
        anyhow::ensure!(
            off == buf.len(),
            "row payload from rank {dev} misaligned (layer {layer}): {} trailing floats",
            buf.len() - off
        );
    }
    for buf in gathered {
        comm.recycle(buf);
    }
    Ok(out)
}

/// Finish one layer's spRS, apply Adam on owned experts, eagerly issue the
/// next iteration's spAG for each updated chunk, and release non-shard
/// replicas — the per-layer tail of the backward sweep, shared by the
/// pipelined and synchronous schedules.
#[allow(clippy::too_many_arguments)]
fn settle_layer(
    sprs: RankSprs<'_>,
    l: usize,
    me: usize,
    iter: u64,
    experts: usize,
    adam: &AdamCfg,
    owners: &Placement,
    grads: &mut ChunkStore,
    layer: &mut RankLayerState,
    ov: &mut Overlap,
    comm: &mut RankComm,
    pool: &mut BufferPool,
    metrics: &mut Metrics,
) -> anyhow::Result<()> {
    let t0 = Instant::now();
    sprs.finish(grads, comm)?;
    metrics.add_duration("spmd.sprs", t0.elapsed());
    comm.trace_span(TracePhase::SprsWait, iter, l, t0, 0);

    let t0 = Instant::now();
    debug_assert_eq!(owners.num_chunks(), experts);
    for e in owners.chunks_on_iter(DeviceId(me)) {
        let grad = grads.get(e).ok_or_else(|| {
            anyhow::anyhow!("owner {me} of expert {e} lost its gradient (layer {l})")
        })?;
        let p = layer.store.get_mut(e).expect("owner holds its shard");
        let st = layer.opt.get_mut(&e).expect("owner holds the optimizer state");
        st.update(adam, p, grad);
        let sent = ov.eager_issue(l, e, me, iter + 1, &layer.store, comm)?;
        metrics.add("spmd.eager_sends", sent as f64);
    }
    metrics.add_duration("spmd.adam", t0.elapsed());
    comm.trace_span(TracePhase::Adam, iter, l, t0, 0);

    // re-materialization: drop non-shard replicas (§4), recycling their
    // buffers through the rank's pool
    layer.store.retain_chunks(|c| owners.contains(c, DeviceId(me)), pool);
    Ok(())
}

/// Split one rank's route keys for a layer across scoped kernel worker
/// threads — the SPMD twin of [`crate::fssdp`]'s `expert_keys_threaded`,
/// working on the rank's own [`ChunkStore`] instead of the whole cluster
/// memory. Every chunk must already be resident (the caller pulls missing
/// replicas first). Each worker owns a stateless kernel set of the rank's
/// [`ComputeMode`] plus its own scratch, and accumulates each key's
/// gradient into a zeroed per-key buffer — the identical add sequence the
/// in-line loop performs into the zeroed gradient store. Outputs come back
/// in ascending-expert order and the caller merges them on the rank
/// thread, so Reference mode is bit-identical to the in-line loop at any
/// thread count and Fast mode is deterministic at any thread count.
#[allow(clippy::too_many_arguments)]
fn rank_keys_threaded(
    threads: usize,
    kernel_mode: ComputeMode,
    dims: &LayerDims,
    store: &ChunkStore,
    me: usize,
    routes: &Routes,
    keys: &[usize],
    acts: &[Vec<f32>],
    mode: KeyMode<'_>,
) -> anyhow::Result<Vec<(usize, KeyOut)>> {
    if keys.is_empty() {
        return Ok(Vec::new());
    }
    let nt = threads.min(keys.len()).max(1);
    let per = (keys.len() + nt - 1) / nt;
    let chunk_len = dims.chunk_len();
    let results: Vec<anyhow::Result<Vec<(usize, KeyOut)>>> = std::thread::scope(|sc| {
        let handles: Vec<_> = keys
            .chunks(per)
            .map(|slice| {
                sc.spawn(move || -> anyhow::Result<Vec<(usize, KeyOut)>> {
                    let mut compute = Compute::for_mode(kernel_mode);
                    let mut scr = KeyScratch::default();
                    let mut outs = Vec::with_capacity(slice.len());
                    for &e in slice {
                        let toks = routes.get(&(me, e)).expect("key from this map");
                        let chunk = store
                            .get(e)
                            .ok_or_else(|| anyhow::anyhow!("rank {me} lacks expert {e}"))?;
                        let mut rows = Vec::new();
                        let (loss, grad) = match mode {
                            KeyMode::FusedLast { inv_t, want_gx } => {
                                let mut acc = vec![0.0f32; chunk_len];
                                let lo = compute_expert_key(
                                    &mut compute,
                                    dims,
                                    chunk,
                                    toks,
                                    acts,
                                    inv_t,
                                    &mut acc,
                                    want_gx,
                                    &mut scr,
                                    &mut rows,
                                )?;
                                (lo, acc)
                            }
                            KeyMode::Forward => {
                                forward_expert_rows(
                                    &mut compute,
                                    dims,
                                    chunk,
                                    toks,
                                    acts,
                                    &mut scr,
                                    &mut rows,
                                )?;
                                (0.0, Vec::new())
                            }
                            KeyMode::Backward { g } => {
                                let mut acc = vec![0.0f32; chunk_len];
                                backward_expert_key(
                                    &mut compute,
                                    dims,
                                    chunk,
                                    toks,
                                    acts,
                                    g,
                                    &mut acc,
                                    &mut scr,
                                    &mut rows,
                                )?;
                                (0.0, acc)
                            }
                        };
                        outs.push((e, KeyOut { loss, grad, rows }));
                    }
                    Ok(outs)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("rank kernel worker panicked"))
            .collect()
    });
    let mut out = Vec::with_capacity(keys.len());
    for r in results {
        out.extend(r?);
    }
    Ok(out)
}

/// The rank program: the body of [`FssdpEngine::step`], restricted to one
/// rank's slice of the work, with communicator exchanges where the
/// sequential engine touches other devices' memory.
fn rank_main(ctx: RankCtx) -> anyhow::Result<RankOut> {
    let RankCtx {
        me,
        nd,
        sources,
        start,
        iters,
        dims,
        topo,
        shards,
        gate_w,
        adam,
        cons,
        overlap,
        kernel_mode,
        kthreads,
        mut layers,
        mut comm,
        meter_epoch,
    } = ctx;
    let nl = layers.len();
    let mut compute = Compute::for_mode(kernel_mode);
    let mut ov = Overlap::new(overlap);
    // Debug builds audit every transfer and (on rank 0) record the
    // realized loads, feeding the schedule model's drift cross-check.
    if cfg!(debug_assertions) {
        comm.enable_audit();
    }
    let mut realized_log: Vec<Vec<Vec<f64>>> = Vec::new();
    let mut metrics = Metrics::new();
    let mut meter = meter_epoch.map(|epoch| StepMeter::with_epoch(epoch, me as u32));
    let mut losses: Vec<f64> = Vec::with_capacity(iters);
    let mut global: Vec<GlobalStats> = Vec::new();
    // Per-rank workspace, reused across the span's iterations and layers:
    // kernel scratch for the gate/expert kernels and a buffer pool the
    // gradient stores and released replicas cycle through.
    let mut scr = KeyScratch::default();
    let mut pool = BufferPool::new();
    let mut gate_payload: Vec<f32> = Vec::new();

    for k in 0..iters {
        let iter = start + k as u64;
        let last_iter = k + 1 == iters;
        if me == 0 && cfg!(debug_assertions) {
            realized_log.push(Vec::with_capacity(nl));
        }

        // ---- plans (replicated): per layer, predict → Algorithm 1 ----
        let t0 = Instant::now();
        let plans: Vec<IterPlan> = match ov.next_plans.take() {
            Some(p) => p,
            None => {
                let mut v = Vec::with_capacity(nl);
                for (l, ls) in layers.iter().enumerate() {
                    v.push(build_iter_plan(topo, &shards[l], &ls.predictor.predict(), cons)?);
                }
                v
            }
        };
        metrics.add_duration("spmd.plan", t0.elapsed());
        comm.trace_span(TracePhase::Plan, iter, 0, t0, 0);

        let mut spags: Vec<Option<RankSpag>> = (0..nl).map(|_| None).collect();
        let mut acts: Vec<Vec<f32>> =
            (0..sources).map(|s| batch_for(&dims, iter, s)).collect();
        let mut acts_stack: Vec<Vec<Vec<f32>>> = Vec::with_capacity(nl.saturating_sub(1));
        let mut all_routes: Vec<Routes> = Vec::with_capacity(nl);
        let mut grads_stack: Vec<ChunkStore> = Vec::with_capacity(nl);
        let mut g: Vec<Vec<f32>> = Vec::new();
        let inv_t = 1.0f32 / (dims.tokens * sources) as f32;
        let mut loss = 0.0f64;
        let mut gs = GlobalStats { sparsity: 0.0, replicas: 0, remote_tokens: 0, straggler: 0.0 };

        // ---- forward sweep ----
        for l in 0..nl {
            let last_layer = l + 1 == nl;

            // spAG: the pipeline may have begun this layer already (one
            // layer ahead); otherwise issue our sends now
            if spags[l].is_none() {
                let pre = ov.take_pre_issued(l);
                spags[l] = Some(RankSpag::begin(
                    &plans[l].spag,
                    me,
                    iter,
                    l,
                    &layers[l].store,
                    &comm,
                    &pre,
                )?);
            }
            if !ov.enabled {
                // synchronous collectives: materialize before the gate
                let t0 = Instant::now();
                spags[l].as_mut().expect("begun above").finish(&mut layers[l].store, &mut comm)?;
                let d = t0.elapsed();
                metrics.add_duration("spmd.spag_wait", d);
                metrics.add_duration(&format!("spmd.spag_wait.l{l}"), d);
                comm.trace_span(TracePhase::SpagWait, iter, l, t0, 0);
            }

            // ---- gate our sources on this layer's input; exchange ----
            let t0 = Instant::now();
            let mut gate_idx: Vec<Vec<i32>> = vec![Vec::new(); sources];
            let mut gate_w_out: Vec<Vec<f32>> = vec![Vec::new(); sources];
            gate_payload.clear();
            for (s, x) in acts.iter().enumerate() {
                if s % nd != me {
                    continue;
                }
                let mut w = Vec::new();
                let mut idx = Vec::new();
                compute.gate_fwd_into(
                    x,
                    &gate_w[l],
                    dims.tokens,
                    dims.d_model,
                    dims.experts,
                    &mut scr.kernel,
                    &mut w,
                    &mut idx,
                )?;
                gate_payload.push(s as f32);
                gate_payload.extend_from_slice(&w);
                gate_payload.extend(idx.iter().map(|&v| v as f32));
                gate_w_out[s] = w;
                gate_idx[s] = idx;
            }
            let gathered = comm.allgather(iter, MsgKind::Gate, l, &gate_payload)?;
            let rec = 1 + 4 * dims.tokens; // source id + 2T weights + 2T indices
            for (r, buf) in gathered.iter().enumerate() {
                if r == me {
                    continue;
                }
                anyhow::ensure!(
                    buf.len() % rec == 0,
                    "gate payload misaligned from rank {r} (layer {l})"
                );
                for record in buf.chunks(rec) {
                    let s = record[0] as usize;
                    anyhow::ensure!(s < sources && s % nd == r, "bogus gate source {s}");
                    gate_w_out[s] = record[1..1 + 2 * dims.tokens].to_vec();
                    gate_idx[s] =
                        record[1 + 2 * dims.tokens..].iter().map(|&v| v as i32).collect();
                }
            }
            for buf in gathered {
                comm.recycle(buf);
            }
            metrics.add_duration("spmd.gate", t0.elapsed());
            comm.trace_span(TracePhase::Gate, iter, l, t0, 0);

            // predictor update (replicated, feeds next iteration's plan)
            let realized = realized_loads(dims.experts, &gate_idx);
            if me == 0 {
                if let Some(m) = meter.as_mut() {
                    // load observatory (control plane is replicated, so
                    // rank 0 records for everyone): `predict()` is pure
                    // and this layer's predictor has only observed through
                    // `iter - 1`, so this equals the plan-time prediction
                    // — including when the plan was pre-built by the
                    // overlap pipeline at the end of the previous iter
                    let pred = layers[l].predictor.predict();
                    m.sample_load(iter as usize, l, &pred, &realized);
                }
            }
            layers[l].predictor.observe(&realized);
            if me == 0 && cfg!(debug_assertions) {
                realized_log.last_mut().expect("one entry per iteration").push(realized);
            }

            // ---- §4.3 cross-layer pipeline: issue layer l+1's spAG
            //      sends now, so its materialization hides under this
            //      layer's expert compute ----
            if ov.enabled && !last_layer && spags[l + 1].is_none() {
                let pre = ov.take_pre_issued(l + 1);
                spags[l + 1] = Some(RankSpag::begin(
                    &plans[l + 1].spag,
                    me,
                    iter,
                    l + 1,
                    &layers[l + 1].store,
                    &comm,
                    &pre,
                )?);
            }

            // ---- routing (replicated) + rank-0 global stats ----
            let routes = routes_from_gates(
                topo,
                &plans[l].placement,
                nd,
                dims.experts,
                &gate_idx,
                &gate_w_out,
            );
            if me == 0 {
                let asg = assignment_matrix(nd, dims.experts, &gate_idx);
                let dplan = dispatch(topo, &plans[l].placement, &asg);
                let toks: Vec<f64> =
                    dplan.device_compute_tokens().iter().map(|&t| t as f64).collect();
                gs.sparsity += plans[l].spag.sparsity;
                gs.replicas += plans[l].placement.len() - shards[l].len();
                gs.remote_tokens += dplan.remote_tokens();
                gs.straggler += crate::util::stats::straggler_factor(&toks);
            }

            // ---- expert compute on our route keys, shards-resident
            //      first; replicas are pulled as compute reaches them ----
            let mut grads = ChunkStore::new();
            for e in plans[l].placement.chunks_on_iter(DeviceId(me)) {
                grads.insert(e, pool.take_zeroed(dims.chunk_len()));
            }
            let my_keys: Vec<usize> =
                routes.keys().filter(|(d, _)| *d == me).map(|(_, e)| *e).collect();
            let order = order_resident_first(&my_keys, &layers[l].store);
            let mut out_rows: BTreeMap<usize, Vec<f32>> = BTreeMap::new();
            // Per-key losses merge in ascending-expert order below, so the
            // rank's partial loss carries the same bits at every kernel
            // thread count.
            let mut key_loss: BTreeMap<usize, f64> = BTreeMap::new();
            if kthreads > 1 && my_keys.len() > 1 {
                // Threaded: pull every missing replica first (in the same
                // resident-first order, with the same spag accounting),
                // then fan the per-key compute across the rank's pool.
                for &e in &order {
                    if !layers[l].store.contains(e) {
                        let t0 = Instant::now();
                        spags[l]
                            .as_mut()
                            .expect("begun")
                            .ensure(&mut layers[l].store, &mut comm, e)?;
                        let d = t0.elapsed();
                        metrics.add_duration("spmd.spag_wait", d);
                        metrics.add_duration(&format!("spmd.spag_wait.l{l}"), d);
                        metrics.add("spmd.lazy_chunks", 1.0);
                        comm.trace_span(TracePhase::SpagWait, iter, l, t0, 1);
                    }
                }
                let t0 = Instant::now();
                let kmode = if last_layer {
                    KeyMode::FusedLast { inv_t, want_gx: nl > 1 }
                } else {
                    KeyMode::Forward
                };
                let outs = rank_keys_threaded(
                    kthreads,
                    kernel_mode,
                    &dims,
                    &layers[l].store,
                    me,
                    &routes,
                    &my_keys,
                    &acts,
                    kmode,
                )?;
                let mut rows_total = 0u64;
                for (e, out) in outs {
                    let toks = routes.get(&(me, e)).expect("key from this map");
                    rows_total += toks.len() as u64;
                    metrics.add("spmd.groups", toks.chunks(dims.cap).len() as f64);
                    if last_layer {
                        let acc = grads.get_mut(e).expect("grads cover the placement");
                        acc.copy_from_slice(&out.grad);
                        key_loss.insert(e, out.loss);
                        if nl > 1 {
                            out_rows.insert(e, out.rows);
                        }
                    } else {
                        out_rows.insert(e, out.rows);
                    }
                }
                let d = t0.elapsed();
                metrics.add_duration("spmd.compute", d);
                metrics.add_duration(&format!("spmd.compute.l{l}"), d);
                comm.trace_span(TracePhase::ExpertFwd, iter, l, t0, rows_total);
            } else {
                for e in order {
                    if !layers[l].store.contains(e) {
                        let t0 = Instant::now();
                        spags[l]
                            .as_mut()
                            .expect("begun")
                            .ensure(&mut layers[l].store, &mut comm, e)?;
                        let d = t0.elapsed();
                        metrics.add_duration("spmd.spag_wait", d);
                        metrics.add_duration(&format!("spmd.spag_wait.l{l}"), d);
                        metrics.add("spmd.lazy_chunks", 1.0);
                        comm.trace_span(TracePhase::SpagWait, iter, l, t0, 1);
                    }
                    let toks = routes.get(&(me, e)).expect("key from this map");
                    let chunk = layers[l].store.get(e).expect("ensured above");
                    let t0 = Instant::now();
                    if last_layer {
                        let acc = grads.get_mut(e).expect("grads cover the placement");
                        let mut gx = Vec::new();
                        let lo = compute_expert_key(
                            &mut compute,
                            &dims,
                            chunk,
                            toks,
                            &acts,
                            inv_t,
                            acc,
                            nl > 1,
                            &mut scr,
                            &mut gx,
                        )?;
                        key_loss.insert(e, lo);
                        if nl > 1 {
                            out_rows.insert(e, gx);
                        }
                    } else {
                        let mut rows = Vec::new();
                        forward_expert_rows(
                            &mut compute,
                            &dims,
                            chunk,
                            toks,
                            &acts,
                            &mut scr,
                            &mut rows,
                        )?;
                        out_rows.insert(e, rows);
                    }
                    let d = t0.elapsed();
                    metrics.add_duration("spmd.compute", d);
                    metrics.add_duration(&format!("spmd.compute.l{l}"), d);
                    metrics.add("spmd.groups", toks.chunks(dims.cap).len() as f64);
                    comm.trace_span(TracePhase::ExpertFwd, iter, l, t0, toks.len() as u64);
                }
            }
            for lo in key_loss.values() {
                loss += *lo;
            }

            // Remaining receives + fan-out duties before the next phase.
            let t0 = Instant::now();
            spags[l].as_mut().expect("begun").finish(&mut layers[l].store, &mut comm)?;
            let d = t0.elapsed();
            metrics.add_duration("spmd.spag_wait", d);
            metrics.add_duration(&format!("spmd.spag_wait.l{l}"), d);
            comm.trace_span(TracePhase::SpagWait, iter, l, t0, 0);
            if let Some(m) = meter.as_mut() {
                // memory ledger: the layer is fully materialized on this
                // rank — owned shards + replicas, the per-iteration peak
                m.sample_mem(
                    iter as usize,
                    l,
                    me,
                    layers[l].store.resident_len() as u64 * 4,
                    pool.idle_bytes(),
                    comm.payload_pool_bytes(),
                );
            }

            // ---- layer boundary: combine (fwd) / seed cotangent (bwd) ----
            if !last_layer {
                let t0 = Instant::now();
                let next = exchange_rows(
                    &mut comm,
                    iter,
                    MsgKind::Combine,
                    l,
                    &routes,
                    &out_rows,
                    nd,
                    sources,
                    &dims,
                )?;
                metrics.add_duration("spmd.combine", t0.elapsed());
                comm.trace_span(TracePhase::Combine, iter, l, t0, 0);
                acts_stack.push(std::mem::replace(&mut acts, next));
            } else if nl > 1 {
                let t0 = Instant::now();
                g = exchange_rows(
                    &mut comm,
                    iter,
                    MsgKind::GradX,
                    l,
                    &routes,
                    &out_rows,
                    nd,
                    sources,
                    &dims,
                )?;
                metrics.add_duration("spmd.combine", t0.elapsed());
                comm.trace_span(TracePhase::Combine, iter, l, t0, 0);
            }
            all_routes.push(routes);
            grads_stack.push(grads);
        }
        losses.push(loss);
        if me == 0 {
            gs.sparsity /= nl as f64;
            gs.straggler /= nl as f64;
            global.push(gs);
        }

        // ---- next iteration's plans are now knowable (all layers'
        //      predictors observed), which is what makes the eager
        //      re-materialization mechanisms sound ----
        if ov.enabled && !last_iter {
            let t0 = Instant::now();
            let mut nexts = Vec::with_capacity(nl);
            for (l, ls) in layers.iter().enumerate() {
                nexts.push(build_iter_plan(topo, &shards[l], &ls.predictor.predict(), cons)?);
            }
            ov.next_plans = Some(nexts);
            metrics.add_duration("spmd.plan", t0.elapsed());
            comm.trace_span(TracePhase::Plan, iter, 0, t0, 0);
        }

        // ---- backward sweep: bwd compute (inner layers) with the spRS
        //      of the layer above pipelined underneath (§4.3) ----
        let mut sprss: Vec<Option<RankSprs>> = (0..nl).map(|_| None).collect();
        for l in (0..nl).rev() {
            if l + 1 < nl {
                let routes = &all_routes[l];
                let my_keys: Vec<usize> =
                    routes.keys().filter(|(d, _)| *d == me).map(|(_, e)| *e).collect();
                let mut gx_rows: BTreeMap<usize, Vec<f32>> = BTreeMap::new();
                if kthreads > 1 && my_keys.len() > 1 {
                    // replicas live until their bwd, so every chunk is
                    // already resident: fan out immediately
                    let t0 = Instant::now();
                    let outs = rank_keys_threaded(
                        kthreads,
                        kernel_mode,
                        &dims,
                        &layers[l].store,
                        me,
                        routes,
                        &my_keys,
                        &acts_stack[l],
                        KeyMode::Backward { g: &g },
                    )?;
                    let mut rows_total = 0u64;
                    for (e, out) in outs {
                        let toks = routes.get(&(me, e)).expect("key from this map");
                        rows_total += toks.len() as u64;
                        let acc =
                            grads_stack[l].get_mut(e).expect("grads cover the placement");
                        acc.copy_from_slice(&out.grad);
                        if l > 0 {
                            gx_rows.insert(e, out.rows);
                        }
                    }
                    let d = t0.elapsed();
                    metrics.add_duration("spmd.compute", d);
                    metrics.add_duration(&format!("spmd.compute.l{l}"), d);
                    comm.trace_span(TracePhase::ExpertBwd, iter, l, t0, rows_total);
                } else {
                    for e in my_keys {
                        let toks = routes.get(&(me, e)).expect("key from this map");
                        let chunk =
                            layers[l].store.get(e).expect("replicas live until their bwd");
                        let acc =
                            grads_stack[l].get_mut(e).expect("grads cover the placement");
                        let t0 = Instant::now();
                        let mut gx = Vec::new();
                        backward_expert_key(
                            &mut compute,
                            &dims,
                            chunk,
                            toks,
                            &acts_stack[l],
                            &g,
                            acc,
                            &mut scr,
                            &mut gx,
                        )?;
                        let d = t0.elapsed();
                        metrics.add_duration("spmd.compute", d);
                        metrics.add_duration(&format!("spmd.compute.l{l}"), d);
                        comm.trace_span(TracePhase::ExpertBwd, iter, l, t0, toks.len() as u64);
                        if l > 0 {
                            gx_rows.insert(e, gx);
                        }
                    }
                }
                if l > 0 {
                    let t0 = Instant::now();
                    g = exchange_rows(
                        &mut comm,
                        iter,
                        MsgKind::GradX,
                        l,
                        routes,
                        &gx_rows,
                        nd,
                        sources,
                        &dims,
                    )?;
                    metrics.add_duration("spmd.combine", t0.elapsed());
                    comm.trace_span(TracePhase::Combine, iter, l, t0, 0);
                }
            }
            // this layer's grads are final: issue its spRS stage-0 sends
            let t0 = Instant::now();
            sprss[l] = Some(RankSprs::begin(
                &plans[l].sprs,
                &shards[l],
                me,
                iter,
                l,
                &grads_stack[l],
                &comm,
            )?);
            metrics.add_duration("spmd.sprs", t0.elapsed());

            if ov.enabled {
                // pipelined: the layer ABOVE settles now — its spRS flew
                // while this layer's backward computed
                if l + 1 < nl {
                    let sp = sprss[l + 1].take().expect("begun one step earlier");
                    settle_layer(
                        sp,
                        l + 1,
                        me,
                        iter,
                        dims.experts,
                        &adam,
                        &shards[l + 1],
                        &mut grads_stack[l + 1],
                        &mut layers[l + 1],
                        &mut ov,
                        &mut comm,
                        &mut pool,
                        &mut metrics,
                    )?;
                }
            } else {
                // synchronous: settle this layer immediately
                let sp = sprss[l].take().expect("just begun");
                settle_layer(
                    sp,
                    l,
                    me,
                    iter,
                    dims.experts,
                    &adam,
                    &shards[l],
                    &mut grads_stack[l],
                    &mut layers[l],
                    &mut ov,
                    &mut comm,
                    &mut pool,
                    &mut metrics,
                )?;
            }
        }
        if ov.enabled {
            let sp = sprss[0].take().expect("begun in the loop");
            settle_layer(
                sp,
                0,
                me,
                iter,
                dims.experts,
                &adam,
                &shards[0],
                &mut grads_stack[0],
                &mut layers[0],
                &mut ov,
                &mut comm,
                &mut pool,
                &mut metrics,
            )?;
        }
        // iteration teardown: this iteration's gradient buffers go back to
        // the rank's pool for the next iteration's stores
        for grads in grads_stack.iter_mut() {
            grads.retain_chunks(|_| false, &mut pool);
        }
    }

    // workspace counters: fresh pool allocations and payload recycling of
    // this rank's span. These are per-rank levels, written as gauges so
    // the cross-rank merge reports the worst rank instead of summing an
    // N×-inflated total.
    metrics.set("spmd.ws_allocs", pool.allocated as f64);
    metrics.set("spmd.ws_reused", pool.reused as f64);
    let (hits, misses) = comm.payload_pool_stats();
    metrics.set("spmd.payload_reused", hits as f64);
    metrics.set("spmd.payload_alloc", misses as f64);

    Ok(RankOut {
        layers,
        metrics,
        loss: losses,
        global,
        tracer: comm.take_tracer(),
        meter,
        audit: comm.take_audit(),
        realized: realized_log,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fssdp::{reference_dims, Executor};
    use crate::testing::all_chunks as final_chunks;

    #[test]
    fn spmd_span_matches_sequential_bitwise() {
        let dims = reference_dims();
        let sources = 4;
        let mut seq = FssdpEngine::new_reference_layers(dims, 1, Topology::cluster_a(2, 2), 21);
        let seq_stats = seq.run_span(0, 3, sources).unwrap();

        let mut par = FssdpEngine::new_reference_layers(dims, 1, Topology::cluster_a(2, 2), 21);
        par.executor = Executor::Spmd { threads: 4, overlap: true };
        let par_stats = par.run_span(0, 3, sources).unwrap();

        assert_eq!(final_chunks(&seq), final_chunks(&par), "parameters must be bit-identical");
        for (s, p) in seq_stats.iter().zip(par_stats.iter()) {
            assert!((s.loss - p.loss).abs() <= 1e-9 * s.loss.abs().max(1.0));
            assert_eq!(s.replicas, p.replicas);
            assert_eq!(s.remote_tokens, p.remote_tokens);
        }
        assert!(par.spmd_metrics().is_some());
    }

    #[test]
    fn multilayer_spmd_span_matches_sequential_bitwise() {
        let dims = reference_dims();
        let sources = 4;
        let mut seq = FssdpEngine::new_reference_layers(dims, 2, Topology::cluster_a(2, 2), 23);
        let seq_stats = seq.run_span(0, 2, sources).unwrap();

        let mut par = FssdpEngine::new_reference_layers(dims, 2, Topology::cluster_a(2, 2), 23);
        par.executor = Executor::Spmd { threads: 4, overlap: true };
        let par_stats = par.run_span(0, 2, sources).unwrap();

        assert_eq!(final_chunks(&seq), final_chunks(&par), "2-layer SPMD must be bit-identical");
        for (s, p) in seq_stats.iter().zip(par_stats.iter()) {
            assert!((s.loss - p.loss).abs() <= 1e-9 * s.loss.abs().max(1.0));
            assert_eq!(s.replicas, p.replicas);
        }
    }

    #[test]
    fn overlap_off_is_also_bitwise_identical() {
        let dims = reference_dims();
        let mut a = FssdpEngine::new_reference_layers(dims, 2, Topology::cluster_a(2, 2), 5);
        a.executor = Executor::Spmd { threads: 4, overlap: false };
        a.run_span(0, 3, 4).unwrap();
        let mut b = FssdpEngine::new_reference_layers(dims, 2, Topology::cluster_a(2, 2), 5);
        b.executor = Executor::Spmd { threads: 4, overlap: true };
        b.run_span(0, 3, 4).unwrap();
        assert_eq!(final_chunks(&a), final_chunks(&b));
    }

    #[test]
    fn traced_spmd_span_is_bitwise_identical_and_covers_every_rank() {
        let dims = reference_dims();
        let mut plain = FssdpEngine::new_reference_layers(dims, 2, Topology::cluster_a(2, 2), 9);
        plain.executor = Executor::Spmd { threads: 4, overlap: true };
        plain.run_span(0, 3, 4).unwrap();

        let mut traced = FssdpEngine::new_reference_layers(dims, 2, Topology::cluster_a(2, 2), 9);
        traced.executor = Executor::Spmd { threads: 4, overlap: true };
        traced.tracer = Some(TraceRecorder::new(0));
        traced.run_span(0, 3, 4).unwrap();

        assert_eq!(
            final_chunks(&plain),
            final_chunks(&traced),
            "tracing is observational: traced run must stay bit-identical"
        );
        let events = traced.trace_events().expect("recorder installed");
        for r in 0..4u32 {
            assert!(events.iter().any(|e| e.rank == r), "no events from rank {r}");
        }
        for want in [
            TracePhase::Gate,
            TracePhase::ExpertFwd,
            TracePhase::ExpertBwd,
            TracePhase::SpagIssue,
            TracePhase::SprsIssue,
            TracePhase::SendChunk,
            TracePhase::Adam,
        ] {
            assert!(events.iter().any(|e| e.phase == want), "missing phase {want:?}");
        }
        // per-rank timelines are pushed in span-end order
        for r in 0..4u32 {
            let mut last = f64::NEG_INFINITY;
            for e in events.iter().filter(|e| e.rank == r) {
                let end = e.ts_us + e.dur_us;
                assert!(end >= last, "rank {r} end times must be non-decreasing");
                last = end;
            }
        }
    }

    #[test]
    fn metered_spmd_span_is_bitwise_identical_and_samples_every_rank() {
        let dims = reference_dims();
        let mut plain = FssdpEngine::new_reference_layers(dims, 2, Topology::cluster_a(2, 2), 9);
        plain.executor = Executor::Spmd { threads: 4, overlap: true };
        plain.run_span(0, 3, 4).unwrap();

        let mut metered =
            FssdpEngine::new_reference_layers(dims, 2, Topology::cluster_a(2, 2), 9);
        metered.executor = Executor::Spmd { threads: 4, overlap: true };
        metered.meter = Some(StepMeter::new(0));
        metered.run_span(0, 3, 4).unwrap();

        assert_eq!(
            final_chunks(&plain),
            final_chunks(&metered),
            "metering is observational: metered run must stay bit-identical"
        );
        let m = metered.meter_samples().expect("meter installed");
        // one mem sample per (iter, layer, rank)
        assert_eq!(m.mem_samples().len(), 3 * 2 * 4);
        for r in 0..4u32 {
            assert!(m.mem_samples().iter().any(|s| s.rank == r), "no samples from rank {r}");
        }
        // load samples come from rank 0 only (replicated control plane)
        assert_eq!(m.load_samples().len(), 3 * 2);
        // every rank materializes at least its own shards each iteration
        assert!(m.mem_samples().iter().all(|s| s.resident_bytes > 0));
        // high-water dominates every sample
        let hw = m.high_water();
        for s in m.mem_samples() {
            assert!(hw[&(s.rank, s.layer)] >= s.resident_bytes);
        }
    }

    #[test]
    fn rank_kernel_pool_is_bitwise_invariant_across_thread_counts() {
        let dims = reference_dims();
        let mut base = FssdpEngine::new_reference_layers(dims, 2, Topology::cluster_a(2, 2), 31);
        base.executor = Executor::Spmd { threads: 4, overlap: true };
        let base_stats = base.run_span(0, 3, 4).unwrap();
        for kthreads in [2usize, 4] {
            let mut e =
                FssdpEngine::new_reference_layers(dims, 2, Topology::cluster_a(2, 2), 31);
            e.executor = Executor::Spmd { threads: 4, overlap: true };
            e.compute_threads = kthreads;
            let stats = e.run_span(0, 3, 4).unwrap();
            assert_eq!(
                final_chunks(&base),
                final_chunks(&e),
                "params must be bit-identical at {kthreads} kernel threads"
            );
            for (a, b) in base_stats.iter().zip(stats.iter()) {
                assert_eq!(
                    a.loss.to_bits(),
                    b.loss.to_bits(),
                    "loss must carry the same bits at {kthreads} kernel threads"
                );
            }
        }
    }

    #[test]
    fn fast_mode_spmd_is_deterministic_and_thread_count_invariant() {
        let dims = reference_dims();
        let run = |kthreads: usize| {
            let mut e =
                FssdpEngine::new_reference_layers(dims, 2, Topology::cluster_a(2, 2), 33);
            e.set_compute_mode(ComputeMode::Fast);
            e.executor = Executor::Spmd { threads: 4, overlap: true };
            e.compute_threads = kthreads;
            e.run_span(0, 3, 4).unwrap();
            final_chunks(&e)
        };
        let a = run(2);
        assert_eq!(a, run(2), "Fast-mode SPMD must be deterministic run-to-run");
        // per-key buffers + ascending-expert merge make even the Fast tier
        // invariant to the kernel thread count
        assert_eq!(a, run(1));
        assert_eq!(a, run(4));
    }

    #[test]
    fn thread_count_must_match_devices() {
        let mut e =
            FssdpEngine::new_reference_layers(reference_dims(), 1, Topology::cluster_a(2, 2), 1);
        e.executor = Executor::Spmd { threads: 3, overlap: true };
        let err = e.run_span(0, 1, 4).unwrap_err().to_string();
        assert!(err.contains("one OS thread per rank"), "{err}");
    }

    #[test]
    fn empty_span_is_a_noop() {
        let mut e =
            FssdpEngine::new_reference_layers(reference_dims(), 1, Topology::cluster_a(2, 2), 1);
        e.executor = Executor::spmd_for(&e.topo);
        assert!(e.run_span(0, 0, 4).unwrap().is_empty());
    }
}
