//! The SPMD communicator: MPI-style tag matching over a pluggable
//! [`Transport`].
//!
//! Every pair of ranks is connected by a dedicated FIFO link; which kind
//! of link is the backend's business ([`super::transport`]): the
//! [`inproc`](super::transport::inproc) backend uses unbounded
//! `std::sync::mpsc` channels between rank threads, the
//! [`socket`](super::transport::socket) backend TCP/UDS streams between
//! processes. On top of the raw link the communicator provides MPI-style
//! **tag matching**: a receive names `(source, Tag)` and consumes the
//! first message on that link carrying the tag, stashing earlier arrivals
//! with other tags for their own receives. Tags carry the iteration
//! number, so ranks may run ahead (the overlap scheduler issues
//! next-iteration spAG traffic while peers still compute) without any
//! global barrier — on either backend, since the tag travels in the wire
//! frame.
//!
//! Primitives:
//! * [`RankComm::isend`] — nonblocking tagged send (never blocks; links
//!   are unbounded / stream-buffered).
//! * [`RankComm::irecv`] / [`RankComm::wait`] / [`RankComm::try_wait`] —
//!   nonblocking receive with a completion handle, blocking completion,
//!   and polling completion.
//! * [`RankComm::barrier`] — full-communicator barrier: the backend's
//!   native barrier when it has one (in-proc), otherwise an all-to-all
//!   exchange of empty [`MsgKind::Barrier`] messages.
//! * [`RankComm::allgather`] — each rank contributes one buffer, all
//!   ranks receive all buffers (used for the gate-decision exchange).
//!
//! Failures are typed [`CommError`]s: a dropped peer surfaces as a
//! closed-link error (never a hang — the socket backend additionally
//! arms a receive timeout), carrying the rank/peer/tag context.
//!
//! **Link pacing** (optional, in-proc only): with a [`Pacing`] config,
//! each message is assigned a delivery instant from the α–β model of the
//! topology tier it crosses, so bottleneck-link contention (Eq. 1) is
//! physically reproduced in wall-clock time rather than only predicted.

use std::cell::RefCell;
use std::collections::VecDeque;
use std::time::{Duration, Instant};

use super::transport::{CommError, Envelope, Transport, TransportKind};
use crate::telemetry::{Phase as TracePhase, TraceRecorder};

pub use super::transport::Pacing;

/// Message classes multiplexed over one link.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum MsgKind {
    /// spAG parameter-chunk transfer (`a` = chunk, `b` = stage).
    SpagChunk,
    /// spRS gradient-chunk transfer (`a` = chunk, `b` = stage).
    SprsChunk,
    /// Gate-decision exchange (`a` = sending rank, `b` = 0).
    Gate,
    /// Inter-layer combine exchange: each rank broadcasts its routed
    /// tokens' weighted expert outputs (`a` = sending rank, `b` = 0).
    Combine,
    /// Inter-layer input-cotangent exchange during backward
    /// (`a` = sending rank, `b` = 0).
    GradX,
    /// Free-form control/test traffic.
    Ctrl,
    /// Empty-payload barrier round (`iter` = barrier sequence number,
    /// `a` = sending rank) — the fallback for backends without a native
    /// barrier.
    Barrier,
}

/// Matching key of a message. Two messages on one link never share a tag
/// within an iteration (the sparse plans contain at most one transfer per
/// `(layer, chunk, src, dst, stage)`), so matching is unambiguous.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct Tag {
    pub iter: u64,
    pub kind: MsgKind,
    /// MoE layer the message belongs to (chunk ids repeat across layers,
    /// so layer is part of the matching key).
    pub layer: usize,
    /// Chunk id for collectives, sending rank for gate/combine exchanges.
    pub a: usize,
    /// Stage for collectives, 0 otherwise.
    pub b: usize,
}

/// Completion handle of a posted receive.
#[derive(Debug, Clone, Copy)]
pub struct Recv {
    pub src: usize,
    pub tag: Tag,
}

/// One audited transfer through the endpoint, recorded when auditing is
/// enabled ([`RankComm::enable_audit`]). Debug builds compare the audit
/// log of every SPMD span against the static schedule model
/// ([`crate::analysis`]) so the analyzer cannot drift from the executor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AuditEvent {
    /// `true` for a send, `false` for a completed receive.
    pub send: bool,
    /// Destination rank of a send, source rank of a receive.
    pub peer: usize,
    /// Matching tag on the wire.
    pub tag: Tag,
    /// Payload length in `f32` elements.
    pub floats: usize,
}

/// Free-list of message payload buffers, per rank endpoint. Senders draw
/// staging copies from it ([`RankComm::isend_slice`]) and receivers return
/// consumed payloads ([`RankComm::recycle`]); since every rank both sends
/// and receives each iteration, the lists reach a steady state and message
/// traffic stops allocating. Interior mutability (`RefCell`) because sends
/// happen under shared borrows of the endpoint; a `RankComm` is owned by
/// exactly one rank thread, so there is no contention.
#[derive(Debug, Default)]
struct PayloadPool {
    free: Vec<Vec<f32>>,
    hits: u64,
    misses: u64,
}

/// One rank's endpoint of the communicator: tag matching, payload
/// recycling, and telemetry over a boxed [`Transport`].
pub struct RankComm {
    pub me: usize,
    n: usize,
    transport: Box<dyn Transport>,
    /// Arrived-but-unmatched messages, per source link.
    stash: Vec<VecDeque<Envelope>>,
    /// Sequence number of the next fallback barrier round.
    barrier_seq: u64,
    pool: RefCell<PayloadPool>,
    /// Per-rank telemetry recorder (None when tracing is off). `RefCell`
    /// because sends happen under shared borrows; the endpoint is owned by
    /// one rank thread, so there is no contention.
    tracer: RefCell<Option<TraceRecorder>>,
    /// Traffic audit log (None when auditing is off). Same `RefCell`
    /// rationale as the tracer.
    audit: RefCell<Option<Vec<AuditEvent>>>,
}

/// Build the full n×n in-process mailbox fabric; element `r` is rank
/// `r`'s endpoint. (The socket analog is
/// [`local_fabric`](super::transport::socket::local_fabric); separate
/// worker processes build endpoints via
/// [`mesh_connect`](super::transport::socket::mesh_connect).)
pub fn fabric(n: usize, pacing: Option<Pacing>) -> Vec<RankComm> {
    super::transport::inproc::fabric(n, pacing)
        .into_iter()
        .map(|t| RankComm::endpoint(Box::new(t)))
        .collect()
}

impl RankComm {
    /// Wrap a connected transport endpoint into a communicator endpoint.
    pub fn endpoint(transport: Box<dyn Transport>) -> RankComm {
        let (me, n) = (transport.me(), transport.num_ranks());
        RankComm {
            me,
            n,
            transport,
            stash: (0..n).map(|_| VecDeque::new()).collect(),
            barrier_seq: 0,
            pool: RefCell::new(PayloadPool::default()),
            tracer: RefCell::new(None),
            audit: RefCell::new(None),
        }
    }

    /// Number of ranks in the communicator.
    pub fn num_ranks(&self) -> usize {
        self.n
    }

    /// Which backend carries this endpoint's traffic.
    pub fn transport_kind(&self) -> TransportKind {
        self.transport.kind()
    }

    /// Backend + addressing description (the socket backend reports its
    /// listen path), for logs and trace metadata.
    pub fn endpoint_desc(&self) -> String {
        self.transport.describe()
    }

    /// Install this rank's telemetry recorder (the SPMD runtime does this
    /// at span entry when tracing is on).
    pub fn set_tracer(&self, tr: TraceRecorder) {
        *self.tracer.borrow_mut() = Some(tr);
    }

    /// Remove and return the recorder (span exit; events are merged into
    /// the engine's timeline).
    pub fn take_tracer(&self) -> Option<TraceRecorder> {
        self.tracer.borrow_mut().take()
    }

    /// Start recording every send and completed receive into an audit log
    /// (the debug-build schedule cross-check turns this on at span entry).
    pub fn enable_audit(&self) {
        *self.audit.borrow_mut() = Some(Vec::new());
    }

    /// Remove and return the audit log (empty when auditing was off).
    pub fn take_audit(&self) -> Vec<AuditEvent> {
        self.audit.borrow_mut().take().unwrap_or_default()
    }

    fn audit_event(&self, send: bool, peer: usize, tag: Tag, floats: usize) {
        if let Some(log) = self.audit.borrow_mut().as_mut() {
            log.push(AuditEvent { send, peer, tag, floats });
        }
    }

    /// Record a rank-level span through the endpoint's recorder — the one
    /// telemetry seam for the rank loop, the overlapped-collective drivers,
    /// and the scheduler (all of which already hold `&RankComm`). One
    /// branch when tracing is off.
    pub fn trace_span(
        &self,
        phase: TracePhase,
        iter: u64,
        layer: usize,
        start: Instant,
        detail: u64,
    ) {
        if let Some(tr) = self.tracer.borrow_mut().as_mut() {
            tr.span_from(phase, iter as usize, layer, start, detail);
        }
    }

    /// Record a send on the comm row (`dur` 0: sends are nonblocking).
    fn trace_send(&self, tag: Tag, bytes: u64) {
        if let Some(tr) = self.tracer.borrow_mut().as_mut() {
            let phase = match tag.kind {
                MsgKind::SpagChunk | MsgKind::SprsChunk => TracePhase::SendChunk,
                _ => TracePhase::SendRow,
            };
            tr.event_at(phase, tag.iter as usize, tag.layer, Instant::now(), Duration::ZERO, bytes);
        }
    }

    /// Record a completed delivery: the span covers the message's modeled
    /// in-flight window (ending now), so the comm row shows wire occupancy.
    fn trace_delivery(&self, tag: Tag, bytes: u64, wire_us: u64) {
        if let Some(tr) = self.tracer.borrow_mut().as_mut() {
            let phase = match tag.kind {
                MsgKind::SpagChunk | MsgKind::SprsChunk => TracePhase::RecvChunk,
                _ => TracePhase::RecvRow,
            };
            let dur = Duration::from_micros(wire_us);
            let now = Instant::now();
            let start = now.checked_sub(dur).unwrap_or(now);
            tr.event_at(phase, tag.iter as usize, tag.layer, start, dur, bytes);
        }
    }

    /// Complete a matched envelope: under pacing, physically sleep until
    /// the modeled delivery instant (recorded as `pacing_wait`).
    fn deliver(&self, env: Envelope) -> Vec<f32> {
        if let Some(t) = env.ready_at {
            let now = Instant::now();
            if t > now {
                let pause = t - now;
                std::thread::sleep(pause);
                if let Some(tr) = self.tracer.borrow_mut().as_mut() {
                    tr.event_at(
                        TracePhase::PacingWait,
                        env.tag.iter as usize,
                        env.tag.layer,
                        now,
                        pause,
                        0,
                    );
                }
            }
        }
        self.trace_delivery(env.tag, env.data.len() as u64 * 4, env.wire_us);
        env.data
    }

    /// Nonblocking tagged send. Never blocks (unbounded link); errors only
    /// if the destination rank has died. If the transport is done with the
    /// buffer at return time (socket: it serialized a wire copy), the
    /// buffer recycles into the payload free list.
    pub fn isend(&self, dst: usize, tag: Tag, data: Vec<f32>) -> Result<(), CommError> {
        self.trace_send(tag, data.len() as u64 * 4);
        self.audit_event(true, dst, tag, data.len());
        if let Some(buf) = self.transport.send(dst, tag, data)? {
            self.recycle(buf);
        }
        Ok(())
    }

    /// [`RankComm::isend`] from a borrowed slice: the wire copy is staged
    /// in a recycled payload buffer instead of a fresh allocation.
    pub fn isend_slice(&self, dst: usize, tag: Tag, data: &[f32]) -> Result<(), CommError> {
        self.isend(dst, tag, self.payload_from(data))
    }

    /// Copy `data` into a buffer from the free list (fresh allocation only
    /// when the list is empty).
    fn payload_from(&self, data: &[f32]) -> Vec<f32> {
        let mut p = self.pool.borrow_mut();
        match p.free.pop() {
            Some(mut b) => {
                p.hits += 1;
                b.clear();
                b.extend_from_slice(data);
                b
            }
            None => {
                p.misses += 1;
                data.to_vec()
            }
        }
    }

    /// Return a consumed message payload to the free list. Buffers that
    /// crossed threads recycle into the *receiver's* list — fine, since
    /// every rank both sends and receives, the lists self-balance.
    pub fn recycle(&self, mut buf: Vec<f32>) {
        buf.clear();
        self.pool.borrow_mut().free.push(buf);
    }

    /// `(recycled, fresh)` payload-buffer counts of this endpoint.
    pub fn payload_pool_stats(&self) -> (u64, u64) {
        let p = self.pool.borrow();
        (p.hits, p.misses)
    }

    /// Bytes of capacity held idle on the payload free list (the memory
    /// ledger's wire-buffer column; [`RankComm::recycle`] clears returned
    /// buffers, so the held memory is the capacity).
    pub fn payload_pool_bytes(&self) -> u64 {
        self.pool.borrow().free.iter().map(|b| b.capacity() as u64 * 4).sum()
    }

    /// Post a receive; complete it with [`RankComm::wait`] or
    /// [`RankComm::try_wait`].
    pub fn irecv(&self, src: usize, tag: Tag) -> Recv {
        Recv { src, tag }
    }

    /// Blocking completion of a posted receive.
    pub fn wait(&mut self, r: Recv) -> Result<Vec<f32>, CommError> {
        if let Some(i) = self.stash[r.src].iter().position(|e| e.tag == r.tag) {
            let env = self.stash[r.src].remove(i).expect("index valid");
            self.audit_event(false, r.src, r.tag, env.data.len());
            return Ok(self.deliver(env));
        }
        loop {
            let env = self.transport.recv_next(r.src).map_err(|e| e.with_tag(r.tag))?;
            if env.tag == r.tag {
                self.audit_event(false, r.src, r.tag, env.data.len());
                return Ok(self.deliver(env));
            }
            self.stash[r.src].push_back(env);
        }
    }

    /// Polling completion: `Ok(None)` if the message has not arrived (or,
    /// under pacing, is still on the wire). Errors if the link is closed
    /// (or broken) and the message can no longer arrive — arrivals already
    /// stashed before the failure still complete first.
    pub fn try_wait(&mut self, r: Recv) -> Result<Option<Vec<f32>>, CommError> {
        let mut link_err: Option<CommError> = None;
        loop {
            match self.transport.try_recv_next(r.src) {
                Ok(Some(env)) => self.stash[r.src].push_back(env),
                Ok(None) => break,
                Err(e) => {
                    link_err = Some(e);
                    break;
                }
            }
        }
        if let Some(i) = self.stash[r.src].iter().position(|e| e.tag == r.tag) {
            if let Some(t) = self.stash[r.src][i].ready_at {
                if t > Instant::now() {
                    return Ok(None); // still on the wire
                }
            }
            let env = self.stash[r.src].remove(i).expect("index valid");
            self.audit_event(false, r.src, r.tag, env.data.len());
            self.trace_delivery(env.tag, env.data.len() as u64 * 4, env.wire_us);
            return Ok(Some(env.data));
        }
        match link_err {
            Some(e) => Err(e.with_tag(r.tag)),
            None => Ok(None),
        }
    }

    /// Blocking tagged receive (`irecv` + `wait`).
    pub fn recv(&mut self, src: usize, tag: Tag) -> Result<Vec<f32>, CommError> {
        let r = self.irecv(src, tag);
        self.wait(r)
    }

    /// Full-communicator barrier: the backend's native barrier when it
    /// has one, otherwise an all-to-all round of empty
    /// [`MsgKind::Barrier`] messages under a fresh sequence number (no
    /// rank leaves before every rank has entered).
    pub fn barrier(&mut self) -> Result<(), CommError> {
        if self.transport.barrier_wait() {
            return Ok(());
        }
        let seq = self.barrier_seq;
        self.barrier_seq += 1;
        for dst in 0..self.n {
            if dst != self.me {
                let t = Tag { iter: seq, kind: MsgKind::Barrier, layer: 0, a: self.me, b: 0 };
                self.isend_slice(dst, t, &[])?;
            }
        }
        for src in 0..self.n {
            if src != self.me {
                let t = Tag { iter: seq, kind: MsgKind::Barrier, layer: 0, a: src, b: 0 };
                let buf = self.recv(src, t)?;
                self.recycle(buf);
            }
        }
        Ok(())
    }

    /// Each rank contributes one buffer; returns all ranks' buffers
    /// indexed by rank. Tag disambiguation: `(iter, kind, layer, sender, 0)`.
    /// Wire copies stage through the payload free list; callers should
    /// [`RankComm::recycle`] the returned buffers once consumed.
    pub fn allgather(
        &mut self,
        iter: u64,
        kind: MsgKind,
        layer: usize,
        mine: &[f32],
    ) -> Result<Vec<Vec<f32>>, CommError> {
        for dst in 0..self.n {
            if dst != self.me {
                self.isend_slice(dst, Tag { iter, kind, layer, a: self.me, b: 0 }, mine)?;
            }
        }
        let mut out: Vec<Vec<f32>> = Vec::with_capacity(self.n);
        for src in 0..self.n {
            if src == self.me {
                out.push(self.payload_from(mine));
            } else {
                out.push(self.recv(src, Tag { iter, kind, layer, a: src, b: 0 })?);
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    fn tag(iter: u64, a: usize) -> Tag {
        Tag { iter, kind: MsgKind::Ctrl, layer: 0, a, b: 0 }
    }

    #[test]
    fn out_of_order_tag_matching() {
        let mut comms = fabric(2, None);
        let mut c1 = comms.remove(1);
        let c0 = comms.remove(0);
        let sender = thread::spawn(move || {
            // sent B-first, received A-first
            c0.isend(1, tag(0, 7), vec![7.0]).unwrap();
            c0.isend(1, tag(0, 3), vec![3.0]).unwrap();
            c0 // keep the link alive until the receiver is done
        });
        assert_eq!(c1.recv(0, tag(0, 3)).unwrap(), vec![3.0]);
        assert_eq!(c1.recv(0, tag(0, 7)).unwrap(), vec![7.0]);
        sender.join().unwrap();
    }

    #[test]
    fn cross_iteration_runahead_is_stashed() {
        let mut comms = fabric(2, None);
        let mut c1 = comms.remove(1);
        let c0 = comms.remove(0);
        let sender = thread::spawn(move || {
            c0.isend(1, tag(5, 0), vec![5.0]).unwrap(); // next iteration, early
            c0.isend(1, tag(4, 0), vec![4.0]).unwrap();
            c0
        });
        assert_eq!(c1.recv(0, tag(4, 0)).unwrap(), vec![4.0]);
        assert_eq!(c1.recv(0, tag(5, 0)).unwrap(), vec![5.0]);
        sender.join().unwrap();
    }

    #[test]
    fn layers_disambiguate_same_chunk_and_stage() {
        // Two layers' spAG transfers of the same chunk id must not match
        // each other's receives.
        let mut comms = fabric(2, None);
        let mut c1 = comms.remove(1);
        let c0 = comms.remove(0);
        let mk = |layer: usize| Tag { iter: 0, kind: MsgKind::SpagChunk, layer, a: 3, b: 0 };
        let sender = thread::spawn(move || {
            c0.isend(1, mk(1), vec![1.0]).unwrap();
            c0.isend(1, mk(0), vec![0.0]).unwrap();
            c0
        });
        assert_eq!(c1.recv(0, mk(0)).unwrap(), vec![0.0]);
        assert_eq!(c1.recv(0, mk(1)).unwrap(), vec![1.0]);
        sender.join().unwrap();
    }

    #[test]
    #[cfg_attr(miri, ignore = "wall-clock polling loop is too slow under the interpreter")]
    fn try_wait_polls_without_blocking() {
        let mut comms = fabric(2, None);
        let mut c1 = comms.remove(1);
        let c0 = comms.remove(0);
        let r = c1.irecv(0, tag(0, 1));
        assert!(c1.try_wait(r).unwrap().is_none());
        c0.isend(1, tag(0, 1), vec![1.5]).unwrap();
        // the message is in flight on an unpaced link: it must arrive
        let mut got = None;
        for _ in 0..1000 {
            got = c1.try_wait(r).unwrap();
            if got.is_some() {
                break;
            }
            thread::sleep(Duration::from_micros(50));
        }
        assert_eq!(got, Some(vec![1.5]));
    }

    #[test]
    fn closed_link_errors_instead_of_hanging() {
        let mut comms = fabric(2, None);
        let mut c1 = comms.remove(1);
        let c0 = comms.remove(0);
        drop(c0); // rank 0 dies
        let err = c1.recv(0, tag(0, 0)).unwrap_err();
        assert!(err.to_string().contains("link from rank 0 closed"), "{err}");
        assert!(err.to_string().contains("will never arrive"), "awaited tag context: {err}");
        let r = c1.irecv(0, tag(0, 0));
        assert!(c1.try_wait(r).is_err());
    }

    #[test]
    fn barrier_and_allgather() {
        let n = 4;
        let comms = fabric(n, None);
        let handles: Vec<_> = comms
            .into_iter()
            .map(|mut c| {
                thread::spawn(move || {
                    c.barrier().unwrap();
                    let mine = vec![c.me as f32; c.me + 1];
                    let all = c.allgather(9, MsgKind::Ctrl, 0, &mine).unwrap();
                    c.barrier().unwrap();
                    all
                })
            })
            .collect();
        for h in handles {
            let all = h.join().unwrap();
            assert_eq!(all.len(), n);
            for (r, buf) in all.iter().enumerate() {
                assert_eq!(buf, &vec![r as f32; r + 1]);
            }
        }
    }

    #[test]
    fn payload_pool_recycles_across_send_and_receive() {
        let mut comms = fabric(2, None);
        let mut c1 = comms.remove(1);
        let c0 = comms.remove(0);
        // first send allocates (miss), the recycled receive feeds the next
        c0.isend_slice(1, tag(0, 0), &[1.0, 2.0]).unwrap();
        let buf = c1.recv(0, tag(0, 0)).unwrap();
        assert_eq!(buf, vec![1.0, 2.0]);
        c1.recycle(buf);
        c1.isend_slice(0, tag(0, 1), &[3.0]).unwrap();
        let (hits, misses) = c1.payload_pool_stats();
        assert_eq!((hits, misses), (1, 0), "recycled buffer must be reused");
        let (_, m0) = c0.payload_pool_stats();
        assert_eq!(m0, 1, "first send allocates once");
        // payload correctness is untouched by recycling
        let mut c0 = c0;
        assert_eq!(c0.recv(1, tag(0, 1)).unwrap(), vec![3.0]);
    }

    #[test]
    #[cfg_attr(miri, ignore = "wall-clock pacing timing is meaningless under the interpreter")]
    fn pacing_serializes_contended_link() {
        // 1 kB at 10 kB/s = 100 ms per message. Two messages into the same
        // destination port must serialize: the second completes ≥ ~200 ms
        // after the first was scheduled.
        let pacing = Pacing::uniform(10_000.0, 0.0);
        let mut comms = fabric(3, Some(pacing));
        let mut c2 = comms.remove(2);
        let c1 = comms.remove(1);
        let c0 = comms.remove(0);
        let t0 = Instant::now();
        c0.isend(2, tag(0, 0), vec![0.0; 250]).unwrap();
        c1.isend(2, tag(0, 1), vec![0.0; 250]).unwrap();
        c2.recv(0, tag(0, 0)).unwrap();
        c2.recv(1, tag(0, 1)).unwrap();
        let elapsed = t0.elapsed();
        assert!(
            elapsed >= Duration::from_millis(190),
            "contended port did not serialize: {elapsed:?}"
        );
        drop((c0, c1));
    }

    #[test]
    #[cfg_attr(miri, ignore = "wall-clock pacing timing is meaningless under the interpreter")]
    fn tracer_records_sends_deliveries_and_pacing() {
        // 1 kB at 10 kB/s: ~100 ms on the wire. The sender logs a
        // send_chunk, the receiver a pacing_wait (it blocked) and a
        // recv_chunk whose duration is the modeled wire time.
        let pacing = Pacing::uniform(10_000.0, 0.0);
        let mut comms = fabric(2, Some(pacing));
        let mut c1 = comms.remove(1);
        let c0 = comms.remove(0);
        let epoch = Instant::now();
        c0.set_tracer(TraceRecorder::with_epoch(epoch, 0));
        c1.set_tracer(TraceRecorder::with_epoch(epoch, 1));
        let t = Tag { iter: 2, kind: MsgKind::SpagChunk, layer: 1, a: 0, b: 0 };
        c0.isend(1, t, vec![0.0; 250]).unwrap();
        assert_eq!(c1.recv(0, t).unwrap().len(), 250);

        let send = c0.take_tracer().unwrap();
        assert_eq!(send.events().len(), 1);
        assert_eq!(send.events()[0].phase, TracePhase::SendChunk);
        assert_eq!(send.events()[0].detail, 1000, "detail carries bytes");

        let recv = c1.take_tracer().unwrap();
        let phases: Vec<TracePhase> = recv.events().iter().map(|e| e.phase).collect();
        assert!(phases.contains(&TracePhase::PacingWait), "{phases:?}");
        let rc = recv.events().iter().find(|e| e.phase == TracePhase::RecvChunk).unwrap();
        assert!(rc.dur_us >= 90_000.0, "recv span must carry wire time: {}", rc.dur_us);
        assert_eq!((rc.iter, rc.layer, rc.rank), (2, 1, 1), "tag threads through");
        drop(c0);
    }

    #[test]
    #[cfg_attr(miri, ignore = "wall-clock pacing timing is meaningless under the interpreter")]
    fn pacing_uncontended_is_single_transfer_time() {
        let pacing = Pacing::uniform(10_000.0, 0.0);
        let mut comms = fabric(2, Some(pacing));
        let mut c1 = comms.remove(1);
        let c0 = comms.remove(0);
        let t0 = Instant::now();
        c0.isend(1, tag(0, 0), vec![0.0; 250]).unwrap(); // 100 ms
        c1.recv(0, tag(0, 0)).unwrap();
        let elapsed = t0.elapsed();
        assert!(elapsed >= Duration::from_millis(90), "pacing too fast: {elapsed:?}");
        assert!(elapsed < Duration::from_millis(500), "pacing too slow: {elapsed:?}");
        drop(c0);
    }

    #[test]
    #[cfg_attr(miri, ignore = "socket syscalls are unsupported under the interpreter")]
    fn fallback_barrier_synchronizes_socket_ranks() {
        // The socket backend has no native barrier: the all-to-all
        // Barrier-message round must still hold every rank until all
        // have entered, twice in a row (sequence numbers disambiguate).
        use std::sync::atomic::{AtomicUsize, Ordering};
        use std::sync::Arc;
        let n = 3;
        let comms = super::super::transport::socket::local_fabric(n, None).unwrap();
        let entered = Arc::new(AtomicUsize::new(0));
        let handles: Vec<_> = comms
            .into_iter()
            .map(|mut c| {
                let entered = Arc::clone(&entered);
                thread::spawn(move || {
                    entered.fetch_add(1, Ordering::SeqCst);
                    c.barrier().unwrap();
                    assert_eq!(entered.load(Ordering::SeqCst), n, "barrier leaked a rank early");
                    c.barrier().unwrap();
                    c
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    #[cfg_attr(miri, ignore = "socket syscalls are unsupported under the interpreter")]
    fn fallback_barrier_times_out_when_a_peer_never_enters() {
        // A silent (but alive) peer must surface as CommError::Timeout
        // from the barrier's receive phase — never a hang.
        use super::super::transport::socket;
        let mut comms = socket::local_fabric(2, Some(Duration::from_millis(50))).unwrap();
        let _c1 = comms.remove(1); // alive, never enters the barrier
        let mut c0 = comms.remove(0);
        let err = c0.barrier().unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("timed out"), "{msg}");
        assert!(msg.contains("Barrier"), "awaited tag context: {msg}");
        assert!(CommError::is_peer_loss_msg(&msg), "{msg}");
    }

    #[test]
    #[cfg_attr(miri, ignore = "socket syscalls are unsupported under the interpreter")]
    fn fallback_barrier_errors_when_a_peer_exits() {
        // A peer that exits mid-barrier surfaces as a typed peer-loss
        // error (closed link, or a timeout if the exit raced the send).
        use super::super::transport::socket;
        let mut comms = socket::local_fabric(2, Some(Duration::from_millis(200))).unwrap();
        drop(comms.remove(1)); // rank 1's "process" exits
        let mut c0 = comms.remove(0);
        let err = c0.barrier().unwrap_err();
        let msg = err.to_string();
        assert!(CommError::is_peer_loss_msg(&msg), "{msg}");
    }

    #[test]
    fn audit_log_records_sends_and_completed_receives() {
        // The debug-build schedule cross-check consumes this log; it must
        // see every send and every completed receive — through the direct
        // wait path, the stash path, and the try_wait path alike.
        let mut comms = fabric(2, None);
        let mut c1 = comms.remove(1);
        let c0 = comms.remove(0);
        c0.enable_audit();
        c1.enable_audit();
        let sender = thread::spawn(move || {
            c0.isend(1, tag(0, 7), vec![7.0]).unwrap(); // stashed by the tag(0,3) wait
            c0.isend(1, tag(0, 3), vec![3.0, 3.5]).unwrap();
            c0.isend(1, tag(0, 9), vec![9.0]).unwrap();
            c0
        });
        assert_eq!(c1.recv(0, tag(0, 3)).unwrap(), vec![3.0, 3.5]); // loop-match path
        assert_eq!(c1.recv(0, tag(0, 7)).unwrap(), vec![7.0]); // stash path
        let r = c1.irecv(0, tag(0, 9));
        let mut got = None;
        for _ in 0..1000 {
            got = c1.try_wait(r).unwrap();
            if got.is_some() {
                break;
            }
            thread::sleep(Duration::from_micros(50));
        }
        assert_eq!(got, Some(vec![9.0])); // try_wait path
        let c0 = sender.join().unwrap();
        let sends = c0.take_audit();
        assert_eq!(
            sends,
            vec![
                AuditEvent { send: true, peer: 1, tag: tag(0, 7), floats: 1 },
                AuditEvent { send: true, peer: 1, tag: tag(0, 3), floats: 2 },
                AuditEvent { send: true, peer: 1, tag: tag(0, 9), floats: 1 },
            ]
        );
        let recvs = c1.take_audit();
        assert_eq!(
            recvs,
            vec![
                AuditEvent { send: false, peer: 0, tag: tag(0, 3), floats: 2 },
                AuditEvent { send: false, peer: 0, tag: tag(0, 7), floats: 1 },
                AuditEvent { send: false, peer: 0, tag: tag(0, 9), floats: 1 },
            ]
        );
        // auditing is one-shot: the log is gone until re-enabled
        assert!(c1.take_audit().is_empty());
    }
}
