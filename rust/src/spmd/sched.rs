//! The overlap scheduler: hide re-materialization behind expert compute,
//! within an iteration and across the layer stack (§4.3).
//!
//! Three mechanisms, all bit-exactness-preserving:
//!
//! 1. **Lazy completion** — spAG receives are not awaited up front. The
//!    rank computes route groups for experts whose chunks are already
//!    resident (its own shards) first, and completes a replica's transfer
//!    only when compute first needs it ([`RankSpag::ensure`]); transfers
//!    keep landing in the mailboxes while earlier groups run.
//! 2. **Cross-layer pipelining** — with all layers' plans knowable at
//!    iteration start (the predictors are replicated deterministic state),
//!    layer `l+1`'s spAG sends are issued *before* layer `l`'s expert
//!    compute, so the next layer's materialization rides under the current
//!    layer's compute; symmetrically, layer `l+1`'s spRS is begun right
//!    after its gradients finalize and *finished* only after layer `l`'s
//!    backward compute ([`crate::spmd::exec::RankSprs`] begin/finish).
//! 3. **Eager next-iteration issue** — after the gate exchange of
//!    iteration `i`, every rank already knows iteration `i+1`'s placements,
//!    so as soon as a shard owner finishes an expert's Adam update it
//!    issues that chunk's `i+1` spAG transfers, while other ranks are
//!    still in iteration `i`. Receivers match on (iteration, layer)-tagged
//!    mailboxes, so run-ahead needs no barrier.
//!
//! None of the mechanisms changes any floating-point order: per-buffer
//! gradient accumulation order is fixed by the route map, spAG only
//! copies, and spRS receives stay in plan order.
//!
//! Communication-wise the eager issue is *multiset-neutral*: it sends the
//! same `(iter+1, layer)`-tagged transfers that [`RankSpag::begin`] would
//! send at iteration `i+1`'s start, just earlier, and `next_plans` is
//! `None` on a span's last iteration, so no message escapes the span.
//! That is what lets the static schedule verifier (`crate::analysis`)
//! model each iteration's sends at begin time and still match debug-build
//! audits exactly.
//!
//! [`RankSpag`]: crate::spmd::exec::RankSpag

use std::collections::BTreeSet;
use std::time::Instant;

use crate::collectives::exec::ChunkStore;
use crate::fssdp::IterPlan;
use crate::placement::ChunkId;
use crate::telemetry::Phase as TracePhase;

use super::comm::RankComm;

/// Per-rank overlap state carried across iterations of a span.
pub(crate) struct Overlap {
    pub enabled: bool,
    /// Iteration `i+1`'s plans, one per layer, computed right after
    /// iteration `i`'s gate exchanges (None at span start, on the last
    /// iteration, or with overlap disabled).
    pub next_plans: Option<Vec<IterPlan>>,
    /// `(layer, chunk, dst)` spAG transfers of the next iteration already
    /// sent eagerly; [`RankSpag::begin`] skips them.
    ///
    /// [`RankSpag::begin`]: crate::spmd::exec::RankSpag::begin
    pub pre_issued: BTreeSet<(usize, ChunkId, usize)>,
}

impl Overlap {
    pub fn new(enabled: bool) -> Overlap {
        Overlap { enabled, next_plans: None, pre_issued: BTreeSet::new() }
    }

    /// Drain the pre-issued set of one layer into the `(chunk, dst)` form
    /// [`crate::spmd::exec::RankSpag::begin`] consumes.
    pub fn take_pre_issued(&mut self, layer: usize) -> BTreeSet<(ChunkId, usize)> {
        let mut out = BTreeSet::new();
        let keys: Vec<(usize, ChunkId, usize)> = self
            .pre_issued
            .iter()
            .filter(|(l, _, _)| *l == layer)
            .copied()
            .collect();
        for k in keys {
            self.pre_issued.remove(&k);
            out.insert((k.1, k.2));
        }
        out
    }

    /// Eagerly issue the next iteration's spAG transfers of `layer`
    /// sourced at this rank for chunk `e` (called right after the owner's
    /// Adam update of `e`, while peers still compute iteration
    /// `next_iter - 1`).
    pub fn eager_issue(
        &mut self,
        layer: usize,
        e: ChunkId,
        me: usize,
        next_iter: u64,
        store: &ChunkStore,
        comm: &RankComm,
    ) -> anyhow::Result<usize> {
        let Some(next) = &self.next_plans else {
            return Ok(0);
        };
        let t0 = Instant::now();
        let mut sent = 0;
        for t in next[layer].spag.transfers.iter().filter(|t| t.src.0 == me && t.chunk == e) {
            let Some(buf) = store.get(e) else {
                continue; // not resident here (fan-out source) — deferred
            };
            comm.isend_slice(
                t.dst.0,
                super::comm::Tag {
                    iter: next_iter,
                    kind: super::comm::MsgKind::SpagChunk,
                    layer,
                    a: t.chunk,
                    b: t.stage,
                },
                buf,
            )?;
            self.pre_issued.insert((layer, t.chunk, t.dst.0));
            sent += 1;
        }
        if sent > 0 {
            // run-ahead spAG issue, tagged with the iteration it serves
            comm.trace_span(TracePhase::SpagIssue, next_iter, layer, t0, sent as u64);
        }
        Ok(sent)
    }
}

/// Compute order for this rank's route keys: experts whose parameters are
/// already resident (own shards) first, materialized replicas after —
/// stable by expert id within each class, so per-buffer accumulation
/// order is untouched (one buffer per key).
pub(crate) fn order_resident_first(keys: &[ChunkId], store: &ChunkStore) -> Vec<ChunkId> {
    let mut resident: Vec<ChunkId> = Vec::with_capacity(keys.len());
    let mut deferred: Vec<ChunkId> = Vec::new();
    for &e in keys {
        if store.contains(e) {
            resident.push(e);
        } else {
            deferred.push(e);
        }
    }
    resident.extend(deferred);
    resident
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resident_first_is_stable() {
        let mut store = ChunkStore::new();
        store.insert(2, vec![0.0]);
        store.insert(5, vec![0.0]);
        let order = order_resident_first(&[1, 2, 3, 5, 7], &store);
        assert_eq!(order, vec![2, 5, 1, 3, 7]);
    }

    #[test]
    fn overlap_without_next_plans_is_a_noop() {
        let comms = crate::spmd::comm::fabric(1, None);
        let comm = comms.into_iter().next().unwrap();
        let store = ChunkStore::new();
        let mut ov = Overlap::new(true);
        assert_eq!(ov.eager_issue(0, 0, 0, 1, &store, &comm).unwrap(), 0);
        assert!(ov.pre_issued.is_empty());
    }

    #[test]
    fn pre_issued_drains_per_layer() {
        let mut ov = Overlap::new(true);
        ov.pre_issued.insert((0, 3, 1));
        ov.pre_issued.insert((1, 3, 1));
        ov.pre_issued.insert((1, 5, 2));
        let l1: BTreeSet<(ChunkId, usize)> = ov.take_pre_issued(1);
        assert_eq!(l1.len(), 2);
        assert!(l1.contains(&(3, 1)) && l1.contains(&(5, 2)));
        assert_eq!(ov.pre_issued.len(), 1, "layer 0's entry stays");
        assert!(ov.take_pre_issued(0).contains(&(3, 1)));
        assert!(ov.pre_issued.is_empty());
    }
}
