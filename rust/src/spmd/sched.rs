//! The overlap scheduler: hide re-materialization behind expert compute.
//!
//! Two mechanisms, both bit-exactness-preserving (§4.3 of the paper, the
//! "re-materialization overlap"):
//!
//! 1. **Lazy completion** — spAG receives are not awaited up front. The
//!    rank computes route groups for experts whose chunks are already
//!    resident (its own shards) first, and completes a replica's transfer
//!    only when compute first needs it ([`RankSpag::ensure`]); transfers
//!    keep landing in the mailboxes while earlier groups run.
//! 2. **Eager next-iteration issue** — after the gate exchange of
//!    iteration `i`, every rank already knows iteration `i+1`'s placement
//!    (the predictor is replicated deterministic state), so as soon as a
//!    shard owner finishes an expert's Adam update it issues that chunk's
//!    `i+1` spAG transfers, while other ranks are still in iteration `i`
//!    compute. Receivers match on iteration-tagged mailboxes, so run-ahead
//!    needs no barrier.
//!
//! Neither mechanism changes any floating-point order: per-buffer gradient
//! accumulation order is fixed by the route map, and spAG only copies.

use std::collections::BTreeSet;

use crate::collectives::exec::ChunkStore;
use crate::fssdp::IterPlan;
use crate::placement::ChunkId;

use super::comm::RankComm;

/// Per-rank overlap state carried across iterations of a span.
pub(crate) struct Overlap {
    pub enabled: bool,
    /// Iteration `i+1`'s plan, computed right after iteration `i`'s gate
    /// exchange (None at span start, on the last iteration, or with
    /// overlap disabled).
    pub next_plan: Option<IterPlan>,
    /// `(chunk, dst)` spAG transfers of the next iteration already sent
    /// eagerly; [`RankSpag::begin`] skips them.
    pub pre_issued: BTreeSet<(ChunkId, usize)>,
}

impl Overlap {
    pub fn new(enabled: bool) -> Overlap {
        Overlap { enabled, next_plan: None, pre_issued: BTreeSet::new() }
    }

    /// Eagerly issue the next iteration's spAG transfers sourced at this
    /// rank for chunk `e` (called right after the owner's Adam update of
    /// `e`, while peers still compute iteration `next_iter - 1`).
    pub fn eager_issue(
        &mut self,
        e: ChunkId,
        me: usize,
        next_iter: u64,
        store: &ChunkStore,
        comm: &RankComm,
    ) -> anyhow::Result<usize> {
        let Some(next) = &self.next_plan else {
            return Ok(0);
        };
        let mut sent = 0;
        for t in next.spag.transfers.iter().filter(|t| t.src.0 == me && t.chunk == e) {
            let Some(buf) = store.get(e) else {
                continue; // not resident here (fan-out source) — deferred
            };
            comm.isend(
                t.dst.0,
                super::comm::Tag {
                    iter: next_iter,
                    kind: super::comm::MsgKind::SpagChunk,
                    a: t.chunk,
                    b: t.stage,
                },
                buf.clone(),
            )?;
            self.pre_issued.insert((t.chunk, t.dst.0));
            sent += 1;
        }
        Ok(sent)
    }
}

/// Compute order for this rank's route keys: experts whose parameters are
/// already resident (own shards) first, materialized replicas after —
/// stable by expert id within each class, so per-buffer accumulation
/// order is untouched (one buffer per key).
pub(crate) fn order_resident_first(keys: &[ChunkId], store: &ChunkStore) -> Vec<ChunkId> {
    let mut resident: Vec<ChunkId> = Vec::with_capacity(keys.len());
    let mut deferred: Vec<ChunkId> = Vec::new();
    for &e in keys {
        if store.contains(e) {
            resident.push(e);
        } else {
            deferred.push(e);
        }
    }
    resident.extend(deferred);
    resident
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resident_first_is_stable() {
        let mut store = ChunkStore::new();
        store.insert(2, vec![0.0]);
        store.insert(5, vec![0.0]);
        let order = order_resident_first(&[1, 2, 3, 5, 7], &store);
        assert_eq!(order, vec![2, 5, 1, 3, 7]);
    }

    #[test]
    fn overlap_without_next_plan_is_a_noop() {
        let comms = crate::spmd::comm::fabric(1, None);
        let comm = comms.into_iter().next().unwrap();
        let store = ChunkStore::new();
        let mut ov = Overlap::new(true);
        assert_eq!(ov.eager_issue(0, 0, 1, &store, &comm).unwrap(), 0);
        assert!(ov.pre_issued.is_empty());
    }
}
