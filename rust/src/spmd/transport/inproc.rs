//! The in-process backend: per-link mailboxes over `std::sync::mpsc`.
//!
//! Every pair of ranks is connected by a dedicated unbounded channel (the
//! "link"), so sends never block and per-link FIFO order is guaranteed by
//! the channel itself. This is the original Hecate fabric — one OS thread
//! per rank inside one process — and it remains the zero-alloc reference
//! backend (the `ws_allocs == 0` steady-state lock runs over it).
//!
//! **Link pacing** (optional): with a [`Pacing`] config, each message is
//! assigned a delivery instant from the α–β model of the topology,
//! serialized on the contended resource for its tier crossing — the
//! device's NVLink port within a node, the node's NIC within a rack, the
//! rack's uplink across racks — so bottleneck-link contention (Eq. 1) is
//! physically reproduced in wall-clock time rather than only predicted.
//! Pacing shapes *time*, never payloads, so it cannot affect numerics.

use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Barrier, Mutex};
use std::time::{Duration, Instant};

use super::{CommError, Envelope, Transport, TransportKind};
use crate::spmd::comm::Tag;
use crate::topology::Topology;

/// α–β link pacing configuration (all times in seconds, bandwidth in
/// bytes/s). `time_scale` maps modeled seconds to real seconds so that
/// GPU-cluster bandwidths produce observable wall-clock effects.
///
/// Three tiers, selected by the link's crossing: device ports within a
/// node (`intra_*`), node NICs within a rack (`inter_*`), rack uplinks
/// across racks (`rack_*`).
#[derive(Debug, Clone, Copy)]
pub struct Pacing {
    pub devices_per_node: usize,
    /// Nodes per rack (`usize::MAX` = everything in one rack, the
    /// pre-hierarchical default).
    pub nodes_per_rack: usize,
    pub intra_bw: f64,
    pub inter_bw: f64,
    /// Cross-rack uplink bandwidth (bytes/s).
    pub rack_bw: f64,
    pub intra_lat: f64,
    pub inter_lat: f64,
    /// Cross-rack latency (seconds).
    pub rack_lat: f64,
    pub time_scale: f64,
}

impl Pacing {
    /// Derive per-link α–β from a topology's tier parameters: the tier a
    /// message crosses ([`Topology::tier`]) picks its bandwidth/latency
    /// pair and the serializing resource.
    pub fn from_topology(t: &Topology, time_scale: f64) -> Pacing {
        Pacing {
            devices_per_node: t.devices_per_node,
            nodes_per_rack: t.nodes_per_rack(),
            intra_bw: t.intra_bw,
            inter_bw: t.inter_bw,
            rack_bw: t.rack_bw,
            intra_lat: t.intra_lat,
            inter_lat: t.inter_lat,
            rack_lat: t.rack_lat,
            time_scale,
        }
    }

    /// Uniform single-switch pacing (tests): every transfer of `bytes`
    /// bytes occupies its src/dst ports for `lat + bytes/bw` seconds.
    pub fn uniform(n_bytes_per_sec: f64, lat: f64) -> Pacing {
        Pacing {
            devices_per_node: usize::MAX,
            nodes_per_rack: usize::MAX,
            intra_bw: n_bytes_per_sec,
            inter_bw: n_bytes_per_sec,
            rack_bw: n_bytes_per_sec,
            intra_lat: lat,
            inter_lat: lat,
            rack_lat: lat,
            time_scale: 1.0,
        }
    }
}

/// Shared pacing clocks: per-device port, per-node NIC, and per-rack
/// uplink busy-until times, in modeled seconds since `epoch`.
struct Clocks {
    dev_out: Vec<f64>,
    dev_in: Vec<f64>,
    nic_out: Vec<f64>,
    nic_in: Vec<f64>,
    rack_out: Vec<f64>,
    rack_in: Vec<f64>,
}

pub(crate) struct Pacer {
    cfg: Pacing,
    epoch: Instant,
    clocks: Mutex<Clocks>,
}

impl Pacer {
    pub(crate) fn new(cfg: Pacing, n: usize) -> Pacer {
        let dpn = cfg.devices_per_node.max(1);
        let nodes = if dpn >= n { 1 } else { (n + dpn - 1) / dpn };
        let npr = cfg.nodes_per_rack.max(1);
        let racks = if npr >= nodes { 1 } else { (nodes + npr - 1) / npr };
        Pacer {
            cfg,
            epoch: Instant::now(),
            clocks: Mutex::new(Clocks {
                dev_out: vec![0.0; n],
                dev_in: vec![0.0; n],
                nic_out: vec![0.0; nodes],
                nic_in: vec![0.0; nodes],
                rack_out: vec![0.0; racks],
                rack_in: vec![0.0; racks],
            }),
        }
    }

    /// Reserve the contended resources for a `bytes`-byte transfer and
    /// return its delivery instant: the transfer starts when both the
    /// source's egress and the destination's ingress are free at the
    /// link's tier, and holds both for its α–β duration (serialization on
    /// the bottleneck link). Intra-node links contend on device ports,
    /// intra-rack links on node NICs, cross-rack links on rack uplinks.
    pub(crate) fn schedule(&self, src: usize, dst: usize, bytes: f64) -> Instant {
        let dpn = self.cfg.devices_per_node.max(1);
        let npr = self.cfg.nodes_per_rack.max(1);
        let (sn, dn) = (src / dpn, dst / dpn);
        let (sr, dr) = (sn / npr, dn / npr);
        let (bw, lat) = if sn == dn {
            (self.cfg.intra_bw, self.cfg.intra_lat)
        } else if sr == dr {
            (self.cfg.inter_bw, self.cfg.inter_lat)
        } else {
            (self.cfg.rack_bw, self.cfg.rack_lat)
        };
        let dur = (lat + bytes / bw.max(1.0)) * self.cfg.time_scale;
        let now = self.epoch.elapsed().as_secs_f64();
        let mut c = self.clocks.lock().expect("pacer lock poisoned");
        let (out_clock, in_clock): (&mut Vec<f64>, &mut Vec<f64>) = if sn == dn {
            (&mut c.dev_out, &mut c.dev_in)
        } else if sr == dr {
            (&mut c.nic_out, &mut c.nic_in)
        } else {
            (&mut c.rack_out, &mut c.rack_in)
        };
        let (oi, ii) = if sn == dn {
            (src, dst)
        } else if sr == dr {
            (sn, dn)
        } else {
            (sr, dr)
        };
        let start = now.max(out_clock[oi]).max(in_clock[ii]);
        let fin = start + dur;
        out_clock[oi] = fin;
        in_clock[ii] = fin;
        self.epoch + Duration::from_secs_f64(fin)
    }
}

/// One rank's endpoint of the in-process mailbox fabric.
pub struct InProcTransport {
    me: usize,
    n: usize,
    tx: Vec<Sender<Envelope>>,
    rx: Vec<Receiver<Envelope>>,
    barrier: Arc<Barrier>,
    pacer: Option<Arc<Pacer>>,
}

/// Build the full n×n mailbox fabric; element `r` is rank `r`'s endpoint.
pub fn fabric(n: usize, pacing: Option<Pacing>) -> Vec<InProcTransport> {
    assert!(n > 0, "communicator needs at least one rank");
    // Channel (src → dst): src holds the Sender, dst the Receiver.
    // senders[src][dst] / receivers[dst][src] — the nested loops append
    // exactly one entry per (src, dst) pair to each side, in index order.
    let mut senders: Vec<Vec<Sender<Envelope>>> = (0..n).map(|_| Vec::with_capacity(n)).collect();
    let mut receivers: Vec<Vec<Receiver<Envelope>>> =
        (0..n).map(|_| Vec::with_capacity(n)).collect();
    for src in 0..n {
        for dst in 0..n {
            let (tx, rx) = channel();
            senders[src].push(tx); // appended at index dst
            receivers[dst].push(rx); // appended at index src
        }
    }
    let barrier = Arc::new(Barrier::new(n));
    let pacer = pacing.map(|p| Arc::new(Pacer::new(p, n)));
    let mut out = Vec::with_capacity(n);
    for (me, (tx, rx)) in senders.into_iter().zip(receivers).enumerate() {
        out.push(InProcTransport {
            me,
            n,
            tx,
            rx,
            barrier: Arc::clone(&barrier),
            pacer: pacer.clone(),
        });
    }
    out
}

impl Transport for InProcTransport {
    fn me(&self) -> usize {
        self.me
    }

    fn num_ranks(&self) -> usize {
        self.n
    }

    fn send(&self, dst: usize, tag: Tag, data: Vec<f32>) -> Result<Option<Vec<f32>>, CommError> {
        let ready_at =
            self.pacer.as_ref().map(|p| p.schedule(self.me, dst, data.len() as f64 * 4.0));
        let wire_us = ready_at
            .map_or(0, |t| t.saturating_duration_since(Instant::now()).as_micros() as u64);
        self.tx[dst].send(Envelope { tag, data, ready_at, wire_us }).map_err(|_| {
            CommError::PeerClosed { rank: self.me, peer: dst, sending: true, tag: Some(tag) }
        })?;
        Ok(None) // ownership moved into the fabric
    }

    fn recv_next(&mut self, src: usize) -> Result<Envelope, CommError> {
        self.rx[src].recv().map_err(|_| CommError::PeerClosed {
            rank: self.me,
            peer: src,
            sending: false,
            tag: None,
        })
    }

    fn try_recv_next(&mut self, src: usize) -> Result<Option<Envelope>, CommError> {
        match self.rx[src].try_recv() {
            Ok(env) => Ok(Some(env)),
            Err(TryRecvError::Empty) => Ok(None),
            Err(TryRecvError::Disconnected) => Err(CommError::PeerClosed {
                rank: self.me,
                peer: src,
                sending: false,
                tag: None,
            }),
        }
    }

    fn barrier_wait(&self) -> bool {
        self.barrier.wait();
        true
    }

    fn kind(&self) -> TransportKind {
        TransportKind::InProc
    }

    fn describe(&self) -> String {
        format!("inproc rank {}/{} (mpsc)", self.me, self.n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spmd::comm::MsgKind;

    fn tag(a: usize) -> Tag {
        Tag { iter: 0, kind: MsgKind::Ctrl, layer: 0, a, b: 0 }
    }

    #[test]
    fn send_and_recv_next_move_payloads_fifo() {
        let mut f = fabric(2, None);
        let mut t1 = f.remove(1);
        let t0 = f.remove(0);
        assert!(t0.send(1, tag(0), vec![1.0]).unwrap().is_none(), "inproc keeps the buffer");
        t0.send(1, tag(1), vec![2.0]).unwrap();
        let a = t1.recv_next(0).unwrap();
        let b = t1.recv_next(0).unwrap();
        assert_eq!((a.tag.a, a.data), (0, vec![1.0]));
        assert_eq!((b.tag.a, b.data), (1, vec![2.0]));
        assert!(t1.try_recv_next(0).unwrap().is_none());
        assert_eq!(t0.kind(), TransportKind::InProc);
    }

    #[test]
    fn dropped_peer_is_a_typed_error() {
        let mut f = fabric(2, None);
        let mut t1 = f.remove(1);
        drop(f.remove(0));
        match t1.recv_next(0) {
            Err(CommError::PeerClosed { rank: 1, peer: 0, sending: false, .. }) => {}
            other => panic!("unexpected: {:?}", other.map(|e| e.tag)),
        }
        assert!(matches!(t1.try_recv_next(0), Err(CommError::PeerClosed { .. })));
    }

    #[test]
    fn fifo_order_holds_under_interleaved_tags() {
        // The static schedule verifier's deadlock/matching model assumes
        // per-(src, dst) FIFO delivery *regardless of tag*: messages with
        // interleaved tags must surface in send order, and `try_recv_next`
        // must drain the same queue `recv_next` reads. Interleave three
        // logical streams (Ctrl a=0/1/2) on one link and check order.
        let mut f = fabric(2, None);
        let mut t1 = f.remove(1);
        let t0 = f.remove(0);
        let order = [0usize, 2, 1, 0, 1, 2, 2, 0];
        for (i, &a) in order.iter().enumerate() {
            t0.send(1, tag(a), vec![i as f32]).unwrap();
        }
        let mut seen = Vec::new();
        // Alternate polling and blocking receives: both must respect FIFO.
        for i in 0..order.len() {
            let env = if i % 2 == 0 {
                t1.try_recv_next(0).unwrap().expect("message already queued")
            } else {
                t1.recv_next(0).unwrap()
            };
            assert_eq!(env.data, vec![i as f32], "payload {i} out of order");
            seen.push(env.tag.a);
        }
        assert_eq!(seen, order, "tags must surface in send order, not tag order");
        assert!(t1.try_recv_next(0).unwrap().is_none());
    }

    #[test]
    #[cfg_attr(miri, ignore = "wall-clock pacing is meaningless under Miri")]
    fn rack_tier_paces_slower_than_intra_rack() {
        // 2 devices per node, 1 node per rack: ranks {0,1} rack 0,
        // ranks {2,3} rack 1. Cross-rack bandwidth is 100× slower, so the
        // same payload takes ≥ ~100 ms across racks vs ~1 ms within a node.
        let cfg = Pacing {
            devices_per_node: 2,
            nodes_per_rack: 1,
            intra_bw: 1_000_000.0,
            inter_bw: 1_000_000.0,
            rack_bw: 10_000.0,
            intra_lat: 0.0,
            inter_lat: 0.0,
            rack_lat: 0.0,
            time_scale: 1.0,
        };
        let pacer = Pacer::new(cfg, 4);
        let t0 = Instant::now();
        let intra = pacer.schedule(0, 1, 1000.0);
        let cross = pacer.schedule(0, 2, 1000.0);
        assert!(intra.duration_since(t0) < Duration::from_millis(50));
        assert!(cross.duration_since(t0) >= Duration::from_millis(90));
    }

    #[test]
    #[cfg_attr(miri, ignore = "wall-clock pacing is meaningless under Miri")]
    fn cross_rack_transfers_serialize_on_the_rack_uplink() {
        // Two different node pairs crossing the same rack boundary must
        // share the rack uplink: second transfer finishes ~2× later.
        let cfg = Pacing {
            devices_per_node: 1,
            nodes_per_rack: 2,
            intra_bw: 1e9,
            inter_bw: 1e9,
            rack_bw: 10_000.0,
            intra_lat: 0.0,
            inter_lat: 0.0,
            rack_lat: 0.0,
            time_scale: 1.0,
        };
        // 4 ranks = 4 nodes = 2 racks: {0,1} and {2,3}.
        let pacer = Pacer::new(cfg, 4);
        let t0 = Instant::now();
        let first = pacer.schedule(0, 2, 1000.0); // rack 0 → rack 1, 100 ms
        let second = pacer.schedule(1, 3, 1000.0); // same uplink, serialized
        assert!(first.duration_since(t0) >= Duration::from_millis(90));
        assert!(second.duration_since(t0) >= Duration::from_millis(190));
    }
}
