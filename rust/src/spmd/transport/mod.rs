//! Pluggable rank-to-rank transports under the SPMD communicator.
//!
//! [`RankComm`](crate::spmd::comm::RankComm) owns everything that makes the
//! communicator *a communicator* — MPI-style tag matching, the per-link
//! stash, payload free-lists, and the telemetry seams — and delegates the
//! raw byte movement to a [`Transport`] object. Two backends implement the
//! trait:
//!
//! * [`inproc`] — the original per-link `std::sync::mpsc` mailbox fabric
//!   (one OS thread per rank inside one process), with optional α–β link
//!   [`Pacing`] so wire time is physically on the clock.
//! * [`socket`] — TCP/UDS streams with a versioned, length-prefixed wire
//!   codec, so ranks can run as separate processes (`hecate worker`). The
//!   codec carries the full `(iter, layer, kind, a, b)` tag, which is what
//!   keeps iteration-tagged, barrier-free overlap (§4.3) working across
//!   process boundaries.
//!
//! The trait contract every backend must honor (the determinism contract
//! of `DESIGN.md §SPMD` leans on all three):
//!
//! 1. **Per-link FIFO** — messages from one `src` arrive in send order.
//! 2. **Reliable, non-blocking sends** — `send` never blocks on a healthy
//!    peer and never drops a message.
//! 3. **Payload integrity** — `f32` payloads arrive bit-identical
//!    (IEEE-754 bit patterns, including NaN payloads, survive the wire).
//!
//! Failures surface as typed [`CommError`]s carrying the rank, peer, and
//! (where known) the tag being waited on, so a dead worker process reports
//! *which* link broke instead of hanging the fabric.
//!
//! The static schedule verifier (`crate::analysis`, `hecate analyze
//! schedule`) leans on the same contract: its deadlock and matching
//! analysis pairs sends with receives per `(src, dst, tag)` in FIFO
//! order, which is sound only because guarantee 1 holds on every backend
//! (both test suites pin it with interleaved-tag FIFO tests).

use std::time::Duration;

use crate::spmd::comm::Tag;

pub mod inproc;
pub mod socket;

pub use inproc::Pacing;

/// Which transport backs the SPMD communicator fabric.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum TransportKind {
    /// In-process mpsc mailboxes (one OS thread per rank, one process).
    InProc,
    /// TCP/UDS streams with the versioned wire codec (rank threads or
    /// separate `hecate worker` processes).
    Socket,
}

impl TransportKind {
    /// Parse a CLI spelling (`inproc` | `socket`).
    pub fn parse(s: &str) -> Option<TransportKind> {
        match s {
            "inproc" | "in-proc" | "mpsc" => Some(TransportKind::InProc),
            "socket" | "uds" | "tcp" => Some(TransportKind::Socket),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            TransportKind::InProc => "inproc",
            TransportKind::Socket => "socket",
        }
    }
}

impl std::fmt::Display for TransportKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// One message as the transport hands it to the matching layer: the tag,
/// the payload, and (under pacing) the modeled delivery schedule.
pub struct Envelope {
    pub tag: Tag,
    pub data: Vec<f32>,
    /// With pacing: the modeled delivery instant (the transfer is "on the
    /// wire" until then). `None` on unpaced links and the socket backend
    /// (socket wall-clock is real, not modeled).
    pub ready_at: Option<std::time::Instant>,
    /// Modeled in-flight time (queueing + transfer) in µs, 0 unpaced.
    /// Carried on the wire so the receiver can attribute it in the trace.
    pub wire_us: u64,
}

/// A typed communicator failure: every variant names the local rank and —
/// where the failure is link-scoped — the peer and the tag being carried
/// or awaited, so errors out of an 8-process fabric are actionable.
///
/// The vendored `anyhow` stand-in is string-erased (no `downcast_ref`), so
/// callers that only hold a rendered error chain classify it with
/// [`CommError::is_comm_failure_msg`] / [`CommError::is_peer_loss_msg`];
/// both are locked to the `Display` forms below by unit tests.
#[derive(Debug, Clone, PartialEq)]
pub enum CommError {
    /// The peer endpoint is gone: its rank thread died, its process
    /// exited, or the stream hit EOF / a broken pipe.
    PeerClosed {
        rank: usize,
        peer: usize,
        /// True when detected on the send path, false on the receive path.
        sending: bool,
        /// The tag being sent / awaited, when known.
        tag: Option<Tag>,
    },
    /// A blocking receive exceeded the configured timeout (socket backend;
    /// see `SessionConfigBuilder::recv_timeout`).
    Timeout { rank: usize, peer: usize, tag: Option<Tag>, after: Duration },
    /// The peer sent bytes the wire codec rejects (bad magic/version/
    /// length, truncated frame, unknown message kind).
    Codec { rank: usize, peer: usize, detail: String },
    /// An OS-level transport error (connect/bind/read/write).
    Io { rank: usize, peer: usize, op: &'static str, detail: String },
    /// A handshake or addressing violation (wrong rank count, duplicate
    /// peer, self-receive, malformed address).
    Protocol { rank: usize, detail: String },
}

impl CommError {
    /// Attach the awaited tag to a link-scoped error that was raised below
    /// the matching layer (which alone knows what it was waiting for).
    pub(crate) fn with_tag(self, t: Tag) -> CommError {
        match self {
            CommError::PeerClosed { rank, peer, sending, tag: None } => {
                CommError::PeerClosed { rank, peer, sending, tag: Some(t) }
            }
            CommError::Timeout { rank, peer, tag: None, after } => {
                CommError::Timeout { rank, peer, tag: Some(t), after }
            }
            other => other,
        }
    }

    /// Does a rendered error chain contain a communicator failure? (The
    /// CLI maps these to a dedicated nonzero exit code.)
    pub fn is_comm_failure_msg(msg: &str) -> bool {
        [
            "link to rank",
            "link from rank",
            "timed out after",
            "wire codec error",
            "transport i/o error",
            "transport protocol error",
        ]
        .iter()
        .any(|needle| msg.contains(needle))
    }

    /// Does a rendered error chain describe a *lost peer* (closed link or
    /// receive timeout)? Used by the span merge to demote secondary
    /// "my peer died" errors behind the primary failure that killed it.
    pub fn is_peer_loss_msg(msg: &str) -> bool {
        msg.contains("closed") || msg.contains("timed out")
    }
}

impl std::fmt::Display for CommError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CommError::PeerClosed { rank, peer, sending: true, tag } => {
                write!(f, "rank {rank}: link to rank {peer} closed (peer rank died)")?;
                if let Some(t) = tag {
                    write!(f, " while sending {t:?}")?;
                }
                Ok(())
            }
            CommError::PeerClosed { rank, peer, sending: false, tag } => {
                write!(f, "rank {rank}: link from rank {peer} closed")?;
                if let Some(t) = tag {
                    write!(f, "; {t:?} will never arrive")?;
                }
                Ok(())
            }
            CommError::Timeout { rank, peer, tag, after } => {
                write!(f, "rank {rank}: receive from rank {peer} timed out after {after:?}")?;
                if let Some(t) = tag {
                    write!(f, " while waiting for {t:?}")?;
                }
                Ok(())
            }
            CommError::Codec { rank, peer, detail } => {
                write!(f, "rank {rank}: wire codec error on link from rank {peer}: {detail}")
            }
            CommError::Io { rank, peer, op, detail } => {
                write!(f, "rank {rank}: transport i/o error ({op}, peer rank {peer}): {detail}")
            }
            CommError::Protocol { rank, detail } => {
                write!(f, "rank {rank}: transport protocol error: {detail}")
            }
        }
    }
}

impl std::error::Error for CommError {}

/// The raw endpoint a [`RankComm`](crate::spmd::comm::RankComm) speaks to.
///
/// The object moves messages; it does **not** match tags — `recv_next` /
/// `try_recv_next` surface whatever is next on the link and the
/// communicator stashes non-matching arrivals. Sends take `&self`
/// (the overlap scheduler and the collective drivers send under shared
/// borrows of the endpoint); backends use interior mutability for their
/// writer state. An endpoint is owned by exactly one rank thread.
pub trait Transport: Send {
    /// This endpoint's rank.
    fn me(&self) -> usize;

    /// Number of ranks in the fabric.
    fn num_ranks(&self) -> usize;

    /// Nonblocking tagged send of an owned payload. Returns the payload
    /// buffer when the transport is done with it at return time (the
    /// socket backend serializes into its own scratch), so the caller can
    /// recycle it; `None` when ownership moved into the fabric (in-proc).
    fn send(&self, dst: usize, tag: Tag, data: Vec<f32>) -> Result<Option<Vec<f32>>, CommError>;

    /// Blocking receive of the next message from `src`, any tag. Honors
    /// the backend's receive timeout, if any.
    fn recv_next(&mut self, src: usize) -> Result<Envelope, CommError>;

    /// Nonblocking poll: `Ok(None)` when no message is currently
    /// available on the link from `src`.
    fn try_recv_next(&mut self, src: usize) -> Result<Option<Envelope>, CommError>;

    /// Execute a native fabric-wide barrier if the backend has one
    /// (in-proc: `std::sync::Barrier`). Returns false when the backend has
    /// no native barrier; the communicator then runs its message-based
    /// fallback over [`Transport::send`] / [`Transport::recv_next`].
    fn barrier_wait(&self) -> bool;

    /// Which backend this is.
    fn kind(&self) -> TransportKind;

    /// Human-readable endpoint description (backend + addressing) for
    /// error messages and traces — the socket backend reports its
    /// listen path here.
    fn describe(&self) -> String;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spmd::comm::MsgKind;

    fn tag() -> Tag {
        Tag { iter: 3, kind: MsgKind::Ctrl, layer: 1, a: 2, b: 0 }
    }

    #[test]
    fn transport_kind_parses_cli_spellings() {
        assert_eq!(TransportKind::parse("inproc"), Some(TransportKind::InProc));
        assert_eq!(TransportKind::parse("socket"), Some(TransportKind::Socket));
        assert_eq!(TransportKind::parse("uds"), Some(TransportKind::Socket));
        assert_eq!(TransportKind::parse("carrier-pigeon"), None);
        assert_eq!(TransportKind::Socket.to_string(), "socket");
    }

    #[test]
    fn errors_render_rank_peer_and_tag_context() {
        let e = CommError::PeerClosed { rank: 1, peer: 0, sending: false, tag: Some(tag()) };
        let msg = e.to_string();
        assert!(msg.contains("rank 1"), "{msg}");
        assert!(msg.contains("link from rank 0 closed"), "{msg}");
        assert!(msg.contains("will never arrive"), "{msg}");

        let e = CommError::PeerClosed { rank: 2, peer: 3, sending: true, tag: None };
        assert_eq!(e.to_string(), "rank 2: link to rank 3 closed (peer rank died)");

        let e = CommError::Timeout {
            rank: 0,
            peer: 1,
            tag: Some(tag()),
            after: Duration::from_secs(5),
        };
        let msg = e.to_string();
        assert!(msg.contains("timed out after"), "{msg}");
        assert!(msg.contains("while waiting for"), "{msg}");
    }

    #[test]
    fn with_tag_fills_only_missing_tags() {
        let e = CommError::PeerClosed { rank: 0, peer: 1, sending: false, tag: None };
        match e.with_tag(tag()) {
            CommError::PeerClosed { tag: Some(t), .. } => assert_eq!(t, tag()),
            other => panic!("unexpected: {other:?}"),
        }
        let preset = Tag { iter: 9, kind: MsgKind::Gate, layer: 0, a: 1, b: 0 };
        let e = CommError::PeerClosed { rank: 0, peer: 1, sending: false, tag: Some(preset) };
        match e.with_tag(tag()) {
            CommError::PeerClosed { tag: Some(t), .. } => assert_eq!(t, preset),
            other => panic!("unexpected: {other:?}"),
        }
    }

    #[test]
    fn rendered_chain_classifiers_match_every_variant() {
        // The vendored anyhow cannot downcast, so these substring
        // classifiers are the CLI's and the span merge's only handle on
        // typed comm failures — lock them to the Display forms.
        let all = [
            CommError::PeerClosed { rank: 0, peer: 1, sending: true, tag: None },
            CommError::PeerClosed { rank: 0, peer: 1, sending: false, tag: Some(tag()) },
            CommError::Timeout { rank: 0, peer: 1, tag: None, after: Duration::from_secs(1) },
            CommError::Codec { rank: 0, peer: 1, detail: "bad magic".into() },
            CommError::Io { rank: 0, peer: 1, op: "write", detail: "broken pipe".into() },
            CommError::Protocol { rank: 0, detail: "duplicate handshake".into() },
        ];
        for e in &all {
            assert!(
                CommError::is_comm_failure_msg(&e.to_string()),
                "not classified as comm failure: {e}"
            );
        }
        // peer-loss covers exactly the closed-link and timeout shapes
        assert!(CommError::is_peer_loss_msg(&all[0].to_string()));
        assert!(CommError::is_peer_loss_msg(&all[1].to_string()));
        assert!(CommError::is_peer_loss_msg(&all[2].to_string()));
        assert!(!CommError::is_peer_loss_msg(&all[3].to_string()));
        assert!(!CommError::is_comm_failure_msg("the gate weights are frozen"));
    }
}
