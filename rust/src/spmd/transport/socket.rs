//! The socket backend: TCP/UDS streams with a versioned wire codec.
//!
//! Ranks connect as a full mesh — rank `r` dials every rank below it and
//! accepts from every rank above it, identifying inbound peers with a
//! 9-byte handshake — so the same endpoint works for rank threads inside
//! one process ([`local_fabric`]) and for separate `hecate worker`
//! processes on localhost. A dedicated reader thread per inbound link
//! decodes frames into an unbounded channel, preserving the per-link FIFO
//! guarantee the tag-matching layer relies on; a clean peer shutdown
//! surfaces exactly like a dropped in-proc mailbox (the channel
//! disconnects and the receive reports a closed link).
//!
//! ## Wire format (version 1, all integers little-endian)
//!
//! ```text
//! handshake (once per connection, dialer → acceptor):
//!   [magic  4B = "HCTP"] [version 1B] [rank 4B]
//! frame (repeated):
//!   [len 4B] [version 1B] [kind 1B] [iter 8B] [layer 4B] [a 4B] [b 4B]
//!   [payload: len-22 bytes of f32 little-endian bit patterns]
//! ```
//!
//! `len` counts every byte after the length prefix, so an empty payload
//! frame has `len == 22`. Frames carry the full `(iter, kind, layer, a,
//! b)` tag, which is what keeps iteration-tagged, barrier-free overlap
//! working across process boundaries. Payloads are raw IEEE-754 bit
//! patterns — `f32::to_bits`/`from_bits`, never a text round-trip — so
//! parameters arrive bit-identical and the `in-proc ≡ socket` equivalence
//! lock can compare with `==`. Decoding rejects bad magic, unknown
//! versions or kinds, truncated frames, payload lengths that are not a
//! multiple of 4, and frames beyond [`MAX_FRAME_LEN`].

use std::cell::RefCell;
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use super::{CommError, Envelope, Transport, TransportKind};
use crate::spmd::comm::{MsgKind, RankComm, Tag};

/// Wire protocol version carried in the handshake and every frame.
pub const WIRE_VERSION: u8 = 1;
/// Handshake magic ("HeCaTe Transport Protocol").
pub const MAGIC: [u8; 4] = *b"HCTP";
/// Frame bytes after the length prefix, before the payload.
pub const HEADER_LEN: usize = 22;
/// Largest accepted frame body (header + 64 MiB of payload).
pub const MAX_FRAME_LEN: usize = HEADER_LEN + (64 << 20);

/// Default blocking-receive timeout of the socket backend: a vanished
/// peer process must surface as an error, never a hang.
pub const DEFAULT_RECV_TIMEOUT: Duration = Duration::from_secs(30);
/// Default time budget for establishing the full mesh.
pub const DEFAULT_CONNECT_TIMEOUT: Duration = Duration::from_secs(10);

fn kind_code(k: MsgKind) -> u8 {
    match k {
        MsgKind::SpagChunk => 0,
        MsgKind::SprsChunk => 1,
        MsgKind::Gate => 2,
        MsgKind::Combine => 3,
        MsgKind::GradX => 4,
        MsgKind::Ctrl => 5,
        MsgKind::Barrier => 6,
    }
}

fn kind_from_code(c: u8) -> Option<MsgKind> {
    Some(match c {
        0 => MsgKind::SpagChunk,
        1 => MsgKind::SprsChunk,
        2 => MsgKind::Gate,
        3 => MsgKind::Combine,
        4 => MsgKind::GradX,
        5 => MsgKind::Ctrl,
        6 => MsgKind::Barrier,
        _ => return None,
    })
}

/// Serialize one tagged message as a full frame (length prefix included)
/// into `out`, which is cleared first. The payload is written as raw
/// little-endian `f32` bit patterns.
pub fn encode_frame(tag: Tag, data: &[f32], out: &mut Vec<u8>) {
    out.clear();
    let len = HEADER_LEN + data.len() * 4;
    out.reserve(4 + len);
    out.extend_from_slice(&(len as u32).to_le_bytes());
    out.push(WIRE_VERSION);
    out.push(kind_code(tag.kind));
    out.extend_from_slice(&tag.iter.to_le_bytes());
    out.extend_from_slice(&(tag.layer as u32).to_le_bytes());
    out.extend_from_slice(&(tag.a as u32).to_le_bytes());
    out.extend_from_slice(&(tag.b as u32).to_le_bytes());
    for x in data {
        out.extend_from_slice(&x.to_bits().to_le_bytes());
    }
}

/// Decode a frame body (everything after the length prefix). Errors are
/// context-free detail strings; the transport wraps them in
/// [`CommError::Codec`] with rank/peer context.
pub fn decode_frame(body: &[u8]) -> Result<(Tag, Vec<f32>), String> {
    if body.len() < HEADER_LEN {
        return Err(format!("truncated frame: {} bytes, header needs {HEADER_LEN}", body.len()));
    }
    if body.len() > MAX_FRAME_LEN {
        return Err(format!("frame of {} bytes exceeds cap {MAX_FRAME_LEN}", body.len()));
    }
    let version = body[0];
    if version != WIRE_VERSION {
        return Err(format!("unsupported wire version {version} (expected {WIRE_VERSION})"));
    }
    let kind = kind_from_code(body[1]).ok_or_else(|| format!("unknown msg kind {}", body[1]))?;
    let le_u32 = |b: &[u8]| u32::from_le_bytes(b.try_into().expect("4 bytes")) as usize;
    let iter = u64::from_le_bytes(body[2..10].try_into().expect("8 bytes"));
    let tag = Tag {
        iter,
        kind,
        layer: le_u32(&body[10..14]),
        a: le_u32(&body[14..18]),
        b: le_u32(&body[18..22]),
    };
    let payload = &body[HEADER_LEN..];
    if payload.len() % 4 != 0 {
        return Err(format!("payload of {} bytes is not a whole number of f32s", payload.len()));
    }
    let data = payload
        .chunks_exact(4)
        .map(|c| f32::from_bits(u32::from_le_bytes(c.try_into().expect("4 bytes"))))
        .collect();
    Ok((tag, data))
}

/// A stream of either flavor; everything above this enum is
/// address-family agnostic.
enum Conn {
    Unix(UnixStream),
    Tcp(TcpStream),
}

impl Conn {
    fn try_clone(&self) -> std::io::Result<Conn> {
        Ok(match self {
            Conn::Unix(s) => Conn::Unix(s.try_clone()?),
            Conn::Tcp(s) => Conn::Tcp(s.try_clone()?),
        })
    }

    fn set_read_timeout(&self, d: Option<Duration>) -> std::io::Result<()> {
        match self {
            Conn::Unix(s) => s.set_read_timeout(d),
            Conn::Tcp(s) => s.set_read_timeout(d),
        }
    }

    fn set_nonblocking(&self, nb: bool) -> std::io::Result<()> {
        match self {
            Conn::Unix(s) => s.set_nonblocking(nb),
            Conn::Tcp(s) => s.set_nonblocking(nb),
        }
    }
}

impl Read for Conn {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Conn::Unix(s) => s.read(buf),
            Conn::Tcp(s) => s.read(buf),
        }
    }
}

impl Write for Conn {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Conn::Unix(s) => s.write(buf),
            Conn::Tcp(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Conn::Unix(s) => s.flush(),
            Conn::Tcp(s) => s.flush(),
        }
    }
}

/// Frames decoded off one inbound link, or the error that ended it.
type InboundChannel = Receiver<Result<Envelope, CommError>>;

fn io_error(me: usize, peer: usize, op: &'static str, detail: String) -> CommError {
    CommError::Io { rank: me, peer, op, detail }
}

/// A bound listener of either flavor, plus its resolved address string.
pub struct Listener {
    inner: ListenerInner,
    addr: String,
}

enum ListenerInner {
    Unix(UnixListener),
    Tcp(TcpListener),
}

/// Normalize an endpoint address: `unix:/path`, `tcp:host:port`, or a
/// bare absolute path (treated as UDS).
fn split_addr(addr: &str) -> Result<(bool, &str), String> {
    if let Some(p) = addr.strip_prefix("unix:") {
        Ok((true, p))
    } else if let Some(hp) = addr.strip_prefix("tcp:") {
        Ok((false, hp))
    } else if addr.starts_with('/') {
        Ok((true, addr))
    } else if addr.contains(':') {
        Ok((false, addr))
    } else {
        Err(format!("unrecognized address `{addr}` (use unix:/path or tcp:host:port)"))
    }
}

/// Bind a listening endpoint for this rank. A stale UDS path from a
/// crashed earlier run is unlinked before binding.
pub fn bind(me: usize, addr: &str) -> Result<Listener, CommError> {
    let (is_unix, rest) =
        split_addr(addr).map_err(|detail| CommError::Protocol { rank: me, detail })?;
    let io = |e: std::io::Error| io_error(me, me, "bind", format!("{addr}: {e}"));
    if is_unix {
        let _ = std::fs::remove_file(rest);
        let l = UnixListener::bind(rest).map_err(io)?;
        Ok(Listener { inner: ListenerInner::Unix(l), addr: format!("unix:{rest}") })
    } else {
        let l = TcpListener::bind(rest).map_err(io)?;
        let resolved = l.local_addr().map_err(io)?;
        Ok(Listener { inner: ListenerInner::Tcp(l), addr: format!("tcp:{resolved}") })
    }
}

impl Listener {
    /// The resolved address (`tcp:` with the OS-assigned port filled in).
    pub fn addr(&self) -> &str {
        &self.addr
    }
}

fn connect(me: usize, peer: usize, addr: &str, deadline: Instant) -> Result<Conn, CommError> {
    let (is_unix, rest) =
        split_addr(addr).map_err(|detail| CommError::Protocol { rank: me, detail })?;
    loop {
        let attempt = if is_unix {
            UnixStream::connect(rest).map(Conn::Unix)
        } else {
            TcpStream::connect(rest).map(Conn::Tcp)
        };
        match attempt {
            Ok(c) => {
                if let Conn::Tcp(s) = &c {
                    let _ = s.set_nodelay(true);
                }
                return Ok(c);
            }
            // The peer's listener may not be up yet — retry until the deadline.
            Err(_) if Instant::now() < deadline => std::thread::sleep(Duration::from_millis(2)),
            Err(e) => return Err(io_error(me, peer, "connect", format!("{addr}: {e}"))),
        }
    }
}

/// Map a send/read I/O failure: connection teardown shapes become
/// [`CommError::PeerClosed`], everything else [`CommError::Io`].
fn map_io(me: usize, peer: usize, sending: bool, op: &'static str, e: std::io::Error) -> CommError {
    match e.kind() {
        ErrorKind::BrokenPipe
        | ErrorKind::ConnectionReset
        | ErrorKind::ConnectionAborted
        | ErrorKind::NotConnected => CommError::PeerClosed { rank: me, peer, sending, tag: None },
        _ => CommError::Io { rank: me, peer, op, detail: e.to_string() },
    }
}

/// Read frames off one inbound link until EOF or error, forwarding into
/// the per-source channel. A clean EOF just drops the sender (the
/// receive side then reports a closed link, mirroring in-proc); a codec
/// or I/O error is forwarded first so the receiver sees the cause.
fn reader_loop(mut conn: Conn, me: usize, src: usize, tx: Sender<Result<Envelope, CommError>>) {
    loop {
        let mut len_buf = [0u8; 4];
        match conn.read_exact(&mut len_buf) {
            Ok(()) => {}
            Err(e) if e.kind() == ErrorKind::UnexpectedEof => return, // clean close
            Err(e) => {
                let _ = tx.send(Err(map_io(me, src, false, "read", e)));
                return;
            }
        }
        let len = u32::from_le_bytes(len_buf) as usize;
        if !(HEADER_LEN..=MAX_FRAME_LEN).contains(&len) {
            let _ = tx.send(Err(CommError::Codec {
                rank: me,
                peer: src,
                detail: format!("bad frame length {len}"),
            }));
            return;
        }
        let mut body = vec![0u8; len];
        if let Err(e) = conn.read_exact(&mut body) {
            let err = if e.kind() == ErrorKind::UnexpectedEof {
                CommError::Codec { rank: me, peer: src, detail: "truncated frame".into() }
            } else {
                map_io(me, src, false, "read", e)
            };
            let _ = tx.send(Err(err));
            return;
        }
        match decode_frame(&body) {
            Ok((tag, data)) => {
                let env = Envelope { tag, data, ready_at: None, wire_us: 0 };
                if tx.send(Ok(env)).is_err() {
                    return; // endpoint dropped
                }
            }
            Err(detail) => {
                let _ = tx.send(Err(CommError::Codec { rank: me, peer: src, detail }));
                return;
            }
        }
    }
}

/// One rank's endpoint over the socket mesh.
pub struct SocketTransport {
    me: usize,
    n: usize,
    listen: String,
    /// Outbound stream per peer (`None` at `me`). `Mutex` because sends
    /// happen under shared borrows of the endpoint; uncontended in
    /// practice (one rank thread owns the endpoint).
    writers: Vec<Option<Mutex<Conn>>>,
    /// Per-source decoded-frame channels fed by the reader threads.
    rx: Vec<Option<InboundChannel>>,
    recv_timeout: Option<Duration>,
    /// Reused frame-serialization buffer (steady-state sends allocate
    /// nothing on the encode path).
    scratch: RefCell<Vec<u8>>,
}

impl SocketTransport {
    fn channel_for(&self, src: usize) -> Result<&InboundChannel, CommError> {
        self.rx.get(src).and_then(|r| r.as_ref()).ok_or_else(|| CommError::Protocol {
            rank: self.me,
            detail: format!("receive from invalid peer {src}"),
        })
    }

    /// The address this endpoint accepted peers on.
    pub fn listen_addr(&self) -> &str {
        &self.listen
    }
}

impl Transport for SocketTransport {
    fn me(&self) -> usize {
        self.me
    }

    fn num_ranks(&self) -> usize {
        self.n
    }

    fn send(&self, dst: usize, tag: Tag, data: Vec<f32>) -> Result<Option<Vec<f32>>, CommError> {
        let w = self.writers.get(dst).and_then(|w| w.as_ref()).ok_or_else(|| {
            CommError::Protocol { rank: self.me, detail: format!("send to invalid peer {dst}") }
        })?;
        let mut frame = self.scratch.borrow_mut();
        encode_frame(tag, &data, &mut frame);
        let mut conn = w.lock().expect("writer lock poisoned");
        conn.write_all(&frame)
            .and_then(|()| conn.flush())
            .map_err(|e| map_io(self.me, dst, true, "write", e).with_tag(tag))?;
        Ok(Some(data)) // serialized — the caller may recycle the buffer
    }

    fn recv_next(&mut self, src: usize) -> Result<Envelope, CommError> {
        let (me, timeout) = (self.me, self.recv_timeout);
        let ch = self.channel_for(src)?;
        match timeout {
            Some(d) => match ch.recv_timeout(d) {
                Ok(next) => next,
                Err(RecvTimeoutError::Timeout) => {
                    Err(CommError::Timeout { rank: me, peer: src, tag: None, after: d })
                }
                Err(RecvTimeoutError::Disconnected) => {
                    Err(CommError::PeerClosed { rank: me, peer: src, sending: false, tag: None })
                }
            },
            None => match ch.recv() {
                Ok(next) => next,
                Err(_) => {
                    Err(CommError::PeerClosed { rank: me, peer: src, sending: false, tag: None })
                }
            },
        }
    }

    fn try_recv_next(&mut self, src: usize) -> Result<Option<Envelope>, CommError> {
        let me = self.me;
        let ch = self.channel_for(src)?;
        match ch.try_recv() {
            Ok(next) => next.map(Some),
            Err(TryRecvError::Empty) => Ok(None),
            Err(TryRecvError::Disconnected) => {
                Err(CommError::PeerClosed { rank: me, peer: src, sending: false, tag: None })
            }
        }
    }

    fn barrier_wait(&self) -> bool {
        false // no native barrier — the communicator runs its message fallback
    }

    fn kind(&self) -> TransportKind {
        TransportKind::Socket
    }

    fn describe(&self) -> String {
        format!("socket rank {}/{} on {}", self.me, self.n, self.listen)
    }
}

/// Establish this rank's endpoint of the full mesh: dial every rank below
/// `me` (retrying until `connect_timeout` — peers may still be starting),
/// accept and handshake every rank above, then spawn one reader thread
/// per inbound link. `peer_addrs[r]` is rank `r`'s listen address;
/// `peer_addrs[me]` is ignored (the bound `listener` is used).
pub fn mesh_connect(
    me: usize,
    listener: Listener,
    peer_addrs: &[String],
    recv_timeout: Option<Duration>,
    connect_timeout: Duration,
) -> Result<SocketTransport, CommError> {
    let n = peer_addrs.len();
    assert!(n > 0, "communicator needs at least one rank");
    assert!(me < n, "rank {me} out of range for {n} ranks");
    let deadline = Instant::now() + connect_timeout;
    let mut conns: Vec<Option<Conn>> = (0..n).map(|_| None).collect();

    // Dial down: rank r initiates to every lower rank and identifies
    // itself. The 9-byte handshake rides the connect, so this never
    // waits on the acceptor's progress — no ordering deadlock.
    for (peer, addr) in peer_addrs.iter().enumerate().take(me) {
        let mut c = connect(me, peer, addr, deadline)?;
        let mut hello = [0u8; 9];
        hello[..4].copy_from_slice(&MAGIC);
        hello[4] = WIRE_VERSION;
        hello[5..9].copy_from_slice(&(me as u32).to_le_bytes());
        c.write_all(&hello)
            .and_then(|()| c.flush())
            .map_err(|e| map_io(me, peer, true, "handshake", e))?;
        conns[peer] = Some(c);
    }

    // Accept up: every higher rank dials us; the handshake says which.
    let listen_addr = listener.addr.clone();
    match &listener.inner {
        ListenerInner::Unix(l) => l.set_nonblocking(true),
        ListenerInner::Tcp(l) => l.set_nonblocking(true),
    }
    .map_err(|e| io_error(me, me, "listen", e.to_string()))?;
    let mut pending = n - me - 1;
    while pending > 0 {
        let accepted = match &listener.inner {
            ListenerInner::Unix(l) => l.accept().map(|(s, _)| Conn::Unix(s)),
            ListenerInner::Tcp(l) => l.accept().map(|(s, _)| {
                let _ = s.set_nodelay(true);
                Conn::Tcp(s)
            }),
        };
        let mut c = match accepted {
            Ok(c) => c,
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                if Instant::now() > deadline {
                    return Err(CommError::Protocol {
                        rank: me,
                        detail: format!(
                            "timed out on {listen_addr} with {pending} peer connection(s) missing"
                        ),
                    });
                }
                std::thread::sleep(Duration::from_millis(1));
                continue;
            }
            Err(e) => return Err(io_error(me, me, "accept", e.to_string())),
        };
        c.set_nonblocking(false).map_err(|e| io_error(me, me, "accept", e.to_string()))?;
        c.set_read_timeout(Some(connect_timeout))
            .map_err(|e| io_error(me, me, "accept", e.to_string()))?;
        let mut hello = [0u8; 9];
        c.read_exact(&mut hello).map_err(|e| map_io(me, me, false, "handshake", e))?;
        if hello[..4] != MAGIC {
            return Err(CommError::Protocol { rank: me, detail: "bad handshake magic".into() });
        }
        if hello[4] != WIRE_VERSION {
            return Err(CommError::Protocol {
                rank: me,
                detail: format!("peer speaks wire version {}, we speak {WIRE_VERSION}", hello[4]),
            });
        }
        let peer = u32::from_le_bytes(hello[5..9].try_into().expect("4 bytes")) as usize;
        if peer <= me || peer >= n {
            return Err(CommError::Protocol {
                rank: me,
                detail: format!("unexpected handshake from rank {peer}"),
            });
        }
        if conns[peer].is_some() {
            return Err(CommError::Protocol {
                rank: me,
                detail: format!("duplicate connection from rank {peer}"),
            });
        }
        c.set_read_timeout(None).map_err(|e| io_error(me, peer, "accept", e.to_string()))?;
        conns[peer] = Some(c);
        pending -= 1;
    }

    // Split each stream: the writer half stays on the endpoint, the
    // reader half feeds a per-source channel from its own thread.
    let mut writers: Vec<Option<Mutex<Conn>>> = Vec::with_capacity(n);
    let mut rx: Vec<Option<InboundChannel>> = Vec::with_capacity(n);
    for (peer, slot) in conns.into_iter().enumerate() {
        match slot {
            Some(conn) => {
                let reader =
                    conn.try_clone().map_err(|e| io_error(me, peer, "clone", e.to_string()))?;
                let (tx, r) = channel();
                std::thread::Builder::new()
                    .name(format!("hecate-rx-{me}-from-{peer}"))
                    .spawn(move || reader_loop(reader, me, peer, tx))
                    .map_err(|e| io_error(me, peer, "spawn", e.to_string()))?;
                writers.push(Some(Mutex::new(conn)));
                rx.push(Some(r));
            }
            None => {
                writers.push(None);
                rx.push(None);
            }
        }
    }
    Ok(SocketTransport {
        me,
        n,
        listen: listen_addr,
        writers,
        rx,
        recv_timeout: recv_timeout.or(Some(DEFAULT_RECV_TIMEOUT)),
        scratch: RefCell::new(Vec::new()),
    })
}

static FABRIC_SEQ: AtomicU64 = AtomicU64::new(0);

/// Build a full n-rank socket fabric *inside this process* over UDS in a
/// private temp directory: bind all listeners up front (no dial race),
/// run the n mesh handshakes on scoped threads, and wrap each endpoint
/// in a [`RankComm`]. This is how `--transport socket` runs under the
/// library API (rank threads, real sockets) and how the `in-proc ≡
/// socket` equivalence lock gets a socket fabric without spawning
/// processes. Socket files are unlinked once the mesh is up.
pub fn local_fabric(n: usize, recv_timeout: Option<Duration>) -> Result<Vec<RankComm>, CommError> {
    assert!(n > 0, "communicator needs at least one rank");
    let seq = FABRIC_SEQ.fetch_add(1, Ordering::Relaxed);
    let dir = std::env::temp_dir().join(format!("hecate-fab-{}-{seq}", std::process::id()));
    std::fs::create_dir_all(&dir)
        .map_err(|e| io_error(0, 0, "bind", format!("{}: {e}", dir.display())))?;
    let paths: Vec<String> =
        (0..n).map(|r| format!("unix:{}", dir.join(format!("sock-{r}")).display())).collect();
    let mut listeners = Vec::with_capacity(n);
    for (r, p) in paths.iter().enumerate() {
        listeners.push(bind(r, p)?);
    }
    let mut endpoints: Vec<Result<SocketTransport, CommError>> = Vec::with_capacity(n);
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(n);
        for (me, listener) in listeners.into_iter().enumerate() {
            let paths = &paths;
            handles.push(scope.spawn(move || {
                mesh_connect(me, listener, paths, recv_timeout, DEFAULT_CONNECT_TIMEOUT)
            }));
        }
        for h in handles {
            endpoints.push(h.join().expect("mesh thread panicked"));
        }
    });
    let _ = std::fs::remove_dir_all(&dir);
    let mut out = Vec::with_capacity(n);
    for t in endpoints {
        out.push(RankComm::endpoint(Box::new(t?)));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tag(iter: u64, a: usize) -> Tag {
        Tag { iter, kind: MsgKind::Ctrl, layer: 0, a, b: 0 }
    }

    #[test]
    fn frame_round_trips_payload_bits() {
        let t = Tag { iter: 7, kind: MsgKind::SpagChunk, layer: 2, a: 5, b: 1 };
        let data = [1.5f32, -0.0, f32::NAN, f32::MIN_POSITIVE, 3.25e-12];
        let mut frame = Vec::new();
        encode_frame(t, &data, &mut frame);
        let len = u32::from_le_bytes(frame[..4].try_into().unwrap()) as usize;
        assert_eq!(len, frame.len() - 4);
        let (tag, out) = decode_frame(&frame[4..]).unwrap();
        assert_eq!(tag, t);
        let bits: Vec<u32> = out.iter().map(|x| x.to_bits()).collect();
        let want: Vec<u32> = data.iter().map(|x| x.to_bits()).collect();
        assert_eq!(bits, want, "payload must survive bit-exactly (incl. NaN, -0.0)");
    }

    #[test]
    fn empty_payload_frame_round_trips() {
        let t = Tag { iter: 0, kind: MsgKind::Barrier, layer: 0, a: 3, b: 0 };
        let mut frame = Vec::new();
        encode_frame(t, &[], &mut frame);
        assert_eq!(frame.len(), 4 + HEADER_LEN);
        let (tag, out) = decode_frame(&frame[4..]).unwrap();
        assert_eq!((tag, out.len()), (t, 0));
    }

    #[test]
    fn max_size_chunk_round_trips() {
        // A full-size expert chunk at the repo's reference dims is tiny;
        // stress the codec with a 1 MiB payload instead.
        let data: Vec<f32> = (0..262_144).map(|i| i as f32 * 0.5).collect();
        let mut frame = Vec::new();
        encode_frame(tag(1, 0), &data, &mut frame);
        let (_, out) = decode_frame(&frame[4..]).unwrap();
        assert_eq!(out, data);
    }

    #[test]
    fn garbage_and_truncated_frames_are_rejected() {
        // too short for the header
        assert!(decode_frame(&[0u8; 5]).unwrap_err().contains("truncated"));
        // wrong version
        let mut frame = Vec::new();
        encode_frame(tag(0, 0), &[1.0], &mut frame);
        let mut bad = frame[4..].to_vec();
        bad[0] = 9;
        assert!(decode_frame(&bad).unwrap_err().contains("version"));
        // unknown kind
        let mut bad = frame[4..].to_vec();
        bad[1] = 200;
        assert!(decode_frame(&bad).unwrap_err().contains("kind"));
        // payload not a multiple of 4 bytes
        let mut bad = frame[4..].to_vec();
        bad.push(0xAB);
        assert!(decode_frame(&bad).unwrap_err().contains("whole number"));
    }

    #[test]
    fn addresses_parse_and_reject() {
        assert_eq!(split_addr("unix:/tmp/x.sock").unwrap(), (true, "/tmp/x.sock"));
        assert_eq!(split_addr("/tmp/x.sock").unwrap(), (true, "/tmp/x.sock"));
        assert_eq!(split_addr("tcp:127.0.0.1:0").unwrap(), (false, "127.0.0.1:0"));
        assert_eq!(split_addr("127.0.0.1:4000").unwrap(), (false, "127.0.0.1:4000"));
        assert!(split_addr("carrier pigeon").is_err());
    }

    #[test]
    #[cfg_attr(miri, ignore = "Miri cannot emulate socket syscalls")]
    fn two_rank_mesh_moves_tagged_payloads_both_ways() {
        let mut comms = local_fabric(2, None).unwrap();
        let mut c1 = comms.remove(1);
        let mut c0 = comms.remove(0);
        let h = std::thread::spawn(move || {
            c0.isend(1, tag(0, 1), vec![1.0, 2.0]).unwrap();
            let back = c0.recv(1, tag(0, 2)).unwrap();
            (c0, back)
        });
        assert_eq!(c1.recv(0, tag(0, 1)).unwrap(), vec![1.0, 2.0]);
        c1.isend(0, tag(0, 2), vec![3.0]).unwrap();
        let (_c0, back) = h.join().unwrap();
        assert_eq!(back, vec![3.0]);
    }

    #[test]
    #[cfg_attr(miri, ignore = "Miri cannot emulate socket syscalls")]
    fn recv_timeout_fires_instead_of_hanging() {
        let mut comms = local_fabric(2, Some(Duration::from_millis(50))).unwrap();
        let mut c1 = comms.remove(1);
        let _c0 = comms.remove(0); // alive but silent
        let err = c1.recv(0, tag(0, 0)).unwrap_err();
        assert!(err.to_string().contains("timed out"), "{err}");
    }

    #[test]
    #[cfg_attr(miri, ignore = "Miri cannot emulate socket syscalls")]
    fn dropped_peer_process_surfaces_as_closed_link() {
        let mut comms = local_fabric(2, None).unwrap();
        let mut c1 = comms.remove(1);
        drop(comms.remove(0)); // rank 0 "process" exits
        let err = c1.recv(0, tag(0, 0)).unwrap_err();
        assert!(err.to_string().contains("closed"), "{err}");
    }

    #[test]
    #[cfg_attr(miri, ignore = "Miri cannot emulate socket syscalls")]
    fn socket_fifo_order_holds_under_interleaved_tags() {
        // Same FIFO contract the static schedule verifier relies on, but
        // over a real stream socket: the per-link reader thread must hand
        // frames to the mailbox in wire order even when tags interleave.
        // Build raw endpoints (no RankComm) so delivery order is visible.
        let dir = std::env::temp_dir().join(format!("hecate-fifo-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let paths: Vec<String> =
            (0..2).map(|r| format!("unix:{}", dir.join(format!("sock-{r}")).display())).collect();
        let listeners: Vec<_> =
            paths.iter().enumerate().map(|(r, p)| bind(r, p).unwrap()).collect();
        let mut endpoints: Vec<SocketTransport> = Vec::new();
        std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for (me, listener) in listeners.into_iter().enumerate() {
                let paths = &paths;
                handles.push(scope.spawn(move || {
                    mesh_connect(me, listener, paths, None, DEFAULT_CONNECT_TIMEOUT).unwrap()
                }));
            }
            for h in handles {
                endpoints.push(h.join().unwrap());
            }
        });
        let _ = std::fs::remove_dir_all(&dir);
        let mut t1 = endpoints.remove(1);
        let t0 = endpoints.remove(0);
        let order = [3usize, 0, 2, 0, 3, 1];
        for (i, &a) in order.iter().enumerate() {
            t0.send(1, tag(5, a), vec![i as f32]).unwrap();
        }
        for (i, &a) in order.iter().enumerate() {
            let env = t1.recv_next(0).unwrap();
            assert_eq!(env.tag, tag(5, a), "frame {i} out of order");
            assert_eq!(env.data, vec![i as f32]);
        }
        assert!(t1.try_recv_next(0).unwrap().is_none());
    }
}
