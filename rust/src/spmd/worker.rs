//! `hecate worker` — one SPMD rank as its own OS process — plus the
//! coordinator-side launcher behind `hecate fssdp --parallel --transport
//! socket`.
//!
//! The worker is the same rank program the in-process executor runs on a
//! thread ([`super::rank_main`]), built from the same deterministic recipe:
//! it reconstructs the full engine from `(devices, nodes, racks, layers,
//! seed)` through the shared [`SessionConfig`] validation path, slices out
//! its own rank's state with [`super::split_rank_state`], joins the socket
//! mesh, and runs the span. Because every rank derives the identical
//! replicated control plane, no coordinator→worker state shipping is
//! needed — the CLI flags *are* the state.
//!
//! At span end each worker serializes its result (per-iteration losses,
//! rank-0 global stats, its owned expert chunks) into a little-endian
//! state blob (`HWKR` magic, versioned) that the launcher merges exactly
//! like [`super::run_span`] merges `RankOut`s. `--verify-inproc` then
//! reruns the span on the in-process transport and asserts the final
//! parameters are bit-identical — the cross-process determinism lock.

use std::collections::BTreeMap;
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::process::{Command, Stdio};
use std::time::Instant;

use crate::fssdp::{Executor, FssdpEngine, SessionConfig};
use crate::materialize::MatConstraints;
use crate::placement::Placement;
use crate::util::cli::Args;

use super::comm::RankComm;
use super::transport::socket::{self, DEFAULT_CONNECT_TIMEOUT};
use super::transport::{Transport as _, TransportKind};
use super::{GlobalStats, RankCtx};

/// Magic of the worker state blob.
pub const STATE_MAGIC: [u8; 4] = *b"HWKR";
/// Version byte of the worker state blob.
pub const STATE_VERSION: u8 = 1;

/// One worker's span result, as carried by the state blob.
struct WorkerState {
    rank: usize,
    world: usize,
    /// This rank's per-iteration partial loss.
    losses: Vec<f64>,
    /// Rank 0 only; empty elsewhere.
    global: Vec<GlobalStats>,
    /// Per layer: expert id -> final chunk (owned shards only).
    layers: Vec<BTreeMap<usize, Vec<f32>>>,
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn encode_state(ws: &WorkerState) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(&STATE_MAGIC);
    out.push(STATE_VERSION);
    put_u32(&mut out, ws.rank as u32);
    put_u32(&mut out, ws.world as u32);
    put_u32(&mut out, ws.layers.len() as u32);
    put_u32(&mut out, ws.losses.len() as u32);
    for l in &ws.losses {
        put_f64(&mut out, *l);
    }
    out.push(if ws.global.is_empty() { 0 } else { 1 });
    if !ws.global.is_empty() {
        debug_assert_eq!(ws.global.len(), ws.losses.len());
        for g in &ws.global {
            put_f64(&mut out, g.sparsity);
            put_u64(&mut out, g.replicas as u64);
            put_u64(&mut out, g.remote_tokens as u64);
            put_f64(&mut out, g.straggler);
        }
    }
    for layer in &ws.layers {
        put_u32(&mut out, layer.len() as u32);
        for (e, data) in layer {
            put_u32(&mut out, *e as u32);
            put_u32(&mut out, data.len() as u32);
            for x in data {
                put_u32(&mut out, x.to_bits());
            }
        }
    }
    out
}

/// Bounds-checked little-endian reader over a state blob.
struct Cur<'a> {
    buf: &'a [u8],
    off: usize,
}

impl<'a> Cur<'a> {
    fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        anyhow::ensure!(
            self.off + n <= self.buf.len(),
            "truncated worker state blob at byte {} (wanted {n} more of {})",
            self.off,
            self.buf.len()
        );
        let s = &self.buf[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    fn u8(&mut self) -> anyhow::Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> anyhow::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }

    fn u64(&mut self) -> anyhow::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }

    fn f64(&mut self) -> anyhow::Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }
}

fn decode_state(buf: &[u8]) -> anyhow::Result<WorkerState> {
    let mut c = Cur { buf, off: 0 };
    anyhow::ensure!(c.take(4)? == STATE_MAGIC, "not a worker state blob (bad magic)");
    let version = c.u8()?;
    anyhow::ensure!(
        version == STATE_VERSION,
        "worker state blob version {version}, this build speaks {STATE_VERSION}"
    );
    let rank = c.u32()? as usize;
    let world = c.u32()? as usize;
    let nl = c.u32()? as usize;
    let iters = c.u32()? as usize;
    let mut losses = Vec::with_capacity(iters);
    for _ in 0..iters {
        losses.push(c.f64()?);
    }
    let mut global = Vec::new();
    if c.u8()? != 0 {
        for _ in 0..iters {
            let sparsity = c.f64()?;
            let replicas = c.u64()? as usize;
            let remote_tokens = c.u64()? as usize;
            let straggler = c.f64()?;
            global.push(GlobalStats { sparsity, replicas, remote_tokens, straggler });
        }
    }
    let mut layers = Vec::with_capacity(nl);
    for _ in 0..nl {
        let nchunks = c.u32()? as usize;
        let mut layer = BTreeMap::new();
        for _ in 0..nchunks {
            let e = c.u32()? as usize;
            let len = c.u32()? as usize;
            let mut data = Vec::with_capacity(len);
            for _ in 0..len {
                data.push(f32::from_bits(c.u32()?));
            }
            anyhow::ensure!(layer.insert(e, data).is_none(), "duplicate chunk {e} in blob");
        }
        layers.push(layer);
    }
    anyhow::ensure!(
        c.off == buf.len(),
        "{} trailing bytes in worker state blob",
        buf.len() - c.off
    );
    Ok(WorkerState { rank, world, losses, global, layers })
}

/// Build the validated session config a worker/launcher pair shares: both
/// sides call this with the same flag values, so the resolved topology,
/// dims, and seed are identical by construction.
fn worker_config(args: &Args) -> anyhow::Result<SessionConfig> {
    let mut b = SessionConfig::builder()
        .reference()
        .cluster(args.usize_or("nodes", 2)?, args.usize_or("devices", 8)?)
        .seed(args.usize_or("seed", 42)? as u64)
        .parallel(true)
        .overlap(args.bool_or("overlap", true)?)
        .transport(TransportKind::Socket);
    if args.has("racks") {
        b = b.racks(args.usize_or("racks", 1)?);
    }
    if args.has("layers") {
        b = b.layers(args.usize_or("layers", 1)?);
    }
    if args.has("data-shards") {
        b = b.data_shards(args.usize_or("data-shards", 1)?);
    }
    if let Some(t) = args.str_opt("recv-timeout")? {
        b = b.recv_timeout(crate::fssdp::parse_recv_timeout(&t)?);
    }
    if let Some(m) = args.str_opt("compute-mode")? {
        b = b.compute_mode(crate::fssdp::parse_compute_mode(&m)?);
    }
    b = b.compute_threads(args.usize_or("compute-threads", 1)?);
    Ok(b.build()?)
}

/// `hecate worker`: run one rank of a socket-transport span and write the
/// state blob to `--out`. Spawned by the launcher; runnable by hand for
/// debugging (all ranks must agree on every engine flag).
pub(crate) fn cmd_worker(args: &Args) -> anyhow::Result<()> {
    args.reject_unknown(&[
        "rank", "world", "listen", "peers", "devices", "nodes", "racks", "layers", "seed",
        "data-shards", "iters", "overlap", "recv-timeout", "out", "compute-mode",
        "compute-threads",
    ])?;
    let rank: usize = args.req("rank")?.parse()?;
    let world: usize = args.req("world")?.parse()?;
    let listen = args.req("listen")?;
    let peers: Vec<String> = args.req("peers")?.split(',').map(|s| s.to_string()).collect();
    let out_path = PathBuf::from(args.req("out")?);
    let iters = args.usize_or("iters", 10)?;
    let cfg = worker_config(args)?;

    let nd = cfg.topology().num_devices();
    anyhow::ensure!(world == nd, "--world {world} must equal the device count {nd}");
    anyhow::ensure!(rank < world, "--rank {rank} out of range for --world {world}");
    anyhow::ensure!(
        peers.len() == world,
        "--peers lists {} addresses, world is {world}",
        peers.len()
    );

    // The same deterministic engine every peer builds (and the launcher's
    // --verify-inproc rebuilds): replicated control plane from flags alone.
    let layers = cfg.layers.unwrap_or(1);
    let engine =
        FssdpEngine::new_reference_layers(cfg.dims, layers, cfg.topology().clone(), cfg.seed);
    let sources = cfg.data_shards.unwrap_or(nd);
    let rank_layers = super::split_rank_state(&engine, rank)?;
    let shards_v: Vec<Placement> = engine.layers.iter().map(|ls| ls.shards.clone()).collect();
    let gate_w_v: Vec<Vec<f32>> = engine.layers.iter().map(|ls| ls.gate_w.clone()).collect();
    let cons =
        MatConstraints { overlap_degree: engine.overlap_degree, mem_slots: engine.mem_slots };
    let overlap = matches!(cfg.executor(), Executor::Spmd { overlap: true, .. });

    let listener = socket::bind(rank, &listen)?;
    eprintln!("worker {rank}/{world}: listening on {}", listener.addr());
    let transport =
        socket::mesh_connect(rank, listener, &peers, cfg.recv_timeout, DEFAULT_CONNECT_TIMEOUT)?;
    eprintln!("worker {rank}/{world}: mesh up ({})", transport.describe());

    let topo = engine.topo.clone();
    let ctx = RankCtx {
        me: rank,
        nd,
        sources,
        start: 0,
        iters,
        dims: engine.dims,
        topo: &topo,
        shards: &shards_v,
        gate_w: &gate_w_v,
        adam: engine.adam,
        cons,
        overlap,
        kernel_mode: cfg.compute_mode(),
        kthreads: cfg.compute_threads.max(1),
        layers: rank_layers,
        comm: RankComm::endpoint(Box::new(transport)),
        meter_epoch: None,
    };
    let out = super::rank_main(ctx)?;

    let mut layer_chunks = Vec::with_capacity(out.layers.len());
    for rls in &out.layers {
        let mut ids: Vec<usize> = rls.store.chunks().collect();
        ids.sort_unstable();
        let mut layer = BTreeMap::new();
        for e in ids {
            layer.insert(e, rls.store.get(e).expect("listed above").to_vec());
        }
        layer_chunks.push(layer);
    }
    let ws = WorkerState {
        rank,
        world,
        losses: out.loss,
        global: out.global,
        layers: layer_chunks,
    };
    std::fs::write(&out_path, encode_state(&ws))
        .map_err(|e| anyhow::anyhow!("worker {rank}: writing {}: {e}", out_path.display()))?;
    eprintln!("worker {rank}/{world}: span complete ({iters} iters) -> {}", out_path.display());
    let _ = std::io::stderr().flush();
    Ok(())
}

/// Tail of a worker's log file, for failure reports.
fn log_tail(path: &Path, lines: usize) -> String {
    match std::fs::read_to_string(path) {
        Err(_) => String::from("(no log)"),
        Ok(text) => {
            let all: Vec<&str> = text.lines().collect();
            let start = all.len().saturating_sub(lines);
            all[start..].join("\n      ")
        }
    }
}

/// `hecate fssdp --parallel --transport socket`: spawn one `hecate worker`
/// process per rank on a localhost UDS mesh, wait, merge the state blobs,
/// and print the run exactly like the in-process path. With
/// `verify_inproc`, rerun on the in-process transport and assert the final
/// parameters are bit-identical.
pub(crate) fn launch_local(
    cfg: &SessionConfig,
    iters: usize,
    verify_inproc: bool,
    worker_dir: Option<String>,
) -> anyhow::Result<()> {
    let nd = cfg.topology().num_devices();
    let Executor::Spmd { overlap, .. } = cfg.executor() else {
        anyhow::bail!("the socket launcher requires the SPMD executor (--parallel)");
    };
    let layers = cfg.layers.unwrap_or(1);
    let sources = cfg.data_shards.unwrap_or(nd);
    let dir = match worker_dir {
        Some(d) => PathBuf::from(d),
        None => std::env::temp_dir().join(format!("hecate-launch-{}", std::process::id())),
    };
    std::fs::create_dir_all(&dir)
        .map_err(|e| anyhow::anyhow!("creating worker dir {}: {e}", dir.display()))?;
    let addrs: Vec<String> =
        (0..nd).map(|r| format!("unix:{}", dir.join(format!("sock-{r}")).display())).collect();
    let peers = addrs.join(",");
    let exe = std::env::current_exe()
        .map_err(|e| anyhow::anyhow!("resolving the hecate binary for workers: {e}"))?;

    println!(
        "FSSDP numeric engine on {} ({} devices, {} worker processes over unix sockets)",
        cfg.topology().name,
        nd,
        nd
    );
    let t0 = Instant::now();
    let mut children = Vec::with_capacity(nd);
    for (r, addr) in addrs.iter().enumerate() {
        let log = std::fs::File::create(dir.join(format!("worker-{r}.log")))
            .map_err(|e| anyhow::anyhow!("creating worker-{r}.log: {e}"))?;
        let log_err = log.try_clone().map_err(|e| anyhow::anyhow!("cloning log handle: {e}"))?;
        let mut cmd = Command::new(&exe);
        cmd.arg("worker")
            .arg("--rank")
            .arg(r.to_string())
            .arg("--world")
            .arg(nd.to_string())
            .arg("--listen")
            .arg(addr)
            .arg("--peers")
            .arg(&peers)
            .arg("--devices")
            .arg(nd.to_string())
            .arg("--nodes")
            .arg(cfg.topology().nodes.to_string())
            .arg("--racks")
            .arg(cfg.topology().racks.to_string())
            .arg("--layers")
            .arg(layers.to_string())
            .arg("--seed")
            .arg(cfg.seed.to_string())
            .arg("--data-shards")
            .arg(sources.to_string())
            .arg("--iters")
            .arg(iters.to_string())
            .arg("--overlap")
            .arg(if overlap { "true" } else { "false" })
            .arg("--compute-mode")
            .arg(cfg.compute_mode().as_str())
            .arg("--compute-threads")
            .arg(cfg.compute_threads.to_string())
            .arg("--out")
            .arg(dir.join(format!("state-{r}.bin")))
            .stdin(Stdio::null())
            .stdout(Stdio::from(log))
            .stderr(Stdio::from(log_err));
        if let Some(t) = cfg.recv_timeout {
            cmd.arg("--recv-timeout").arg(format!("{}", t.as_secs_f64()));
        }
        let child = cmd
            .spawn()
            .map_err(|e| anyhow::anyhow!("spawning worker {r} ({}): {e}", exe.display()))?;
        children.push(child);
    }

    let mut failed: Vec<(usize, String)> = Vec::new();
    for (r, child) in children.iter_mut().enumerate() {
        let status = child
            .wait()
            .map_err(|e| anyhow::anyhow!("waiting for worker {r}: {e}"))?;
        if !status.success() {
            let code = match status.code() {
                Some(c) => c.to_string(),
                None => "a signal".to_string(),
            };
            failed.push((r, code));
        }
    }
    if !failed.is_empty() {
        let (r, code) = &failed[0];
        anyhow::bail!(
            "{} of {nd} worker processes failed; worker {r} exited with {code}, log tail \
             ({}):\n      {}",
            failed.len(),
            dir.join(format!("worker-{r}.log")).display(),
            log_tail(&dir.join(format!("worker-{r}.log")), 12)
        );
    }
    let wall = t0.elapsed();

    // Merge the blobs exactly like run_span merges RankOuts: losses summed
    // in rank order, global stats from rank 0, chunks onto their owners.
    let mut losses = vec![0.0f64; iters];
    let mut global: Vec<GlobalStats> = Vec::new();
    let mut merged: Vec<BTreeMap<usize, Vec<f32>>> = vec![BTreeMap::new(); layers];
    for r in 0..nd {
        let path = dir.join(format!("state-{r}.bin"));
        let bytes = std::fs::read(&path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        let ws = decode_state(&bytes)?;
        anyhow::ensure!(ws.rank == r && ws.world == nd, "state blob {r} is from another run");
        anyhow::ensure!(ws.losses.len() == iters, "worker {r} ran {} iters", ws.losses.len());
        anyhow::ensure!(ws.layers.len() == layers, "worker {r} has {} layers", ws.layers.len());
        for (i, l) in ws.losses.iter().enumerate() {
            losses[i] += *l;
        }
        if r == 0 {
            global = ws.global;
        }
        for (l, layer) in ws.layers.into_iter().enumerate() {
            for (e, data) in layer {
                anyhow::ensure!(
                    merged[l].insert(e, data).is_none(),
                    "expert {e} of layer {l} came back from two workers"
                );
            }
        }
    }
    for (i, loss) in losses.iter().enumerate() {
        match global.get(i) {
            Some(g) => println!(
                "iter {i:>3}  loss {loss:.5}  λ={:.2}  replicas {}  remote_tokens {}  straggler {:.2}",
                g.sparsity, g.replicas, g.remote_tokens, g.straggler
            ),
            None => println!("iter {i:>3}  loss {loss:.5}"),
        }
    }
    println!(
        "workers: {nd} processes, {iters} iters in {:.2}s — logs and state under {}",
        wall.as_secs_f64(),
        dir.display()
    );

    if verify_inproc {
        let mut engine =
            FssdpEngine::new_reference_layers(cfg.dims, layers, cfg.topology().clone(), cfg.seed);
        engine.executor = Executor::Spmd { threads: nd, overlap };
        engine.set_compute_mode(cfg.compute_mode());
        engine.compute_threads = cfg.compute_threads;
        engine.run_span(0, iters, sources)?;
        let want = crate::testing::all_chunks(&engine);
        let experts = engine.dims.experts;
        anyhow::ensure!(
            want.len() == layers * experts,
            "in-proc rerun produced {} chunks, expected {}",
            want.len(),
            layers * experts
        );
        for l in 0..layers {
            for e in 0..experts {
                let got = merged[l].get(&e).ok_or_else(|| {
                    anyhow::anyhow!("socket run lost expert {e} of layer {l}")
                })?;
                anyhow::ensure!(
                    got == &want[l * experts + e],
                    "socket and in-proc parameters diverged at layer {l}, expert {e}"
                );
            }
        }
        println!(
            "verify: socket run is bit-identical to the in-process executor \
             ({} chunks compared)",
            want.len()
        );
    }
    println!("done — parameters live on their shard owners (one global copy).");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_state(with_global: bool) -> WorkerState {
        let global = if with_global {
            vec![
                GlobalStats { sparsity: 0.25, replicas: 3, remote_tokens: 17, straggler: 1.5 },
                GlobalStats { sparsity: 0.5, replicas: 0, remote_tokens: 0, straggler: 1.0 },
            ]
        } else {
            Vec::new()
        };
        let mut l0 = BTreeMap::new();
        l0.insert(2usize, vec![1.0f32, f32::NAN, -0.0, f32::MIN_POSITIVE]);
        let mut l1 = BTreeMap::new();
        l1.insert(0usize, Vec::new());
        l1.insert(7usize, vec![-3.5]);
        WorkerState {
            rank: 1,
            world: 4,
            losses: vec![2.5, -0.125],
            global,
            layers: vec![l0, l1],
        }
    }

    #[test]
    fn state_blob_round_trips_bit_exactly() {
        for with_global in [false, true] {
            let ws = sample_state(with_global);
            let back = decode_state(&encode_state(&ws)).unwrap();
            assert_eq!(back.rank, 1);
            assert_eq!(back.world, 4);
            assert_eq!(back.losses, ws.losses);
            assert_eq!(back.global.len(), ws.global.len());
            for (a, b) in back.global.iter().zip(ws.global.iter()) {
                assert_eq!(a.sparsity, b.sparsity);
                assert_eq!(a.replicas, b.replicas);
                assert_eq!(a.remote_tokens, b.remote_tokens);
                assert_eq!(a.straggler, b.straggler);
            }
            assert_eq!(back.layers.len(), 2);
            // NaN payloads survive (bit compare, not float compare)
            let got = &back.layers[0][&2];
            let want = &ws.layers[0][&2];
            assert_eq!(got.len(), want.len());
            for (g, w) in got.iter().zip(want.iter()) {
                assert_eq!(g.to_bits(), w.to_bits());
            }
            assert_eq!(back.layers[1], ws.layers[1]);
        }
    }

    #[test]
    fn garbage_and_truncated_blobs_are_rejected() {
        let good = encode_state(&sample_state(true));
        assert!(decode_state(&[]).is_err(), "empty blob");
        let mut bad_magic = good.clone();
        bad_magic[0] = b'X';
        let err = decode_state(&bad_magic).unwrap_err().to_string();
        assert!(err.contains("bad magic"), "{err}");
        let mut bad_version = good.clone();
        bad_version[4] = 99;
        let err = decode_state(&bad_version).unwrap_err().to_string();
        assert!(err.contains("version 99"), "{err}");
        for cut in [5, 12, good.len() / 2, good.len() - 1] {
            assert!(decode_state(&good[..cut]).is_err(), "cut at {cut} must fail");
        }
        let mut trailing = good.clone();
        trailing.push(0);
        let err = decode_state(&trailing).unwrap_err().to_string();
        assert!(err.contains("trailing"), "{err}");
    }

    #[test]
    fn worker_flags_validate_before_any_socket_work() {
        let args = |s: &str| Args::parse(s.split_whitespace().map(|t| t.to_string()));
        // rank out of range
        let err = cmd_worker(&args(
            "--rank 9 --world 4 --listen unix:/tmp/x --peers a,b,c,d --devices 4 --out /tmp/o",
        ))
        .unwrap_err()
        .to_string();
        assert!(err.contains("--rank 9 out of range"), "{err}");
        // world disagrees with the topology
        let err = cmd_worker(&args(
            "--rank 0 --world 3 --listen unix:/tmp/x --peers a,b,c --devices 4 --out /tmp/o",
        ))
        .unwrap_err()
        .to_string();
        assert!(err.contains("must equal the device count"), "{err}");
        // peer list length mismatch
        let err = cmd_worker(&args(
            "--rank 0 --world 4 --listen unix:/tmp/x --peers a,b --devices 4 --out /tmp/o",
        ))
        .unwrap_err()
        .to_string();
        assert!(err.contains("--peers lists 2 addresses"), "{err}");
        // unknown flags are rejected like every other subcommand
        let err = cmd_worker(&args("--rank 0 --bogus 1")).unwrap_err().to_string();
        assert!(err.contains("unknown option --bogus"), "{err}");
    }
}
