//! Per-rank execution of the sparse collectives over the communicator.
//!
//! [`crate::collectives::exec`] applies a [`SparsePlan`] to all device
//! memories in one sequential loop; this module is the SPMD port: every
//! rank walks the *same* plan but only acts on transfers it sources
//! (isend) or sinks (receive + insert/accumulate), staged exactly as the
//! plan's `stage` field dictates. Every tag carries the MoE layer, so a
//! multi-layer iteration's collectives (and run-ahead into the next
//! iteration) never cross-match.
//!
//! Determinism contract (bit-exactness vs the sequential executor):
//!
//! * **spAG** only copies buffers — any completion order is bit-identical.
//! * **spRS** accumulates. The sequential executor applies a stage's
//!   transfers in plan order; [`RankSprs`] therefore completes a rank's
//!   incoming reduces of each stage *in plan order*, which is the same
//!   per-buffer floating-point order (transfers into one buffer are
//!   totally ordered by (stage, plan index) in both executors). Splitting
//!   [`RankSprs::begin`] (stage-0 sends) from [`RankSprs::finish`]
//!   (everything else) moves no receive and reorders no accumulation — it
//!   only lets the sends' flight time overlap the next layer's backward
//!   compute (§4.3 cross-layer pipeline).
//!
//! Deadlock freedom:
//!
//! * [`RankSprs`] is stage-synchronous per rank: all stage-`s` sends are
//!   issued (nonblocking) before any stage-`s` receive blocks, and stage
//!   `s` receives depend only on stage-`s` sends, which every rank issues
//!   after completing stage `s-1` — an acyclic stage DAG. With the split
//!   begin/finish, stage-0 sends happen at `begin` and stages ≥ 1 inside
//!   `finish`; every rank reaches its `finish` without waiting on a peer's
//!   `finish` (the interleaved work is compute plus allgathers whose sends
//!   precede any blocking spRS receive in program order).
//! * [`RankSpag`] (the overlapped spAG) never blocks on one message: it
//!   polls all outstanding receives, forwarding fan-out sends as chunks
//!   land, so a rank stalled on a late chunk still serves its own
//!   forwarding duties. See `DESIGN.md` (SPMD executor).
//!
//! Both properties are *checked statically*: `crate::analysis::model`
//! replays the staged send/receive structure above symbolically (same
//! tags, same stage order, zero kernels) and `hecate analyze schedule`
//! proves match-completeness and wait-graph acyclicity over it; debug
//! builds additionally assert each run's audited traffic equals the
//! model's multiset (`analysis::model::verify_span_traffic`).

use std::collections::BTreeSet;
use std::time::{Duration, Instant};

use crate::collectives::exec::ChunkStore;
use crate::collectives::sparse::{SparsePlan, Transfer};
use crate::placement::{ChunkId, Placement};
use crate::telemetry::Phase as TracePhase;
use crate::topology::DeviceId;

use super::comm::{MsgKind, RankComm, Tag};

/// Poll interval while waiting for in-flight spAG chunks.
const POLL: Duration = Duration::from_micros(20);

fn spag_tag(iter: u64, layer: usize, t: &Transfer) -> Tag {
    Tag { iter, kind: MsgKind::SpagChunk, layer, a: t.chunk, b: t.stage }
}

fn sprs_tag(iter: u64, layer: usize, t: &Transfer) -> Tag {
    Tag { iter, kind: MsgKind::SprsChunk, layer, a: t.chunk, b: t.stage }
}

/// One rank's in-flight SparseAllGather: issue sends up front, complete
/// receives lazily (the overlap scheduler pulls chunks in the order expert
/// compute needs them), forward fan-out transfers as their chunks arrive.
pub struct RankSpag<'p> {
    plan: &'p SparsePlan,
    me: usize,
    iter: u64,
    layer: usize,
    /// Plan indices of transfers destined to this rank, not yet received.
    pending_recv: Vec<usize>,
    /// Plan indices of transfers sourced here whose chunk was not resident
    /// at issue time (intra-node fan-out from a chunk we first receive).
    pending_send: Vec<usize>,
}

impl<'p> RankSpag<'p> {
    /// Register this rank's slice of the plan and immediately issue every
    /// send whose source buffer is already resident. `pre_issued` lists
    /// `(chunk, dst)` transfers the overlap scheduler already sent during
    /// the previous iteration (eager re-materialization) — they are
    /// skipped here, their data is already in flight.
    pub fn begin(
        plan: &'p SparsePlan,
        me: usize,
        iter: u64,
        layer: usize,
        store: &ChunkStore,
        comm: &RankComm,
        pre_issued: &BTreeSet<(ChunkId, usize)>,
    ) -> anyhow::Result<RankSpag<'p>> {
        let t0 = Instant::now();
        let mut s = RankSpag {
            plan,
            me,
            iter,
            layer,
            pending_recv: Vec::new(),
            pending_send: Vec::new(),
        };
        let mut issued = 0u64;
        for (ti, t) in plan.transfers.iter().enumerate() {
            anyhow::ensure!(!t.reduce, "spAG plan must not contain reduce transfers");
            if t.dst.0 == me {
                s.pending_recv.push(ti);
            }
            if t.src.0 == me {
                if pre_issued.contains(&(t.chunk, t.dst.0)) {
                    continue;
                }
                if let Some(buf) = store.get(t.chunk) {
                    comm.isend_slice(t.dst.0, spag_tag(iter, layer, t), buf)?;
                    issued += 1;
                } else {
                    s.pending_send.push(ti);
                }
            }
        }
        comm.trace_span(TracePhase::SpagIssue, iter, layer, t0, issued);
        Ok(s)
    }

    /// Outstanding receives (0 once fully materialized).
    pub fn outstanding(&self) -> usize {
        self.pending_recv.len()
    }

    /// Complete receives until `chunk` is resident (lazy materialization:
    /// called right before expert compute needs the replica).
    pub fn ensure(
        &mut self,
        store: &mut ChunkStore,
        comm: &mut RankComm,
        chunk: ChunkId,
    ) -> anyhow::Result<()> {
        self.progress(store, comm, Some(chunk))
    }

    /// Complete every outstanding receive and forwarding duty.
    pub fn finish(&mut self, store: &mut ChunkStore, comm: &mut RankComm) -> anyhow::Result<()> {
        self.progress(store, comm, None)
    }

    fn progress(
        &mut self,
        store: &mut ChunkStore,
        comm: &mut RankComm,
        want: Option<ChunkId>,
    ) -> anyhow::Result<()> {
        if let Some(c) = want {
            let inbound =
                self.pending_recv.iter().any(|&ti| self.plan.transfers[ti].chunk == c);
            if !store.contains(c) && !inbound {
                anyhow::bail!(
                    "rank {}: chunk {c} neither resident nor inbound in the layer-{} spAG plan",
                    self.me,
                    self.layer
                );
            }
        }
        loop {
            let done = match want {
                Some(c) => store.contains(c),
                None => self.pending_recv.is_empty(),
            };
            if done {
                return Ok(());
            }
            // Poll every outstanding receive (never block on one message:
            // forwarding duties for other chunks must stay serviceable).
            let mut advanced = false;
            let mut i = 0;
            while i < self.pending_recv.len() {
                let t = self.plan.transfers[self.pending_recv[i]];
                let r = comm.irecv(t.src.0, spag_tag(self.iter, self.layer, &t));
                if let Some(buf) = comm.try_wait(r)? {
                    store.insert(t.chunk, buf);
                    self.pending_recv.remove(i);
                    self.flush_sends(store, comm, t.chunk)?;
                    advanced = true;
                } else {
                    i += 1;
                }
            }
            if !advanced {
                std::thread::sleep(POLL);
            }
        }
    }

    /// Issue deferred fan-out sends of a chunk that just became resident.
    fn flush_sends(
        &mut self,
        store: &ChunkStore,
        comm: &RankComm,
        chunk: ChunkId,
    ) -> anyhow::Result<()> {
        let mut i = 0;
        while i < self.pending_send.len() {
            let t = self.plan.transfers[self.pending_send[i]];
            if t.chunk == chunk {
                let buf = store.get(chunk).expect("chunk just inserted");
                comm.isend_slice(t.dst.0, spag_tag(self.iter, self.layer, &t), buf)?;
                self.pending_send.remove(i);
            } else {
                i += 1;
            }
        }
        Ok(())
    }
}

/// This rank's slice of a SparseAllGather, start to finish (the
/// non-overlapped path and the microbenchmarks).
pub fn run_spag_rank(
    store: &mut ChunkStore,
    plan: &SparsePlan,
    me: usize,
    iter: u64,
    layer: usize,
    comm: &mut RankComm,
) -> anyhow::Result<()> {
    let mut s = RankSpag::begin(plan, me, iter, layer, store, comm, &BTreeSet::new())?;
    s.finish(store, comm)
}

/// One rank's in-flight SparseReduceScatter, split so its wire time can
/// hide under the next layer's backward compute: [`RankSprs::begin`]
/// issues this rank's stage-0 sends (reading the final gradient buffers),
/// [`RankSprs::finish`] runs the remaining stage loop (receives in plan
/// order, later-stage sends) and the owner scatter.
pub struct RankSprs<'p> {
    plan: &'p SparsePlan,
    owners: &'p Placement,
    me: usize,
    iter: u64,
    layer: usize,
}

impl<'p> RankSprs<'p> {
    fn issue_stage_sends(
        &self,
        stage: usize,
        store: &ChunkStore,
        comm: &RankComm,
    ) -> anyhow::Result<u64> {
        let mut sent = 0u64;
        for t in self.plan.transfers.iter().filter(|t| t.stage == stage && t.src.0 == self.me) {
            let buf = store.get(t.chunk).ok_or_else(|| {
                anyhow::anyhow!(
                    "spRS rank {} layer {}: missing source chunk {}",
                    self.me,
                    self.layer,
                    t.chunk
                )
            })?;
            comm.isend_slice(t.dst.0, sprs_tag(self.iter, self.layer, t), buf)?;
            sent += 1;
        }
        Ok(sent)
    }

    /// Register the plan and issue this rank's stage-0 sends. The gradient
    /// buffers must be final — `finish` assumes stage-0 payloads already
    /// carry the pre-reduce state.
    pub fn begin(
        plan: &'p SparsePlan,
        owners: &'p Placement,
        me: usize,
        iter: u64,
        layer: usize,
        store: &ChunkStore,
        comm: &RankComm,
    ) -> anyhow::Result<RankSprs<'p>> {
        let t0 = Instant::now();
        let s = RankSprs { plan, owners, me, iter, layer };
        if plan.num_stages > 0 {
            let sent = s.issue_stage_sends(0, store, comm)?;
            comm.trace_span(TracePhase::SprsIssue, iter, layer, t0, sent);
        }
        Ok(s)
    }

    /// Run the remaining stage loop: per stage, receives in **plan order**
    /// (the sequential executor's per-buffer accumulation order), then the
    /// next stage's sends; finally release replicas not owned per the
    /// post-condition (the "scatter").
    pub fn finish(self, store: &mut ChunkStore, comm: &mut RankComm) -> anyhow::Result<()> {
        for stage in 0..self.plan.num_stages {
            if stage > 0 {
                // Sends read post-(stage-1) state; issuing before any
                // receive of this stage keeps the stage DAG acyclic.
                self.issue_stage_sends(stage, store, comm)?;
            }
            for t in
                self.plan.transfers.iter().filter(|t| t.stage == stage && t.dst.0 == self.me)
            {
                let buf = comm.recv(t.src.0, sprs_tag(self.iter, self.layer, t))?;
                if t.reduce {
                    let acc = store.get_mut(t.chunk).ok_or_else(|| {
                        anyhow::anyhow!(
                            "spRS rank {} layer {}: reduce destination lacks chunk {}",
                            self.me,
                            self.layer,
                            t.chunk
                        )
                    })?;
                    anyhow::ensure!(acc.len() == buf.len(), "chunk size mismatch");
                    for (a, b) in acc.iter_mut().zip(buf.iter()) {
                        *a += b;
                    }
                    comm.recycle(buf);
                } else {
                    store.insert(t.chunk, buf);
                }
            }
        }
        // Scatter: release replicas not owned per the post-condition,
        // recycling the buffers into the payload free list.
        let resident: Vec<ChunkId> = store.chunks().collect();
        for c in resident {
            if !self.owners.contains(c, DeviceId(self.me)) {
                if let Some(buf) = store.remove(c) {
                    comm.recycle(buf);
                }
            }
        }
        Ok(())
    }
}

/// This rank's slice of a SparseReduceScatter, start to finish: stage-
/// synchronous sends and plan-ordered receive/accumulate, then release of
/// non-owner replicas. Matches [`crate::collectives::exec::run_sprs`]
/// bit-for-bit on the owner buffers (same per-buffer accumulation order).
pub fn run_sprs_rank(
    store: &mut ChunkStore,
    plan: &SparsePlan,
    owners: &Placement,
    me: usize,
    iter: u64,
    layer: usize,
    comm: &mut RankComm,
) -> anyhow::Result<()> {
    let s = RankSprs::begin(plan, owners, me, iter, layer, store, comm)?;
    s.finish(store, comm)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::exec::{run_spag, run_sprs, ClusterMem};
    use crate::collectives::sparse::{build_spag, build_sprs};
    use crate::spmd::comm::fabric;
    use crate::topology::Topology;
    use crate::util::rng::Rng;

    fn fill(mem: &mut ClusterMem, p: &Placement, len: usize, rng: &mut Rng) {
        for c in 0..p.num_chunks() {
            for d in p.holders(c) {
                let buf: Vec<f32> = (0..len).map(|_| rng.normal() as f32).collect();
                mem.dev_mut(d).insert(c, buf);
            }
        }
    }

    fn random_post(pre: &Placement, extra: usize, seed: u64) -> Placement {
        let mut rng = Rng::new(seed);
        let mut post = pre.clone();
        for _ in 0..extra {
            post.add(rng.below(pre.num_chunks()), DeviceId(rng.below(pre.num_devices())));
        }
        post
    }

    /// Run each rank's slice on its own OS thread; returns the stores.
    fn run_ranks<F>(stores: Vec<ChunkStore>, f: F) -> Vec<ChunkStore>
    where
        F: Fn(usize, &mut ChunkStore, &mut RankComm) -> anyhow::Result<()> + Sync,
    {
        let n = stores.len();
        let comms = fabric(n, None);
        std::thread::scope(|sc| {
            let mut handles = Vec::with_capacity(n);
            for (me, (mut store, mut comm)) in
                stores.into_iter().zip(comms.into_iter()).enumerate()
            {
                let f = &f;
                handles.push(sc.spawn(move || {
                    f(me, &mut store, &mut comm).unwrap();
                    store
                }));
            }
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        })
    }

    #[test]
    fn rank_spag_matches_sequential() {
        let t = Topology::cluster_a(2, 4);
        let pre = Placement::round_robin(8, 8);
        let post = random_post(&pre, 14, 3);
        let plan = build_spag(&t, &pre, &post).unwrap();

        let mut mem = ClusterMem::new(8);
        let mut rng = Rng::new(1);
        fill(&mut mem, &pre, 16, &mut rng);

        let mut seq = mem.clone();
        run_spag(&mut seq, &plan).unwrap();

        let stores = run_ranks(mem.devices.clone(), |me, store, comm| {
            run_spag_rank(store, &plan, me, 0, 0, comm)
        });
        for (d, (got, want)) in stores.iter().zip(seq.devices.iter()).enumerate() {
            let gc: Vec<_> = got.chunks().collect();
            let wc: Vec<_> = want.chunks().collect();
            assert_eq!(gc, wc, "device {d} chunk set");
            for c in gc {
                assert_eq!(got.get(c).unwrap(), want.get(c).unwrap(), "device {d} chunk {c}");
            }
        }
    }

    #[test]
    fn rank_sprs_matches_sequential_bitwise() {
        let t = Topology::cluster_a(2, 4);
        let owners = Placement::round_robin(8, 8);
        let materialized = random_post(&owners, 12, 7);
        let plan = build_sprs(&t, &materialized, &owners).unwrap();

        let mut grads = ClusterMem::new(8);
        let mut rng = Rng::new(2);
        fill(&mut grads, &materialized, 32, &mut rng);

        let mut seq = grads.clone();
        run_sprs(&mut seq, &plan, &owners).unwrap();

        let stores = run_ranks(grads.devices.clone(), |me, store, comm| {
            run_sprs_rank(store, &plan, &owners, me, 0, 0, comm)
        });
        for c in 0..8 {
            let owner = owners.holders(c).next().unwrap();
            let got = stores[owner.0].get(c).unwrap();
            let want = seq.dev(owner).get(c).unwrap();
            assert_eq!(got, want, "owner sum of chunk {c} must be bit-identical");
        }
        // scatter: non-owners released
        for (d, store) in stores.iter().enumerate() {
            for c in store.chunks() {
                assert!(owners.contains(c, DeviceId(d)), "device {d} kept chunk {c}");
            }
        }
    }

    #[test]
    fn split_sprs_begin_finish_matches_sequential_bitwise() {
        // The cross-layer pipeline's begin/finish split must leave owner
        // sums bit-identical to the one-shot path.
        let t = Topology::cluster_a(2, 2);
        let owners = Placement::round_robin(8, 4);
        let materialized = random_post(&owners, 10, 5);
        let plan = build_sprs(&t, &materialized, &owners).unwrap();

        let mut grads = ClusterMem::new(4);
        let mut rng = Rng::new(6);
        fill(&mut grads, &materialized, 16, &mut rng);
        let mut seq = grads.clone();
        run_sprs(&mut seq, &plan, &owners).unwrap();

        let stores = run_ranks(grads.devices.clone(), |me, store, comm| {
            let s = RankSprs::begin(&plan, &owners, me, 4, 2, store, comm)?;
            // unrelated work happens here in the real pipeline
            s.finish(store, comm)
        });
        for c in 0..8 {
            let owner = owners.holders(c).next().unwrap();
            assert_eq!(
                stores[owner.0].get(c).unwrap(),
                seq.dev(owner).get(c).unwrap(),
                "owner sum of chunk {c}"
            );
        }
    }

    #[test]
    fn lazy_ensure_pulls_chunks_on_demand() {
        let t = Topology::cluster_a(2, 2);
        let pre = Placement::round_robin(4, 4);
        let mut post = pre.clone();
        post.add(0, DeviceId(3)); // cross-node materialization
        post.add(0, DeviceId(2)); // fan-out on node 1
        post.add(1, DeviceId(2));
        let plan = build_spag(&t, &pre, &post).unwrap();

        let mut mem = ClusterMem::new(4);
        let mut rng = Rng::new(9);
        fill(&mut mem, &pre, 8, &mut rng);
        let want0 = mem.dev(DeviceId(0)).get(0).unwrap().to_vec();
        let want1 = mem.dev(DeviceId(1)).get(1).unwrap().to_vec();

        let stores = run_ranks(mem.devices.clone(), |me, store, comm| {
            let mut s = RankSpag::begin(&plan, me, 0, 0, store, comm, &BTreeSet::new())?;
            if me == 2 {
                // pull in reverse plan order to exercise out-of-order ensure
                s.ensure(store, comm, 1)?;
                s.ensure(store, comm, 0)?;
                assert_eq!(s.outstanding(), 0);
            }
            s.finish(store, comm)
        });
        assert_eq!(stores[2].get(0).unwrap(), want0.as_slice());
        assert_eq!(stores[2].get(1).unwrap(), want1.as_slice());
        assert_eq!(stores[3].get(0).unwrap(), want0.as_slice());
    }

    #[test]
    fn ensure_unknown_chunk_errors() {
        let t = Topology::flat(2, 1e9);
        let pre = Placement::round_robin(2, 2);
        let plan = build_spag(&t, &pre, &pre).unwrap(); // empty plan
        let comms = fabric(1, None);
        let mut comm = comms.into_iter().next().unwrap();
        let mut store = ChunkStore::new();
        let mut s = RankSpag::begin(&plan, 0, 0, 0, &store, &comm, &BTreeSet::new()).unwrap();
        assert!(s.ensure(&mut store, &mut comm, 1).is_err());
    }
}
