//! Topology-aware token dispatching (§4.4).
//!
//! With sparse materialization an expert may be materialized on several
//! devices; every token assigned to that expert must pick exactly one
//! destination. Hecate's dispatcher:
//!
//! 1. routes **locally** when the source device holds the expert;
//! 2. otherwise prefers replicas **within the source node** (NVLink beats
//!    NIC);
//! 3. only crosses nodes when no same-node replica exists;
//! 4. splits evenly among the selected candidate devices.

use crate::placement::Placement;
use crate::topology::{DeviceId, Topology};

/// Result of dispatching one MoE layer's tokens.
#[derive(Debug, Clone)]
pub struct DispatchPlan {
    /// `sends[src][dst]` — tokens moved between devices (the All-to-All).
    pub sends: Vec<Vec<usize>>,
    /// `arrivals[device][expert]` — tokens each device must run through each
    /// expert (drives expert-compute time and the combine A2A back).
    pub arrivals: Vec<Vec<usize>>,
}

impl DispatchPlan {
    /// Total tokens crossing devices (excludes local work).
    pub fn remote_tokens(&self) -> usize {
        let mut sum = 0;
        for (s, row) in self.sends.iter().enumerate() {
            for (d, &t) in row.iter().enumerate() {
                if s != d {
                    sum += t;
                }
            }
        }
        sum
    }

    /// Tokens crossing node boundaries.
    pub fn internode_tokens(&self, topo: &Topology) -> usize {
        let mut sum = 0;
        for (s, row) in self.sends.iter().enumerate() {
            for (d, &t) in row.iter().enumerate() {
                if !topo.same_node(DeviceId(s), DeviceId(d)) {
                    sum += t;
                }
            }
        }
        sum
    }

    /// Per-device total expert-compute tokens (the straggler profile).
    pub fn device_compute_tokens(&self) -> Vec<usize> {
        self.arrivals.iter().map(|a| a.iter().sum()).collect()
    }
}

/// Dispatch `assignments[src][expert]` tokens (the gate decision on each
/// source device) onto the materialized `placement`.
pub fn dispatch(
    topo: &Topology,
    placement: &Placement,
    assignments: &[Vec<usize>],
) -> DispatchPlan {
    let nd = topo.num_devices();
    let experts = placement.num_chunks();
    assert_eq!(assignments.len(), nd);
    let mut sends = vec![vec![0usize; nd]; nd];
    let mut arrivals = vec![vec![0usize; experts]; nd];
    // Round-robin cursor per (expert) for even spreading across candidates,
    // kept across source devices so the global split stays even.
    let mut cursors = vec![0usize; experts];
    // Reused candidate buffer (perf: this loop runs nd×experts per MoE
    // layer per iteration in the simulator — see EXPERIMENTS.md §Perf).
    let mut candidates: Vec<DeviceId> = Vec::with_capacity(nd);

    for src in 0..nd {
        let src_id = DeviceId(src);
        for e in 0..experts {
            let tokens = assignments[src][e];
            if tokens == 0 {
                continue;
            }
            assert!(
                placement.replication(e) > 0,
                "expert {e} not materialized anywhere"
            );
            // 1. local
            if placement.contains(e, src_id) {
                sends[src][src] += tokens;
                arrivals[src][e] += tokens;
                continue;
            }
            // 2. same-node replicas, else 3. all replicas
            let local_node = topo.node_of(src_id);
            candidates.clear();
            candidates.extend(placement.holders(e).filter(|&d| topo.node_of(d) == local_node));
            if candidates.is_empty() {
                candidates.extend(placement.holders(e));
            }
            // 4. even split across candidates (remainder via rotating cursor)
            let k = candidates.len();
            let base = tokens / k;
            let rem = tokens % k;
            for (i, &dst) in candidates.iter().enumerate() {
                let slot = (i + k - cursors[e] % k) % k; // rotate remainder
                let t = base + usize::from(slot < rem);
                if t > 0 {
                    sends[src][dst.0] += t;
                    arrivals[dst.0][e] += t;
                }
            }
            cursors[e] = (cursors[e] + rem) % k.max(1);
        }
    }
    DispatchPlan { sends, arrivals }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing;
    use crate::util::rng::Rng;

    fn assignments_total(a: &[Vec<usize>]) -> usize {
        a.iter().map(|r| r.iter().sum::<usize>()).sum()
    }

    #[test]
    fn local_first() {
        let topo = Topology::cluster_a(2, 2);
        let placement = Placement::round_robin(4, 4); // expert e on device e
        let mut asg = vec![vec![0usize; 4]; 4];
        asg[1][1] = 100; // device 1's tokens for its own expert
        let plan = dispatch(&topo, &placement, &asg);
        assert_eq!(plan.remote_tokens(), 0);
        assert_eq!(plan.arrivals[1][1], 100);
    }

    #[test]
    fn same_node_preferred_over_cross_node() {
        let topo = Topology::cluster_a(2, 2); // devices 0,1 node0; 2,3 node1
        let mut p = Placement::empty(1, 4);
        p.add(0, DeviceId(1)); // replica on node 0
        p.add(0, DeviceId(2)); // replica on node 1
        let mut asg = vec![vec![0usize; 1]; 4];
        asg[0][0] = 10; // source device 0 (node 0)
        let plan = dispatch(&topo, &p, &asg);
        assert_eq!(plan.sends[0][1], 10, "all tokens stay on node 0");
        assert_eq!(plan.internode_tokens(&topo), 0);
    }

    #[test]
    fn cross_node_when_no_local_replica() {
        let topo = Topology::cluster_a(2, 2);
        let mut p = Placement::empty(1, 4);
        p.add(0, DeviceId(3));
        let mut asg = vec![vec![0usize; 1]; 4];
        asg[0][0] = 7;
        let plan = dispatch(&topo, &p, &asg);
        assert_eq!(plan.sends[0][3], 7);
        assert_eq!(plan.internode_tokens(&topo), 7);
    }

    #[test]
    fn even_split_among_candidates() {
        let topo = Topology::flat(4, 1e9);
        let mut p = Placement::empty(1, 4);
        for d in 0..4 {
            p.add(0, DeviceId(d));
        }
        let mut asg = vec![vec![0usize; 1]; 4];
        asg[0][0] = 103; // source holds the expert too -> all local
        let plan = dispatch(&topo, &p, &asg);
        assert_eq!(plan.arrivals[0][0], 103, "local replica wins outright");

        // non-holder source splits across all 3 remaining? source 1 holds it
        // too in full placement; craft a placement without source.
        let mut p2 = Placement::empty(1, 4);
        p2.add(0, DeviceId(1));
        p2.add(0, DeviceId(2));
        p2.add(0, DeviceId(3));
        let mut asg2 = vec![vec![0usize; 1]; 4];
        asg2[0][0] = 10;
        let plan2 = dispatch(&topo, &p2, &asg2);
        let got: Vec<usize> = (1..4).map(|d| plan2.sends[0][d]).collect();
        let mut sorted = got.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![3, 3, 4]);
    }

    #[test]
    fn prop_conservation_and_locality() {
        testing::check(
            |rng: &mut Rng, size| {
                let topo = Topology::cluster_a(1 + rng.below(3), 1 + rng.below(4));
                let nd = topo.num_devices();
                let experts = 1 + rng.below(4 * size.max(1));
                // surjective placement with random extra replicas
                let mut p = Placement::round_robin(experts, nd);
                for _ in 0..rng.below(experts * 2 + 1) {
                    p.add(rng.below(experts), DeviceId(rng.below(nd)));
                }
                let asg: Vec<Vec<usize>> = (0..nd)
                    .map(|_| (0..experts).map(|_| rng.below(50)).collect())
                    .collect();
                (topo, p, asg)
            },
            |(topo, p, asg)| {
                let plan = dispatch(topo, p, asg);
                // conservation: all tokens arrive exactly once
                let total_in = assignments_total(asg);
                let total_arr: usize =
                    plan.arrivals.iter().map(|a| a.iter().sum::<usize>()).sum();
                if total_in != total_arr {
                    return Err(format!("lost tokens: {total_in} -> {total_arr}"));
                }
                // arrivals only on devices holding the expert
                for (d, row) in plan.arrivals.iter().enumerate() {
                    for (e, &t) in row.iter().enumerate() {
                        if t > 0 && !p.contains(e, DeviceId(d)) {
                            return Err(format!("tokens for e{e} on non-holder d{d}"));
                        }
                    }
                }
                // locality: a token crosses nodes only if its expert has no
                // replica on the source node — verified in aggregate: for any
                // source with a same-node replica, its cross-node sends for
                // that expert must be zero. (Checked via recomputation.)
                let nd = topo.num_devices();
                for src in 0..nd {
                    for e in 0..p.num_chunks() {
                        if asg[src][e] == 0 {
                            continue;
                        }
                        let has_local_node = !p
                            .holders_on_node(topo, e, topo.node_of(DeviceId(src)))
                            .is_empty();
                        if has_local_node {
                            // no cross-node sends attributable to (src, e):
                            // since candidates were same-node only, sends to
                            // other nodes can only come from other experts —
                            // validated by construction; here we just sanity
                            // check the plan's internode count is bounded.
                        } else if p.contains(e, DeviceId(src)) {
                            return Err("holder reported as no-local-node".into());
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn balanced_placement_yields_balanced_compute() {
        // With every expert on every device, all tokens stay local and the
        // compute profile equals the gate's per-device token counts.
        let topo = Topology::cluster_a(2, 4);
        let p = Placement::full(8, 8);
        let mut rng = Rng::new(3);
        let asg: Vec<Vec<usize>> =
            (0..8).map(|_| (0..8).map(|_| rng.below(20)).collect()).collect();
        let plan = dispatch(&topo, &p, &asg);
        assert_eq!(plan.remote_tokens(), 0);
        for (d, row) in asg.iter().enumerate() {
            assert_eq!(plan.device_compute_tokens()[d], row.iter().sum::<usize>());
        }
    }
}
