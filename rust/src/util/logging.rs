//! Tiny leveled logger writing to stderr. Controlled by `HECATE_LOG`
//! (`error|warn|info|debug|trace`, default `info`).

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(2);
static INIT: OnceLock<()> = OnceLock::new();

/// Initialize the level from `HECATE_LOG` (idempotent).
pub fn init() {
    INIT.get_or_init(|| {
        let lvl = match std::env::var("HECATE_LOG").unwrap_or_default().to_lowercase().as_str() {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => Level::Info,
        };
        LEVEL.store(lvl as u8, Ordering::Relaxed);
    });
}

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn enabled(level: Level) -> bool {
    init();
    (level as u8) <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(level: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if enabled(level) {
        let tag = match level {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{tag}] {module}: {msg}");
    }
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_filtering() {
        init();
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }
}
