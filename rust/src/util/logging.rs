//! Tiny leveled logger writing to stderr. Controlled by `HECATE_LOG`
//! (`error|warn|info|debug|trace`, default `info`).
//!
//! Plain lines go through `log_error!` … `log_trace!`; `log_kv!` emits a
//! structured `key=value` line (`[INFO ] module: event k1=v1 k2=v2`) for
//! diagnostics that downstream tooling greps.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(2);
static INIT: OnceLock<()> = OnceLock::new();

/// Initialize the level from `HECATE_LOG` (idempotent).
pub fn init() {
    INIT.get_or_init(|| {
        let lvl = match std::env::var("HECATE_LOG").unwrap_or_default().to_lowercase().as_str() {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => Level::Info,
        };
        LEVEL.store(lvl as u8, Ordering::Relaxed);
    });
}

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn enabled(level: Level) -> bool {
    init();
    (level as u8) <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(level: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if enabled(level) {
        let tag = match level {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{tag}] {module}: {msg}");
    }
}

/// Structured log line: `event` plus `key=value` pairs, space-separated.
/// Values are formatted with `Display`; formatting is skipped entirely
/// when the level is filtered out.
pub fn log_kv(level: Level, module: &str, event: &str, pairs: &[(&str, &dyn std::fmt::Display)]) {
    if !enabled(level) {
        return;
    }
    let mut line = String::from(event);
    for (k, v) in pairs {
        line.push(' ');
        line.push_str(k);
        line.push('=');
        line.push_str(&v.to_string());
    }
    log(level, module, format_args!("{line}"));
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Error, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_trace {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Trace, module_path!(), format_args!($($arg)*))
    };
}

/// `log_kv!(Level::Info, "event", k1 = v1, k2 = v2)` — structured
/// `key=value` diagnostics; each value only needs `Display`.
#[macro_export]
macro_rules! log_kv {
    ($level:expr, $event:expr $(, $key:ident = $val:expr)* $(,)?) => {
        $crate::util::logging::log_kv(
            $level,
            module_path!(),
            $event,
            &[$((stringify!($key), &$val as &dyn ::std::fmt::Display)),*],
        )
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_filtering() {
        init();
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
        assert!(!enabled(Level::Trace));
        set_level(Level::Trace);
        assert!(enabled(Level::Trace));
        set_level(Level::Info);
    }

    #[test]
    fn kv_macro_accepts_mixed_display_values() {
        init();
        // smoke: filtered-out level takes the early-return path, enabled
        // level renders every pair via Display
        crate::log_kv!(Level::Trace, "skipped", step = 1);
        set_level(Level::Info);
        crate::log_kv!(Level::Info, "reshard", step = 12u64, moved = 3usize, dir = "out");
        crate::log_kv!(Level::Info, "bare_event");
    }
}
