//! Deterministic PRNG + the distributions the load simulator needs.
//!
//! The registry snapshot has no `rand`, so this module provides a
//! xoshiro256** generator (seeded via splitmix64, the reference seeding
//! procedure) plus uniform / normal / zipf / dirichlet / categorical
//! sampling. Everything is deterministic given a seed, which the simulator
//! and property tests rely on for reproducibility.

/// xoshiro256** 1.0 — fast, high-quality, 256-bit state.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed the generator. Equal seeds produce equal streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut word = || splitmix64(&mut sm);
        Rng { s: [word(), word(), word(), word()] }
    }

    /// Derive an independent child stream (for per-layer / per-device rngs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Snapshot the generator state (for checkpointing). Restoring via
    /// [`Rng::from_state`] resumes the exact stream.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a [`Rng::state`] snapshot.
    pub fn from_state(s: [u64; 4]) -> Rng {
        Rng { s }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in `[0, n)`. Panics if `n == 0`.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        // Lemire-style rejection-free-enough for our sizes: widening multiply.
        let x = self.next_u64();
        (((x as u128) * (n as u128)) >> 64) as usize
    }

    /// Uniform integer in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Gamma(shape k, scale 1) via Marsaglia–Tsang (k >= 0 handled; k < 1
    /// boosted with the standard power trick).
    pub fn gamma(&mut self, k: f64) -> f64 {
        assert!(k > 0.0);
        if k < 1.0 {
            let u = loop {
                let u = self.f64();
                if u > 0.0 {
                    break u;
                }
            };
            return self.gamma(k + 1.0) * u.powf(1.0 / k);
        }
        let d = k - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4) || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
                return d * v;
            }
        }
    }

    /// Symmetric Dirichlet(alpha) over `n` categories — the canonical model
    /// for a (possibly very skewed) expert-load distribution. Small `alpha`
    /// → highly imbalanced loads; large `alpha` → near-uniform.
    pub fn dirichlet(&mut self, alpha: f64, n: usize) -> Vec<f64> {
        let mut g: Vec<f64> = (0..n).map(|_| self.gamma(alpha)).collect();
        let sum: f64 = g.iter().sum();
        if sum <= 0.0 {
            return vec![1.0 / n as f64; n];
        }
        for x in &mut g {
            *x /= sum;
        }
        g
    }

    /// Zipf-like normalized weights (rank r gets weight r^-s), shuffled.
    pub fn zipf_weights(&mut self, s: f64, n: usize) -> Vec<f64> {
        let mut w: Vec<f64> = (1..=n).map(|r| (r as f64).powf(-s)).collect();
        let sum: f64 = w.iter().sum();
        for x in &mut w {
            *x /= sum;
        }
        self.shuffle(&mut w);
        w
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "categorical with zero total weight");
        let mut x = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Multinomial: distribute `n` trials over `weights`.
    pub fn multinomial(&mut self, n: usize, weights: &[f64]) -> Vec<usize> {
        let mut counts = vec![0usize; weights.len()];
        for _ in 0..n {
            counts[self.categorical(weights)] += 1;
        }
        counts
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        // partial Fisher–Yates
        for i in 0..k {
            let j = self.range(i, n);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(8);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(2);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::new(4);
        for &alpha in &[0.1, 0.5, 1.0, 10.0] {
            let p = r.dirichlet(alpha, 64);
            assert_eq!(p.len(), 64);
            let s: f64 = p.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
            assert!(p.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn dirichlet_skew_increases_with_small_alpha() {
        let mut r = Rng::new(5);
        let max_small: f64 = (0..50).map(|_| {
            r.dirichlet(0.05, 32).iter().cloned().fold(0.0, f64::max)
        }).sum::<f64>() / 50.0;
        let max_large: f64 = (0..50).map(|_| {
            r.dirichlet(10.0, 32).iter().cloned().fold(0.0, f64::max)
        }).sum::<f64>() / 50.0;
        assert!(max_small > 2.0 * max_large, "{max_small} vs {max_large}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(6);
        let counts = r.multinomial(100_000, &[1.0, 3.0]);
        let frac = counts[1] as f64 / 100_000.0;
        assert!((frac - 0.75).abs() < 0.01, "{frac}");
    }

    #[test]
    fn zipf_weights_normalized() {
        let mut r = Rng::new(7);
        let w = r.zipf_weights(1.2, 64);
        assert!((w.iter().sum::<f64>() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(8);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(9);
        let s = r.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 20);
    }

    #[test]
    fn state_roundtrip_resumes_stream() {
        let mut a = Rng::new(11);
        for _ in 0..17 {
            a.next_u64();
        }
        let snap = a.state();
        let tail: Vec<u64> = (0..32).map(|_| a.next_u64()).collect();
        let mut b = Rng::from_state(snap);
        let replay: Vec<u64> = (0..32).map(|_| b.next_u64()).collect();
        assert_eq!(tail, replay);
    }

    #[test]
    fn fork_streams_differ() {
        let mut r = Rng::new(10);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
