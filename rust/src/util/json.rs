//! Minimal JSON parser and serializer.
//!
//! The registry snapshot has no `serde`/`serde_json`, so configs and the
//! artifact manifest are handled by this self-contained codec. It supports
//! the full JSON grammar (objects, arrays, strings with escapes, numbers,
//! booleans, null) and preserves object insertion order.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Object. `BTreeMap` gives deterministic serialization order.
    Obj(BTreeMap<String, Json>),
}

/// Error produced while parsing JSON text.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    /// Parse a JSON document from text.
    pub fn parse(text: &str) -> Result<Json, ParseError> {
        let mut p = Parser { b: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Serialize to a compact string.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                newline(out, indent, depth);
                out.push(']');
            }
            Json::Obj(map) => {
                if map.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline(out, indent, depth);
                out.push('}');
            }
        }
    }

    // ---- typed accessors ----

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 && n <= usize::MAX as f64 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Look up a key in an object value.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// `get` + error context, for required fields.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing required json field `{key}`"))
    }

    /// Canonicalizing number constructor: finite values become
    /// [`Json::Num`], non-finite values (which JSON cannot represent)
    /// become [`Json::Null`]. Prefer this over `Json::Num(..)` directly.
    pub fn num(n: f64) -> Json {
        if n.is_finite() {
            Json::Num(n)
        } else {
            Json::Null
        }
    }

    /// Strict number constructor: errors on NaN/±Infinity instead of
    /// degrading, for callers that must not lose the value silently.
    pub fn finite(n: f64) -> anyhow::Result<Json> {
        anyhow::ensure!(n.is_finite(), "non-finite number {n} cannot be represented in JSON");
        Ok(Json::Num(n))
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}

impl From<f64> for Json {
    /// Non-finite floats are canonicalized to `Json::Null`: JSON has no
    /// NaN/Infinity, and silently emitting them would corrupt documents
    /// (e.g. checkpoint manifests) for every other parser.
    fn from(n: f64) -> Self {
        Json::num(n)
    }
}

impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}

impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}

impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Convenience builder for object literals:
/// `obj([("a", 1.0.into()), ("b", "x".into())])`.
pub fn obj<I: IntoIterator<Item = (&'static str, Json)>>(items: I) -> Json {
    Json::Obj(items.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.is_finite() && n.fract() == 0.0 && n.abs() < 1e15 {
        out.push_str(&format!("{}", n as i64));
    } else if n.is_finite() {
        out.push_str(&format!("{n}"));
    } else {
        // JSON has no Inf/NaN; degrade to null like most encoders.
        out.push_str("null");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{word}`")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a json value")),
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected `,` or `}`"));
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected `,` or `]`"));
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Handle surrogate pairs.
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let combined =
                                0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(combined)
                        } else {
                            char::from_u32(cp)
                        };
                        s.push(c.ok_or_else(|| self.err("invalid codepoint"))?);
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // Re-decode UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let width = utf8_width(c);
                        let end = start + width;
                        if end > self.b.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk = std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_width(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn parse_unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é😀");
    }

    #[test]
    fn parse_utf8_passthrough() {
        let v = Json::parse("\"héllo 😀\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo 😀");
    }

    #[test]
    fn errors() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"abc").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"model":"gpt-moe-s","experts":64,"layers":[1,2,3],"flag":true,"note":null}"#;
        let v = Json::parse(src).unwrap();
        let compact = v.to_string();
        assert_eq!(Json::parse(&compact).unwrap(), v);
        let pretty = v.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"n": 7, "s": "x", "b": false, "a": []}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_usize().unwrap(), 7);
        assert!(v.get("n").unwrap().as_str().is_none());
        assert!(v.req("missing").is_err());
        assert_eq!(v.get("b").unwrap().as_bool(), Some(false));
        assert!(v.get("a").unwrap().as_arr().unwrap().is_empty());
    }

    #[test]
    fn builder() {
        let v = obj([("a", 1usize.into()), ("b", vec![1.0f64, 2.0].into())]);
        assert_eq!(v.get("a").unwrap().as_usize(), Some(1));
        assert_eq!(v.get("b").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn non_finite_canonicalized() {
        // From<f64> and Json::num degrade NaN/±Inf to null…
        assert_eq!(Json::from(f64::NAN), Json::Null);
        assert_eq!(Json::from(f64::INFINITY), Json::Null);
        assert_eq!(Json::num(f64::NEG_INFINITY), Json::Null);
        assert_eq!(Json::num(1.5), Json::Num(1.5));
        // …and Json::finite rejects them outright.
        assert!(Json::finite(f64::NAN).is_err());
        assert!(Json::finite(f64::INFINITY).is_err());
        assert_eq!(Json::finite(2.0).unwrap(), Json::Num(2.0));
    }

    #[test]
    fn non_finite_serialization_stays_valid_json() {
        // Even a directly constructed Num(NaN) must serialize to a document
        // every JSON parser accepts (null), and round-trip through ours.
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let doc = obj([("x", Json::Num(bad)), ("y", 3usize.into())]);
            let text = doc.to_string();
            let back = Json::parse(&text).expect("serialized text must stay parseable");
            assert_eq!(back.get("x").unwrap(), &Json::Null);
            assert_eq!(back.get("y").unwrap().as_usize(), Some(3));
        }
    }

    #[test]
    fn finite_numbers_roundtrip_exactly() {
        for v in [0.0, -0.0, 0.1, -3.5e2, 1e-12, 9007199254740991.0, 1.25e15] {
            let text = Json::Num(v).to_string();
            let back = Json::parse(&text).unwrap();
            assert_eq!(back.as_f64().unwrap(), v, "value {v} via `{text}`");
        }
    }

    #[test]
    fn number_precision() {
        let v = Json::parse("0.1").unwrap();
        assert!((v.as_f64().unwrap() - 0.1).abs() < 1e-15);
        // integers survive exactly
        let v = Json::parse("9007199254740991").unwrap();
        assert_eq!(v.as_f64().unwrap(), 9007199254740991.0);
    }
}
