//! Summary statistics used by the simulator reports and the bench harness.

/// Arithmetic mean. Returns 0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Geometric mean (paper reports geo-mean speedups). Requires positives.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let log_sum: f64 = xs.iter().map(|x| x.max(f64::MIN_POSITIVE).ln()).sum();
    (log_sum / xs.len() as f64).exp()
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Linear-interpolated percentile, `q` in [0, 100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = (q / 100.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let frac = pos - lo as f64;
        v[lo] * (1.0 - frac) + v[hi] * frac
    }
}

/// Median.
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Median absolute deviation — robust spread for bench reporting.
pub fn mad(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = median(xs);
    let devs: Vec<f64> = xs.iter().map(|x| (x - m).abs()).collect();
    median(&devs)
}

/// Coefficient of variation of a load vector — the imbalance metric used in
/// simulator reports (0 = perfectly balanced).
pub fn cv(xs: &[f64]) -> f64 {
    let m = mean(xs);
    if m == 0.0 {
        return 0.0;
    }
    stddev(xs) / m
}

/// max/mean ratio — the straggler factor of a load vector (1 = balanced).
/// This is exactly the slowdown the most-loaded device imposes under EP.
pub fn straggler_factor(xs: &[f64]) -> f64 {
    let m = mean(xs);
    if m == 0.0 {
        return 1.0;
    }
    xs.iter().cloned().fold(f64::MIN, f64::max) / m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_mean_median() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn geomean_matches_hand_calc() {
        let g = geomean(&[1.0, 4.0]);
        assert!((g - 2.0).abs() < 1e-12);
        let g = geomean(&[2.0, 8.0, 4.0]);
        assert!((g - 4.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interp() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 100.0), 40.0);
        assert!((percentile(&xs, 50.0) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn straggler_and_cv() {
        assert_eq!(straggler_factor(&[1.0, 1.0, 1.0, 1.0]), 1.0);
        assert_eq!(straggler_factor(&[4.0, 0.0, 0.0, 0.0]), 4.0);
        assert_eq!(cv(&[5.0, 5.0]), 0.0);
        assert!(cv(&[0.0, 10.0]) > 0.9);
    }

    #[test]
    fn mad_robust() {
        let xs = [1.0, 1.0, 1.0, 1.0, 100.0];
        assert_eq!(mad(&xs), 0.0);
        assert!(stddev(&xs) > 30.0);
    }
}
