//! Minimal command-line argument parser (the registry snapshot has no
//! `clap`). Supports `--flag`, `--key value`, `--key=value`, and positional
//! arguments, with typed accessors and a generated usage string.

use std::collections::{BTreeMap, BTreeSet};

/// Parsed arguments for one (sub)command.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// `--key value` / `--key=value` pairs. A bare `--flag` maps to "true".
    opts: BTreeMap<String, String>,
    /// Keys that were given as *bare* flags (no `=value` and no following
    /// value token). Boolean accessors accept them; value accessors reject
    /// them — `hecate fssdp --devices` (value flag as the final token) must
    /// be a parse error, not a silent `--devices true`.
    bare: BTreeSet<String>,
    /// Positional arguments in order.
    pub positional: Vec<String>,
}

impl Args {
    /// Parse a raw arg list (excluding the program/subcommand name).
    ///
    /// A token starting with `--` either contains `=` (split there) or, if
    /// the next token does not start with `--`, consumes it as the value;
    /// otherwise it is a boolean flag. Which keys take values is only known
    /// to the typed accessors, so a bare flag is *recorded* here and
    /// rejected there when a value is required.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let toks: Vec<String> = raw.into_iter().collect();
        let mut args = Args::default();
        let mut i = 0;
        while i < toks.len() {
            let t = &toks[i];
            if let Some(body) = t.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    args.bare.remove(k);
                    args.opts.insert(k.to_string(), v.to_string());
                } else if i + 1 < toks.len() && !toks[i + 1].starts_with("--") {
                    args.bare.remove(body);
                    args.opts.insert(body.to_string(), toks[i + 1].clone());
                    i += 1;
                } else {
                    args.bare.insert(body.to_string());
                    args.opts.insert(body.to_string(), "true".to_string());
                }
            } else {
                args.positional.push(t.clone());
            }
            i += 1;
        }
        args
    }

    pub fn has(&self, key: &str) -> bool {
        self.opts.contains_key(key)
    }

    /// True when the key was given as a bare `--flag` (no value token).
    pub fn is_bare(&self, key: &str) -> bool {
        self.bare.contains(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    fn missing_value(&self, key: &str) -> anyhow::Error {
        anyhow::anyhow!(
            "--{key} expects a value but none was given (it appeared as a bare flag \
             at the end of the arguments or before another --flag)"
        )
    }

    /// Like [`Args::get`] for value-taking string options: errors when the
    /// key was given as a bare flag instead of silently yielding "true".
    pub fn str_opt(&self, key: &str) -> anyhow::Result<Option<String>> {
        if self.is_bare(key) {
            return Err(self.missing_value(key));
        }
        Ok(self.get(key).map(|s| s.to_string()))
    }

    pub fn str_or(&self, key: &str, default: &str) -> anyhow::Result<String> {
        Ok(self.str_opt(key)?.unwrap_or_else(|| default.to_string()))
    }

    pub fn usize_or(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        if self.is_bare(key) {
            return Err(self.missing_value(key));
        }
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got `{v}`")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> anyhow::Result<f64> {
        if self.is_bare(key) {
            return Err(self.missing_value(key));
        }
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects a number, got `{v}`")),
        }
    }

    pub fn bool_or(&self, key: &str, default: bool) -> anyhow::Result<bool> {
        match self.get(key) {
            None => Ok(default),
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(v) => Err(anyhow::anyhow!("--{key} expects a bool, got `{v}`")),
        }
    }

    /// Required string option.
    pub fn req(&self, key: &str) -> anyhow::Result<String> {
        if self.is_bare(key) {
            return Err(self.missing_value(key));
        }
        self.get(key)
            .map(|s| s.to_string())
            .ok_or_else(|| anyhow::anyhow!("missing required option --{key}"))
    }

    /// All unknown keys, for strict validation.
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.opts.keys().map(|s| s.as_str())
    }

    /// Error if any provided option is not in `allowed`.
    pub fn reject_unknown(&self, allowed: &[&str]) -> anyhow::Result<()> {
        for k in self.keys() {
            if !allowed.contains(&k) {
                return Err(anyhow::anyhow!(
                    "unknown option --{k}; expected one of: {}",
                    allowed.iter().map(|a| format!("--{a}")).collect::<Vec<_>>().join(", ")
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|t| t.to_string()))
    }

    #[test]
    fn key_value_forms() {
        // NB: a bare `--flag` greedily consumes a following non-`--` token,
        // so positionals go before flags (or use `--flag=true`).
        let a = parse("pos1 pos2 --model gpt-moe-s --gpus=32 --verbose");
        assert_eq!(a.get("model"), Some("gpt-moe-s"));
        assert_eq!(a.usize_or("gpus", 0).unwrap(), 32);
        assert!(a.bool_or("verbose", false).unwrap());
        assert_eq!(a.positional, vec!["pos1", "pos2"]);
    }

    #[test]
    fn flag_before_flag() {
        let a = parse("--rm --steps 10");
        assert!(a.bool_or("rm", false).unwrap());
        assert_eq!(a.usize_or("steps", 0).unwrap(), 10);
    }

    #[test]
    fn defaults_and_required() {
        let a = parse("");
        assert_eq!(a.usize_or("n", 5).unwrap(), 5);
        assert!(a.req("x").is_err());
    }

    #[test]
    fn type_errors() {
        let a = parse("--n abc");
        assert!(a.usize_or("n", 0).is_err());
        assert!(a.f64_or("n", 0.0).is_err());
    }

    #[test]
    fn reject_unknown() {
        let a = parse("--good 1 --bad 2");
        assert!(a.reject_unknown(&["good"]).is_err());
        assert!(a.reject_unknown(&["good", "bad"]).is_ok());
    }

    #[test]
    fn key_value_with_embedded_equals() {
        // split happens at the FIRST `=`; the value keeps the rest intact.
        let a = parse("--filter=name=hecate --url=http://host:8080/p?q=1");
        assert_eq!(a.get("filter"), Some("name=hecate"));
        assert_eq!(a.get("url"), Some("http://host:8080/p?q=1"));
        // empty value after `=` stays empty (distinct from a bare flag)
        let b = parse("--empty=");
        assert_eq!(b.get("empty"), Some(""));
        assert!(b.bool_or("empty", true).is_err(), "empty string is not a bool");
    }

    #[test]
    fn bare_trailing_flag_maps_to_true() {
        let a = parse("--steps 10 --verbose");
        assert_eq!(a.usize_or("steps", 0).unwrap(), 10);
        assert_eq!(a.get("verbose"), Some("true"));
        assert!(a.bool_or("verbose", false).unwrap());
        // also when it is the only token
        let b = parse("--dry-run");
        assert!(b.bool_or("dry-run", false).unwrap());
        assert!(b.positional.is_empty());
    }

    #[test]
    fn negative_number_values_are_consumed() {
        // "-0.5" starts with a single dash, so it is a value, not a flag.
        let a = parse("--lr -0.5 --delta -3 --offset=-7");
        assert_eq!(a.f64_or("lr", 0.0).unwrap(), -0.5);
        assert_eq!(a.get("delta"), Some("-3"));
        assert!(a.usize_or("delta", 0).is_err(), "negative is not a usize");
        assert_eq!(a.f64_or("offset", 0.0).unwrap(), -7.0);
        assert!(a.positional.is_empty());
    }

    #[test]
    fn double_dash_token_is_never_a_value() {
        // `--a --b` makes both bare flags; `--` alone is a bare flag with
        // empty name (degenerate but must not panic or consume `x`).
        let a = parse("--a --b x");
        assert!(a.bool_or("a", false).unwrap());
        assert_eq!(a.get("b"), Some("x"));
        let b = parse("-- x");
        assert_eq!(b.get(""), Some("x"));
    }

    #[test]
    fn trailing_value_flag_is_a_parse_error_not_a_panic() {
        // Regression: `hecate fssdp --devices` (a value-taking flag as the
        // final token) must produce a parse error from the typed accessors
        // rather than panicking or silently acting as `--devices true`.
        let a = parse("--devices");
        assert!(a.is_bare("devices"));
        let err = a.usize_or("devices", 8).unwrap_err().to_string();
        assert!(err.contains("expects a value"), "{err}");
        assert!(a.f64_or("devices", 1.0).is_err());
        assert!(a.req("devices").is_err());
        assert!(a.str_or("devices", "x").is_err());
        assert!(a.str_opt("devices").is_err());
        // ...same when the bare flag precedes another --flag
        let b = parse("--checkpoint-dir --reference");
        assert!(b.str_opt("checkpoint-dir").is_err());
        assert!(b.bool_or("reference", false).unwrap());
        // a bare flag used as a bool is still fine
        assert!(a.bool_or("devices", false).unwrap());
        // and an explicit value clears bareness
        let c = parse("--devices --devices=4");
        assert!(!c.is_bare("devices"));
        assert_eq!(c.usize_or("devices", 0).unwrap(), 4);
    }

    #[test]
    fn str_accessors_pass_real_values_through() {
        let a = parse("--dir /tmp/x --mode fast");
        assert_eq!(a.str_or("dir", "d").unwrap(), "/tmp/x");
        assert_eq!(a.str_or("missing", "d").unwrap(), "d");
        assert_eq!(a.str_opt("mode").unwrap(), Some("fast".to_string()));
        assert_eq!(a.str_opt("missing").unwrap(), None);
    }

    #[test]
    fn checkpoint_resume_subcommand_flags_parse() {
        // The exact flag shapes the coordinator's checkpoint/resume flows use.
        let a = parse("--dir /tmp/ckpt --devices 8 --iters 20 --checkpoint-every 5");
        assert_eq!(a.req("dir").unwrap(), "/tmp/ckpt");
        assert_eq!(a.usize_or("devices", 0).unwrap(), 8);
        assert_eq!(a.usize_or("checkpoint-every", 0).unwrap(), 5);
        assert!(a.reject_unknown(&["dir", "devices", "iters", "checkpoint-every"]).is_ok());
        let b = parse("--resume=/data/run 1/ckpt --reference");
        // `=` form keeps paths with spaces intact per token; the stray token
        // becomes positional, and --reference stays a bare flag.
        assert_eq!(b.get("resume"), Some("/data/run"));
        assert_eq!(b.positional, vec!["1/ckpt"]);
        assert!(b.bool_or("reference", false).unwrap());
    }
}
