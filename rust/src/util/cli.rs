//! Minimal command-line argument parser (the registry snapshot has no
//! `clap`). Supports `--flag`, `--key value`, `--key=value`, and positional
//! arguments, with typed accessors and a generated usage string.

use std::collections::BTreeMap;

/// Parsed arguments for one (sub)command.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// `--key value` / `--key=value` pairs. A bare `--flag` maps to "true".
    opts: BTreeMap<String, String>,
    /// Positional arguments in order.
    pub positional: Vec<String>,
}

impl Args {
    /// Parse a raw arg list (excluding the program/subcommand name).
    ///
    /// A token starting with `--` either contains `=` (split there) or, if
    /// the next token does not start with `--`, consumes it as the value;
    /// otherwise it is a boolean flag.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let toks: Vec<String> = raw.into_iter().collect();
        let mut args = Args::default();
        let mut i = 0;
        while i < toks.len() {
            let t = &toks[i];
            if let Some(body) = t.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    args.opts.insert(k.to_string(), v.to_string());
                } else if i + 1 < toks.len() && !toks[i + 1].starts_with("--") {
                    args.opts.insert(body.to_string(), toks[i + 1].clone());
                    i += 1;
                } else {
                    args.opts.insert(body.to_string(), "true".to_string());
                }
            } else {
                args.positional.push(t.clone());
            }
            i += 1;
        }
        args
    }

    pub fn has(&self, key: &str) -> bool {
        self.opts.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got `{v}`")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects a number, got `{v}`")),
        }
    }

    pub fn bool_or(&self, key: &str, default: bool) -> anyhow::Result<bool> {
        match self.get(key) {
            None => Ok(default),
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(v) => Err(anyhow::anyhow!("--{key} expects a bool, got `{v}`")),
        }
    }

    /// Required string option.
    pub fn req(&self, key: &str) -> anyhow::Result<String> {
        self.get(key)
            .map(|s| s.to_string())
            .ok_or_else(|| anyhow::anyhow!("missing required option --{key}"))
    }

    /// All unknown keys, for strict validation.
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.opts.keys().map(|s| s.as_str())
    }

    /// Error if any provided option is not in `allowed`.
    pub fn reject_unknown(&self, allowed: &[&str]) -> anyhow::Result<()> {
        for k in self.keys() {
            if !allowed.contains(&k) {
                return Err(anyhow::anyhow!(
                    "unknown option --{k}; expected one of: {}",
                    allowed.iter().map(|a| format!("--{a}")).collect::<Vec<_>>().join(", ")
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|t| t.to_string()))
    }

    #[test]
    fn key_value_forms() {
        // NB: a bare `--flag` greedily consumes a following non-`--` token,
        // so positionals go before flags (or use `--flag=true`).
        let a = parse("pos1 pos2 --model gpt-moe-s --gpus=32 --verbose");
        assert_eq!(a.get("model"), Some("gpt-moe-s"));
        assert_eq!(a.usize_or("gpus", 0).unwrap(), 32);
        assert!(a.bool_or("verbose", false).unwrap());
        assert_eq!(a.positional, vec!["pos1", "pos2"]);
    }

    #[test]
    fn flag_before_flag() {
        let a = parse("--rm --steps 10");
        assert!(a.bool_or("rm", false).unwrap());
        assert_eq!(a.usize_or("steps", 0).unwrap(), 10);
    }

    #[test]
    fn defaults_and_required() {
        let a = parse("");
        assert_eq!(a.usize_or("n", 5).unwrap(), 5);
        assert!(a.req("x").is_err());
    }

    #[test]
    fn type_errors() {
        let a = parse("--n abc");
        assert!(a.usize_or("n", 0).is_err());
        assert!(a.f64_or("n", 0.0).is_err());
    }

    #[test]
    fn reject_unknown() {
        let a = parse("--good 1 --bad 2");
        assert!(a.reject_unknown(&["good"]).is_err());
        assert!(a.reject_unknown(&["good", "bad"]).is_ok());
    }
}
