//! Infrastructure substrates built in-repo because the build environment is
//! offline (no serde / clap / rand / criterion in the registry): a minimal
//! JSON codec, a fast deterministic PRNG with the distributions the load
//! simulator needs, summary statistics, a CLI argument parser, and a tiny
//! logger.

pub mod cli;
pub mod json;
pub mod logging;
pub mod rng;
pub mod stats;
