//! Metrics: named timers/counters and table rendering for the repro
//! drivers (markdown + CSV so EXPERIMENTS.md rows are copy-pasteable).
//!
//! The string-keyed [`Metrics`] type below is the legacy shim; the typed
//! registry ([`registry::Registry`] with `Counter`/`Gauge`/`Histogram`
//! series and label sets) and the step meter ([`meter::StepMeter`], the
//! per-rank memory ledger + load observatory) are the PR-7 surface.

pub mod meter;
pub mod registry;

use std::collections::{BTreeMap, BTreeSet};
use std::time::{Duration, Instant};

/// Accumulating named metrics.
///
/// Two merge semantics coexist under one key space: values written with
/// [`Metrics::add`] are **counters** (summed by [`Metrics::merge`], the
/// multi-rank aggregation), while values written with [`Metrics::set`]
/// are **gauges** (per-rank levels; `merge` takes the max instead of
/// inflating them by the rank count).
#[derive(Debug, Default, Clone)]
pub struct Metrics {
    counters: BTreeMap<String, f64>,
    timers: BTreeMap<String, Duration>,
    /// Keys written via [`Metrics::set`]: gauge semantics under merge.
    gauges: BTreeSet<String>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn add(&mut self, name: &str, v: f64) {
        *self.counters.entry(name.to_string()).or_default() += v;
    }

    pub fn counter(&self, name: &str) -> f64 {
        self.counters.get(name).copied().unwrap_or(0.0)
    }

    /// Overwrite a counter (gauges that must not sum under [`Metrics::merge`]).
    pub fn set(&mut self, name: &str, v: f64) {
        self.counters.insert(name.to_string(), v);
        self.gauges.insert(name.to_string());
    }

    /// Accumulate an externally measured duration (for call sites where a
    /// closure does not fit, e.g. `?`-heavy phases of the SPMD rank loop).
    pub fn add_duration(&mut self, name: &str, d: Duration) {
        *self.timers.entry(name.to_string()).or_default() += d;
    }

    /// Merge another metrics set into this one: counters and timers sum,
    /// gauges (keys written via [`Metrics::set`] on either side) take the
    /// max. This is the multi-rank aggregation path: each SPMD rank
    /// records into a local `Metrics` (no locks on the hot path) and the
    /// executor merges them after the span — summing a per-rank gauge
    /// like `spmd.ws_allocs` across N ranks would inflate it N×, so the
    /// merged gauge reports the worst rank instead.
    pub fn merge(&mut self, other: &Metrics) {
        for (k, v) in &other.counters {
            if self.gauges.contains(k) || other.gauges.contains(k) {
                let e = self.counters.entry(k.clone()).or_insert(f64::NEG_INFINITY);
                *e = e.max(*v);
            } else {
                *self.counters.entry(k.clone()).or_default() += v;
            }
        }
        for g in &other.gauges {
            self.gauges.insert(g.clone());
        }
        for (k, v) in &other.timers {
            *self.timers.entry(k.clone()).or_default() += *v;
        }
    }

    pub fn time<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        *self.timers.entry(name.to_string()).or_default() += t0.elapsed();
        out
    }

    pub fn timer(&self, name: &str) -> Duration {
        self.timers.get(name).copied().unwrap_or_default()
    }

    pub fn report(&self) -> String {
        let mut s = String::new();
        for (k, v) in &self.counters {
            s.push_str(&format!("{k}: {v}\n"));
        }
        for (k, v) in &self.timers {
            s.push_str(&format!("{k}: {:?}\n", v));
        }
        s
    }
}

/// A simple column-aligned table for repro output.
#[derive(Debug, Clone)]
pub struct Table {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
    }

    /// Markdown rendering (also valid for EXPERIMENTS.md).
    pub fn to_markdown(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.chars().count();
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                width[i] = width[i].max(c.chars().count());
            }
        }
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<w$} |", c, w = width[i]));
            }
            s.push('\n');
            s
        };
        let mut out = fmt_row(&self.header);
        out.push('|');
        for w in &width {
            out.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r));
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let mut out = self.header.join(",");
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.join(","));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let mut m = Metrics::new();
        m.add("tokens", 10.0);
        m.add("tokens", 5.0);
        assert_eq!(m.counter("tokens"), 15.0);
        assert_eq!(m.counter("missing"), 0.0);
    }

    #[test]
    fn timers_measure() {
        let mut m = Metrics::new();
        let v = m.time("work", || {
            std::thread::sleep(Duration::from_millis(5));
            42
        });
        assert_eq!(v, 42);
        assert!(m.timer("work") >= Duration::from_millis(4));
    }

    #[test]
    fn merge_sums_counters_and_timers() {
        let mut a = Metrics::new();
        a.add("tokens", 10.0);
        a.add("groups", 2.0);
        a.add_duration("compute", Duration::from_millis(30));
        let mut b = Metrics::new();
        b.add("tokens", 5.0);
        b.add("sends", 7.0);
        b.add_duration("compute", Duration::from_millis(20));
        b.add_duration("comm", Duration::from_millis(4));
        a.merge(&b);
        assert_eq!(a.counter("tokens"), 15.0);
        assert_eq!(a.counter("groups"), 2.0);
        assert_eq!(a.counter("sends"), 7.0);
        assert_eq!(a.timer("compute"), Duration::from_millis(50));
        assert_eq!(a.timer("comm"), Duration::from_millis(4));
        // merge with empty is identity
        let snapshot = a.clone();
        a.merge(&Metrics::new());
        assert_eq!(a.counter("tokens"), snapshot.counter("tokens"));
        assert_eq!(a.timer("compute"), snapshot.timer("compute"));
    }

    #[test]
    fn gauges_take_max_under_an_8_rank_merge() {
        // Regression: per-rank gauges written via `set()` (pool levels,
        // `spmd.ws_allocs`) were summed across ranks on merge, reporting
        // 8× the actual per-rank value after an 8-rank span.
        let mut merged = Metrics::new();
        for rank in 0..8 {
            let mut m = Metrics::new();
            m.set("spmd.ws_allocs", 3.0); // same level on every rank
            m.set("pool.idle", rank as f64); // rank 7 holds the most
            m.add("spmd.sends", 10.0); // counters still sum
            merged.merge(&m);
        }
        assert_eq!(merged.counter("spmd.ws_allocs"), 3.0, "gauge must not sum");
        assert_eq!(merged.counter("pool.idle"), 7.0, "gauge merge takes the max");
        assert_eq!(merged.counter("spmd.sends"), 80.0, "counters keep summing");
        // a later local set() still overwrites
        merged.set("pool.idle", 1.0);
        assert_eq!(merged.counter("pool.idle"), 1.0);
    }

    #[test]
    fn table_markdown_and_csv() {
        let mut t = Table::new(&["system", "speedup"]);
        t.row(vec!["Hecate".into(), "3.54".into()]);
        let md = t.to_markdown();
        assert!(md.contains("| Hecate"));
        assert!(md.contains("|---"));
        let csv = t.to_csv();
        assert!(csv.starts_with("system,speedup\n"));
        assert!(csv.contains("Hecate,3.54"));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn table_rejects_bad_rows() {
        let mut t = Table::new(&["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }
}
