//! The step meter: per-rank memory ledger + expert-load observatory.
//!
//! A [`StepMeter`] is the state-domain twin of the telemetry
//! `TraceRecorder` (PR 6 covered the *time* domain): when metering is on,
//! the engine holds `Some(StepMeter)` and every step samples
//!
//! * **memory** — resident expert bytes per rank per layer (right after
//!   spAG materializes the layer, i.e. the per-iteration peak of
//!   shards + replicas), workspace-pool idle bytes, and the communicator
//!   payload free-list bytes, with per-`(rank, layer)` high-water marks;
//! * **load** — the realized expert-load distribution's imbalance ratio
//!   (max/mean), gate entropy, and the `LoadPredictor`'s accuracy against
//!   it (per-step MAE + rank-order correlation).
//!
//! Metering is purely observational: samples are *reads* of existing
//! state, recorded into plain `Vec`s owned by the meter — the training
//! math, the buffer pools, and the `ws_allocs == 0` steady-state lock are
//! untouched, and a metered run is bit-identical to an unmetered one.
//! Every instrumentation site is one `Option` branch, mirroring the
//! tracing discipline.
//!
//! The analytic FSSDP memory model ([`MemModel`]) prices the same
//! quantity from the iteration plan — placement chunks × chunk bytes —
//! next to the replicated (every expert everywhere) and EP (shards only)
//! baselines, so the measured ledger can be checked against expectation.

use std::time::Instant;

/// One memory-ledger sample (bytes, one rank × layer × iteration).
#[derive(Debug, Clone, PartialEq)]
pub struct MemSample {
    /// Microseconds since the meter epoch (counter-track timestamp).
    pub ts_us: f64,
    pub iter: u32,
    pub layer: u32,
    pub rank: u32,
    /// Chunk bytes resident in the layer's store right after spAG
    /// (owned shards + materialized replicas — the per-iteration peak).
    pub resident_bytes: u64,
    /// Idle capacity held by the workspace [`BufferPool`] free list.
    ///
    /// [`BufferPool`]: crate::collectives::exec::BufferPool
    pub pool_idle_bytes: u64,
    /// Idle capacity held by the communicator payload free list
    /// (0 on the sequential executor — no wire).
    pub payload_idle_bytes: u64,
}

/// One load-observatory sample (one layer × iteration; the control plane
/// is replicated, so SPMD records these on rank 0 only).
#[derive(Debug, Clone, PartialEq)]
pub struct LoadSample {
    /// Microseconds since the meter epoch (counter-track timestamp).
    pub ts_us: f64,
    pub iter: u32,
    pub layer: u32,
    /// Imbalance ratio of the realized loads: max/mean (1.0 = perfectly
    /// balanced; EP's straggler factor).
    pub imbalance: f64,
    /// Gate entropy of the realized distribution, −Σ p·ln p (nats).
    pub entropy: f64,
    /// Mean absolute error of the plan-time prediction vs realized loads.
    pub mae: f64,
    /// Spearman rank-order correlation of prediction vs realized loads
    /// (0.0 when either side is constant, e.g. the uniform cold start).
    pub rank_corr: f64,
    /// Hottest realized expert fraction (the load histogram's tail).
    pub max_load: f64,
}

/// Per-rank memory + load samples for a run, absorbed across SPMD ranks
/// the way trace recorders are.
#[derive(Debug, Clone)]
pub struct StepMeter {
    epoch: Instant,
    rank: u32,
    mem: Vec<MemSample>,
    load: Vec<LoadSample>,
}

impl StepMeter {
    /// Fresh meter for `rank`, with its own epoch.
    pub fn new(rank: u32) -> StepMeter {
        StepMeter::with_epoch(Instant::now(), rank)
    }

    /// Meter sharing an existing epoch (SPMD ranks share the tracer's so
    /// counter tracks line up with span rows).
    pub fn with_epoch(epoch: Instant, rank: u32) -> StepMeter {
        StepMeter { epoch, rank, mem: Vec::new(), load: Vec::new() }
    }

    /// The shared epoch.
    pub fn epoch(&self) -> Instant {
        self.epoch
    }

    /// The rank this meter records as (memory samples may override it
    /// per call — the sequential engine meters all devices).
    pub fn rank(&self) -> u32 {
        self.rank
    }

    /// Record a memory-ledger sample for `(rank, layer)` at this instant.
    pub fn sample_mem(
        &mut self,
        iter: usize,
        layer: usize,
        rank: usize,
        resident_bytes: u64,
        pool_idle_bytes: u64,
        payload_idle_bytes: u64,
    ) {
        self.mem.push(MemSample {
            ts_us: self.epoch.elapsed().as_secs_f64() * 1e6,
            iter: iter as u32,
            layer: layer as u32,
            rank: rank as u32,
            resident_bytes,
            pool_idle_bytes,
            payload_idle_bytes,
        });
    }

    /// Record a load-observatory sample: `predicted` is the plan-time
    /// `LoadPredictor::predict()` output, `realized` the fractions the
    /// gate actually produced.
    pub fn sample_load(&mut self, iter: usize, layer: usize, predicted: &[f64], realized: &[f64]) {
        self.load.push(LoadSample {
            ts_us: self.epoch.elapsed().as_secs_f64() * 1e6,
            iter: iter as u32,
            layer: layer as u32,
            imbalance: imbalance_ratio(realized),
            entropy: gate_entropy(realized),
            mae: mean_absolute_error(predicted, realized),
            rank_corr: rank_correlation(predicted, realized),
            max_load: realized.iter().cloned().fold(0.0, f64::max),
        });
    }

    /// All memory samples, in record order.
    pub fn mem_samples(&self) -> &[MemSample] {
        &self.mem
    }

    /// All load samples, in record order.
    pub fn load_samples(&self) -> &[LoadSample] {
        &self.load
    }

    /// High-water resident bytes per `(rank, layer)`, derived from the
    /// ledger (0 entries are never created — no samples, no water).
    pub fn high_water(&self) -> std::collections::BTreeMap<(u32, u32), u64> {
        let mut hw = std::collections::BTreeMap::new();
        for s in &self.mem {
            let e = hw.entry((s.rank, s.layer)).or_insert(0u64);
            *e = (*e).max(s.resident_bytes);
        }
        hw
    }

    /// Absorb another rank's samples (SPMD span exit, rank order).
    pub fn absorb(&mut self, other: StepMeter) {
        self.mem.extend(other.mem);
        self.load.extend(other.load);
    }

    /// Number of samples recorded (both ledgers).
    pub fn len(&self) -> usize {
        self.mem.len() + self.load.len()
    }

    pub fn is_empty(&self) -> bool {
        self.mem.is_empty() && self.load.is_empty()
    }
}

/// Analytic per-device memory model: FSSDP (placement chunks) vs the
/// replicated baseline (every expert on every device) vs EP (shards
/// only), all in bytes of expert parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MemModel {
    /// FSSDP: chunks the iteration plan materializes on the device
    /// (shards + replicas) × chunk bytes.
    pub fssdp_bytes: u64,
    /// Replicated/DP baseline: all experts × chunk bytes.
    pub replicated_bytes: u64,
    /// EP baseline: owned shards only × chunk bytes.
    pub ep_bytes: u64,
}

impl MemModel {
    /// Price one device's layer from chunk counts.
    pub fn per_device(
        placement_chunks: usize,
        shard_chunks: usize,
        experts: usize,
        chunk_len: usize,
    ) -> MemModel {
        let b = chunk_len as u64 * 4;
        MemModel {
            fssdp_bytes: placement_chunks as u64 * b,
            replicated_bytes: experts as u64 * b,
            ep_bytes: shard_chunks as u64 * b,
        }
    }
}

/// Imbalance ratio of a load distribution: max/mean (≥ 1.0 whenever the
/// loads are non-negative and not all zero; 1.0 on empty/zero input).
pub fn imbalance_ratio(loads: &[f64]) -> f64 {
    if loads.is_empty() {
        return 1.0;
    }
    let max = loads.iter().cloned().fold(f64::MIN, f64::max);
    let mean = loads.iter().sum::<f64>() / loads.len() as f64;
    if mean <= 0.0 {
        1.0
    } else {
        max / mean
    }
}

/// Gate entropy −Σ p·ln p over the distribution (zero entries skipped;
/// the input need not be normalized — it is re-normalized first).
pub fn gate_entropy(loads: &[f64]) -> f64 {
    let total: f64 = loads.iter().filter(|&&p| p > 0.0).sum();
    if total <= 0.0 {
        return 0.0;
    }
    -loads
        .iter()
        .filter(|&&p| p > 0.0)
        .map(|&p| {
            let q = p / total;
            q * q.ln()
        })
        .sum::<f64>()
}

/// Mean absolute error between two equal-length distributions.
pub fn mean_absolute_error(pred: &[f64], real: &[f64]) -> f64 {
    assert_eq!(pred.len(), real.len(), "MAE needs equal-length inputs");
    if pred.is_empty() {
        return 0.0;
    }
    pred.iter().zip(real.iter()).map(|(a, b)| (a - b).abs()).sum::<f64>() / pred.len() as f64
}

/// Average fractional ranks (ties share the mean of the positions they
/// occupy — standard Spearman tie handling).
fn average_ranks(v: &[f64]) -> Vec<f64> {
    let n = v.len();
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| v[a].total_cmp(&v[b]));
    let mut ranks = vec![0.0; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && v[idx[j + 1]] == v[idx[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0 + 1.0; // 1-based average position
        for &k in &idx[i..=j] {
            ranks[k] = avg;
        }
        i = j + 1;
    }
    ranks
}

/// Spearman rank-order correlation of two equal-length sequences:
/// Pearson correlation of their average ranks, in `[-1, 1]`. Returns 0.0
/// when either side is constant (the uniform cold-start prediction has
/// no ordering to correlate).
pub fn rank_correlation(pred: &[f64], real: &[f64]) -> f64 {
    assert_eq!(pred.len(), real.len(), "rank correlation needs equal-length inputs");
    let n = pred.len();
    if n < 2 {
        return 0.0;
    }
    let ra = average_ranks(pred);
    let rb = average_ranks(real);
    let mean = (n as f64 + 1.0) / 2.0; // ranks always average to this
    let mut cov = 0.0;
    let mut va = 0.0;
    let mut vb = 0.0;
    for (a, b) in ra.iter().zip(rb.iter()) {
        let da = a - mean;
        let db = b - mean;
        cov += da * db;
        va += da * da;
        vb += db * db;
    }
    if va <= 0.0 || vb <= 0.0 {
        return 0.0;
    }
    cov / (va.sqrt() * vb.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn imbalance_and_entropy_known_answers() {
        assert_eq!(imbalance_ratio(&[0.25, 0.25, 0.25, 0.25]), 1.0);
        // mean 0.25, max 0.7 → 2.8
        let r = imbalance_ratio(&[0.7, 0.1, 0.1, 0.1]);
        assert!((r - 2.8).abs() < 1e-12, "{r}");
        assert_eq!(imbalance_ratio(&[]), 1.0);
        assert_eq!(imbalance_ratio(&[0.0, 0.0]), 1.0);

        // uniform over 4 → ln 4; degenerate → 0
        assert!((gate_entropy(&[0.25; 4]) - 4.0f64.ln()).abs() < 1e-12);
        assert_eq!(gate_entropy(&[1.0, 0.0, 0.0]), 0.0);
        assert_eq!(gate_entropy(&[0.0; 3]), 0.0);
    }

    #[test]
    fn mae_known_answer() {
        // |0.5-0.25| + |0.25-0.25| + |0.25-0.5| = 0.5 over 3 experts
        let mae = mean_absolute_error(&[0.5, 0.25, 0.25], &[0.25, 0.25, 0.5]);
        assert!((mae - 0.5 / 3.0).abs() < 1e-12, "{mae}");
        assert_eq!(mean_absolute_error(&[0.3, 0.7], &[0.3, 0.7]), 0.0);
    }

    #[test]
    fn rank_correlation_known_answers() {
        // perfectly concordant / discordant orderings
        assert!(
            (rank_correlation(&[0.1, 0.2, 0.3, 0.4], &[1.0, 2.0, 3.0, 4.0]) - 1.0).abs() < 1e-12
        );
        assert!(
            (rank_correlation(&[0.1, 0.2, 0.3, 0.4], &[4.0, 3.0, 2.0, 1.0]) + 1.0).abs() < 1e-12
        );
        // constant side (uniform cold-start prediction) → defined as 0
        assert_eq!(rank_correlation(&[0.25; 4], &[0.1, 0.2, 0.3, 0.4]), 0.0);
        // hand-computed with one swap: ranks (1,2,3,4) vs (1,2,4,3)
        // Spearman = 1 − 6·Σd²/(n(n²−1)) = 1 − 6·2/60 = 0.8
        let r = rank_correlation(&[0.1, 0.2, 0.3, 0.4], &[0.1, 0.2, 0.4, 0.3]);
        assert!((r - 0.8).abs() < 1e-12, "{r}");
        // ties get average ranks: [1, 2, 2] → ranks (1, 2.5, 2.5)
        let r = rank_correlation(&[1.0, 2.0, 2.0], &[1.0, 2.0, 3.0]);
        assert!(r > 0.0 && r < 1.0, "tied ranks correlate partially: {r}");
    }

    #[test]
    fn meter_samples_and_high_water() {
        let mut m = StepMeter::new(0);
        m.sample_mem(0, 0, 0, 1000, 64, 0);
        m.sample_mem(1, 0, 0, 1400, 64, 0);
        m.sample_mem(1, 1, 0, 600, 64, 0);
        m.sample_load(0, 0, &[0.25; 4], &[0.4, 0.3, 0.2, 0.1]);
        assert_eq!(m.mem_samples().len(), 3);
        assert_eq!(m.load_samples().len(), 1);
        assert_eq!(m.len(), 4);
        let hw = m.high_water();
        assert_eq!(hw[&(0, 0)], 1400);
        assert_eq!(hw[&(0, 1)], 600);
        // high-water dominates every sample
        for s in m.mem_samples() {
            assert!(hw[&(s.rank, s.layer)] >= s.resident_bytes);
        }
        // absorb another rank's meter
        let mut other = StepMeter::with_epoch(m.epoch(), 1);
        other.sample_mem(0, 0, 1, 2000, 0, 128);
        m.absorb(other);
        assert_eq!(m.mem_samples().len(), 4);
        assert_eq!(m.high_water()[&(1, 0)], 2000);
    }

    #[test]
    fn mem_model_per_device() {
        let m = MemModel::per_device(5, 2, 8, 280);
        assert_eq!(m.fssdp_bytes, 5 * 280 * 4);
        assert_eq!(m.replicated_bytes, 8 * 280 * 4);
        assert_eq!(m.ep_bytes, 2 * 280 * 4);
    }
}
