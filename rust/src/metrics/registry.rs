//! Typed metrics registry: `Counter` / `Gauge` / `Histogram` series with
//! label sets, per-rank instances merged with correct semantics (counters
//! sum, gauges take the max, histograms merge bucket-wise), and a
//! Prometheus-style text exposition with a matching parser so exports can
//! be round-trip checked.
//!
//! The registry is plain data — no interior mutability, no locks: each
//! SPMD rank records into its own [`Registry`] and the executor calls
//! [`Registry::merge`] after the span, mirroring the legacy
//! [`Metrics`](super::Metrics) aggregation path.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Number of log-2 histogram buckets. Bucket `i` counts observations with
/// `value <= 2^i` (bucket 0 also catches everything `<= 1`, including
/// zero and negatives); values beyond the last bound land in the overflow
/// bucket rendered as `+Inf`.
pub const HISTOGRAM_BUCKETS: usize = 40;

/// One metric series: the merge/exposition semantics plus the value.
#[derive(Debug, Clone, PartialEq)]
pub enum Series {
    /// Monotone total; merge sums.
    Counter(f64),
    /// Instantaneous level; merge takes the max (the worst rank).
    Gauge(f64),
    /// Fixed log-2-bucket distribution; merge adds bucket-wise.
    Histogram(Histogram),
}

/// Fixed-bucket log-2 histogram (`HISTOGRAM_BUCKETS` bounds `2^0..2^39`
/// plus an overflow bucket), with the running count and sum Prometheus
/// exposition needs.
#[derive(Debug, Clone, PartialEq)]
pub struct Histogram {
    /// `buckets[i]` counts observations `<= 2^i`; the last slot is the
    /// `+Inf` overflow bucket.
    buckets: Vec<u64>,
    count: u64,
    sum: f64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram { buckets: vec![0; HISTOGRAM_BUCKETS + 1], count: 0, sum: 0.0 }
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram::default()
    }

    /// Index of the first bucket whose upper bound covers `v`.
    fn bucket_index(v: f64) -> usize {
        if !v.is_finite() {
            return HISTOGRAM_BUCKETS; // overflow bucket
        }
        let mut bound = 1.0f64;
        for i in 0..HISTOGRAM_BUCKETS {
            if v <= bound {
                return i;
            }
            bound *= 2.0;
        }
        HISTOGRAM_BUCKETS
    }

    /// Record one observation.
    pub fn observe(&mut self, v: f64) {
        self.buckets[Self::bucket_index(v)] += 1;
        self.count += 1;
        self.sum += v;
    }

    /// Total observations.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Sum of all observed values.
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Per-bucket counts (non-cumulative), overflow bucket last.
    pub fn buckets(&self) -> &[u64] {
        &self.buckets
    }

    /// Upper bound of bucket `i` (`None` for the overflow bucket).
    pub fn bound(i: usize) -> Option<f64> {
        (i < HISTOGRAM_BUCKETS).then(|| 2.0f64.powi(i as i32))
    }

    /// Bucket-wise merge: distributions from different ranks add.
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
    }
}

/// Series identity: metric name plus a sorted label set
/// (`BTreeMap` keeps the exposition deterministic).
pub type Labels = BTreeMap<String, String>;

/// Helper: build a label set from `(key, value)` pairs.
pub fn labels(pairs: &[(&str, &str)]) -> Labels {
    pairs.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect()
}

/// The typed registry: `(name, labels) → Series`.
#[derive(Debug, Default, Clone)]
pub struct Registry {
    series: BTreeMap<(String, Labels), Series>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Add to a counter series (created at zero on first touch).
    pub fn counter_add(&mut self, name: &str, labels: Labels, v: f64) {
        let e = self
            .series
            .entry((name.to_string(), labels))
            .or_insert(Series::Counter(0.0));
        match e {
            Series::Counter(c) => *c += v,
            other => panic!("metric {name} is not a counter: {other:?}"),
        }
    }

    /// Set a gauge series to an instantaneous level.
    pub fn gauge_set(&mut self, name: &str, labels: Labels, v: f64) {
        let e = self
            .series
            .entry((name.to_string(), labels))
            .or_insert(Series::Gauge(f64::NEG_INFINITY));
        match e {
            Series::Gauge(g) => *g = v,
            other => panic!("metric {name} is not a gauge: {other:?}"),
        }
    }

    /// Record one observation into a histogram series.
    pub fn histogram_observe(&mut self, name: &str, labels: Labels, v: f64) {
        let e = self
            .series
            .entry((name.to_string(), labels))
            .or_insert_with(|| Series::Histogram(Histogram::new()));
        match e {
            Series::Histogram(h) => h.observe(v),
            other => panic!("metric {name} is not a histogram: {other:?}"),
        }
    }

    /// Read a series back (exact name + label match).
    pub fn get(&self, name: &str, labels: &Labels) -> Option<&Series> {
        self.series.get(&(name.to_string(), labels.clone()))
    }

    /// Scalar value of a counter/gauge series, 0.0 when absent.
    pub fn value(&self, name: &str, labels: &Labels) -> f64 {
        match self.get(name, labels) {
            Some(Series::Counter(v)) | Some(Series::Gauge(v)) => *v,
            _ => 0.0,
        }
    }

    /// Number of series.
    pub fn len(&self) -> usize {
        self.series.len()
    }

    pub fn is_empty(&self) -> bool {
        self.series.is_empty()
    }

    /// All series in deterministic `(name, labels)` order.
    pub fn iter(&self) -> impl Iterator<Item = (&(String, Labels), &Series)> {
        self.series.iter()
    }

    /// Multi-rank aggregation: counters sum, gauges take the max,
    /// histograms merge bucket-wise. A series kind mismatch between the
    /// two registries is a programming error and panics.
    pub fn merge(&mut self, other: &Registry) {
        for (key, s) in &other.series {
            match self.series.get_mut(key) {
                None => {
                    self.series.insert(key.clone(), s.clone());
                }
                Some(mine) => match (mine, s) {
                    (Series::Counter(a), Series::Counter(b)) => *a += b,
                    (Series::Gauge(a), Series::Gauge(b)) => *a = a.max(*b),
                    (Series::Histogram(a), Series::Histogram(b)) => a.merge(b),
                    (mine, s) => {
                        panic!("metric {} kind mismatch: {mine:?} vs {s:?}", key.0)
                    }
                },
            }
        }
    }

    /// Prometheus text exposition: `# TYPE` comment per metric name, one
    /// sample line per series, histograms expanded into cumulative
    /// `_bucket{le=…}` lines plus `_sum`/`_count`.
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        let mut last_name: Option<&str> = None;
        for ((name, labels), s) in &self.series {
            if last_name != Some(name.as_str()) {
                let kind = match s {
                    Series::Counter(_) => "counter",
                    Series::Gauge(_) => "gauge",
                    Series::Histogram(_) => "histogram",
                };
                let _ = writeln!(out, "# TYPE {name} {kind}");
                last_name = Some(name.as_str());
            }
            match s {
                Series::Counter(v) | Series::Gauge(v) => {
                    let _ = writeln!(out, "{name}{} {v}", render_labels(labels, None));
                }
                Series::Histogram(h) => {
                    let mut cum = 0u64;
                    for (i, n) in h.buckets().iter().enumerate() {
                        cum += n;
                        let le = Histogram::bound(i)
                            .map(|b| format!("{b}"))
                            .unwrap_or_else(|| "+Inf".to_string());
                        let _ = writeln!(
                            out,
                            "{name}_bucket{} {cum}",
                            render_labels(labels, Some(&le))
                        );
                    }
                    let _ = writeln!(out, "{name}_sum{} {}", render_labels(labels, None), h.sum());
                    let _ =
                        writeln!(out, "{name}_count{} {}", render_labels(labels, None), h.count());
                }
            }
        }
        out
    }
}

fn render_labels(labels: &Labels, le: Option<&str>) -> String {
    if labels.is_empty() && le.is_none() {
        return String::new();
    }
    let mut parts: Vec<String> =
        labels.iter().map(|(k, v)| format!("{k}=\"{v}\"")).collect();
    if let Some(le) = le {
        parts.push(format!("le=\"{le}\""));
    }
    format!("{{{}}}", parts.join(","))
}

/// One parsed Prometheus sample line.
#[derive(Debug, Clone, PartialEq)]
pub struct PromSample {
    pub name: String,
    pub labels: Labels,
    pub value: f64,
}

/// Minimal Prometheus text-format parser — enough to round-trip
/// [`Registry::to_prometheus`] output (the CI export check). Comment and
/// blank lines are skipped; anything else must be
/// `name[{k="v",…}] value`.
pub fn parse_prometheus(text: &str) -> anyhow::Result<Vec<PromSample>> {
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let err = |what: &str| anyhow::anyhow!("prometheus line {}: {what}: `{line}`", i + 1);
        let (head, value) = match line.rsplit_once(' ') {
            Some(parts) => parts,
            None => return Err(err("expected `name value`")),
        };
        let value: f64 = value.parse().map_err(|_| err("unparseable value"))?;
        let (name, labels) = match head.split_once('{') {
            None => (head.to_string(), Labels::new()),
            Some((name, rest)) => {
                let body = rest.strip_suffix('}').ok_or_else(|| err("unterminated labels"))?;
                let mut labels = Labels::new();
                for pair in body.split(',').filter(|p| !p.is_empty()) {
                    let (k, v) = pair.split_once('=').ok_or_else(|| err("bad label pair"))?;
                    let v = v
                        .strip_prefix('"')
                        .and_then(|v| v.strip_suffix('"'))
                        .ok_or_else(|| err("unquoted label value"))?;
                    labels.insert(k.to_string(), v.to_string());
                }
                (name.to_string(), labels)
            }
        };
        out.push(PromSample { name, labels, value });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_gauge_histogram_merge_semantics() {
        let mut a = Registry::new();
        a.counter_add("sends", labels(&[("rank", "0")]), 10.0);
        a.gauge_set("resident_bytes", labels(&[("rank", "0")]), 640.0);
        a.histogram_observe("load", Labels::new(), 3.0);
        let mut b = Registry::new();
        b.counter_add("sends", labels(&[("rank", "0")]), 4.0);
        b.gauge_set("resident_bytes", labels(&[("rank", "0")]), 320.0);
        b.gauge_set("resident_bytes", labels(&[("rank", "1")]), 960.0);
        b.histogram_observe("load", Labels::new(), 100.0);
        a.merge(&b);
        assert_eq!(a.value("sends", &labels(&[("rank", "0")])), 14.0);
        assert_eq!(
            a.value("resident_bytes", &labels(&[("rank", "0")])),
            640.0,
            "gauge merge takes the max, not the sum"
        );
        assert_eq!(a.value("resident_bytes", &labels(&[("rank", "1")])), 960.0);
        match a.get("load", &Labels::new()).unwrap() {
            Series::Histogram(h) => {
                assert_eq!(h.count(), 2);
                assert_eq!(h.sum(), 103.0);
            }
            other => panic!("not a histogram: {other:?}"),
        }
    }

    #[test]
    fn histogram_buckets_are_log2_and_merge_bucket_wise() {
        let mut h = Histogram::new();
        // bucket bounds: 1, 2, 4, 8, …
        h.observe(1.0); // bucket 0
        h.observe(1.5); // bucket 1
        h.observe(2.0); // bucket 1 (inclusive upper bound)
        h.observe(7.0); // bucket 3
        h.observe(f64::INFINITY); // overflow
        assert_eq!(h.buckets()[0], 1);
        assert_eq!(h.buckets()[1], 2);
        assert_eq!(h.buckets()[2], 0);
        assert_eq!(h.buckets()[3], 1);
        assert_eq!(h.buckets()[HISTOGRAM_BUCKETS], 1);
        assert_eq!(h.count(), 5);

        let mut other = Histogram::new();
        other.observe(0.0); // bucket 0 catches <= 1 including zero
        other.observe(6.5); // bucket 3
        h.merge(&other);
        assert_eq!(h.buckets()[0], 2);
        assert_eq!(h.buckets()[3], 2);
        assert_eq!(h.count(), 7);
        assert_eq!(Histogram::bound(3), Some(8.0));
        assert_eq!(Histogram::bound(HISTOGRAM_BUCKETS), None);
    }

    #[test]
    fn eight_rank_gauge_merge_regression() {
        // Registry-level twin of the legacy Metrics regression: per-rank
        // pool gauges must survive an 8-way merge un-inflated.
        let mut merged = Registry::new();
        for rank in 0..8 {
            let mut r = Registry::new();
            r.gauge_set("pool_idle_bytes", labels(&[("rank", &rank.to_string())]), 1024.0);
            r.counter_add("steps", Labels::new(), 3.0);
            merged.merge(&r);
        }
        for rank in 0..8 {
            let l = labels(&[("rank", &rank.to_string())]);
            assert_eq!(merged.value("pool_idle_bytes", &l), 1024.0);
        }
        assert_eq!(merged.value("steps", &Labels::new()), 24.0);
    }

    #[test]
    fn prometheus_exposition_round_trips_through_the_parser() {
        let mut r = Registry::new();
        r.counter_add("spag_transfers_total", labels(&[("rank", "0"), ("layer", "1")]), 12.0);
        r.gauge_set("resident_bytes", labels(&[("rank", "0")]), 4480.0);
        r.histogram_observe("expert_load", Labels::new(), 3.0);
        r.histogram_observe("expert_load", Labels::new(), 5.0);
        let text = r.to_prometheus();
        assert!(text.contains("# TYPE resident_bytes gauge"), "{text}");
        assert!(text.contains("# TYPE expert_load histogram"), "{text}");
        assert!(text.contains("expert_load_bucket{le=\"+Inf\"} 2"), "{text}");

        let samples = parse_prometheus(&text).unwrap();
        let find = |name: &str| samples.iter().find(|s| s.name == name).unwrap();
        assert_eq!(find("resident_bytes").value, 4480.0);
        assert_eq!(find("resident_bytes").labels, labels(&[("rank", "0")]));
        assert_eq!(
            find("spag_transfers_total").labels,
            labels(&[("layer", "1"), ("rank", "0")])
        );
        assert_eq!(find("expert_load_sum").value, 8.0);
        assert_eq!(find("expert_load_count").value, 2.0);
        // cumulative buckets: le=4 covers 3, le=8 covers both
        let bucket = |le: &str| {
            samples
                .iter()
                .find(|s| {
                    s.name == "expert_load_bucket"
                        && s.labels.get("le").map(String::as_str) == Some(le)
                })
                .unwrap()
                .value
        };
        assert_eq!(bucket("4"), 1.0);
        assert_eq!(bucket("8"), 2.0);
    }

    #[test]
    fn parser_rejects_malformed_lines() {
        assert!(parse_prometheus("just_a_name").is_err());
        assert!(parse_prometheus("name{k=\"v\" 1.0").is_err());
        assert!(parse_prometheus("name{k=v} 1.0").is_err());
        assert!(parse_prometheus("name notanumber").is_err());
        // comments and blanks are fine
        assert_eq!(parse_prometheus("# TYPE x counter\n\n").unwrap().len(), 0);
    }
}
