//! Vanilla FSDP applied to MoE layers (§2.4): shard everything, AllGather
//! the **entire** layer before compute, ReduceScatter all gradients after.
//! With |E| experts this moves |E|× the traffic of a dense layer — the
//! inefficiency that motivates FSSDP.

use crate::collectives::dense;
use crate::config::SystemKind;
use crate::placement::Placement;
use crate::topology::DeviceId;

use super::{GradSync, IterationPlan, LayerPlan, MatComm, MoeMemory, MoeSystem, PlanCtx};

pub struct Fsdp;

impl Fsdp {
    pub fn new() -> Fsdp {
        Fsdp
    }
}

impl Default for Fsdp {
    fn default() -> Self {
        Self::new()
    }
}

impl MoeSystem for Fsdp {
    fn kind(&self) -> SystemKind {
        SystemKind::Fsdp
    }

    fn plan(
        &mut self,
        _iter: usize,
        ctx: &PlanCtx,
        _predicted: &[Vec<f64>],
        _realized: &[Vec<f64>],
    ) -> IterationPlan {
        let nd = ctx.topo.num_devices();
        let shards = Placement::round_robin(ctx.model.experts, nd);
        let full = Placement::full(ctx.model.experts, nd);
        let devices: Vec<DeviceId> = ctx.topo.all_devices().collect();
        let layer_bytes = ctx.model.experts as f64 * ctx.expert_bytes();
        let ag_time = dense::allgather_time(&ctx.topo, &devices, layer_bytes);
        IterationPlan {
            layers: (0..ctx.model.layers)
                .map(|_| LayerPlan {
                    placement: full.clone(),
                    owners: shards.clone(),
                    grad_sync: GradSync::DenseRs,
                    mat_comm: MatComm::DenseAg { time: ag_time },
                })
                .collect(),
            global_critical_time: 0.0,
        }
    }

    fn memory(&self, ctx: &PlanCtx, _plan: &IterationPlan) -> MoeMemory {
        let nd = ctx.topo.num_devices() as f64;
        let e = ctx.model.experts as f64;
        let l = ctx.model.layers as f64;
        let shard_params = e / nd * ctx.expert_bytes() * l;
        // FSDP materializes (and frees) one full layer at a time.
        let materialized = e * ctx.expert_bytes();
        MoeMemory {
            params: shard_params + materialized,
            grads: materialized, // full-layer grads before ReduceScatter
            opt: e / nd * ctx.expert_opt_bytes() * l,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::systems::test_ctx;

    #[test]
    fn full_materialization_every_layer() {
        let ctx = test_ctx(2, 4);
        let mut s = Fsdp::new();
        let loads = vec![vec![1.0 / 16.0; 16]; ctx.model.layers];
        let plan = s.plan(0, &ctx, &loads, &loads);
        for lp in &plan.layers {
            assert_eq!(lp.placement.replication(0), 8);
            match lp.mat_comm {
                MatComm::DenseAg { time } => assert!(time > 0.0),
                _ => panic!("expected DenseAg"),
            }
        }
    }

    #[test]
    fn opt_memory_is_sharded() {
        let ctx = test_ctx(2, 4);
        let mut s = Fsdp::new();
        let loads = vec![vec![0.0; 16]; ctx.model.layers];
        let plan = s.plan(0, &ctx, &loads, &loads);
        let mem = s.memory(&ctx, &plan);
        let ep_mem = crate::systems::ep_memory(&ctx);
        assert_eq!(mem.opt, ep_mem.opt, "same sharded opt as EP's even share");
        assert!(mem.params > ep_mem.params, "materialized layer adds params");
    }
}
