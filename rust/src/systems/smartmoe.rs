//! SmartMoE-style expert *exchange* (§2.3, [44]): periodically permute the
//! expert→device assignment so that hot and cold experts share devices
//! (classic LPT bin-packing), moving parameters **and optimizer states**.
//! No replication — memory stays EP-like, but the achievable balance is
//! limited (a device's load is the sum of whole experts), and the
//! rearrangement traffic lands on the critical path at each trigger.

use crate::config::{SystemConfig, SystemKind};
use crate::placement::Placement;
use crate::topology::DeviceId;

use super::{ep_memory, GradSync, IterationPlan, LayerPlan, MatComm, MoeMemory, MoeSystem, PlanCtx};

pub struct SmartMoe {
    cfg: SystemConfig,
    current: Option<Vec<Placement>>,
}

impl SmartMoe {
    pub fn new(cfg: SystemConfig) -> SmartMoe {
        SmartMoe { cfg, current: None }
    }

    /// LPT packing: experts sorted by load descending, each assigned to the
    /// least-loaded device that still has slots (E/N experts per device —
    /// the permutation constraint of [44]).
    fn pack(ctx: &PlanCtx, loads: &[f64]) -> Placement {
        let nd = ctx.topo.num_devices();
        let e = ctx.model.experts;
        let cap = e.div_ceil(nd);
        let mut slots = vec![cap; nd];
        let mut dev_load = vec![0.0f64; nd];
        let mut order: Vec<usize> = (0..e).collect();
        order.sort_by(|&a, &b| loads[b].partial_cmp(&loads[a]).unwrap());
        let mut p = Placement::empty(e, nd);
        for ex in order {
            let d = (0..nd)
                .filter(|&d| slots[d] > 0)
                .min_by(|&a, &b| dev_load[a].partial_cmp(&dev_load[b]).unwrap())
                .expect("slots exhausted");
            p.add(ex, DeviceId(d));
            slots[d] -= 1;
            dev_load[d] += loads[ex];
        }
        p
    }
}

impl MoeSystem for SmartMoe {
    fn kind(&self) -> SystemKind {
        SystemKind::SmartMoe
    }

    fn plan(
        &mut self,
        iter: usize,
        ctx: &PlanCtx,
        predicted: &[Vec<f64>],
        _realized: &[Vec<f64>],
    ) -> IterationPlan {
        let interval = self.cfg.rearrange_interval.max(1);
        let mut rearr_time = 0.0;
        if self.current.is_none() || iter % interval == 0 {
            let new: Vec<Placement> =
                predicted.iter().map(|f| Self::pack(ctx, f)).collect();
            if let Some(old) = &self.current {
                // moved experts carry params + optimizer state across devices
                let mut moved = 0usize;
                for (po, pn) in old.iter().zip(new.iter()) {
                    for e in 0..po.num_chunks() {
                        if po.holders(e).next() != pn.holders(e).next() {
                            moved += 1;
                        }
                    }
                }
                let bytes = moved as f64 * (ctx.expert_bytes() + ctx.expert_opt_bytes());
                // exchanges are point-to-point, many in parallel; bottleneck
                // ≈ the busiest NIC carrying its share of the bytes
                let nodes = ctx.topo.nodes.max(1) as f64;
                rearr_time = ctx.topo.inter_lat + bytes / nodes / ctx.topo.inter_bw;
            }
            self.current = Some(new);
        }
        let placements = self.current.as_ref().unwrap();
        IterationPlan {
            layers: placements
                .iter()
                .map(|p| LayerPlan {
                    placement: p.clone(),
                    owners: p.clone(),
                    grad_sync: GradSync::None,
                    mat_comm: MatComm::None,
                })
                .collect(),
            global_critical_time: rearr_time,
        }
    }

    fn memory(&self, ctx: &PlanCtx, _plan: &IterationPlan) -> MoeMemory {
        // permutation keeps the EP memory profile (the paper's Figure 13
        // shows SmartMoE ≈ EP).
        ep_memory(ctx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::systems::test_ctx;
    use crate::util::stats;

    #[test]
    fn packing_balances_device_load() {
        let ctx = test_ctx(2, 4);
        let mut loads = vec![0.02; 16];
        loads[0] = 0.40;
        loads[1] = 0.30;
        let p = SmartMoe::pack(&ctx, &loads);
        assert!(p.is_partition());
        // hot experts end on different devices
        assert_ne!(p.holders(0).next(), p.holders(1).next());
        let mut dev_load = vec![0.0; 8];
        for e in 0..16 {
            dev_load[p.holders(e).next().unwrap().0] += loads[e];
        }
        let rr = Placement::round_robin(16, 8);
        let mut rr_load = vec![0.0; 8];
        for e in 0..16 {
            rr_load[rr.holders(e).next().unwrap().0] += loads[e];
        }
        assert!(stats::straggler_factor(&dev_load) <= stats::straggler_factor(&rr_load));
    }

    #[test]
    fn rearranges_only_at_interval() {
        let ctx = test_ctx(2, 4);
        let mut cfg = SystemConfig::new(SystemKind::SmartMoe);
        cfg.rearrange_interval = 5;
        let mut s = SmartMoe::new(cfg);
        let mut loads = vec![vec![1.0 / 16.0; 16]; ctx.model.layers];
        let p0 = s.plan(0, &ctx, &loads, &loads);
        assert_eq!(p0.global_critical_time, 0.0, "first placement is free (init)");
        // shift loads so the next trigger moves experts
        for l in &mut loads {
            l[3] = 0.6;
            let rest = 0.4 / 15.0;
            for (i, v) in l.iter_mut().enumerate() {
                if i != 3 {
                    *v = rest;
                }
            }
        }
        let p1 = s.plan(1, &ctx, &loads, &loads);
        assert_eq!(p1.global_critical_time, 0.0, "no trigger between intervals");
        let p5 = s.plan(5, &ctx, &loads, &loads);
        assert!(p5.global_critical_time > 0.0, "interval trigger pays rearr cost");
    }
}
