//! Plain expert parallelism (EP, §2.2): experts statically round-robin
//! across devices; no replication, no parameter traffic; the straggler
//! effect hits in full.

use crate::config::SystemKind;
use crate::placement::Placement;

use super::{ep_memory, GradSync, IterationPlan, LayerPlan, MatComm, MoeMemory, MoeSystem, PlanCtx};

pub struct Ep;

impl Ep {
    pub fn new() -> Ep {
        Ep
    }
}

impl Default for Ep {
    fn default() -> Self {
        Self::new()
    }
}

impl MoeSystem for Ep {
    fn kind(&self) -> SystemKind {
        SystemKind::Ep
    }

    fn plan(
        &mut self,
        _iter: usize,
        ctx: &PlanCtx,
        _predicted: &[Vec<f64>],
        _realized: &[Vec<f64>],
    ) -> IterationPlan {
        let p = Placement::round_robin(ctx.model.experts, ctx.topo.num_devices());
        IterationPlan {
            layers: (0..ctx.model.layers)
                .map(|_| LayerPlan {
                    placement: p.clone(),
                    owners: p.clone(),
                    grad_sync: GradSync::None,
                    mat_comm: MatComm::None,
                })
                .collect(),
            global_critical_time: 0.0,
        }
    }

    fn memory(&self, ctx: &PlanCtx, _plan: &IterationPlan) -> MoeMemory {
        ep_memory(ctx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::systems::test_ctx;

    #[test]
    fn static_partition_no_comm() {
        let ctx = test_ctx(2, 4);
        let mut ep = Ep::new();
        let loads = vec![vec![1.0 / 16.0; 16]; ctx.model.layers];
        let plan = ep.plan(0, &ctx, &loads, &loads);
        for lp in &plan.layers {
            assert!(lp.placement.is_partition());
            assert!(matches!(lp.mat_comm, MatComm::None));
            assert!(matches!(lp.grad_sync, GradSync::None));
        }
        assert_eq!(plan.global_critical_time, 0.0);
    }

    #[test]
    fn memory_matches_even_share() {
        let ctx = test_ctx(2, 4); // 16 experts / 8 devices = 2 per device
        let mut ep = Ep::new();
        let loads = vec![vec![0.0; 16]; ctx.model.layers];
        let plan = ep.plan(0, &ctx, &loads, &loads);
        let mem = ep.memory(&ctx, &plan);
        let expect_params = 2.0 * ctx.expert_bytes() * ctx.model.layers as f64;
        assert!((mem.params - expect_params).abs() < 1.0);
        assert!(mem.opt > mem.params, "Adam state dominates");
    }
}
