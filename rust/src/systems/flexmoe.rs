//! FlexMoE-style dynamic device placement (§2.3, [31]): both replication
//! and relocation of experts, driven by the observed load, within a
//! *reserved memory* budget per device. Replicated experts carry their
//! **optimizer states** (unlike Hecate), so both the placement-transition
//! traffic and the standing memory cost are high — the paper measures 83%
//! more memory than Hecate and a 4×-reserve-for-2.65×-speedup tradeoff.

use crate::config::{SystemConfig, SystemKind};
use crate::materialize::top_by_load;
use crate::placement::Placement;
use crate::topology::DeviceId;

use super::{ep_memory, GradSync, IterationPlan, LayerPlan, MatComm, MoeMemory, MoeSystem, PlanCtx};

pub struct FlexMoe {
    cfg: SystemConfig,
    current: Option<Vec<Placement>>,
}

impl FlexMoe {
    pub fn new(cfg: SystemConfig) -> FlexMoe {
        FlexMoe { cfg, current: None }
    }

    /// Build a placement: base shards + load-proportional replicas filling
    /// each device's reserved slots (every MoE layer gets the same reserve —
    /// the uniform-allocation inefficiency Figure 11 calls out).
    fn place(ctx: &PlanCtx, loads: &[f64], reserve: usize) -> Placement {
        let nd = ctx.topo.num_devices();
        let e = ctx.model.experts;
        let mut p = Placement::round_robin(e, nd);
        if reserve == 0 {
            return p;
        }
        let tot_slots = nd * reserve;
        let mut free = vec![reserve; nd];
        let hot = top_by_load(loads, (e / 2).max(1));
        let hot_sum: f64 = hot.iter().map(|&x| loads[x]).sum();
        let mut remaining = tot_slots;
        for &ex in &hot {
            if remaining == 0 {
                break;
            }
            let n = (((loads[ex] / hot_sum.max(1e-12)) * tot_slots as f64).round() as usize)
                .clamp(1, remaining)
                .min(nd);
            let mut placed = 0;
            while placed < n {
                // fill the least-loaded device without the expert
                let Some(d) = (0..nd)
                    .filter(|&d| free[d] > 0 && !p.contains(ex, DeviceId(d)))
                    .max_by_key(|&d| free[d])
                else {
                    break;
                };
                p.add(ex, DeviceId(d));
                free[d] -= 1;
                placed += 1;
            }
            remaining = remaining.saturating_sub(placed);
        }
        p
    }
}

impl MoeSystem for FlexMoe {
    fn kind(&self) -> SystemKind {
        SystemKind::FlexMoe
    }

    fn plan(
        &mut self,
        iter: usize,
        ctx: &PlanCtx,
        predicted: &[Vec<f64>],
        _realized: &[Vec<f64>],
    ) -> IterationPlan {
        let interval = self.cfg.rearrange_interval.max(1);
        let reserve = self.cfg.reserved_slots;
        let mut transition = 0.0;
        if self.current.is_none() || iter % interval == 0 {
            let new: Vec<Placement> =
                predicted.iter().map(|f| Self::place(ctx, f, reserve)).collect();
            if let Some(old) = &self.current {
                // new replicas receive params + optimizer states
                let mut new_pairs = 0usize;
                for (po, pn) in old.iter().zip(new.iter()) {
                    new_pairs += pn.diff(po).len();
                }
                let bytes = new_pairs as f64 * (ctx.expert_bytes() + ctx.expert_opt_bytes());
                let nodes = ctx.topo.nodes.max(1) as f64;
                transition = ctx.topo.inter_lat + bytes / nodes / ctx.topo.inter_bw;
            }
            self.current = Some(new);
        }
        let placements = self.current.as_ref().unwrap();
        IterationPlan {
            layers: placements
                .iter()
                .map(|p| LayerPlan {
                    placement: p.clone(),
                    owners: p.clone(), // every replica keeps opt state
                    grad_sync: GradSync::AllReduceReplicas,
                    mat_comm: MatComm::None,
                })
                .collect(),
            global_critical_time: transition,
        }
    }

    fn memory(&self, ctx: &PlanCtx, _plan: &IterationPlan) -> MoeMemory {
        // reserved slots hold params + grads + FULL optimizer state per
        // replica, every layer — FlexMoE's memory hunger (Figure 13).
        let mut mem = ep_memory(ctx);
        let extra = self.cfg.reserved_slots as f64 * ctx.model.layers as f64;
        mem.params += extra * ctx.expert_bytes();
        mem.grads += extra * ctx.expert_bytes();
        mem.opt += extra * ctx.expert_opt_bytes();
        mem
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::systems::test_ctx;

    #[test]
    fn replicates_within_reserve() {
        let ctx = test_ctx(2, 4);
        let mut loads = vec![0.01; 16];
        loads[2] = 0.5;
        loads[9] = 0.3;
        let p = FlexMoe::place(&ctx, &loads, 2);
        assert!(p.replication(2) > 1);
        for d in ctx.topo.all_devices() {
            assert!(p.load_of(d) <= 2 + 2, "base 2 + reserve 2");
        }
        // zero reserve degenerates to EP
        let p0 = FlexMoe::place(&ctx, &loads, 0);
        assert!(p0.is_partition());
    }

    #[test]
    fn memory_scales_with_reserve_including_opt() {
        let ctx = test_ctx(2, 4);
        let mut cfg = SystemConfig::new(SystemKind::FlexMoe);
        let loads = vec![vec![1.0 / 16.0; 16]; ctx.model.layers];
        cfg.reserved_slots = 1;
        let mut s1 = FlexMoe::new(cfg.clone());
        let plan1 = s1.plan(0, &ctx, &loads, &loads);
        cfg.reserved_slots = 4;
        let mut s4 = FlexMoe::new(cfg);
        let plan4 = s4.plan(0, &ctx, &loads, &loads);
        let m1 = s1.memory(&ctx, &plan1);
        let m4 = s4.memory(&ctx, &plan4);
        assert!(m4.total() > m1.total());
        assert!(m4.opt > m1.opt, "FlexMoE replicates optimizer state");
    }

    #[test]
    fn transition_cost_on_load_shift() {
        let ctx = test_ctx(2, 4);
        let mut cfg = SystemConfig::new(SystemKind::FlexMoe);
        cfg.rearrange_interval = 2;
        let mut s = FlexMoe::new(cfg);
        let mut loads = vec![vec![1.0 / 16.0; 16]; ctx.model.layers];
        s.plan(0, &ctx, &loads, &loads);
        for l in &mut loads {
            l[5] = 0.8;
        }
        let p = s.plan(2, &ctx, &loads, &loads);
        assert!(p.global_critical_time > 0.0);
    }
}
