//! FasterMoE-style *shadowing* (§2.3, [16]): after the gate decision is
//! known, replicate the most-overloaded experts to **every** device. The
//! broadcast happens inside the iteration — i.e. on the critical path —
//! and the replicas' gradients are AllReduced at iteration end.
//!
//! FasterMoE imposes strict replication conditions (a load threshold) to
//! bound that overhead, which makes it less sensitive to moderate
//! imbalance.

use crate::config::{SystemConfig, SystemKind};
use crate::materialize::top_by_load;
use crate::placement::Placement;
use crate::topology::DeviceId;

use super::{ep_memory, GradSync, IterationPlan, LayerPlan, MatComm, MoeMemory, MoeSystem, PlanCtx};

pub struct FasterMoe {
    cfg: SystemConfig,
    /// Replicate experts whose load exceeds `threshold × mean` (the strict
    /// condition of [16]).
    pub threshold: f64,
}

impl FasterMoe {
    pub fn new(cfg: SystemConfig) -> FasterMoe {
        FasterMoe { cfg, threshold: 2.0 }
    }
}

impl MoeSystem for FasterMoe {
    fn kind(&self) -> SystemKind {
        SystemKind::FasterMoe
    }

    fn plan(
        &mut self,
        _iter: usize,
        ctx: &PlanCtx,
        _predicted: &[Vec<f64>],
        realized: &[Vec<f64>],
    ) -> IterationPlan {
        let nd = ctx.topo.num_devices();
        let experts = ctx.model.experts;
        let base = Placement::round_robin(experts, nd);
        let mean = 1.0 / experts as f64;
        let max_shadows = self.cfg.reserved_slots.max(1);

        let layers = realized
            .iter()
            .map(|loads| {
                // shadow candidates: above-threshold experts, hottest first,
                // bounded by reserved memory slots.
                let hot: Vec<usize> = top_by_load(loads, max_shadows)
                    .into_iter()
                    .filter(|&e| loads[e] > self.threshold * mean)
                    .collect();
                let mut placement = base.clone();
                for &e in &hot {
                    for d in 0..nd {
                        placement.add(e, DeviceId(d));
                    }
                }
                // Shadowing broadcast: each hot expert's params to all other
                // devices, serialized on the owner's ports — on the critical
                // path (FusedKernel Comp+A2A+Rearr in Figure 12).
                let bcast_time: f64 = hot
                    .iter()
                    .map(|&e| {
                        let owner = base.holders(e).next().unwrap();
                        let dsts: Vec<DeviceId> =
                            ctx.topo.all_devices().filter(|&d| d != owner).collect();
                        crate::collectives::dense::broadcast_time(
                            &ctx.topo,
                            owner,
                            &dsts,
                            ctx.expert_bytes(),
                        )
                    })
                    .sum();
                LayerPlan {
                    placement,
                    owners: base.clone(),
                    grad_sync: GradSync::AllReduceReplicas,
                    mat_comm: MatComm::Critical { time: bcast_time },
                }
            })
            .collect();
        IterationPlan { layers, global_critical_time: 0.0 }
    }

    fn memory(&self, ctx: &PlanCtx, plan: &IterationPlan) -> MoeMemory {
        let mut mem = ep_memory(ctx);
        // Shadow replicas add parameter + gradient memory on every device
        // (no optimizer state moves — owners keep it).
        let shadow_layers: f64 = plan
            .layers
            .iter()
            .map(|lp| {
                let extra: usize = (0..lp.placement.num_chunks())
                    .map(|e| lp.placement.replication(e).saturating_sub(1))
                    .sum();
                extra as f64 / ctx.topo.num_devices() as f64
            })
            .sum();
        mem.params += shadow_layers * ctx.expert_bytes();
        mem.grads += shadow_layers * ctx.expert_bytes();
        mem
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::systems::test_ctx;

    #[test]
    fn shadows_only_above_threshold() {
        let ctx = test_ctx(2, 4);
        let mut s = FasterMoe::new(SystemConfig::new(SystemKind::FasterMoe));
        // balanced loads: nothing shadowed, zero rearr time
        let balanced = vec![vec![1.0 / 16.0; 16]; ctx.model.layers];
        let plan = s.plan(0, &ctx, &balanced, &balanced);
        for lp in &plan.layers {
            assert!(lp.placement.is_partition());
            assert!(matches!(lp.mat_comm, MatComm::Critical { time } if time == 0.0));
        }
        // hot expert: shadowed everywhere, positive critical time
        let mut skewed = vec![vec![0.02; 16]; ctx.model.layers];
        for l in &mut skewed {
            l[7] = 0.7;
        }
        let plan = s.plan(1, &ctx, &skewed, &skewed);
        for lp in &plan.layers {
            assert_eq!(lp.placement.replication(7), 8);
            assert!(matches!(lp.mat_comm, MatComm::Critical { time } if time > 0.0));
            assert!(matches!(lp.grad_sync, GradSync::AllReduceReplicas));
        }
    }

    #[test]
    fn shadow_memory_grows() {
        let ctx = test_ctx(2, 4);
        let mut s = FasterMoe::new(SystemConfig::new(SystemKind::FasterMoe));
        let mut skewed = vec![vec![0.02; 16]; ctx.model.layers];
        for l in &mut skewed {
            l[0] = 0.7;
        }
        let plan = s.plan(0, &ctx, &skewed, &skewed);
        let mem = s.memory(&ctx, &plan);
        assert!(mem.params > ep_memory(&ctx).params);
        assert_eq!(mem.opt, ep_memory(&ctx).opt, "opt states never move");
    }
}
