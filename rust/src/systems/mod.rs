//! The MoE training systems under comparison (§2.3, §5.1).
//!
//! Each system is a *placement policy*: per iteration it decides, for every
//! MoE layer, (a) where expert parameters are materialized for compute,
//! (b) where gradients/optimizer state live, (c) what parameter traffic it
//! puts on the critical path (rearrangement) vs. overlappable with
//! attention (Hecate's sparse collectives, FSDP's prefetch), and (d) how
//! gradients of replicated experts are synchronized.
//!
//! The [`crate::sim`] engine turns these plans into time and memory.

pub mod ep;
pub mod fastermoe;
pub mod flexmoe;
pub mod fsdp;
pub mod hecate;
pub mod smartmoe;

use crate::config::{ModelConfig, SystemConfig, SystemKind};
use crate::placement::Placement;
use crate::topology::Topology;

/// Static context every system plans against.
#[derive(Debug, Clone)]
pub struct PlanCtx {
    pub topo: Topology,
    pub model: ModelConfig,
    /// Tokens processed per device per iteration (batch × seq).
    pub tokens_per_device: usize,
    /// Attention (non-MoE) forward latency per layer, seconds — the overlap
    /// window for materialization collectives.
    pub attn_fwd_time: f64,
}

impl PlanCtx {
    pub fn expert_bytes(&self) -> f64 {
        self.model.expert_bytes() as f64
    }

    pub fn expert_opt_bytes(&self) -> f64 {
        (self.model.expert_params() * self.model.opt_bytes_per_param) as f64
    }

    /// Algorithm 1's overlap degree for this context.
    pub fn overlap_degree(&self) -> usize {
        crate::materialize::overlap_degree(
            self.attn_fwd_time,
            self.topo.planning_bw(),
            self.expert_bytes(),
        )
    }
}

/// How the gradients of materialized/replicated experts reach their owners.
#[derive(Debug, Clone)]
pub enum GradSync {
    /// Every expert has exactly one holder: no inter-device sync (EP).
    None,
    /// AllReduce across each expert's replica group (rearrangement systems).
    AllReduceReplicas,
    /// Hecate: SparseReduceScatter back to the MoE shards.
    SparseRs,
    /// FSDP: dense ReduceScatter of the whole layer.
    DenseRs,
}

/// Per-layer plan for one iteration.
#[derive(Debug, Clone)]
pub struct LayerPlan {
    /// Where expert parameters are available for compute this iteration.
    pub placement: Placement,
    /// Where each expert's gradient/optimizer state must end up.
    pub owners: Placement,
    pub grad_sync: GradSync,
    /// Parameter bytes this layer must receive *before compute*, and
    /// whether that traffic is overlappable with preceding attention.
    pub mat_comm: MatComm,
}

/// Materialization communication of one layer.
#[derive(Debug, Clone)]
pub enum MatComm {
    /// No parameter movement (static placement).
    None,
    /// Hecate spAG: overlappable with the attention window; `plan` gives
    /// the exact transfers. `remat` adds a second spAG before backward
    /// (Hecate-RM or the re-use-across-layers mode of §3.2).
    Spag { time: f64, remat: bool },
    /// FSDP-style dense AllGather of the full layer (partially
    /// overlappable).
    DenseAg { time: f64 },
    /// Rearrangement traffic that sits on the critical path (FasterMoE
    /// shadowing, SmartMoE exchange, FlexMoE replication events).
    Critical { time: f64 },
}

/// One iteration's full plan.
#[derive(Debug, Clone)]
pub struct IterationPlan {
    pub layers: Vec<LayerPlan>,
    /// Iteration-level critical-path overhead not attributable to a layer
    /// (e.g. Hecate's periodic re-shard, FlexMoE's placement transition).
    pub global_critical_time: f64,
}

/// Peak memory per device attributable to MoE layers, bytes (Figure 13).
#[derive(Debug, Clone, Copy, Default)]
pub struct MoeMemory {
    pub params: f64,
    pub grads: f64,
    pub opt: f64,
}

impl MoeMemory {
    pub fn total(&self) -> f64 {
        self.params + self.grads + self.opt
    }
}

/// A placement policy under test.
pub trait MoeSystem {
    fn kind(&self) -> SystemKind;

    fn name(&self) -> &'static str {
        self.kind().name()
    }

    /// Plan iteration `iter`. `predicted` are the per-layer expert-load
    /// fractions the system is allowed to see *before* the gate runs
    /// (realized loads of past iterations feed the predictor);
    /// `realized` are this iteration's actual loads, available only to
    /// systems that rearrange after gating (FasterMoE) or for calibration.
    fn plan(&mut self, iter: usize, ctx: &PlanCtx, predicted: &[Vec<f64>], realized: &[Vec<f64>])
        -> IterationPlan;

    /// Peak per-device MoE memory under this system's steady state.
    fn memory(&self, ctx: &PlanCtx, plan: &IterationPlan) -> MoeMemory;
}

/// Instantiate a system from config.
pub fn build_system(cfg: &SystemConfig) -> Box<dyn MoeSystem> {
    match cfg.kind {
        SystemKind::Ep => Box::new(ep::Ep::new()),
        SystemKind::FasterMoe => Box::new(fastermoe::FasterMoe::new(cfg.clone())),
        SystemKind::SmartMoe => Box::new(smartmoe::SmartMoe::new(cfg.clone())),
        SystemKind::FlexMoe => Box::new(flexmoe::FlexMoe::new(cfg.clone())),
        SystemKind::Fsdp => Box::new(fsdp::Fsdp::new()),
        SystemKind::Hecate => Box::new(hecate::Hecate::new(cfg.clone(), false)),
        SystemKind::HecateRm => Box::new(hecate::Hecate::new(cfg.clone(), true)),
    }
}

/// Shared helper: per-device MoE memory of a static `E/N`-experts-per-device
/// layout (EP-style), for `layers` layers.
pub(crate) fn ep_memory(ctx: &PlanCtx) -> MoeMemory {
    let experts_per_dev =
        (ctx.model.experts as f64 / ctx.topo.num_devices() as f64).ceil();
    let per_layer = experts_per_dev * ctx.expert_bytes();
    let l = ctx.model.layers as f64;
    MoeMemory {
        params: per_layer * l,
        grads: per_layer * l,
        opt: experts_per_dev * ctx.expert_opt_bytes() * l,
    }
}

#[cfg(test)]
pub(crate) fn test_ctx(nodes: usize, dpn: usize) -> PlanCtx {
    let topo = Topology::cluster_a(nodes, dpn);
    let model = ModelConfig::preset("gpt-moe-s").unwrap().with_experts(16);
    PlanCtx { topo, model, tokens_per_device: 4096, attn_fwd_time: 4e-3 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn build_all_kinds() {
        for kind in [
            SystemKind::Ep,
            SystemKind::FasterMoe,
            SystemKind::SmartMoe,
            SystemKind::FlexMoe,
            SystemKind::Fsdp,
            SystemKind::Hecate,
            SystemKind::HecateRm,
        ] {
            let sys = build_system(&SystemConfig::new(kind));
            assert_eq!(sys.kind(), kind);
        }
    }

    /// Smoke-run every system for a few iterations and validate invariants
    /// every plan must satisfy.
    #[test]
    fn all_systems_produce_valid_plans() {
        let ctx = test_ctx(2, 4);
        let mut rng = Rng::new(3);
        for kind in [
            SystemKind::Ep,
            SystemKind::FasterMoe,
            SystemKind::SmartMoe,
            SystemKind::FlexMoe,
            SystemKind::Fsdp,
            SystemKind::Hecate,
            SystemKind::HecateRm,
        ] {
            let mut sys = build_system(&SystemConfig::new(kind));
            for iter in 0..6 {
                let loads: Vec<Vec<f64>> = (0..ctx.model.layers)
                    .map(|_| rng.dirichlet(0.3, ctx.model.experts))
                    .collect();
                let plan = sys.plan(iter, &ctx, &loads, &loads);
                assert_eq!(plan.layers.len(), ctx.model.layers, "{kind:?}");
                for (l, lp) in plan.layers.iter().enumerate() {
                    assert!(
                        lp.placement.is_surjective(),
                        "{kind:?} layer {l}: some expert unmaterialized"
                    );
                    assert!(
                        lp.owners.is_surjective(),
                        "{kind:?} layer {l}: some expert unowned"
                    );
                    assert!(
                        lp.owners.is_subset_of(&lp.placement)
                            || matches!(lp.grad_sync, GradSync::None),
                        "{kind:?} layer {l}: owners must be materialized"
                    );
                }
                let mem = sys.memory(&ctx, &plan);
                assert!(mem.total() > 0.0, "{kind:?}: zero memory");
            }
        }
    }
}
