//! Hecate: FSSDP with heterogeneous sharding (Algorithm 2), sparse
//! materialization (Algorithm 1), topology-aware dispatch, and optional
//! re-materialization (§4).
//!
//! Per iteration and layer, a `spAG(P, P')` materializes the planned
//! placement (overlappable with the preceding attention forward); after
//! the layer's backward, `spRS(P', P)` returns gradients to the MoE
//! shards, which hold the single global copy of the optimizer state.
//! Re-sharding runs every `reshard_interval` iterations and only pays when
//! shards actually change (§5.1).

use crate::collectives::sparse::{build_spag, build_sprs};
use crate::config::{SystemConfig, SystemKind};
use crate::materialize::{sparse_materialize, MatConstraints};
use crate::sharding::{self, ShardingPlan};
use crate::topology::DeviceId;

use super::{GradSync, IterationPlan, LayerPlan, MatComm, MoeMemory, MoeSystem, PlanCtx};

pub struct Hecate {
    cfg: SystemConfig,
    /// Re-materialization: release parameters after each layer's compute and
    /// re-gather for backward — 1 layer resident instead of all (§4 "RM").
    pub rm: bool,
    shards: Option<ShardingPlan>,
    /// Fraction of device memory available for materialized placements.
    pub mat_headroom_frac: f64,
}

impl Hecate {
    pub fn new(cfg: SystemConfig, rm: bool) -> Hecate {
        Hecate { cfg, rm, shards: None, mat_headroom_frac: 0.30 }
    }

    /// Memory slots per device available to Algorithm 1 for one layer.
    /// Non-RM keeps every layer's materialization resident simultaneously,
    /// so the headroom divides across layers; RM reserves one layer's worth
    /// (the 90.2% parameter-memory reduction of §5.4).
    fn mem_slots(&self, ctx: &PlanCtx) -> usize {
        let headroom = self.mat_headroom_frac * ctx.topo.device_mem;
        let per_layer = if self.rm {
            headroom
        } else {
            headroom / ctx.model.layers as f64
        };
        (per_layer / ctx.expert_bytes()).floor() as usize
    }

    fn reshard(&mut self, ctx: &PlanCtx, predicted: &[Vec<f64>]) -> f64 {
        let t = ctx.overlap_degree();
        let new = if self.cfg.hetero_sharding {
            sharding::heterogeneous_sticky(&ctx.topo, predicted, t, self.shards.as_ref())
        } else {
            sharding::homogeneous(
                ctx.model.layers,
                ctx.model.experts,
                ctx.topo.num_devices(),
            )
        };
        let cost = match &self.shards {
            None => 0.0, // initial sharding is setup, not steady-state cost
            Some(old) => {
                let bytes = sharding::reshard_bytes(
                    old,
                    &new,
                    ctx.model.expert_bytes(),
                    ctx.model.expert_params() * ctx.model.opt_bytes_per_param,
                ) as f64;
                if bytes == 0.0 {
                    0.0 // §5.1: "executing only when shards change"
                } else {
                    let nodes = ctx.topo.nodes.max(1) as f64;
                    ctx.topo.inter_lat + bytes / nodes / ctx.topo.inter_bw
                }
            }
        };
        self.shards = Some(new);
        cost
    }
}

impl MoeSystem for Hecate {
    fn kind(&self) -> SystemKind {
        if self.rm {
            SystemKind::HecateRm
        } else {
            SystemKind::Hecate
        }
    }

    fn plan(
        &mut self,
        iter: usize,
        ctx: &PlanCtx,
        predicted: &[Vec<f64>],
        _realized: &[Vec<f64>],
    ) -> IterationPlan {
        let interval = self.cfg.reshard_interval.max(1);
        let mut global_critical_time = 0.0;
        if self.shards.is_none() || iter % interval == 0 {
            global_critical_time += self.reshard(ctx, predicted);
        }
        let shards = self.shards.as_ref().unwrap();
        let t = ctx.overlap_degree();
        let m = self.mem_slots(ctx);

        let layers = (0..ctx.model.layers)
            .map(|l| {
                let base = &shards.layers[l];
                if !self.cfg.sparse_materialization {
                    // ablation: heterogeneous shards only, EP-style dispatch
                    return LayerPlan {
                        placement: base.clone(),
                        owners: base.clone(),
                        grad_sync: GradSync::None,
                        mat_comm: MatComm::None,
                    };
                }
                let placement = sparse_materialize(
                    &ctx.topo,
                    base,
                    &predicted[l],
                    MatConstraints { overlap_degree: t, mem_slots: m },
                );
                let spag = build_spag(&ctx.topo, base, &placement)
                    .expect("Alg1 output is a valid spAG target");
                let sprs = build_sprs(&ctx.topo, &placement, base)
                    .expect("symmetric spRS");
                let time = spag.time(&ctx.topo, ctx.expert_bytes())
                    + sprs.time(&ctx.topo, ctx.expert_bytes());
                LayerPlan {
                    placement,
                    owners: base.clone(),
                    grad_sync: GradSync::SparseRs,
                    mat_comm: MatComm::Spag { time, remat: self.rm },
                }
            })
            .collect();
        IterationPlan { layers, global_critical_time }
    }

    fn memory(&self, ctx: &PlanCtx, plan: &IterationPlan) -> MoeMemory {
        let nd = ctx.topo.num_devices();
        let shards = self.shards.as_ref().expect("plan() before memory()");
        // shard memory: params + opt, exactly one global copy (C1)
        let max_shard_slots = (0..nd)
            .map(|d| shards.slots_used(DeviceId(d)))
            .max()
            .unwrap_or(0) as f64;
        let shard_params = max_shard_slots * ctx.expert_bytes();
        let opt = max_shard_slots * ctx.expert_opt_bytes();
        // materialized replicas: per device, extra slots beyond its shard
        let extra_per_layer: Vec<f64> = plan
            .layers
            .iter()
            .enumerate()
            .map(|(l, lp)| {
                (0..nd)
                    .map(|d| {
                        let dd = DeviceId(d);
                        lp.placement.load_of(dd).saturating_sub(shards.layers[l].load_of(dd))
                    })
                    .max()
                    .unwrap_or(0) as f64
            })
            .collect();
        let mat_params = if self.rm {
            // only one layer resident at a time
            extra_per_layer.iter().cloned().fold(0.0, f64::max) * ctx.expert_bytes()
        } else {
            extra_per_layer.iter().sum::<f64>() * ctx.expert_bytes()
        };
        MoeMemory {
            params: shard_params + mat_params,
            // gradients exist per materialized expert until spRS drains them;
            // with backward-overlap one layer's worth is live at a time.
            grads: shard_params
                + extra_per_layer.iter().cloned().fold(0.0, f64::max) * ctx.expert_bytes(),
            opt,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::systems::test_ctx;
    use crate::util::rng::Rng;

    fn skewed(ctx: &PlanCtx, seed: u64) -> Vec<Vec<f64>> {
        let mut rng = Rng::new(seed);
        (0..ctx.model.layers).map(|_| rng.dirichlet(0.2, ctx.model.experts)).collect()
    }

    #[test]
    fn materializes_hot_experts_with_overlappable_comm() {
        let ctx = test_ctx(2, 4);
        let mut h = Hecate::new(SystemConfig::new(SystemKind::Hecate), false);
        let loads = skewed(&ctx, 1);
        let plan = h.plan(0, &ctx, &loads, &loads);
        let mut any_replicated = false;
        for lp in &plan.layers {
            assert!(matches!(lp.grad_sync, GradSync::SparseRs));
            match lp.mat_comm {
                MatComm::Spag { remat, .. } => assert!(!remat),
                _ => panic!("expected spAG"),
            }
            if (0..ctx.model.experts).any(|e| lp.placement.replication(e) > 1) {
                any_replicated = true;
            }
            assert!(lp.owners.is_subset_of(&lp.placement));
        }
        assert!(any_replicated, "skewed loads should trigger materialization");
    }

    #[test]
    fn rm_reduces_param_memory() {
        let ctx = test_ctx(2, 4);
        let loads = skewed(&ctx, 2);
        let mut h = Hecate::new(SystemConfig::new(SystemKind::Hecate), false);
        let p = h.plan(0, &ctx, &loads, &loads);
        let m = h.memory(&ctx, &p);
        let mut hrm = Hecate::new(SystemConfig::new(SystemKind::HecateRm), true);
        let prm = hrm.plan(0, &ctx, &loads, &loads);
        let mrm = hrm.memory(&ctx, &prm);
        assert!(
            mrm.params < m.params,
            "RM params {} should be below Hecate {}",
            mrm.params,
            m.params
        );
        assert_eq!(mrm.opt, m.opt, "opt stays sharded either way");
    }

    #[test]
    fn opt_memory_is_single_global_copy() {
        let ctx = test_ctx(2, 4);
        let loads = skewed(&ctx, 3);
        let mut h = Hecate::new(SystemConfig::new(SystemKind::Hecate), false);
        let p = h.plan(0, &ctx, &loads, &loads);
        let mem = h.memory(&ctx, &p);
        // one global copy spread over 8 devices: per-device opt ≈ E*L/N
        let expect =
            (ctx.model.experts * ctx.model.layers / ctx.topo.num_devices()) as f64
                * ctx.expert_opt_bytes();
        assert!(mem.opt <= expect * 1.5, "opt {} vs even share {}", mem.opt, expect);
    }

    #[test]
    fn reshard_costs_only_on_change() {
        let ctx = test_ctx(2, 4);
        let mut cfg = SystemConfig::new(SystemKind::Hecate);
        cfg.reshard_interval = 2;
        let mut h = Hecate::new(cfg, false);
        let loads = skewed(&ctx, 4);
        let p0 = h.plan(0, &ctx, &loads, &loads);
        assert_eq!(p0.global_critical_time, 0.0, "initial sharding free");
        let p2 = h.plan(2, &ctx, &loads, &loads);
        assert_eq!(p2.global_critical_time, 0.0, "same loads -> same shards -> free");
        let shifted = skewed(&ctx, 99);
        let p4 = h.plan(4, &ctx, &shifted, &shifted);
        assert!(p4.global_critical_time > 0.0, "changed shards pay movement");
    }

    #[test]
    fn ablation_flags() {
        let ctx = test_ctx(2, 4);
        let loads = skewed(&ctx, 5);
        let mut cfg = SystemConfig::new(SystemKind::Hecate);
        cfg.sparse_materialization = false;
        let mut h = Hecate::new(cfg, false);
        let p = h.plan(0, &ctx, &loads, &loads);
        for lp in &p.layers {
            assert!(lp.placement.is_partition(), "no materialization in ablation");
            assert!(matches!(lp.mat_comm, MatComm::None));
        }
    }
}
