//! MoE model architectures — paper Table 1 plus the small configs used by
//! the numeric engine and the end-to-end training example.
//!
//! | Model         | d_model | SeqLen | Layers | Experts | Params |
//! |---------------|---------|--------|--------|---------|--------|
//! | GPT-MoE-S     | 768     | 2048   | 12     | 64      | 1.84B  |
//! | GPT-MoE-L     | 1536    | 2048   | 12     | 64      | 7.36B  |
//! | BERT-MoE      | 1024    | 512    | 12     | 64      | 3.27B  |
//! | BERT-MoE-Deep | 1024    | 512    | 24     | 64      | 6.54B  |
//!
//! Experts are FFNs with `d_ffn = 2 * d_model` (§5.1); gating is GShard
//! top-2.

use crate::util::json::{obj, Json};

/// Transformer-MoE architecture description.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub d_model: usize,
    pub seq_len: usize,
    /// Number of Transformer-MoE blocks (each: attention + MoE FFN).
    pub layers: usize,
    /// Experts per MoE layer.
    pub experts: usize,
    /// Top-k routing (paper uses GShard top-2).
    pub top_k: usize,
    /// Vocabulary size (embedding / lm-head), used by the e2e trainer.
    pub vocab: usize,
    /// Bytes per parameter for *parameters* on device (fp16 in the paper's
    /// mixed-precision setup).
    pub param_bytes: usize,
    /// Bytes of optimizer state per parameter (Adam mixed precision:
    /// fp32 master + m + v = 12, paper says ≥6× params of 2 bytes).
    pub opt_bytes_per_param: usize,
}

impl ModelConfig {
    fn new(name: &str, d_model: usize, seq_len: usize, layers: usize, experts: usize) -> Self {
        ModelConfig {
            name: name.to_string(),
            d_model,
            seq_len,
            layers,
            experts,
            top_k: 2,
            vocab: 50_257,
            param_bytes: 2,
            opt_bytes_per_param: 12,
        }
    }

    /// Paper Table 1 presets. `experts` can be overridden for weak scaling
    /// (the paper uses 32 experts for the 16-GPU runs).
    pub fn preset(name: &str) -> anyhow::Result<ModelConfig> {
        match name.to_ascii_lowercase().as_str() {
            "gpt-moe-s" => Ok(Self::new("GPT-MoE-S", 768, 2048, 12, 64)),
            "gpt-moe-l" => Ok(Self::new("GPT-MoE-L", 1536, 2048, 12, 64)),
            "bert-moe" => Ok(Self::new("BERT-MoE", 1024, 512, 12, 64)),
            "bert-moe-deep" => Ok(Self::new("BERT-MoE-Deep", 1024, 512, 24, 64)),
            // Small configs for the numeric engine / e2e example / tests.
            "tiny" => Ok(ModelConfig {
                vocab: 1024,
                ..Self::new("Tiny", 64, 32, 2, 8)
            }),
            "e2e-100m" => Ok(ModelConfig {
                vocab: 8192,
                ..Self::new("E2E-100M", 512, 256, 4, 16)
            }),
            _ => anyhow::bail!(
                "unknown model `{name}` (gpt-moe-s|gpt-moe-l|bert-moe|bert-moe-deep|tiny|e2e-100m)"
            ),
        }
    }

    pub fn all_paper_models() -> Vec<ModelConfig> {
        ["gpt-moe-s", "gpt-moe-l", "bert-moe", "bert-moe-deep"]
            .iter()
            .map(|n| Self::preset(n).unwrap())
            .collect()
    }

    /// With a different expert count (weak scaling).
    pub fn with_experts(mut self, experts: usize) -> Self {
        self.experts = experts;
        self
    }

    /// FFN hidden dim: `2 * d_model` per the paper.
    pub fn d_ffn(&self) -> usize {
        2 * self.d_model
    }

    /// Parameters in one expert (two dense layers + biases).
    pub fn expert_params(&self) -> usize {
        self.d_model * self.d_ffn() + self.d_ffn() // w1 + b1
            + self.d_ffn() * self.d_model + self.d_model // w2 + b2
    }

    /// Bytes of one expert's parameters on device.
    pub fn expert_bytes(&self) -> usize {
        self.expert_params() * self.param_bytes
    }

    /// Parameters in one attention block (qkv + proj + 2 layernorms).
    pub fn attention_params(&self) -> usize {
        4 * self.d_model * self.d_model + 4 * self.d_model + 4 * self.d_model
    }

    /// Parameters of the gate of one MoE layer.
    pub fn gate_params(&self) -> usize {
        self.d_model * self.experts
    }

    /// Total parameters of the model (embeddings + blocks + head).
    pub fn total_params(&self) -> usize {
        let embed = self.vocab * self.d_model + self.seq_len * self.d_model;
        let per_layer =
            self.attention_params() + self.gate_params() + self.experts * self.expert_params();
        embed + self.layers * per_layer
    }

    /// Total parameters of all MoE experts (the sharded portion in FSSDP).
    pub fn total_expert_params(&self) -> usize {
        self.layers * self.experts * self.expert_params()
    }

    /// Forward flops of one attention block for `tokens` tokens
    /// (projections + score/context matmuls).
    pub fn attention_fwd_flops(&self, tokens: usize) -> f64 {
        let proj = 2.0 * tokens as f64 * (4 * self.d_model * self.d_model) as f64;
        let attn = 2.0 * 2.0 * tokens as f64 * self.seq_len as f64 * self.d_model as f64;
        proj + attn
    }

    /// Forward flops of one expert processing `tokens` tokens.
    pub fn expert_fwd_flops(&self, tokens: usize) -> f64 {
        2.0 * tokens as f64 * (2 * self.d_model * self.d_ffn()) as f64
    }

    pub fn to_json(&self) -> Json {
        obj([
            ("name", self.name.as_str().into()),
            ("d_model", self.d_model.into()),
            ("seq_len", self.seq_len.into()),
            ("layers", self.layers.into()),
            ("experts", self.experts.into()),
            ("top_k", self.top_k.into()),
            ("vocab", self.vocab.into()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_param_counts_match_paper() {
        // Paper Table 1 reports total params; dominated by experts:
        // 64 experts/layer, expert ≈ 4*d_model^2 params.
        let s = ModelConfig::preset("gpt-moe-s").unwrap();
        let b = s.total_params() as f64 / 1e9;
        assert!((b - 1.84).abs() < 0.15, "GPT-MoE-S {b:.2}B vs paper 1.84B");

        let l = ModelConfig::preset("gpt-moe-l").unwrap();
        let b = l.total_params() as f64 / 1e9;
        assert!((b - 7.36).abs() < 0.5, "GPT-MoE-L {b:.2}B vs paper 7.36B");

        let bert = ModelConfig::preset("bert-moe").unwrap();
        let b = bert.total_params() as f64 / 1e9;
        assert!((b - 3.27).abs() < 0.25, "BERT-MoE {b:.2}B vs paper 3.27B");

        let deep = ModelConfig::preset("bert-moe-deep").unwrap();
        let b = deep.total_params() as f64 / 1e9;
        assert!((b - 6.54).abs() < 0.5, "BERT-MoE-Deep {b:.2}B vs paper 6.54B");
    }

    #[test]
    fn e2e_model_is_about_100m() {
        let m = ModelConfig::preset("e2e-100m").unwrap();
        let p = m.total_params() as f64 / 1e6;
        assert!((60.0..200.0).contains(&p), "{p:.1}M params");
    }

    #[test]
    fn ffn_dim_is_2x() {
        let m = ModelConfig::preset("bert-moe").unwrap();
        assert_eq!(m.d_ffn(), 2048);
        assert_eq!(m.top_k, 2);
    }

    #[test]
    fn weak_scaling_override() {
        let m = ModelConfig::preset("gpt-moe-s").unwrap().with_experts(32);
        assert_eq!(m.experts, 32);
    }

    #[test]
    fn flops_monotone_in_tokens() {
        let m = ModelConfig::preset("gpt-moe-s").unwrap();
        assert!(m.expert_fwd_flops(200) > m.expert_fwd_flops(100));
        assert!(m.attention_fwd_flops(2048) > 0.0);
    }

    #[test]
    fn unknown_preset_errors() {
        assert!(ModelConfig::preset("nope").is_err());
    }
}
