//! Selection and hyper-parameters of the MoE training systems under
//! comparison (§5.1): EP, FasterMoE, SmartMoE, FlexMoE, FSDP, and
//! Hecate (± re-materialization).

/// Which system plans expert placement each iteration.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SystemKind {
    /// Plain expert parallelism: static even placement, All-to-All dispatch.
    Ep,
    /// FasterMoE-style shadowing: replicate the most-loaded experts to every
    /// device after the gate decision (rearrangement on the critical path).
    FasterMoe,
    /// SmartMoE-style permutation: periodically *exchange* experts between
    /// devices to pack high+low loads together (no replication).
    SmartMoe,
    /// FlexMoE-style replication/relocation with reserved memory.
    FlexMoe,
    /// Vanilla FSDP applied to MoE layers: full AllGather of every expert.
    Fsdp,
    /// Hecate: FSSDP with heterogeneous sharding + sparse materialization.
    Hecate,
    /// Hecate with re-materialization (release params after use).
    HecateRm,
}

impl SystemKind {
    pub fn parse(s: &str) -> anyhow::Result<SystemKind> {
        match s.to_ascii_lowercase().replace('_', "-").as_str() {
            "ep" => Ok(SystemKind::Ep),
            "fastermoe" | "faster-moe" => Ok(SystemKind::FasterMoe),
            "smartmoe" | "smart-moe" => Ok(SystemKind::SmartMoe),
            "flexmoe" | "flex-moe" => Ok(SystemKind::FlexMoe),
            "fsdp" => Ok(SystemKind::Fsdp),
            "hecate" => Ok(SystemKind::Hecate),
            "hecate-rm" | "hecaterm" => Ok(SystemKind::HecateRm),
            _ => anyhow::bail!(
                "unknown system `{s}` (ep|fastermoe|smartmoe|flexmoe|fsdp|hecate|hecate-rm)"
            ),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SystemKind::Ep => "EP",
            SystemKind::FasterMoe => "FasterMoE",
            SystemKind::SmartMoe => "SmartMoE",
            SystemKind::FlexMoe => "FlexMoE",
            SystemKind::Fsdp => "FSDP",
            SystemKind::Hecate => "Hecate",
            SystemKind::HecateRm => "Hecate-RM",
        }
    }

    /// The comparison set used in the paper's end-to-end figures.
    pub fn paper_lineup() -> Vec<SystemKind> {
        vec![
            SystemKind::Ep,
            SystemKind::FasterMoe,
            SystemKind::SmartMoe,
            SystemKind::FlexMoe,
            SystemKind::Hecate,
        ]
    }
}

/// Per-system tunables (the knobs §2.3 calls out).
#[derive(Debug, Clone)]
pub struct SystemConfig {
    pub kind: SystemKind,
    /// Rearrangement interval in iterations (SmartMoE / FlexMoE). The paper
    /// tunes these per-workload; defaults follow its §1/§5 discussion
    /// (moderate frequency, e.g. every 25 steps).
    pub rearrange_interval: usize,
    /// Extra expert slots of memory reserved per device for rearrangement
    /// (FlexMoE "reserved memory"), in units of experts.
    pub reserved_slots: usize,
    /// Hecate: re-sharding interval (paper: 100, insensitive).
    pub reshard_interval: usize,
    /// Hecate: enable the post-gate calibration stage (§4.2).
    pub calibration: bool,
    /// Hecate ablation switches (Figure 15a).
    pub hetero_sharding: bool,
    pub sparse_materialization: bool,
}

impl SystemConfig {
    pub fn new(kind: SystemKind) -> SystemConfig {
        SystemConfig {
            kind,
            rearrange_interval: 25,
            reserved_slots: match kind {
                SystemKind::FlexMoe => 4,
                SystemKind::FasterMoe => 2,
                _ => 0,
            },
            reshard_interval: 100,
            calibration: true,
            hetero_sharding: true,
            sparse_materialization: true,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_all() {
        for (s, k) in [
            ("ep", SystemKind::Ep),
            ("FasterMoE", SystemKind::FasterMoe),
            ("smart-moe", SystemKind::SmartMoe),
            ("flexmoe", SystemKind::FlexMoe),
            ("fsdp", SystemKind::Fsdp),
            ("hecate", SystemKind::Hecate),
            ("hecate-rm", SystemKind::HecateRm),
        ] {
            assert_eq!(SystemKind::parse(s).unwrap(), k);
        }
        assert!(SystemKind::parse("bogus").is_err());
    }

    #[test]
    fn lineup_contains_hecate_and_ep() {
        let l = SystemKind::paper_lineup();
        assert!(l.contains(&SystemKind::Ep));
        assert!(l.contains(&SystemKind::Hecate));
        assert_eq!(l.len(), 5);
    }

    #[test]
    fn flexmoe_reserves_memory() {
        assert_eq!(SystemConfig::new(SystemKind::FlexMoe).reserved_slots, 4);
        assert_eq!(SystemConfig::new(SystemKind::Ep).reserved_slots, 0);
    }
}
