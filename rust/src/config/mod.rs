//! Configuration system: model architectures (paper Table 1), cluster
//! presets, system selection and hyper-parameters, and training options.
//! Configs load from JSON files or CLI overrides.

pub mod model;
pub mod system;

pub use model::ModelConfig;
pub use system::{SystemConfig, SystemKind};

use crate::topology::Topology;
use crate::util::json::Json;

/// Which paper testbed to simulate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClusterPreset {
    A,
    B,
    Flat,
}

impl ClusterPreset {
    pub fn parse(s: &str) -> anyhow::Result<ClusterPreset> {
        match s.to_ascii_lowercase().as_str() {
            "a" | "cluster-a" | "v100" => Ok(ClusterPreset::A),
            "b" | "cluster-b" | "a100" => Ok(ClusterPreset::B),
            "flat" => Ok(ClusterPreset::Flat),
            _ => anyhow::bail!("unknown cluster `{s}` (expected a|b|flat)"),
        }
    }

    pub fn build(&self, nodes: usize, devices_per_node: usize) -> Topology {
        match self {
            ClusterPreset::A => Topology::cluster_a(nodes, devices_per_node),
            ClusterPreset::B => Topology::cluster_b(nodes, devices_per_node),
            ClusterPreset::Flat => Topology::flat(nodes * devices_per_node, 50e9),
        }
    }
}

/// Training-loop options shared by the simulator and the numeric engine.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Mini-batch size (sequences) per device.
    pub batch_per_device: usize,
    /// Iterations to run.
    pub iterations: usize,
    /// Random seed.
    pub seed: u64,
    /// Sliding window size for load prediction (paper: w = 5).
    pub predict_window: usize,
    /// Re-sharding interval in iterations (paper default: 100).
    pub reshard_interval: usize,
    /// Adam learning rate (numeric engine / e2e training).
    pub lr: f32,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            batch_per_device: 2,
            iterations: 100,
            seed: 42,
            predict_window: 5,
            reshard_interval: 100,
            lr: 1e-3,
        }
    }
}

impl TrainConfig {
    pub fn to_json(&self) -> Json {
        crate::util::json::obj([
            ("batch_per_device", self.batch_per_device.into()),
            ("iterations", self.iterations.into()),
            ("seed", (self.seed as usize).into()),
            ("predict_window", self.predict_window.into()),
            ("reshard_interval", self.reshard_interval.into()),
            ("lr", (self.lr as f64).into()),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<TrainConfig> {
        let d = TrainConfig::default();
        Ok(TrainConfig {
            batch_per_device: j
                .get("batch_per_device")
                .and_then(Json::as_usize)
                .unwrap_or(d.batch_per_device),
            iterations: j.get("iterations").and_then(Json::as_usize).unwrap_or(d.iterations),
            seed: j.get("seed").and_then(Json::as_usize).unwrap_or(d.seed as usize) as u64,
            predict_window: j
                .get("predict_window")
                .and_then(Json::as_usize)
                .unwrap_or(d.predict_window),
            reshard_interval: j
                .get("reshard_interval")
                .and_then(Json::as_usize)
                .unwrap_or(d.reshard_interval),
            lr: j.get("lr").and_then(Json::as_f64).unwrap_or(d.lr as f64) as f32,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cluster_presets() {
        assert_eq!(ClusterPreset::parse("A").unwrap(), ClusterPreset::A);
        assert_eq!(ClusterPreset::parse("v100").unwrap(), ClusterPreset::A);
        assert!(ClusterPreset::parse("z").is_err());
        let t = ClusterPreset::B.build(4, 8);
        assert_eq!(t.num_devices(), 32);
    }

    #[test]
    fn train_config_roundtrip() {
        let c = TrainConfig { batch_per_device: 4, iterations: 7, ..Default::default() };
        let j = c.to_json();
        let back = TrainConfig::from_json(&j).unwrap();
        assert_eq!(back.batch_per_device, 4);
        assert_eq!(back.iterations, 7);
        assert_eq!(back.predict_window, 5);
    }
}
