//! `hecate` — the L3 coordinator binary.
//!
//! See `hecate help` (or [`hecate::coordinator`]) for subcommands: `repro`
//! regenerates the paper's tables/figures, `simulate` runs a single
//! cluster simulation, `train` drives the AOT-compiled model end-to-end
//! through PJRT, and `fssdp` runs the numeric multi-device FSSDP engine.
//!
//! Exit codes: 0 success, 1 any other error, 2 a communicator failure
//! (closed link, receive timeout, codec/handshake violation) — so process
//! supervisors can tell a dead peer from a bad flag.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = hecate::coordinator::run(argv) {
        let rendered = format!("{e:#}");
        eprintln!("error: {rendered}");
        let code =
            if hecate::spmd::transport::CommError::is_comm_failure_msg(&rendered) { 2 } else { 1 };
        std::process::exit(code);
    }
}
