//! `hecate` — the L3 coordinator binary.
//!
//! See `hecate help` (or [`hecate::coordinator`]) for subcommands: `repro`
//! regenerates the paper's tables/figures, `simulate` runs a single
//! cluster simulation, `train` drives the AOT-compiled model end-to-end
//! through PJRT, and `fssdp` runs the numeric multi-device FSSDP engine.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = hecate::coordinator::run(argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
