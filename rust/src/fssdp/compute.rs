//! Compute backends of the numeric FSSDP engine.
//!
//! The engine's math runs through three named entry points (`gate_fwd`,
//! `expert_ffn_fwd`, `expert_ffn_bwd`). [`Compute`] abstracts where they
//! execute:
//!
//! * [`Compute::Pjrt`] — the AOT-compiled HLO executables under PJRT
//!   (requires `artifacts/`; the production path);
//! * [`Compute::Reference`] — pure-Rust kernels mirroring the
//!   `python/compile/kernels/ref.py` oracles (tanh-GeLU FFN, softmax +
//!   GShard top-2 gate). Hermetic: no artifacts, no PJRT. This is what lets
//!   the checkpoint/elastic-resume equivalence tests run everywhere.
//!
//! Both backends use the same calling convention (shape-checked
//! [`HostTensor`] tuples), so the engine body is backend-agnostic.

use crate::runtime::{HostTensor, Runtime};

/// Where the engine's kernels execute.
pub enum Compute {
    /// Real HLO executables through the PJRT runtime.
    Pjrt(Runtime),
    /// In-process reference kernels (see [`Reference`]).
    Reference(Reference),
}

impl Compute {
    pub fn backend_name(&self) -> &'static str {
        match self {
            Compute::Pjrt(_) => "pjrt",
            Compute::Reference(_) => "reference",
        }
    }

    /// Execute a named entry point. Mirrors [`Runtime::execute`].
    pub fn execute(
        &mut self,
        name: &str,
        inputs: &[HostTensor],
    ) -> anyhow::Result<Vec<HostTensor>> {
        match self {
            Compute::Pjrt(rt) => rt.execute(name, inputs),
            Compute::Reference(r) => r.execute(name, inputs),
        }
    }
}

/// Pure-Rust reference kernels.
///
/// Semantics match `python/compile/kernels/ref.py`:
/// `expert_ffn(x) = gelu(x @ w1 + b1) @ w2 + b2` with the tanh-approx GeLU,
/// and `gate(x, wg) = top2(softmax(x @ wg))` with GShard weight
/// normalization (ties toward the lower expert index).
#[derive(Debug, Clone, Copy, Default)]
pub struct Reference;

const GELU_K: f32 = 0.797_884_6; // sqrt(2/pi)
const GELU_C: f32 = 0.044_715;

fn gelu(z: f32) -> f32 {
    0.5 * z * (1.0 + (GELU_K * (z + GELU_C * z * z * z)).tanh())
}

fn gelu_grad(z: f32) -> f32 {
    let u = GELU_K * (z + GELU_C * z * z * z);
    let t = u.tanh();
    let du = GELU_K * (1.0 + 3.0 * GELU_C * z * z);
    0.5 * (1.0 + t) + 0.5 * z * (1.0 - t * t) * du
}

/// `a [n,k] @ b [k,m]`.
fn matmul_nn(a: &[f32], b: &[f32], n: usize, k: usize, m: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n * m];
    for i in 0..n {
        let orow = &mut out[i * m..(i + 1) * m];
        for (p, &av) in a[i * k..(i + 1) * k].iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            for (o, &bv) in orow.iter_mut().zip(b[p * m..(p + 1) * m].iter()) {
                *o += av * bv;
            }
        }
    }
    out
}

/// `a [n,k] @ bᵀ` with `b [m,k]`.
fn matmul_nt(a: &[f32], b: &[f32], n: usize, k: usize, m: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n * m];
    for i in 0..n {
        let arow = &a[i * k..(i + 1) * k];
        for j in 0..m {
            let brow = &b[j * k..(j + 1) * k];
            out[i * m + j] = arow.iter().zip(brow.iter()).map(|(x, y)| x * y).sum();
        }
    }
    out
}

/// `aᵀ @ b` with `a [k,n]`, `b [k,m]`.
fn matmul_tn(a: &[f32], b: &[f32], k: usize, n: usize, m: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n * m];
    for p in 0..k {
        let arow = &a[p * n..(p + 1) * n];
        let brow = &b[p * m..(p + 1) * m];
        for (i, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            for (o, &bv) in out[i * m..(i + 1) * m].iter_mut().zip(brow.iter()) {
                *o += av * bv;
            }
        }
    }
    out
}

fn shape2(t: &HostTensor, what: &str) -> anyhow::Result<(usize, usize)> {
    let s = t.shape();
    anyhow::ensure!(s.len() == 2, "{what}: expected rank-2 tensor, got shape {s:?}");
    Ok((s[0], s[1]))
}

impl Reference {
    pub fn execute(
        &mut self,
        name: &str,
        inputs: &[HostTensor],
    ) -> anyhow::Result<Vec<HostTensor>> {
        match name {
            "gate_fwd" => self.gate_fwd(inputs),
            "expert_ffn_fwd" => self.ffn_fwd(inputs),
            "expert_ffn_bwd" => self.ffn_bwd(inputs),
            other => anyhow::bail!("reference backend has no entry `{other}`"),
        }
    }

    /// logits → softmax → top-2, mirroring the HLO gate: returns
    /// `(probs [T,E], weights [T,2], idx [T,2] i32)`.
    fn gate_fwd(&self, inputs: &[HostTensor]) -> anyhow::Result<Vec<HostTensor>> {
        anyhow::ensure!(inputs.len() == 2, "gate_fwd expects (x, wg)");
        let (t, dm) = shape2(&inputs[0], "gate x")?;
        let (dm2, e) = shape2(&inputs[1], "gate wg")?;
        anyhow::ensure!(dm == dm2, "gate: x d_model {dm} != wg d_model {dm2}");
        anyhow::ensure!(e >= 2, "gate needs at least 2 experts for top-2");
        let x = inputs[0].as_f32()?;
        let wg = inputs[1].as_f32()?;

        let logits = matmul_nn(x, wg, t, dm, e);
        let mut probs = vec![0.0f32; t * e];
        let mut w2 = vec![0.0f32; t * 2];
        let mut idx = vec![0i32; t * 2];
        for row in 0..t {
            let l = &logits[row * e..(row + 1) * e];
            let max = l.iter().cloned().fold(f32::MIN, f32::max);
            let p = &mut probs[row * e..(row + 1) * e];
            let mut sum = 0.0f32;
            for (pi, &li) in p.iter_mut().zip(l.iter()) {
                *pi = (li - max).exp();
                sum += *pi;
            }
            for pi in p.iter_mut() {
                *pi /= sum;
            }
            // top-2 with ties toward the lower index (strict > scans).
            let mut i1 = 0usize;
            for (i, &pi) in p.iter().enumerate() {
                if pi > p[i1] {
                    i1 = i;
                }
            }
            let mut i2 = usize::MAX;
            for (i, &pi) in p.iter().enumerate() {
                if i == i1 {
                    continue;
                }
                if i2 == usize::MAX || pi > p[i2] {
                    i2 = i;
                }
            }
            let (p1, p2) = (p[i1], p[i2]);
            let denom = p1 + p2;
            w2[row * 2] = p1 / denom;
            w2[row * 2 + 1] = p2 / denom;
            idx[row * 2] = i1 as i32;
            idx[row * 2 + 1] = i2 as i32;
        }
        Ok(vec![
            HostTensor::f32(vec![t, e], probs),
            HostTensor::f32(vec![t, 2], w2),
            HostTensor::i32(vec![t, 2], idx),
        ])
    }

    /// Returns the pre-activation `z = x@w1 + b1` and hidden `h = gelu(z)`.
    fn ffn_hidden(
        x: &[f32],
        w1: &[f32],
        b1: &[f32],
        cap: usize,
        dm: usize,
        dff: usize,
    ) -> (Vec<f32>, Vec<f32>) {
        let mut z = matmul_nn(x, w1, cap, dm, dff);
        for row in 0..cap {
            for (zi, &bi) in z[row * dff..(row + 1) * dff].iter_mut().zip(b1.iter()) {
                *zi += bi;
            }
        }
        let h: Vec<f32> = z.iter().map(|&v| gelu(v)).collect();
        (z, h)
    }

    fn ffn_check_shapes(
        inputs: &[HostTensor],
        want: usize,
        what: &str,
    ) -> anyhow::Result<(usize, usize, usize)> {
        anyhow::ensure!(inputs.len() == want, "{what}: expected {want} inputs");
        let (cap, dm) = shape2(&inputs[0], "ffn x")?;
        let (dm2, dff) = shape2(&inputs[1], "ffn w1")?;
        let (dff2, dm3) = shape2(&inputs[3], "ffn w2")?;
        anyhow::ensure!(
            dm == dm2 && dm == dm3 && dff == dff2,
            "{what}: inconsistent dims (x [{cap},{dm}], w1 [{dm2},{dff}], w2 [{dff2},{dm3}])"
        );
        anyhow::ensure!(
            inputs[2].shape() == [dff] && inputs[4].shape() == [dm],
            "{what}: bias shapes {:?}/{:?} vs dff {dff}, d_model {dm}",
            inputs[2].shape(),
            inputs[4].shape()
        );
        Ok((cap, dm, dff))
    }

    /// `y = gelu(x@w1 + b1) @ w2 + b2`.
    fn ffn_fwd(&self, inputs: &[HostTensor]) -> anyhow::Result<Vec<HostTensor>> {
        let (cap, dm, dff) = Self::ffn_check_shapes(inputs, 5, "expert_ffn_fwd")?;
        let x = inputs[0].as_f32()?;
        let w1 = inputs[1].as_f32()?;
        let b1 = inputs[2].as_f32()?;
        let w2 = inputs[3].as_f32()?;
        let b2 = inputs[4].as_f32()?;
        let (_z, h) = Self::ffn_hidden(x, w1, b1, cap, dm, dff);
        let mut y = matmul_nn(&h, w2, cap, dff, dm);
        for row in 0..cap {
            for (yi, &bi) in y[row * dm..(row + 1) * dm].iter_mut().zip(b2.iter()) {
                *yi += bi;
            }
        }
        Ok(vec![HostTensor::f32(vec![cap, dm], y)])
    }

    /// VJP of [`Reference::ffn_fwd`]: returns `(gx, gw1, gb1, gw2, gb2)`.
    fn ffn_bwd(&self, inputs: &[HostTensor]) -> anyhow::Result<Vec<HostTensor>> {
        let (cap, dm, dff) = Self::ffn_check_shapes(inputs, 6, "expert_ffn_bwd")?;
        anyhow::ensure!(
            inputs[5].shape() == [cap, dm],
            "expert_ffn_bwd: gy shape {:?} vs [{cap},{dm}]",
            inputs[5].shape()
        );
        let x = inputs[0].as_f32()?;
        let w1 = inputs[1].as_f32()?;
        let b1 = inputs[2].as_f32()?;
        let w2 = inputs[3].as_f32()?;
        let gy = inputs[5].as_f32()?;

        let (z, h) = Self::ffn_hidden(x, w1, b1, cap, dm, dff);
        // gb2[c] = Σ_rows gy ; gw2 = hᵀ @ gy ; gh = gy @ w2ᵀ
        let mut gb2 = vec![0.0f32; dm];
        for row in 0..cap {
            for (g, &v) in gb2.iter_mut().zip(gy[row * dm..(row + 1) * dm].iter()) {
                *g += v;
            }
        }
        let gw2 = matmul_tn(&h, gy, cap, dff, dm);
        let gh = matmul_nt(gy, w2, cap, dm, dff);
        // gz = gh ⊙ gelu'(z)
        let gz: Vec<f32> = gh.iter().zip(z.iter()).map(|(&g, &zv)| g * gelu_grad(zv)).collect();
        let mut gb1 = vec![0.0f32; dff];
        for row in 0..cap {
            for (g, &v) in gb1.iter_mut().zip(gz[row * dff..(row + 1) * dff].iter()) {
                *g += v;
            }
        }
        let gw1 = matmul_tn(x, &gz, cap, dm, dff);
        let gx = matmul_nt(&gz, w1, cap, dff, dm);
        Ok(vec![
            HostTensor::f32(vec![cap, dm], gx),
            HostTensor::f32(vec![dm, dff], gw1),
            HostTensor::f32(vec![dff], gb1),
            HostTensor::f32(vec![dff, dm], gw2),
            HostTensor::f32(vec![dm], gb2),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(n: usize, f: f32) -> Vec<f32> {
        (0..n).map(|i| ((i as f32) * f).sin() * 0.1).collect()
    }

    #[test]
    fn gate_produces_valid_top2() {
        // Mirrors the PJRT integration test `gate_fwd_produces_valid_top2`.
        let (t, dm, e) = (12, 8, 6);
        let x = HostTensor::f32(vec![t, dm], (0..t * dm).map(|i| (i as f32 * 0.37).sin()).collect());
        let wg = HostTensor::f32(
            vec![dm, e],
            (0..dm * e).map(|i| (i as f32 * 0.11).cos() * 0.3).collect(),
        );
        let out = Reference.execute("gate_fwd", &[x, wg]).unwrap();
        assert_eq!(out.len(), 3);
        let probs = out[0].as_f32().unwrap();
        for row in probs.chunks(e) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-4, "row sum {s}");
        }
        let w = out[1].as_f32().unwrap();
        let idx = out[2].as_i32().unwrap();
        for (wpair, ipair) in w.chunks(2).zip(idx.chunks(2)) {
            assert!((wpair[0] + wpair[1] - 1.0).abs() < 1e-4);
            assert!(wpair[0] >= wpair[1], "first choice has the larger weight");
            assert_ne!(ipair[0], ipair[1]);
            assert!((0..e as i32).contains(&ipair[0]));
            assert!((0..e as i32).contains(&ipair[1]));
        }
    }

    #[test]
    fn gate_tie_breaks_toward_lower_index() {
        // Identical logits everywhere: top-2 must be experts (0, 1).
        let x = HostTensor::f32(vec![2, 3], vec![1.0; 6]);
        let wg = HostTensor::f32(vec![3, 4], vec![0.5; 12]);
        let out = Reference.execute("gate_fwd", &[x, wg]).unwrap();
        let idx = out[2].as_i32().unwrap();
        assert_eq!(idx, &[0, 1, 0, 1]);
    }

    #[test]
    fn ffn_bwd_matches_finite_difference() {
        // Mirrors the PJRT integration test, hermetically.
        let (cap, dm, dff) = (4, 6, 10);
        let x = HostTensor::f32(vec![cap, dm], mk(cap * dm, 0.13));
        let w1 = HostTensor::f32(vec![dm, dff], mk(dm * dff, 0.07));
        let b1 = HostTensor::f32(vec![dff], mk(dff, 0.19));
        let w2 = HostTensor::f32(vec![dff, dm], mk(dff * dm, 0.05));
        let b2 = HostTensor::f32(vec![dm], mk(dm, 0.23));
        let gy = HostTensor::f32(vec![cap, dm], vec![1.0; cap * dm]);

        let bwd = Reference
            .execute(
                "expert_ffn_bwd",
                &[x.clone(), w1.clone(), b1.clone(), w2.clone(), b2.clone(), gy],
            )
            .unwrap();
        assert_eq!(bwd.len(), 5);
        // analytic: dL/db2 with gy=1 is cap (each row contributes 1)
        for &g in bwd[4].as_f32().unwrap() {
            assert!((g - cap as f32).abs() < 1e-3, "gb2 {g} vs {cap}");
        }

        // finite difference on every parameter tensor via L = Σ y
        let run_loss = |w1v: &[f32], b1v: &[f32], w2v: &[f32]| -> f32 {
            let y = Reference
                .execute(
                    "expert_ffn_fwd",
                    &[
                        x.clone(),
                        HostTensor::f32(vec![dm, dff], w1v.to_vec()),
                        HostTensor::f32(vec![dff], b1v.to_vec()),
                        HostTensor::f32(vec![dff, dm], w2v.to_vec()),
                        b2.clone(),
                    ],
                )
                .unwrap();
            y[0].as_f32().unwrap().iter().sum()
        };
        let (w1v, b1v, w2v) = (mk(dm * dff, 0.07), mk(dff, 0.19), mk(dff * dm, 0.05));
        let eps = 1e-3f32;
        let check = |analytic: f32, fd: f32, what: &str| {
            assert!(
                (fd - analytic).abs() < 2e-2 * analytic.abs().max(1.0),
                "{what}: finite diff {fd} vs analytic {analytic}"
            );
        };
        // one element of each of w1, b1, w2
        for (tensor_i, elt) in [(1usize, 5usize), (2, 3), (3, 7)] {
            let (mut a, mut b, mut c) = (w1v.clone(), b1v.clone(), w2v.clone());
            let tgt: &mut Vec<f32> = match tensor_i {
                1 => &mut a,
                2 => &mut b,
                _ => &mut c,
            };
            tgt[elt] += eps;
            let lp = run_loss(&a, &b, &c);
            let tgt: &mut Vec<f32> = match tensor_i {
                1 => &mut a,
                2 => &mut b,
                _ => &mut c,
            };
            tgt[elt] -= 2.0 * eps;
            let lm = run_loss(&a, &b, &c);
            let fd = (lp - lm) / (2.0 * eps);
            let analytic = bwd[tensor_i].as_f32().unwrap()[elt];
            check(analytic, fd, &format!("tensor {tensor_i} elt {elt}"));
        }
    }

    #[test]
    fn gx_matches_finite_difference() {
        let (cap, dm, dff) = (3, 4, 6);
        let xv = mk(cap * dm, 0.31);
        let w1 = HostTensor::f32(vec![dm, dff], mk(dm * dff, 0.07));
        let b1 = HostTensor::f32(vec![dff], mk(dff, 0.19));
        let w2 = HostTensor::f32(vec![dff, dm], mk(dff * dm, 0.05));
        let b2 = HostTensor::f32(vec![dm], mk(dm, 0.23));
        let gy = HostTensor::f32(vec![cap, dm], vec![1.0; cap * dm]);
        let loss = |xv: &[f32]| -> f32 {
            Reference
                .execute(
                    "expert_ffn_fwd",
                    &[
                        HostTensor::f32(vec![cap, dm], xv.to_vec()),
                        w1.clone(),
                        b1.clone(),
                        w2.clone(),
                        b2.clone(),
                    ],
                )
                .unwrap()[0]
                .as_f32()
                .unwrap()
                .iter()
                .sum()
        };
        let bwd = Reference
            .execute(
                "expert_ffn_bwd",
                &[
                    HostTensor::f32(vec![cap, dm], xv.clone()),
                    w1.clone(),
                    b1.clone(),
                    w2.clone(),
                    b2.clone(),
                    gy,
                ],
            )
            .unwrap();
        let gx = bwd[0].as_f32().unwrap();
        let eps = 1e-3f32;
        for elt in [0usize, 5, 11] {
            let mut p = xv.clone();
            p[elt] += eps;
            let lp = loss(&p);
            p[elt] -= 2.0 * eps;
            let lm = loss(&p);
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (fd - gx[elt]).abs() < 2e-2 * gx[elt].abs().max(1.0),
                "gx[{elt}]: fd {fd} vs analytic {}",
                gx[elt]
            );
        }
    }

    #[test]
    fn gelu_grad_matches_finite_difference() {
        for z in [-3.0f32, -0.7, 0.0, 0.4, 2.5] {
            let eps = 1e-3f32;
            let fd = (gelu(z + eps) - gelu(z - eps)) / (2.0 * eps);
            assert!((fd - gelu_grad(z)).abs() < 1e-3, "z={z}: {fd} vs {}", gelu_grad(z));
        }
    }

    #[test]
    fn unknown_entry_errors() {
        assert!(Reference.execute("nope", &[]).is_err());
    }
}
