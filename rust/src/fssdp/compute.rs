//! Compute backends of the numeric FSSDP engine.
//!
//! The engine's math runs through three named entry points (`gate_fwd`,
//! `expert_ffn_fwd`, `expert_ffn_bwd`). [`Compute`] abstracts where they
//! execute:
//!
//! * [`Compute::Pjrt`] — the AOT-compiled HLO executables under PJRT
//!   (requires `artifacts/`; the production path);
//! * [`Compute::Reference`] — pure-Rust kernels mirroring the
//!   `python/compile/kernels/ref.py` oracles (tanh-GeLU FFN, softmax +
//!   GShard top-2 gate). Hermetic: no artifacts, no PJRT. This is what lets
//!   the checkpoint/elastic-resume equivalence tests run everywhere.
//!
//! Two calling conventions coexist:
//!
//! * the shape-checked [`HostTensor`] tuples of [`Compute::execute`] — the
//!   PJRT wire format, kept for the integration tests and any caller that
//!   wants owned tensors;
//! * the zero-copy `*_into` entry points ([`Compute::gate_fwd_into`],
//!   [`Compute::ffn_fwd_into`], [`Compute::ffn_bwd_into`]) the engine hot
//!   path uses: inputs are borrowed slices/[`TensorView`]s (expert
//!   parameters arrive as an [`ExpertParams`] view split straight out of
//!   the packed chunk), outputs land in caller-provided buffers, and all
//!   intermediates live in a reusable [`KernelScratch`]. On the reference
//!   backend this path performs **zero** heap allocations in steady state;
//!   on PJRT it falls back to building `HostTensor`s (the runtime owns its
//!   buffers anyway).
//!
//! The in-process kernels come in **two tiers**, selected by
//! [`ComputeMode`]:
//!
//! * [`ComputeMode::Reference`] ([`Reference`]) — the oracle. Its matmuls
//!   are blocked over rows/columns for cache locality, but the
//!   k-accumulation order of every output element is exactly the naive
//!   kernels' order (ascending `p`, zero-skip unchanged), so results are
//!   **bitwise identical** to the pre-blocking implementation — the oracle
//!   tests below lock this. Bit-identical at any thread count.
//! * [`ComputeMode::Fast`] ([`Fast`]) — the speed tier. Same semantics
//!   within a measured divergence bound, but written for the
//!   autovectorizer: contiguous inner loops with unrolled, FMA-reassociable
//!   accumulation, no zero-skip/ascending-k ordering constraint, split
//!   accumulator lanes in the dot products, a branch-free polynomial
//!   `tanh`, and fused bias/GeLU passes that skip intermediate stores.
//!   Deterministic run-to-run at a fixed thread count (the lane/unroll
//!   reduction order is fixed), but **not** bit-identical to Reference.
//!   The divergence-bound harness (`fssdp::diverge`) measures and locks
//!   the Fast-vs-Reference parameter drift over training spans.

use crate::runtime::{HostTensor, Runtime, TensorView, TensorViewMut};

/// Which kernel tier the in-process (reference-family) backends run.
/// Compute-only: routing, schedules, and communication plans are
/// identical in both modes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ComputeMode {
    /// Bitwise-reproducible oracle kernels ([`Reference`]).
    #[default]
    Reference,
    /// Autovectorizer-friendly fast-math kernels ([`Fast`]).
    Fast,
}

impl ComputeMode {
    /// Canonical CLI spelling (`ref` / `fast`).
    pub fn as_str(&self) -> &'static str {
        match self {
            ComputeMode::Reference => "ref",
            ComputeMode::Fast => "fast",
        }
    }
}

/// Row-tile edge of the blocked matmuls.
const BLOCK_ROWS: usize = 16;
/// Column-tile edge of the blocked matmuls.
const BLOCK_COLS: usize = 128;

/// Where the engine's kernels execute.
pub enum Compute {
    /// Real HLO executables through the PJRT runtime.
    Pjrt(Runtime),
    /// In-process reference kernels (see [`Reference`]).
    Reference(Reference),
    /// In-process fast-math kernels (see [`Fast`]).
    Fast(Fast),
}

/// Borrowed views of one expert's packed parameter chunk
/// (`w1 ++ b1 ++ w2 ++ b2`, split without copying).
#[derive(Debug, Clone, Copy)]
pub struct ExpertParams<'a> {
    /// `d_model × d_ffn`.
    pub w1: &'a [f32],
    /// `d_ffn`.
    pub b1: &'a [f32],
    /// `d_ffn × d_model`.
    pub w2: &'a [f32],
    /// `d_model`.
    pub b2: &'a [f32],
}

/// Caller-provided output buffers of [`Compute::ffn_bwd_into`].
#[derive(Debug)]
pub struct FfnGrads<'a> {
    /// `cap × d_model` — input cotangent.
    pub gx: &'a mut [f32],
    /// `d_model × d_ffn`.
    pub gw1: &'a mut [f32],
    /// `d_ffn`.
    pub gb1: &'a mut [f32],
    /// `d_ffn × d_model`.
    pub gw2: &'a mut [f32],
    /// `d_model`.
    pub gb2: &'a mut [f32],
}

/// Reusable intermediate buffers of the reference kernels (pre-activation,
/// hidden, their cotangents, gate logits/probs). One scratch per execution
/// context (engine workspace, SPMD rank, worker thread); buffers grow to
/// the layer shape once and are reused for every subsequent call.
#[derive(Debug, Default)]
pub struct KernelScratch {
    z: Vec<f32>,
    h: Vec<f32>,
    gh: Vec<f32>,
    gz: Vec<f32>,
    logits: Vec<f32>,
    probs: Vec<f32>,
}

fn sized(buf: &mut Vec<f32>, len: usize) {
    if buf.len() != len {
        buf.resize(len, 0.0);
    }
}

impl Compute {
    pub fn backend_name(&self) -> &'static str {
        match self {
            Compute::Pjrt(_) => "pjrt",
            Compute::Reference(_) => "reference",
            Compute::Fast(_) => "fast",
        }
    }

    /// The in-process backend of `mode` (the hermetic reference family —
    /// what worker threads and SPMD ranks construct locally).
    pub fn for_mode(mode: ComputeMode) -> Compute {
        match mode {
            ComputeMode::Reference => Compute::Reference(Reference),
            ComputeMode::Fast => Compute::Fast(Fast),
        }
    }

    /// The kernel tier of an in-process backend (`None` for PJRT, whose
    /// executables are opaque).
    pub fn mode(&self) -> Option<ComputeMode> {
        match self {
            Compute::Pjrt(_) => None,
            Compute::Reference(_) => Some(ComputeMode::Reference),
            Compute::Fast(_) => Some(ComputeMode::Fast),
        }
    }

    /// Execute a named entry point. Mirrors [`Runtime::execute`].
    pub fn execute(
        &mut self,
        name: &str,
        inputs: &[HostTensor],
    ) -> anyhow::Result<Vec<HostTensor>> {
        match self {
            Compute::Pjrt(rt) => rt.execute(name, inputs),
            Compute::Reference(r) => r.execute(name, inputs),
            Compute::Fast(f) => f.execute(name, inputs),
        }
    }

    /// Gate forward without intermediate tensors: `x [t,dm]` and
    /// `wg [dm,e]` are borrowed slices; the top-2 weights/indices land in
    /// `w2`/`idx` (resized to `t × 2`). Softmax probabilities stay in
    /// `scr.probs` for callers that need them.
    #[allow(clippy::too_many_arguments)]
    pub fn gate_fwd_into(
        &mut self,
        x: &[f32],
        wg: &[f32],
        t: usize,
        dm: usize,
        e: usize,
        scr: &mut KernelScratch,
        w2: &mut Vec<f32>,
        idx: &mut Vec<i32>,
    ) -> anyhow::Result<()> {
        match self {
            Compute::Reference(r) => r.gate_fwd_into(x, wg, t, dm, e, scr, w2, idx),
            Compute::Fast(f) => f.gate_fwd_into(x, wg, t, dm, e, scr, w2, idx),
            Compute::Pjrt(rt) => {
                let out = rt.execute(
                    "gate_fwd",
                    &[
                        HostTensor::f32(vec![t, dm], x.to_vec()),
                        HostTensor::f32(vec![dm, e], wg.to_vec()),
                    ],
                )?;
                // keep the contract: probabilities land in scr.probs on
                // every backend
                scr.probs.clear();
                scr.probs.extend_from_slice(out[0].as_f32()?);
                w2.clear();
                w2.extend_from_slice(out[1].as_f32()?);
                idx.clear();
                idx.extend_from_slice(out[2].as_i32()?);
                Ok(())
            }
        }
    }

    /// Expert FFN forward into the caller's `y` (`cap × dm`). `x` is the
    /// packed capacity-group input; parameters are borrowed chunk views.
    #[allow(clippy::too_many_arguments)]
    pub fn ffn_fwd_into(
        &mut self,
        p: &ExpertParams<'_>,
        x: &[f32],
        cap: usize,
        dm: usize,
        dff: usize,
        scr: &mut KernelScratch,
        y: &mut [f32],
    ) -> anyhow::Result<()> {
        match self {
            Compute::Reference(r) => {
                r.ffn_fwd_into(p, x, cap, dm, dff, scr, y);
                Ok(())
            }
            Compute::Fast(f) => {
                f.ffn_fwd_into(p, x, cap, dm, dff, scr, y);
                Ok(())
            }
            Compute::Pjrt(rt) => {
                let out = rt.execute(
                    "expert_ffn_fwd",
                    &[
                        HostTensor::f32(vec![cap, dm], x.to_vec()),
                        HostTensor::f32(vec![dm, dff], p.w1.to_vec()),
                        HostTensor::f32(vec![dff], p.b1.to_vec()),
                        HostTensor::f32(vec![dff, dm], p.w2.to_vec()),
                        HostTensor::f32(vec![dm], p.b2.to_vec()),
                    ],
                )?;
                y.copy_from_slice(out[0].as_f32()?);
                Ok(())
            }
        }
    }

    /// Expert FFN VJP into the caller's [`FfnGrads`] buffers.
    #[allow(clippy::too_many_arguments)]
    pub fn ffn_bwd_into(
        &mut self,
        p: &ExpertParams<'_>,
        x: &[f32],
        gy: &[f32],
        cap: usize,
        dm: usize,
        dff: usize,
        scr: &mut KernelScratch,
        out: FfnGrads<'_>,
    ) -> anyhow::Result<()> {
        match self {
            Compute::Reference(r) => {
                r.ffn_bwd_into(p, x, gy, cap, dm, dff, scr, out);
                Ok(())
            }
            Compute::Fast(f) => {
                f.ffn_bwd_into(p, x, gy, cap, dm, dff, scr, out);
                Ok(())
            }
            Compute::Pjrt(rt) => {
                let res = rt.execute(
                    "expert_ffn_bwd",
                    &[
                        HostTensor::f32(vec![cap, dm], x.to_vec()),
                        HostTensor::f32(vec![dm, dff], p.w1.to_vec()),
                        HostTensor::f32(vec![dff], p.b1.to_vec()),
                        HostTensor::f32(vec![dff, dm], p.w2.to_vec()),
                        HostTensor::f32(vec![dm], p.b2.to_vec()),
                        HostTensor::f32(vec![cap, dm], gy.to_vec()),
                    ],
                )?;
                out.gx.copy_from_slice(res[0].as_f32()?);
                out.gw1.copy_from_slice(res[1].as_f32()?);
                out.gb1.copy_from_slice(res[2].as_f32()?);
                out.gw2.copy_from_slice(res[3].as_f32()?);
                out.gb2.copy_from_slice(res[4].as_f32()?);
                Ok(())
            }
        }
    }
}

/// Pure-Rust reference kernels.
///
/// Semantics match `python/compile/kernels/ref.py`:
/// `expert_ffn(x) = gelu(x @ w1 + b1) @ w2 + b2` with the tanh-approx GeLU,
/// and `gate(x, wg) = top2(softmax(x @ wg))` with GShard weight
/// normalization (ties toward the lower expert index).
#[derive(Debug, Clone, Copy, Default)]
pub struct Reference;

const GELU_K: f32 = 0.797_884_6; // sqrt(2/pi)
const GELU_C: f32 = 0.044_715;

fn gelu(z: f32) -> f32 {
    0.5 * z * (1.0 + (GELU_K * (z + GELU_C * z * z * z)).tanh())
}

fn gelu_grad(z: f32) -> f32 {
    let u = GELU_K * (z + GELU_C * z * z * z);
    let t = u.tanh();
    let du = GELU_K * (1.0 + 3.0 * GELU_C * z * z);
    0.5 * (1.0 + t) + 0.5 * z * (1.0 - t * t) * du
}

/// `a [n,k] @ b [k,m]` into `out [n,m]`, blocked over rows and columns.
/// Each output element accumulates over ascending `p` with the zero-skip
/// of the naive kernel — bitwise identical to it.
pub fn matmul_nn(a: TensorView<'_>, b: TensorView<'_>, out: &mut [f32]) {
    let (n, k, m) = (a.rows(), a.cols(), b.cols());
    assert_eq!(b.rows(), k, "matmul_nn: inner dims {} vs {}", k, b.rows());
    assert_eq!(out.len(), n * m, "matmul_nn: out len {} vs {n}x{m}", out.len());
    out.fill(0.0);
    let (av, bv) = (a.data(), b.data());
    for i0 in (0..n).step_by(BLOCK_ROWS) {
        let i1 = (i0 + BLOCK_ROWS).min(n);
        for j0 in (0..m).step_by(BLOCK_COLS) {
            let j1 = (j0 + BLOCK_COLS).min(m);
            for i in i0..i1 {
                let orow = &mut out[i * m + j0..i * m + j1];
                for (p, &x) in av[i * k..(i + 1) * k].iter().enumerate() {
                    if x == 0.0 {
                        continue;
                    }
                    let brow = &bv[p * m + j0..p * m + j1];
                    for (o, &y) in orow.iter_mut().zip(brow.iter()) {
                        *o += x * y;
                    }
                }
            }
        }
    }
}

/// `a [n,k] @ bᵀ` with `b [m,k]`, into `out [n,m]`. Dot products keep the
/// ascending-k summation order of the naive kernel.
pub fn matmul_nt(a: TensorView<'_>, b: TensorView<'_>, out: &mut [f32]) {
    let (n, k, m) = (a.rows(), a.cols(), b.rows());
    assert_eq!(b.cols(), k, "matmul_nt: inner dims {} vs {}", k, b.cols());
    assert_eq!(out.len(), n * m, "matmul_nt: out len {} vs {n}x{m}", out.len());
    for i0 in (0..n).step_by(BLOCK_ROWS) {
        let i1 = (i0 + BLOCK_ROWS).min(n);
        for j0 in (0..m).step_by(BLOCK_ROWS) {
            let j1 = (j0 + BLOCK_ROWS).min(m);
            for i in i0..i1 {
                let arow = a.row(i);
                for j in j0..j1 {
                    let brow = b.row(j);
                    out[i * m + j] = arow.iter().zip(brow.iter()).map(|(x, y)| x * y).sum();
                }
            }
        }
    }
}

/// `aᵀ @ b` with `a [k,n]`, `b [k,m]`, into `out [n,m]`. Row-blocked;
/// per-element accumulation stays in ascending `p` with the zero-skip.
pub fn matmul_tn(a: TensorView<'_>, b: TensorView<'_>, out: &mut [f32]) {
    let (k, n, m) = (a.rows(), a.cols(), b.cols());
    assert_eq!(b.rows(), k, "matmul_tn: inner dims {} vs {}", k, b.rows());
    assert_eq!(out.len(), n * m, "matmul_tn: out len {} vs {n}x{m}", out.len());
    out.fill(0.0);
    for i0 in (0..n).step_by(BLOCK_ROWS) {
        let i1 = (i0 + BLOCK_ROWS).min(n);
        for p in 0..k {
            let arow = a.row(p);
            let brow = b.row(p);
            for i in i0..i1 {
                let x = arow[i];
                if x == 0.0 {
                    continue;
                }
                for (o, &y) in out[i * m..(i + 1) * m].iter_mut().zip(brow.iter()) {
                    *o += x * y;
                }
            }
        }
    }
}

/// Softmax + GShard top-2 over the logits already in `scr.logits`
/// (`t × e`), shared by both kernel tiers: probabilities land in
/// `scr.probs`, normalized weights/indices in `w2`/`idx` (resized to
/// `t × 2`), ties toward the lower expert index (strict `>` scans). The
/// selection logic being shared is what keeps the two tiers' routing
/// decisions identical whenever their logits agree on the top-2 order.
fn softmax_top2(scr: &mut KernelScratch, t: usize, e: usize, w2: &mut Vec<f32>, idx: &mut Vec<i32>) {
    w2.clear();
    w2.resize(t * 2, 0.0);
    idx.clear();
    idx.resize(t * 2, 0);
    for row in 0..t {
        let l = &scr.logits[row * e..(row + 1) * e];
        let max = l.iter().cloned().fold(f32::MIN, f32::max);
        let p = &mut scr.probs[row * e..(row + 1) * e];
        let mut sum = 0.0f32;
        for (pi, &li) in p.iter_mut().zip(l.iter()) {
            *pi = (li - max).exp();
            sum += *pi;
        }
        for pi in p.iter_mut() {
            *pi /= sum;
        }
        // top-2 with ties toward the lower index (strict > scans).
        let mut i1 = 0usize;
        for (i, &pi) in p.iter().enumerate() {
            if pi > p[i1] {
                i1 = i;
            }
        }
        let mut i2 = usize::MAX;
        for (i, &pi) in p.iter().enumerate() {
            if i == i1 {
                continue;
            }
            if i2 == usize::MAX || pi > p[i2] {
                i2 = i;
            }
        }
        let (p1, p2) = (p[i1], p[i2]);
        let denom = p1 + p2;
        w2[row * 2] = p1 / denom;
        w2[row * 2 + 1] = p2 / denom;
        idx[row * 2] = i1 as i32;
        idx[row * 2 + 1] = i2 as i32;
    }
}

// ---- the fast tier's kernels -----------------------------------------

/// `a [n,k] @ b [k,m]` into `out [n,m]`, fast tier: four `b` rows are
/// folded per pass with the four products summed in one expression, so the
/// compiler is free to keep vector accumulators and emit FMAs. The
/// remainder rows fall through to the single-row loop. No zero-skip — the
/// branch would block vectorization.
pub fn matmul_nn_fast(a: TensorView<'_>, b: TensorView<'_>, out: &mut [f32]) {
    let (n, k, m) = (a.rows(), a.cols(), b.cols());
    assert_eq!(b.rows(), k, "matmul_nn_fast: inner dims {} vs {}", k, b.rows());
    assert_eq!(out.len(), n * m, "matmul_nn_fast: out len {} vs {n}x{m}", out.len());
    out.fill(0.0);
    let (av, bv) = (a.data(), b.data());
    for i in 0..n {
        let arow = &av[i * k..(i + 1) * k];
        let orow = &mut out[i * m..(i + 1) * m];
        let mut p = 0usize;
        while p + 4 <= k {
            let (x0, x1, x2, x3) = (arow[p], arow[p + 1], arow[p + 2], arow[p + 3]);
            let b0 = &bv[p * m..(p + 1) * m];
            let b1 = &bv[(p + 1) * m..(p + 2) * m];
            let b2 = &bv[(p + 2) * m..(p + 3) * m];
            let b3 = &bv[(p + 3) * m..(p + 4) * m];
            for ((((o, &y0), &y1), &y2), &y3) in
                orow.iter_mut().zip(b0).zip(b1).zip(b2).zip(b3)
            {
                *o += x0 * y0 + x1 * y1 + x2 * y2 + x3 * y3;
            }
            p += 4;
        }
        while p < k {
            let x = arow[p];
            for (o, &y) in orow.iter_mut().zip(&bv[p * m..(p + 1) * m]) {
                *o += x * y;
            }
            p += 1;
        }
    }
}

/// Dot product with eight split accumulator lanes over `chunks_exact(8)`
/// and a fixed-order lane reduction — reassociated relative to the naive
/// left-to-right sum (vectorizable), but deterministic: the lane/tail
/// order never depends on thread count or data values.
fn dot_fast(x: &[f32], y: &[f32]) -> f32 {
    let mut acc = [0.0f32; 8];
    for (cx, cy) in x.chunks_exact(8).zip(y.chunks_exact(8)) {
        for l in 0..8 {
            acc[l] += cx[l] * cy[l];
        }
    }
    let head = x.len() - x.len() % 8;
    let mut tail = 0.0f32;
    for (xv, yv) in x[head..].iter().zip(&y[head..]) {
        tail += xv * yv;
    }
    (((acc[0] + acc[4]) + (acc[1] + acc[5])) + ((acc[2] + acc[6]) + (acc[3] + acc[7]))) + tail
}

/// `a [n,k] @ bᵀ` with `b [m,k]`, fast tier: [`dot_fast`] per output
/// element (both operands row-contiguous).
pub fn matmul_nt_fast(a: TensorView<'_>, b: TensorView<'_>, out: &mut [f32]) {
    let (n, k, m) = (a.rows(), a.cols(), b.rows());
    assert_eq!(b.cols(), k, "matmul_nt_fast: inner dims {} vs {}", k, b.cols());
    assert_eq!(out.len(), n * m, "matmul_nt_fast: out len {} vs {n}x{m}", out.len());
    for i in 0..n {
        let arow = a.row(i);
        for j in 0..m {
            out[i * m + j] = dot_fast(arow, b.row(j));
        }
    }
}

/// `aᵀ @ b` with `a [k,n]`, `b [k,m]`, fast tier: contiguous axpy rows
/// with no zero-skip branch in the inner loop.
pub fn matmul_tn_fast(a: TensorView<'_>, b: TensorView<'_>, out: &mut [f32]) {
    let (k, n, m) = (a.rows(), a.cols(), b.cols());
    assert_eq!(b.rows(), k, "matmul_tn_fast: inner dims {} vs {}", k, b.rows());
    assert_eq!(out.len(), n * m, "matmul_tn_fast: out len {} vs {n}x{m}", out.len());
    out.fill(0.0);
    for p in 0..k {
        let arow = a.row(p);
        let brow = b.row(p);
        for i in 0..n {
            let x = arow[i];
            for (o, &y) in out[i * m..(i + 1) * m].iter_mut().zip(brow.iter()) {
                *o += x * y;
            }
        }
    }
}

/// Branch-free clamped odd Padé(7,6) approximant of `tanh` — no libm
/// call, so the surrounding elementwise loops vectorize. Exact enough for
/// the fast tier: max absolute error ≈ 1.2e-4 (at the clamp edge, where
/// the rational form slightly overshoots 1), well inside the locked
/// Fast-vs-Reference divergence bound.
fn tanh_fast(x: f32) -> f32 {
    let x = x.clamp(-4.97, 4.97);
    let x2 = x * x;
    let p = x * (135135.0 + x2 * (17325.0 + x2 * (378.0 + x2)));
    let q = 135135.0 + x2 * (62370.0 + x2 * (3150.0 + 28.0 * x2));
    p / q
}

fn gelu_fast(z: f32) -> f32 {
    0.5 * z * (1.0 + tanh_fast(GELU_K * (z + GELU_C * z * z * z)))
}

/// Fused GeLU value + derivative sharing one `tanh` evaluation — the
/// backward pass needs both, and the shared `t` halves the transcendental
/// count relative to calling `gelu` and `gelu_grad` separately.
fn gelu_fused_fast(z: f32) -> (f32, f32) {
    let u = GELU_K * (z + GELU_C * z * z * z);
    let t = tanh_fast(u);
    let du = GELU_K * (1.0 + 3.0 * GELU_C * z * z);
    (0.5 * z * (1.0 + t), 0.5 * (1.0 + t) + 0.5 * z * (1.0 - t * t) * du)
}

fn shape2(t: &HostTensor, what: &str) -> anyhow::Result<(usize, usize)> {
    let s = t.shape();
    anyhow::ensure!(s.len() == 2, "{what}: expected rank-2 tensor, got shape {s:?}");
    Ok((s[0], s[1]))
}

impl Reference {
    pub fn execute(
        &mut self,
        name: &str,
        inputs: &[HostTensor],
    ) -> anyhow::Result<Vec<HostTensor>> {
        match name {
            "gate_fwd" => self.gate_fwd(inputs),
            "expert_ffn_fwd" => self.ffn_fwd(inputs),
            "expert_ffn_bwd" => self.ffn_bwd(inputs),
            other => anyhow::bail!("reference backend has no entry `{other}`"),
        }
    }

    /// The zero-copy gate kernel: logits → softmax → top-2, writing the
    /// normalized weights into `w2` and expert indices into `idx` (both
    /// resized to `t × 2`); the softmax probabilities stay in `scr.probs`.
    #[allow(clippy::too_many_arguments)]
    pub fn gate_fwd_into(
        &self,
        x: &[f32],
        wg: &[f32],
        t: usize,
        dm: usize,
        e: usize,
        scr: &mut KernelScratch,
        w2: &mut Vec<f32>,
        idx: &mut Vec<i32>,
    ) -> anyhow::Result<()> {
        anyhow::ensure!(e >= 2, "gate needs at least 2 experts for top-2");
        assert_eq!(x.len(), t * dm, "gate x len");
        assert_eq!(wg.len(), dm * e, "gate wg len");
        sized(&mut scr.logits, t * e);
        sized(&mut scr.probs, t * e);
        matmul_nn(TensorView::new(t, dm, x), TensorView::new(dm, e, wg), &mut scr.logits);
        softmax_top2(scr, t, e, w2, idx);
        Ok(())
    }

    /// `y = gelu(x@w1 + b1) @ w2 + b2` into the caller's `y` (`cap × dm`),
    /// intermediates in `scr`.
    #[allow(clippy::too_many_arguments)]
    pub fn ffn_fwd_into(
        &self,
        p: &ExpertParams<'_>,
        x: &[f32],
        cap: usize,
        dm: usize,
        dff: usize,
        scr: &mut KernelScratch,
        y: &mut [f32],
    ) {
        assert_eq!(x.len(), cap * dm, "ffn x len");
        assert_eq!(y.len(), cap * dm, "ffn y len");
        sized(&mut scr.z, cap * dff);
        sized(&mut scr.h, cap * dff);
        matmul_nn(TensorView::new(cap, dm, x), TensorView::new(dm, dff, p.w1), &mut scr.z);
        for row in 0..cap {
            for (zi, &bi) in scr.z[row * dff..(row + 1) * dff].iter_mut().zip(p.b1.iter()) {
                *zi += bi;
            }
        }
        for (hv, &zv) in scr.h.iter_mut().zip(scr.z.iter()) {
            *hv = gelu(zv);
        }
        matmul_nn(TensorView::new(cap, dff, &scr.h), TensorView::new(dff, dm, p.w2), y);
        let mut yv = TensorViewMut::new(cap, dm, y);
        for row in 0..cap {
            for (yi, &bi) in yv.row_mut(row).iter_mut().zip(p.b2.iter()) {
                *yi += bi;
            }
        }
    }

    /// VJP of [`Reference::ffn_fwd_into`]: recomputes `z`/`h` from the
    /// kept activations and writes all five gradients into `out`.
    #[allow(clippy::too_many_arguments)]
    pub fn ffn_bwd_into(
        &self,
        p: &ExpertParams<'_>,
        x: &[f32],
        gy: &[f32],
        cap: usize,
        dm: usize,
        dff: usize,
        scr: &mut KernelScratch,
        out: FfnGrads<'_>,
    ) {
        assert_eq!(gy.len(), cap * dm, "ffn gy len");
        // recompute z and h (activations kept, intermediates recomputed)
        sized(&mut scr.z, cap * dff);
        sized(&mut scr.h, cap * dff);
        matmul_nn(TensorView::new(cap, dm, x), TensorView::new(dm, dff, p.w1), &mut scr.z);
        for row in 0..cap {
            for (zi, &bi) in scr.z[row * dff..(row + 1) * dff].iter_mut().zip(p.b1.iter()) {
                *zi += bi;
            }
        }
        for (hv, &zv) in scr.h.iter_mut().zip(scr.z.iter()) {
            *hv = gelu(zv);
        }
        // gb2[c] = Σ_rows gy ; gw2 = hᵀ @ gy ; gh = gy @ w2ᵀ
        out.gb2.fill(0.0);
        for row in 0..cap {
            for (g, &v) in out.gb2.iter_mut().zip(gy[row * dm..(row + 1) * dm].iter()) {
                *g += v;
            }
        }
        matmul_tn(TensorView::new(cap, dff, &scr.h), TensorView::new(cap, dm, gy), out.gw2);
        sized(&mut scr.gh, cap * dff);
        matmul_nt(TensorView::new(cap, dm, gy), TensorView::new(dff, dm, p.w2), &mut scr.gh);
        // gz = gh ⊙ gelu'(z)
        sized(&mut scr.gz, cap * dff);
        for ((gzv, &ghv), &zv) in scr.gz.iter_mut().zip(scr.gh.iter()).zip(scr.z.iter()) {
            *gzv = ghv * gelu_grad(zv);
        }
        out.gb1.fill(0.0);
        for row in 0..cap {
            for (g, &v) in out.gb1.iter_mut().zip(scr.gz[row * dff..(row + 1) * dff].iter()) {
                *g += v;
            }
        }
        matmul_tn(TensorView::new(cap, dm, x), TensorView::new(cap, dff, &scr.gz), out.gw1);
        matmul_nt(TensorView::new(cap, dff, &scr.gz), TensorView::new(dm, dff, p.w1), out.gx);
    }

    /// logits → softmax → top-2, mirroring the HLO gate: returns
    /// `(probs [T,E], weights [T,2], idx [T,2] i32)`.
    fn gate_fwd(&self, inputs: &[HostTensor]) -> anyhow::Result<Vec<HostTensor>> {
        anyhow::ensure!(inputs.len() == 2, "gate_fwd expects (x, wg)");
        let (t, dm) = shape2(&inputs[0], "gate x")?;
        let (dm2, e) = shape2(&inputs[1], "gate wg")?;
        anyhow::ensure!(dm == dm2, "gate: x d_model {dm} != wg d_model {dm2}");
        let x = inputs[0].as_f32()?;
        let wg = inputs[1].as_f32()?;
        let mut scr = KernelScratch::default();
        let mut w2 = Vec::new();
        let mut idx = Vec::new();
        self.gate_fwd_into(x, wg, t, dm, e, &mut scr, &mut w2, &mut idx)?;
        Ok(vec![
            HostTensor::f32(vec![t, e], scr.probs),
            HostTensor::f32(vec![t, 2], w2),
            HostTensor::i32(vec![t, 2], idx),
        ])
    }

    fn ffn_check_shapes(
        inputs: &[HostTensor],
        want: usize,
        what: &str,
    ) -> anyhow::Result<(usize, usize, usize)> {
        anyhow::ensure!(inputs.len() == want, "{what}: expected {want} inputs");
        let (cap, dm) = shape2(&inputs[0], "ffn x")?;
        let (dm2, dff) = shape2(&inputs[1], "ffn w1")?;
        let (dff2, dm3) = shape2(&inputs[3], "ffn w2")?;
        anyhow::ensure!(
            dm == dm2 && dm == dm3 && dff == dff2,
            "{what}: inconsistent dims (x [{cap},{dm}], w1 [{dm2},{dff}], w2 [{dff2},{dm3}])"
        );
        anyhow::ensure!(
            inputs[2].shape() == [dff] && inputs[4].shape() == [dm],
            "{what}: bias shapes {:?}/{:?} vs dff {dff}, d_model {dm}",
            inputs[2].shape(),
            inputs[4].shape()
        );
        Ok((cap, dm, dff))
    }

    fn params_of<'a>(inputs: &'a [HostTensor]) -> anyhow::Result<ExpertParams<'a>> {
        Ok(ExpertParams {
            w1: inputs[1].as_f32()?,
            b1: inputs[2].as_f32()?,
            w2: inputs[3].as_f32()?,
            b2: inputs[4].as_f32()?,
        })
    }

    /// `y = gelu(x@w1 + b1) @ w2 + b2`.
    fn ffn_fwd(&self, inputs: &[HostTensor]) -> anyhow::Result<Vec<HostTensor>> {
        let (cap, dm, dff) = Self::ffn_check_shapes(inputs, 5, "expert_ffn_fwd")?;
        let x = inputs[0].as_f32()?;
        let p = Self::params_of(inputs)?;
        let mut scr = KernelScratch::default();
        let mut y = vec![0.0f32; cap * dm];
        self.ffn_fwd_into(&p, x, cap, dm, dff, &mut scr, &mut y);
        Ok(vec![HostTensor::f32(vec![cap, dm], y)])
    }

    /// VJP of [`Reference::ffn_fwd`]: returns `(gx, gw1, gb1, gw2, gb2)`.
    fn ffn_bwd(&self, inputs: &[HostTensor]) -> anyhow::Result<Vec<HostTensor>> {
        let (cap, dm, dff) = Self::ffn_check_shapes(inputs, 6, "expert_ffn_bwd")?;
        anyhow::ensure!(
            inputs[5].shape() == [cap, dm],
            "expert_ffn_bwd: gy shape {:?} vs [{cap},{dm}]",
            inputs[5].shape()
        );
        let x = inputs[0].as_f32()?;
        let p = Self::params_of(inputs)?;
        let gy = inputs[5].as_f32()?;
        let mut scr = KernelScratch::default();
        let mut gx = vec![0.0f32; cap * dm];
        let mut gw1 = vec![0.0f32; dm * dff];
        let mut gb1 = vec![0.0f32; dff];
        let mut gw2 = vec![0.0f32; dff * dm];
        let mut gb2 = vec![0.0f32; dm];
        self.ffn_bwd_into(
            &p,
            x,
            gy,
            cap,
            dm,
            dff,
            &mut scr,
            FfnGrads {
                gx: &mut gx,
                gw1: &mut gw1,
                gb1: &mut gb1,
                gw2: &mut gw2,
                gb2: &mut gb2,
            },
        );
        Ok(vec![
            HostTensor::f32(vec![cap, dm], gx),
            HostTensor::f32(vec![dm, dff], gw1),
            HostTensor::f32(vec![dff], gb1),
            HostTensor::f32(vec![dff, dm], gw2),
            HostTensor::f32(vec![dm], gb2),
        ])
    }
}

/// Pure-Rust fast-math kernels — the speed tier of the reference family.
///
/// Same math as [`Reference`] (`python/compile/kernels/ref.py` semantics)
/// but traded for throughput: reassociated accumulation
/// ([`matmul_nn_fast`]/[`matmul_nt_fast`]/[`matmul_tn_fast`]), the
/// polynomial [`tanh_fast`], and fused bias+GeLU passes that never
/// materialize the biased pre-activation separately. Divergence from
/// [`Reference`] is bounded and measured (`fssdp::diverge`); run-to-run
/// results are deterministic at a fixed thread count.
#[derive(Debug, Clone, Copy, Default)]
pub struct Fast;

impl Fast {
    pub fn execute(
        &mut self,
        name: &str,
        inputs: &[HostTensor],
    ) -> anyhow::Result<Vec<HostTensor>> {
        match name {
            "gate_fwd" => {
                anyhow::ensure!(inputs.len() == 2, "gate_fwd expects (x, wg)");
                let (t, dm) = shape2(&inputs[0], "gate x")?;
                let (dm2, e) = shape2(&inputs[1], "gate wg")?;
                anyhow::ensure!(dm == dm2, "gate: x d_model {dm} != wg d_model {dm2}");
                let mut scr = KernelScratch::default();
                let mut w2 = Vec::new();
                let mut idx = Vec::new();
                self.gate_fwd_into(
                    inputs[0].as_f32()?,
                    inputs[1].as_f32()?,
                    t,
                    dm,
                    e,
                    &mut scr,
                    &mut w2,
                    &mut idx,
                )?;
                Ok(vec![
                    HostTensor::f32(vec![t, e], scr.probs),
                    HostTensor::f32(vec![t, 2], w2),
                    HostTensor::i32(vec![t, 2], idx),
                ])
            }
            "expert_ffn_fwd" => {
                let (cap, dm, dff) = Reference::ffn_check_shapes(inputs, 5, "expert_ffn_fwd")?;
                let p = Reference::params_of(inputs)?;
                let mut scr = KernelScratch::default();
                let mut y = vec![0.0f32; cap * dm];
                self.ffn_fwd_into(&p, inputs[0].as_f32()?, cap, dm, dff, &mut scr, &mut y);
                Ok(vec![HostTensor::f32(vec![cap, dm], y)])
            }
            "expert_ffn_bwd" => {
                let (cap, dm, dff) = Reference::ffn_check_shapes(inputs, 6, "expert_ffn_bwd")?;
                anyhow::ensure!(
                    inputs[5].shape() == [cap, dm],
                    "expert_ffn_bwd: gy shape {:?} vs [{cap},{dm}]",
                    inputs[5].shape()
                );
                let p = Reference::params_of(inputs)?;
                let mut scr = KernelScratch::default();
                let mut gx = vec![0.0f32; cap * dm];
                let mut gw1 = vec![0.0f32; dm * dff];
                let mut gb1 = vec![0.0f32; dff];
                let mut gw2 = vec![0.0f32; dff * dm];
                let mut gb2 = vec![0.0f32; dm];
                self.ffn_bwd_into(
                    &p,
                    inputs[0].as_f32()?,
                    inputs[5].as_f32()?,
                    cap,
                    dm,
                    dff,
                    &mut scr,
                    FfnGrads {
                        gx: &mut gx,
                        gw1: &mut gw1,
                        gb1: &mut gb1,
                        gw2: &mut gw2,
                        gb2: &mut gb2,
                    },
                );
                Ok(vec![
                    HostTensor::f32(vec![cap, dm], gx),
                    HostTensor::f32(vec![dm, dff], gw1),
                    HostTensor::f32(vec![dff], gb1),
                    HostTensor::f32(vec![dff, dm], gw2),
                    HostTensor::f32(vec![dm], gb2),
                ])
            }
            other => anyhow::bail!("fast backend has no entry `{other}`"),
        }
    }

    /// Fast-tier gate: [`matmul_nn_fast`] logits into the shared
    /// [`softmax_top2`] tail, so routing decisions match [`Reference`]
    /// whenever the logits agree on the top-2 order.
    #[allow(clippy::too_many_arguments)]
    pub fn gate_fwd_into(
        &self,
        x: &[f32],
        wg: &[f32],
        t: usize,
        dm: usize,
        e: usize,
        scr: &mut KernelScratch,
        w2: &mut Vec<f32>,
        idx: &mut Vec<i32>,
    ) -> anyhow::Result<()> {
        anyhow::ensure!(e >= 2, "gate needs at least 2 experts for top-2");
        assert_eq!(x.len(), t * dm, "gate x len");
        assert_eq!(wg.len(), dm * e, "gate wg len");
        sized(&mut scr.logits, t * e);
        sized(&mut scr.probs, t * e);
        matmul_nn_fast(TensorView::new(t, dm, x), TensorView::new(dm, e, wg), &mut scr.logits);
        softmax_top2(scr, t, e, w2, idx);
        Ok(())
    }

    /// `y = gelu(x@w1 + b1) @ w2 + b2`, fused: the bias add and GeLU run
    /// in one pass writing `h` directly (the biased pre-activation is
    /// never stored), and the output bias folds into a single row pass
    /// after the second matmul.
    #[allow(clippy::too_many_arguments)]
    pub fn ffn_fwd_into(
        &self,
        p: &ExpertParams<'_>,
        x: &[f32],
        cap: usize,
        dm: usize,
        dff: usize,
        scr: &mut KernelScratch,
        y: &mut [f32],
    ) {
        assert_eq!(x.len(), cap * dm, "ffn x len");
        assert_eq!(y.len(), cap * dm, "ffn y len");
        sized(&mut scr.z, cap * dff);
        sized(&mut scr.h, cap * dff);
        matmul_nn_fast(TensorView::new(cap, dm, x), TensorView::new(dm, dff, p.w1), &mut scr.z);
        for row in 0..cap {
            let zrow = &scr.z[row * dff..(row + 1) * dff];
            let hrow = &mut scr.h[row * dff..(row + 1) * dff];
            for ((hv, &zv), &bv) in hrow.iter_mut().zip(zrow.iter()).zip(p.b1.iter()) {
                *hv = gelu_fast(zv + bv);
            }
        }
        matmul_nn_fast(TensorView::new(cap, dff, &scr.h), TensorView::new(dff, dm, p.w2), y);
        let mut yv = TensorViewMut::new(cap, dm, y);
        for row in 0..cap {
            for (yi, &bi) in yv.row_mut(row).iter_mut().zip(p.b2.iter()) {
                *yi += bi;
            }
        }
    }

    /// VJP of [`Fast::ffn_fwd_into`]: recomputes the pre-activation once,
    /// then one fused pass yields `h` and `gelu'(z)` sharing a single
    /// `tanh` per element (the derivative lands in `scr.z`, overwriting
    /// the raw pre-activation it came from).
    #[allow(clippy::too_many_arguments)]
    pub fn ffn_bwd_into(
        &self,
        p: &ExpertParams<'_>,
        x: &[f32],
        gy: &[f32],
        cap: usize,
        dm: usize,
        dff: usize,
        scr: &mut KernelScratch,
        out: FfnGrads<'_>,
    ) {
        assert_eq!(gy.len(), cap * dm, "ffn gy len");
        sized(&mut scr.z, cap * dff);
        sized(&mut scr.h, cap * dff);
        matmul_nn_fast(TensorView::new(cap, dm, x), TensorView::new(dm, dff, p.w1), &mut scr.z);
        for row in 0..cap {
            let zrow = &mut scr.z[row * dff..(row + 1) * dff];
            let hrow = &mut scr.h[row * dff..(row + 1) * dff];
            for ((zv, hv), &bv) in zrow.iter_mut().zip(hrow.iter_mut()).zip(p.b1.iter()) {
                let (h, dh) = gelu_fused_fast(*zv + bv);
                *hv = h;
                *zv = dh;
            }
        }
        // gb2[c] = Σ_rows gy ; gw2 = hᵀ @ gy ; gh = gy @ w2ᵀ
        out.gb2.fill(0.0);
        for row in 0..cap {
            for (g, &v) in out.gb2.iter_mut().zip(gy[row * dm..(row + 1) * dm].iter()) {
                *g += v;
            }
        }
        matmul_tn_fast(TensorView::new(cap, dff, &scr.h), TensorView::new(cap, dm, gy), out.gw2);
        sized(&mut scr.gh, cap * dff);
        matmul_nt_fast(TensorView::new(cap, dm, gy), TensorView::new(dff, dm, p.w2), &mut scr.gh);
        // gz = gh ⊙ gelu'(z) — the derivative is already sitting in scr.z
        sized(&mut scr.gz, cap * dff);
        for ((gzv, &ghv), &dv) in scr.gz.iter_mut().zip(scr.gh.iter()).zip(scr.z.iter()) {
            *gzv = ghv * dv;
        }
        out.gb1.fill(0.0);
        for row in 0..cap {
            for (g, &v) in out.gb1.iter_mut().zip(scr.gz[row * dff..(row + 1) * dff].iter()) {
                *g += v;
            }
        }
        matmul_tn_fast(TensorView::new(cap, dm, x), TensorView::new(cap, dff, &scr.gz), out.gw1);
        matmul_nt_fast(TensorView::new(cap, dff, &scr.gz), TensorView::new(dm, dff, p.w1), out.gx);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(n: usize, f: f32) -> Vec<f32> {
        (0..n).map(|i| ((i as f32) * f).sin() * 0.1).collect()
    }

    // ---- the pre-blocking kernels, transcribed verbatim: the bitwise
    //      oracles of the blocked implementations ----

    fn naive_nn(a: &[f32], b: &[f32], n: usize, k: usize, m: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; n * m];
        for i in 0..n {
            let orow = &mut out[i * m..(i + 1) * m];
            for (p, &av) in a[i * k..(i + 1) * k].iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                for (o, &bv) in orow.iter_mut().zip(b[p * m..(p + 1) * m].iter()) {
                    *o += av * bv;
                }
            }
        }
        out
    }

    fn naive_nt(a: &[f32], b: &[f32], n: usize, k: usize, m: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; n * m];
        for i in 0..n {
            let arow = &a[i * k..(i + 1) * k];
            for j in 0..m {
                let brow = &b[j * k..(j + 1) * k];
                out[i * m + j] = arow.iter().zip(brow.iter()).map(|(x, y)| x * y).sum();
            }
        }
        out
    }

    fn naive_tn(a: &[f32], b: &[f32], k: usize, n: usize, m: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; n * m];
        for p in 0..k {
            let arow = &a[p * n..(p + 1) * n];
            let brow = &b[p * m..(p + 1) * m];
            for (i, &av) in arow.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                for (o, &bv) in out[i * m..(i + 1) * m].iter_mut().zip(brow.iter()) {
                    *o += av * bv;
                }
            }
        }
        out
    }

    /// Shapes chosen to cross the block edges, stay inside one block, hit
    /// single-row/column extremes, and the empty (`cap = 0`) case.
    const SHAPES: &[(usize, usize, usize)] = &[
        (1, 1, 1),
        (3, 5, 2),
        (0, 4, 7),
        (1, 7, 5),
        (16, 16, 16),
        (17, 23, 9),
        (33, 129, 130),
    ];

    #[test]
    fn blocked_nn_matches_naive_bitwise() {
        for &(n, k, m) in SHAPES {
            let a = mk(n * k, 0.13);
            let b = mk(k * m, 0.07);
            // dirty output buffer: the kernel must fully overwrite it
            let mut out = vec![7.0f32; n * m];
            matmul_nn(TensorView::new(n, k, &a), TensorView::new(k, m, &b), &mut out);
            let want = naive_nn(&a, &b, n, k, m);
            assert_eq!(out, want, "nn {n}x{k}x{m} must be bitwise identical");
        }
    }

    #[test]
    fn blocked_nt_matches_naive_bitwise() {
        for &(n, k, m) in SHAPES {
            let a = mk(n * k, 0.19);
            let b = mk(m * k, 0.05);
            let mut out = vec![7.0f32; n * m];
            matmul_nt(TensorView::new(n, k, &a), TensorView::new(m, k, &b), &mut out);
            let want = naive_nt(&a, &b, n, k, m);
            assert_eq!(out, want, "nt {n}x{k}x{m} must be bitwise identical");
        }
    }

    #[test]
    fn blocked_tn_matches_naive_bitwise() {
        for &(n, k, m) in SHAPES {
            let a = mk(k * n, 0.23);
            let b = mk(k * m, 0.11);
            let mut out = vec![7.0f32; n * m];
            matmul_tn(TensorView::new(k, n, &a), TensorView::new(k, m, &b), &mut out);
            let want = naive_tn(&a, &b, k, n, m);
            assert_eq!(out, want, "tn {n}x{k}x{m} must be bitwise identical");
        }
    }

    #[test]
    fn blocked_kernels_preserve_zero_skip_on_sparse_rows() {
        // zero-heavy inputs exercise the `av == 0.0` skip paths
        let (n, k, m) = (19, 33, 21);
        let mut a = mk(n * k, 0.31);
        for v in a.iter_mut().step_by(3) {
            *v = 0.0;
        }
        let b = mk(k * m, 0.17);
        let mut out = vec![0.0f32; n * m];
        matmul_nn(TensorView::new(n, k, &a), TensorView::new(k, m, &b), &mut out);
        assert_eq!(out, naive_nn(&a, &b, n, k, m));
        let mut out = vec![0.0f32; n * m];
        matmul_tn(TensorView::new(k, n, &a[..k * n]), TensorView::new(k, m, &b), &mut out);
        assert_eq!(out, naive_tn(&a[..k * n], &b, k, n, m));
    }

    #[test]
    fn into_kernels_match_the_host_tensor_path_bitwise() {
        // The engine's zero-copy path and the HostTensor convention must
        // produce identical bits (scratch reuse included: run twice).
        let (cap, dm, dff) = (6, 10, 14);
        let x = mk(cap * dm, 0.13);
        let chunk: Vec<f32> = [mk(dm * dff, 0.07), mk(dff, 0.19), mk(dff * dm, 0.05), mk(dm, 0.23)]
            .concat();
        let p = ExpertParams {
            w1: &chunk[..dm * dff],
            b1: &chunk[dm * dff..dm * dff + dff],
            w2: &chunk[dm * dff + dff..dm * dff + dff + dff * dm],
            b2: &chunk[dm * dff + dff + dff * dm..],
        };
        let gy = mk(cap * dm, 0.29);
        let mut scr = KernelScratch::default();
        let mut y = vec![0.0f32; cap * dm];
        for _ in 0..2 {
            Reference.ffn_fwd_into(&p, &x, cap, dm, dff, &mut scr, &mut y);
        }
        let via_tensors = Reference
            .execute(
                "expert_ffn_fwd",
                &[
                    HostTensor::f32(vec![cap, dm], x.clone()),
                    HostTensor::f32(vec![dm, dff], p.w1.to_vec()),
                    HostTensor::f32(vec![dff], p.b1.to_vec()),
                    HostTensor::f32(vec![dff, dm], p.w2.to_vec()),
                    HostTensor::f32(vec![dm], p.b2.to_vec()),
                ],
            )
            .unwrap();
        assert_eq!(y.as_slice(), via_tensors[0].as_f32().unwrap());

        let mut gx = vec![0.0f32; cap * dm];
        let mut gw1 = vec![0.0f32; dm * dff];
        let mut gb1 = vec![0.0f32; dff];
        let mut gw2 = vec![0.0f32; dff * dm];
        let mut gb2 = vec![0.0f32; dm];
        Reference.ffn_bwd_into(
            &p,
            &x,
            &gy,
            cap,
            dm,
            dff,
            &mut scr,
            FfnGrads {
                gx: &mut gx,
                gw1: &mut gw1,
                gb1: &mut gb1,
                gw2: &mut gw2,
                gb2: &mut gb2,
            },
        );
        let bwd = Reference
            .execute(
                "expert_ffn_bwd",
                &[
                    HostTensor::f32(vec![cap, dm], x.clone()),
                    HostTensor::f32(vec![dm, dff], p.w1.to_vec()),
                    HostTensor::f32(vec![dff], p.b1.to_vec()),
                    HostTensor::f32(vec![dff, dm], p.w2.to_vec()),
                    HostTensor::f32(vec![dm], p.b2.to_vec()),
                    HostTensor::f32(vec![cap, dm], gy.clone()),
                ],
            )
            .unwrap();
        assert_eq!(gx.as_slice(), bwd[0].as_f32().unwrap());
        assert_eq!(gw1.as_slice(), bwd[1].as_f32().unwrap());
        assert_eq!(gb1.as_slice(), bwd[2].as_f32().unwrap());
        assert_eq!(gw2.as_slice(), bwd[3].as_f32().unwrap());
        assert_eq!(gb2.as_slice(), bwd[4].as_f32().unwrap());
    }

    #[test]
    fn gate_produces_valid_top2() {
        // Mirrors the PJRT integration test `gate_fwd_produces_valid_top2`.
        let (t, dm, e) = (12, 8, 6);
        let x =
            HostTensor::f32(vec![t, dm], (0..t * dm).map(|i| (i as f32 * 0.37).sin()).collect());
        let wg = HostTensor::f32(
            vec![dm, e],
            (0..dm * e).map(|i| (i as f32 * 0.11).cos() * 0.3).collect(),
        );
        let out = Reference.execute("gate_fwd", &[x, wg]).unwrap();
        assert_eq!(out.len(), 3);
        let probs = out[0].as_f32().unwrap();
        for row in probs.chunks(e) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-4, "row sum {s}");
        }
        let w = out[1].as_f32().unwrap();
        let idx = out[2].as_i32().unwrap();
        for (wpair, ipair) in w.chunks(2).zip(idx.chunks(2)) {
            assert!((wpair[0] + wpair[1] - 1.0).abs() < 1e-4);
            assert!(wpair[0] >= wpair[1], "first choice has the larger weight");
            assert_ne!(ipair[0], ipair[1]);
            assert!((0..e as i32).contains(&ipair[0]));
            assert!((0..e as i32).contains(&ipair[1]));
        }
    }

    #[test]
    fn gate_tie_breaks_toward_lower_index() {
        // Identical logits everywhere: top-2 must be experts (0, 1).
        let x = HostTensor::f32(vec![2, 3], vec![1.0; 6]);
        let wg = HostTensor::f32(vec![3, 4], vec![0.5; 12]);
        let out = Reference.execute("gate_fwd", &[x, wg]).unwrap();
        let idx = out[2].as_i32().unwrap();
        assert_eq!(idx, &[0, 1, 0, 1]);
    }

    #[test]
    fn ffn_bwd_matches_finite_difference() {
        // Mirrors the PJRT integration test, hermetically.
        let (cap, dm, dff) = (4, 6, 10);
        let x = HostTensor::f32(vec![cap, dm], mk(cap * dm, 0.13));
        let w1 = HostTensor::f32(vec![dm, dff], mk(dm * dff, 0.07));
        let b1 = HostTensor::f32(vec![dff], mk(dff, 0.19));
        let w2 = HostTensor::f32(vec![dff, dm], mk(dff * dm, 0.05));
        let b2 = HostTensor::f32(vec![dm], mk(dm, 0.23));
        let gy = HostTensor::f32(vec![cap, dm], vec![1.0; cap * dm]);

        let bwd = Reference
            .execute(
                "expert_ffn_bwd",
                &[x.clone(), w1.clone(), b1.clone(), w2.clone(), b2.clone(), gy],
            )
            .unwrap();
        assert_eq!(bwd.len(), 5);
        // analytic: dL/db2 with gy=1 is cap (each row contributes 1)
        for &g in bwd[4].as_f32().unwrap() {
            assert!((g - cap as f32).abs() < 1e-3, "gb2 {g} vs {cap}");
        }

        // finite difference on every parameter tensor via L = Σ y
        let run_loss = |w1v: &[f32], b1v: &[f32], w2v: &[f32]| -> f32 {
            let y = Reference
                .execute(
                    "expert_ffn_fwd",
                    &[
                        x.clone(),
                        HostTensor::f32(vec![dm, dff], w1v.to_vec()),
                        HostTensor::f32(vec![dff], b1v.to_vec()),
                        HostTensor::f32(vec![dff, dm], w2v.to_vec()),
                        b2.clone(),
                    ],
                )
                .unwrap();
            y[0].as_f32().unwrap().iter().sum()
        };
        let (w1v, b1v, w2v) = (mk(dm * dff, 0.07), mk(dff, 0.19), mk(dff * dm, 0.05));
        let eps = 1e-3f32;
        let check = |analytic: f32, fd: f32, what: &str| {
            assert!(
                (fd - analytic).abs() < 2e-2 * analytic.abs().max(1.0),
                "{what}: finite diff {fd} vs analytic {analytic}"
            );
        };
        // one element of each of w1, b1, w2
        for (tensor_i, elt) in [(1usize, 5usize), (2, 3), (3, 7)] {
            let (mut a, mut b, mut c) = (w1v.clone(), b1v.clone(), w2v.clone());
            let tgt: &mut Vec<f32> = match tensor_i {
                1 => &mut a,
                2 => &mut b,
                _ => &mut c,
            };
            tgt[elt] += eps;
            let lp = run_loss(&a, &b, &c);
            let tgt: &mut Vec<f32> = match tensor_i {
                1 => &mut a,
                2 => &mut b,
                _ => &mut c,
            };
            tgt[elt] -= 2.0 * eps;
            let lm = run_loss(&a, &b, &c);
            let fd = (lp - lm) / (2.0 * eps);
            let analytic = bwd[tensor_i].as_f32().unwrap()[elt];
            check(analytic, fd, &format!("tensor {tensor_i} elt {elt}"));
        }
    }

    #[test]
    fn gx_matches_finite_difference() {
        let (cap, dm, dff) = (3, 4, 6);
        let xv = mk(cap * dm, 0.31);
        let w1 = HostTensor::f32(vec![dm, dff], mk(dm * dff, 0.07));
        let b1 = HostTensor::f32(vec![dff], mk(dff, 0.19));
        let w2 = HostTensor::f32(vec![dff, dm], mk(dff * dm, 0.05));
        let b2 = HostTensor::f32(vec![dm], mk(dm, 0.23));
        let gy = HostTensor::f32(vec![cap, dm], vec![1.0; cap * dm]);
        let loss = |xv: &[f32]| -> f32 {
            Reference
                .execute(
                    "expert_ffn_fwd",
                    &[
                        HostTensor::f32(vec![cap, dm], xv.to_vec()),
                        w1.clone(),
                        b1.clone(),
                        w2.clone(),
                        b2.clone(),
                    ],
                )
                .unwrap()[0]
                .as_f32()
                .unwrap()
                .iter()
                .sum()
        };
        let bwd = Reference
            .execute(
                "expert_ffn_bwd",
                &[
                    HostTensor::f32(vec![cap, dm], xv.clone()),
                    w1.clone(),
                    b1.clone(),
                    w2.clone(),
                    b2.clone(),
                    gy,
                ],
            )
            .unwrap();
        let gx = bwd[0].as_f32().unwrap();
        let eps = 1e-3f32;
        for elt in [0usize, 5, 11] {
            let mut p = xv.clone();
            p[elt] += eps;
            let lp = loss(&p);
            p[elt] -= 2.0 * eps;
            let lm = loss(&p);
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (fd - gx[elt]).abs() < 2e-2 * gx[elt].abs().max(1.0),
                "gx[{elt}]: fd {fd} vs analytic {}",
                gx[elt]
            );
        }
    }

    #[test]
    fn gelu_grad_matches_finite_difference() {
        for z in [-3.0f32, -0.7, 0.0, 0.4, 2.5] {
            let eps = 1e-3f32;
            let fd = (gelu(z + eps) - gelu(z - eps)) / (2.0 * eps);
            assert!((fd - gelu_grad(z)).abs() < 1e-3, "z={z}: {fd} vs {}", gelu_grad(z));
        }
    }

    #[test]
    fn unknown_entry_errors() {
        assert!(Reference.execute("nope", &[]).is_err());
        assert!(Fast.execute("nope", &[]).is_err());
    }

    // ---- the fast tier: bounded divergence from the naive oracles,
    //      bitwise run-to-run determinism ----

    /// Per-element relative tolerance of one reassociated matmul against
    /// the naive summation order (f32 accumulation noise only — the fast
    /// kernels compute the same products).
    fn assert_close(got: &[f32], want: &[f32], tol: f32, what: &str) {
        assert_eq!(got.len(), want.len(), "{what}: length");
        for (i, (&g, &w)) in got.iter().zip(want.iter()).enumerate() {
            let denom = w.abs().max(1.0e-3);
            assert!(
                (g - w).abs() / denom <= tol,
                "{what}[{i}]: fast {g} vs oracle {w} (rel {})",
                (g - w).abs() / denom
            );
        }
    }

    #[test]
    fn fast_nn_stays_within_reassociation_tolerance_of_naive() {
        for &(n, k, m) in SHAPES {
            let a = mk(n * k, 0.13);
            let b = mk(k * m, 0.07);
            let mut out = vec![7.0f32; n * m];
            matmul_nn_fast(TensorView::new(n, k, &a), TensorView::new(k, m, &b), &mut out);
            assert_close(&out, &naive_nn(&a, &b, n, k, m), 1e-5, &format!("nn {n}x{k}x{m}"));
        }
    }

    #[test]
    fn fast_nt_stays_within_reassociation_tolerance_of_naive() {
        for &(n, k, m) in SHAPES {
            let a = mk(n * k, 0.19);
            let b = mk(m * k, 0.05);
            let mut out = vec![7.0f32; n * m];
            matmul_nt_fast(TensorView::new(n, k, &a), TensorView::new(m, k, &b), &mut out);
            assert_close(&out, &naive_nt(&a, &b, n, k, m), 1e-5, &format!("nt {n}x{k}x{m}"));
        }
    }

    #[test]
    fn fast_tn_stays_within_reassociation_tolerance_of_naive() {
        for &(n, k, m) in SHAPES {
            let a = mk(k * n, 0.23);
            let b = mk(k * m, 0.11);
            let mut out = vec![7.0f32; n * m];
            matmul_tn_fast(TensorView::new(k, n, &a), TensorView::new(k, m, &b), &mut out);
            assert_close(&out, &naive_tn(&a, &b, k, n, m), 1e-5, &format!("tn {n}x{k}x{m}"));
        }
    }

    #[test]
    fn fast_kernels_handle_zero_heavy_inputs() {
        // The fast tier dropped the zero-skip; sparse rows must still
        // produce the same sums (zeros contribute nothing either way).
        let (n, k, m) = (19, 33, 21);
        let mut a = mk(n * k, 0.31);
        for v in a.iter_mut().step_by(3) {
            *v = 0.0;
        }
        let b = mk(k * m, 0.17);
        let mut out = vec![0.0f32; n * m];
        matmul_nn_fast(TensorView::new(n, k, &a), TensorView::new(k, m, &b), &mut out);
        assert_close(&out, &naive_nn(&a, &b, n, k, m), 1e-5, "nn sparse");
        let mut out = vec![0.0f32; n * m];
        matmul_tn_fast(TensorView::new(k, n, &a[..k * n]), TensorView::new(k, m, &b), &mut out);
        assert_close(&out, &naive_tn(&a[..k * n], &b, k, n, m), 1e-5, "tn sparse");
    }

    #[test]
    fn tanh_fast_tracks_libm_tanh() {
        let mut max_err = 0.0f32;
        let mut x = -8.0f32;
        while x <= 8.0 {
            let err = (tanh_fast(x) - x.tanh()).abs();
            max_err = max_err.max(err);
            x += 1.0 / 512.0;
        }
        assert!(max_err < 2e-4, "tanh_fast max abs error {max_err}");
    }

    #[test]
    fn fast_gelu_pair_tracks_reference_gelu() {
        let mut z = -6.0f32;
        while z <= 6.0 {
            let (h, dh) = gelu_fused_fast(z);
            assert!((gelu_fast(z) - gelu(z)).abs() < 1e-3, "gelu at {z}");
            assert!((h - gelu(z)).abs() < 1e-3, "fused gelu at {z}");
            assert!((dh - gelu_grad(z)).abs() < 1e-3, "fused gelu' at {z}");
            z += 1.0 / 64.0;
        }
    }

    #[test]
    fn fast_ffn_paths_stay_close_to_reference_and_are_deterministic() {
        let (cap, dm, dff) = (17, 10, 14);
        let x = mk(cap * dm, 0.13);
        let chunk: Vec<f32> =
            [mk(dm * dff, 0.07), mk(dff, 0.19), mk(dff * dm, 0.05), mk(dm, 0.23)].concat();
        let p = ExpertParams {
            w1: &chunk[..dm * dff],
            b1: &chunk[dm * dff..dm * dff + dff],
            w2: &chunk[dm * dff + dff..dm * dff + dff + dff * dm],
            b2: &chunk[dm * dff + dff + dff * dm..],
        };
        let gy = mk(cap * dm, 0.29);
        let mut scr = KernelScratch::default();
        let mut y_ref = vec![0.0f32; cap * dm];
        Reference.ffn_fwd_into(&p, &x, cap, dm, dff, &mut scr, &mut y_ref);
        let mut y_fast = vec![0.0f32; cap * dm];
        Fast.ffn_fwd_into(&p, &x, cap, dm, dff, &mut scr, &mut y_fast);
        assert_close(&y_fast, &y_ref, 2e-3, "ffn fwd fast vs reference");
        // run-to-run determinism: a second pass through the same (dirty)
        // scratch reproduces every bit
        let mut y_again = vec![0.0f32; cap * dm];
        Fast.ffn_fwd_into(&p, &x, cap, dm, dff, &mut scr, &mut y_again);
        assert_eq!(y_fast, y_again, "fast forward must be deterministic");

        let mut run_bwd = |c: &mut dyn FnMut(
            &mut KernelScratch,
            FfnGrads<'_>,
        )| -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
            let mut gx = vec![0.0f32; cap * dm];
            let mut gw1 = vec![0.0f32; dm * dff];
            let mut gb1 = vec![0.0f32; dff];
            let mut gw2 = vec![0.0f32; dff * dm];
            let mut gb2 = vec![0.0f32; dm];
            let mut scr = KernelScratch::default();
            c(
                &mut scr,
                FfnGrads {
                    gx: &mut gx,
                    gw1: &mut gw1,
                    gb1: &mut gb1,
                    gw2: &mut gw2,
                    gb2: &mut gb2,
                },
            );
            (gx, gw1, gb1, gw2, gb2)
        };
        let r = run_bwd(&mut |scr, out| Reference.ffn_bwd_into(&p, &x, &gy, cap, dm, dff, scr, out));
        let f = run_bwd(&mut |scr, out| Fast.ffn_bwd_into(&p, &x, &gy, cap, dm, dff, scr, out));
        let f2 = run_bwd(&mut |scr, out| Fast.ffn_bwd_into(&p, &x, &gy, cap, dm, dff, scr, out));
        assert_close(&f.0, &r.0, 2e-3, "gx");
        assert_close(&f.1, &r.1, 2e-3, "gw1");
        assert_close(&f.2, &r.2, 2e-3, "gb1");
        assert_close(&f.3, &r.3, 2e-3, "gw2");
        assert_close(&f.4, &r.4, 2e-3, "gb2");
        assert_eq!(f, f2, "fast backward must be deterministic");
    }

    #[test]
    fn fast_gate_routes_like_reference_away_from_ties() {
        let (t, dm, e) = (24, 8, 6);
        let x = mk(t * dm, 0.37);
        let wg = mk(dm * e, 0.11);
        let mut scr_r = KernelScratch::default();
        let (mut w2_r, mut idx_r) = (Vec::new(), Vec::new());
        Reference.gate_fwd_into(&x, &wg, t, dm, e, &mut scr_r, &mut w2_r, &mut idx_r).unwrap();
        let mut scr_f = KernelScratch::default();
        let (mut w2_f, mut idx_f) = (Vec::new(), Vec::new());
        Fast.gate_fwd_into(&x, &wg, t, dm, e, &mut scr_f, &mut w2_f, &mut idx_f).unwrap();
        assert_eq!(idx_r, idx_f, "top-2 routing must agree on well-separated logits");
        assert_close(&w2_f, &w2_r, 1e-4, "gate weights");
    }

    #[test]
    fn fast_host_tensor_path_matches_into_kernels_bitwise() {
        let (cap, dm, dff) = (6, 10, 14);
        let x = mk(cap * dm, 0.13);
        let chunk: Vec<f32> =
            [mk(dm * dff, 0.07), mk(dff, 0.19), mk(dff * dm, 0.05), mk(dm, 0.23)].concat();
        let p = ExpertParams {
            w1: &chunk[..dm * dff],
            b1: &chunk[dm * dff..dm * dff + dff],
            w2: &chunk[dm * dff + dff..dm * dff + dff + dff * dm],
            b2: &chunk[dm * dff + dff + dff * dm..],
        };
        let mut scr = KernelScratch::default();
        let mut y = vec![0.0f32; cap * dm];
        Fast.ffn_fwd_into(&p, &x, cap, dm, dff, &mut scr, &mut y);
        let via_tensors = Fast
            .execute(
                "expert_ffn_fwd",
                &[
                    HostTensor::f32(vec![cap, dm], x.clone()),
                    HostTensor::f32(vec![dm, dff], p.w1.to_vec()),
                    HostTensor::f32(vec![dff], p.b1.to_vec()),
                    HostTensor::f32(vec![dff, dm], p.w2.to_vec()),
                    HostTensor::f32(vec![dm], p.b2.to_vec()),
                ],
            )
            .unwrap();
        assert_eq!(y.as_slice(), via_tensors[0].as_f32().unwrap());
    }

    #[test]
    fn compute_mode_round_trips_through_for_mode() {
        assert_eq!(Compute::for_mode(ComputeMode::Reference).mode(), Some(ComputeMode::Reference));
        assert_eq!(Compute::for_mode(ComputeMode::Fast).mode(), Some(ComputeMode::Fast));
        assert_eq!(Compute::for_mode(ComputeMode::Reference).backend_name(), "reference");
        assert_eq!(Compute::for_mode(ComputeMode::Fast).backend_name(), "fast");
        assert_eq!(ComputeMode::default(), ComputeMode::Reference);
        assert_eq!(ComputeMode::Reference.as_str(), "ref");
        assert_eq!(ComputeMode::Fast.as_str(), "fast");
    }
}
