//! The unified training API: one [`Session`] facade over the numeric FSSDP
//! engine, entered through [`Session::fresh`] (new run) or
//! [`Session::resume`] (elastic restart from a checkpoint directory), and
//! driven through [`StepObserver`] hooks so checkpoint printing, metrics
//! reporting, and stats collection compose instead of living inside one
//! monolithic CLI driver.
//!
//! A session owns the engine, the absolute step cursor, and the logical
//! data-shard count, and enforces the span discipline the executors need:
//! [`Session::run`] splits work at checkpoint boundaries, the engine splits
//! further at re-shard boundaries, and observer hooks fire in
//! absolute-step order. The engine itself is reachable read-only via
//! [`Session::engine`] — its tuning fields are crate-private, so the
//! validated [`SessionConfig`] is the only way to configure execution.
//!
//! ```
//! use hecate::fssdp::{Session, SessionConfig};
//! use hecate::topology::Topology;
//!
//! let cfg = SessionConfig::builder()
//!     .reference()                        // hermetic pure-Rust kernels
//!     .topology(Topology::cluster_a(2, 2))
//!     .layers(2)                          // a 2-layer MoE stack
//!     .seed(7)
//!     .build()
//!     .unwrap();
//! let mut session = Session::fresh(cfg).unwrap();
//! let stats = session.run(2).unwrap();    // two training iterations
//! assert_eq!(stats.len(), 2);
//! assert_eq!(session.step(), 2);
//! let chunk = session.engine().expert_chunk(0); // read-only access
//! assert!(!chunk.is_empty());
//! ```

use std::path::{Path, PathBuf};

use crate::checkpoint::{self, CheckpointInfo, TrainState};
use crate::metrics::Metrics;
use crate::topology::Topology;

use super::config::{Backend, ConfigError, SessionConfig};
use super::{EngineStats, Executor, FssdpEngine, WorkspaceStats};

/// Hooks fired by [`Session::run_observed`] as a run progresses. All
/// methods default to no-ops; implement the ones you need and pass several
/// observers to compose behaviors (printing, collection, custom
/// checkpoint reactions).
pub trait StepObserver {
    /// One training iteration finished. `step` is the absolute iteration
    /// index that just ran.
    fn on_step(&mut self, step: u64, stats: &EngineStats) {
        let _ = (step, stats);
    }

    /// Algorithm 2 re-ran at absolute-step boundary `step` and migrated
    /// `moved` experts.
    fn on_reshard(&mut self, step: u64, moved: usize) {
        let _ = (step, moved);
    }

    /// The session wrote a checkpoint at absolute step `step`.
    fn on_checkpoint(&mut self, step: u64, info: &CheckpointInfo) {
        let _ = (step, info);
    }

    /// An executor span committed (engine state is merged and
    /// snapshot-safe); `ctx` gives read access to the engine and the
    /// span's statistics.
    fn on_span_end(&mut self, ctx: &SpanCtx<'_>) {
        let _ = ctx;
    }
}

/// Read-only view handed to [`StepObserver::on_span_end`]: the merged
/// engine state right after a span commits.
pub struct SpanCtx<'a> {
    engine: &'a FssdpEngine,
    step: u64,
    data_shards: usize,
    stats: &'a [EngineStats],
}

impl SpanCtx<'_> {
    /// Absolute step after the span (== the next iteration to run).
    pub fn step(&self) -> u64 {
        self.step
    }

    /// Per-iteration statistics of the span just committed.
    pub fn stats(&self) -> &[EngineStats] {
        self.stats
    }

    /// The engine, read-only.
    pub fn engine(&self) -> &FssdpEngine {
        self.engine
    }

    /// Capture the complete training state at this boundary (what
    /// [`checkpoint::save`] persists).
    pub fn snapshot(&self) -> TrainState {
        self.engine.snapshot(self.step, self.data_shards)
    }

    /// Per-rank metrics of the span, when it ran on the SPMD executor.
    pub fn spmd_metrics(&self) -> Option<&Metrics> {
        self.engine.spmd_metrics()
    }

    /// Workspace allocation counters at this boundary (cumulative; flat
    /// deltas across spans mean the hot path allocated nothing).
    pub fn workspace_stats(&self) -> WorkspaceStats {
        self.engine.workspace_stats()
    }

    /// All trace events recorded so far (cumulative across spans), when
    /// telemetry is enabled. `None` when tracing is off.
    pub fn trace_events(&self) -> Option<&[crate::telemetry::Event]> {
        self.engine.trace_events()
    }

    /// The step meter — the per-rank memory ledger and load observatory —
    /// accumulated so far (cumulative across spans), when metrics are
    /// enabled. `None` with metering off.
    pub fn meter_samples(&self) -> Option<&crate::metrics::meter::StepMeter> {
        self.engine.meter_samples()
    }
}

/// What [`Session::resume`] restored: the checkpointed position plus the
/// elastic reshard plan's summary.
#[derive(Debug, Clone)]
pub struct ResumeReport {
    /// First iteration the resumed session will run.
    pub step: u64,
    /// Device count that wrote the checkpoint.
    pub old_world: usize,
    /// Device count of this session's topology.
    pub new_world: usize,
    /// Layers in the restored stack.
    pub layers: usize,
    /// Logical data shards (restored; elasticity never changes them).
    pub data_shards: usize,
    /// `(layer, expert)` moves the elastic plan performed.
    pub moved_experts: usize,
    /// Bytes those moves carried (params + Adam state).
    pub bytes_moved: usize,
    /// True when the saved owner layout was reused verbatim (same world
    /// size — the resumed run is bit-identical to the uninterrupted one).
    pub kept_saved_layout: bool,
}

/// A training run: the engine plus its absolute step cursor, data-shard
/// count, and checkpoint cadence. See the module docs for an end-to-end
/// example.
pub struct Session {
    engine: FssdpEngine,
    step: u64,
    data_shards: usize,
    checkpoint_every: usize,
    checkpoint_dir: Option<PathBuf>,
    last_saved_step: Option<u64>,
    resume: Option<ResumeReport>,
}

impl Session {
    /// Start a fresh run from `cfg` (step 0, deterministic init from the
    /// config seed).
    pub fn fresh(cfg: SessionConfig) -> anyhow::Result<Session> {
        let layers = cfg.layers.unwrap_or(1);
        let mut engine = match &cfg.backend {
            Backend::Reference => {
                FssdpEngine::new_reference_layers(cfg.dims, layers, cfg.topo.clone(), cfg.seed)
            }
            Backend::Pjrt { artifact_dir } => {
                FssdpEngine::new_layers(artifact_dir, layers, cfg.topo.clone(), cfg.seed)?
            }
        };
        if let Some(k) = cfg.reshard_every {
            engine.reshard_every = k;
        }
        Self::apply_tuning(&mut engine, &cfg);
        let data_shards = cfg.data_shards.unwrap_or_else(|| cfg.topo.num_devices());
        Ok(Session {
            engine,
            step: 0,
            data_shards,
            checkpoint_every: cfg.checkpoint_every,
            checkpoint_dir: cfg.checkpoint_dir,
            last_saved_step: None,
            resume: None,
        })
    }

    /// Resume the run checkpointed in `dir` onto `cfg`'s topology, which
    /// may have a different device count (elastic resume; the planner
    /// re-shards all layers jointly). Durable run state — step, layer
    /// count, data shards, re-shard cadence, Algorithm 1 budgets — comes
    /// from the checkpoint; config values explicitly set override the
    /// tunables, and an explicit layer count must match the checkpoint.
    pub fn resume(cfg: SessionConfig, dir: &Path) -> anyhow::Result<Session> {
        let (state, saved) = checkpoint::load(dir)?;
        if let Some(l) = cfg.layers {
            if l != state.num_layers() {
                return Err(ConfigError::LayerCountMismatch {
                    requested: l,
                    checkpoint: state.num_layers(),
                }
                .into());
            }
        }
        let (mut engine, plan) = match &cfg.backend {
            Backend::Reference => {
                FssdpEngine::resume_reference(cfg.topo.clone(), &state, saved.world())?
            }
            // The PJRT arm validates the artifact dims against the
            // checkpoint before building anything expensive.
            Backend::Pjrt { artifact_dir } => {
                FssdpEngine::resume(artifact_dir, cfg.topo.clone(), &state, saved.world())?
            }
        };
        if let Some(k) = cfg.reshard_every {
            engine.reshard_every = k;
        }
        Self::apply_tuning(&mut engine, &cfg);
        let report = ResumeReport {
            step: state.step,
            old_world: saved.world(),
            new_world: cfg.topo.num_devices(),
            layers: state.num_layers(),
            data_shards: state.data_shards,
            moved_experts: plan.moved_experts.len(),
            bytes_moved: plan.bytes_moved,
            kept_saved_layout: plan.kept_saved_layout,
        };
        // A resume dir that doubles as the checkpoint destination already
        // holds this step's snapshot; any *other* destination still needs
        // its final snapshot even if no iterations run.
        let resumed_into_destination = cfg.checkpoint_dir.as_deref() == Some(dir);
        Ok(Session {
            engine,
            step: state.step,
            data_shards: state.data_shards,
            checkpoint_every: cfg.checkpoint_every,
            checkpoint_dir: cfg.checkpoint_dir,
            last_saved_step: if resumed_into_destination { Some(state.step) } else { None },
            resume: Some(report),
        })
    }

    /// Tunables shared by both entry points (the engine's fields are
    /// crate-private; this is their single write site outside resume).
    fn apply_tuning(engine: &mut FssdpEngine, cfg: &SessionConfig) {
        engine.executor = cfg.executor;
        engine.pacing = cfg.pacing;
        engine.transport = cfg.transport;
        engine.recv_timeout = cfg.recv_timeout;
        engine.compute_threads = cfg.compute_threads;
        // Kernel tier: a no-op under PJRT (which brings its own kernels)
        // and for the default Reference mode.
        engine.set_compute_mode(cfg.compute_mode);
        if let Some(m) = cfg.mem_slots {
            engine.mem_slots = m;
        }
        if let Some(o) = cfg.overlap_degree {
            engine.overlap_degree = o;
        }
        // Telemetry off (the default) keeps the recorder absent: every
        // instrumentation site reduces to one `Option` branch and the hot
        // path allocates nothing extra.
        engine.tracer = if cfg.telemetry.enabled {
            Some(crate::telemetry::TraceRecorder::new(0))
        } else {
            None
        };
        // Metering follows the same discipline, and shares the tracer's
        // epoch when both are on so counter tracks line up with span rows
        // on one timeline.
        engine.meter = if cfg.telemetry.metrics {
            Some(match &engine.tracer {
                Some(t) => crate::metrics::meter::StepMeter::with_epoch(t.epoch(), 0),
                None => crate::metrics::meter::StepMeter::new(0),
            })
        } else {
            None
        };
    }

    /// Run `iters` iterations from the current step (no observers).
    pub fn run(&mut self, iters: usize) -> anyhow::Result<Vec<EngineStats>> {
        self.run_observed(iters, &mut [])
    }

    /// Run `iters` iterations, firing [`StepObserver`] hooks as work
    /// progresses. Spans split at checkpoint boundaries (the engine splits
    /// further at re-shard boundaries); periodic snapshots land in the
    /// configured checkpoint directory and fire
    /// [`StepObserver::on_checkpoint`].
    pub fn run_observed(
        &mut self,
        iters: usize,
        observers: &mut [&mut dyn StepObserver],
    ) -> anyhow::Result<Vec<EngineStats>> {
        let end = self.step + iters as u64;
        let mut all = Vec::with_capacity(iters);
        while self.step < end {
            let span = if self.checkpoint_every > 0 {
                let ce = self.checkpoint_every as u64;
                let next_ckpt = (self.step / ce + 1) * ce;
                (end.min(next_ckpt) - self.step) as usize
            } else {
                (end - self.step) as usize
            };
            let start = self.step;
            let stats = self.engine.run_span(start, span, self.data_shards)?;
            let reshards = self.engine.take_reshard_events();
            let mut ri = 0;
            for (k, s) in stats.iter().enumerate() {
                let it = start + k as u64;
                for o in observers.iter_mut() {
                    o.on_step(it, s);
                }
                while ri < reshards.len() && reshards[ri].0 == it + 1 {
                    for o in observers.iter_mut() {
                        o.on_reshard(reshards[ri].0, reshards[ri].1);
                    }
                    ri += 1;
                }
            }
            self.step += span as u64;
            if self.checkpoint_every > 0 && self.step % self.checkpoint_every as u64 == 0 {
                let dir = self
                    .checkpoint_dir
                    .clone()
                    .expect("validated at SessionConfig::build: cadence implies a dir");
                let info = self.checkpoint_to(&dir)?;
                for o in observers.iter_mut() {
                    o.on_checkpoint(self.step, &info);
                }
            }
            let ctx = SpanCtx {
                engine: &self.engine,
                step: self.step,
                data_shards: self.data_shards,
                stats: &stats,
            };
            for o in observers.iter_mut() {
                o.on_span_end(&ctx);
            }
            all.extend(stats);
        }
        Ok(all)
    }

    /// End-of-run bookkeeping: when a checkpoint directory is configured
    /// and the current step has not just been snapshotted, write one final
    /// checkpoint (firing [`StepObserver::on_checkpoint`]). Returns the
    /// save info when a snapshot was written.
    pub fn finish(
        &mut self,
        observers: &mut [&mut dyn StepObserver],
    ) -> anyhow::Result<Option<CheckpointInfo>> {
        let Some(dir) = self.checkpoint_dir.clone() else {
            return Ok(None);
        };
        if self.last_saved_step == Some(self.step) {
            return Ok(None);
        }
        let info = self.checkpoint_to(&dir)?;
        for o in observers.iter_mut() {
            o.on_checkpoint(self.step, &info);
        }
        Ok(Some(info))
    }

    /// Write a checkpoint of the current state into `dir` (independent of
    /// the configured cadence/directory).
    pub fn checkpoint_to(&mut self, dir: &Path) -> anyhow::Result<CheckpointInfo> {
        let info = checkpoint::save(dir, &self.snapshot(), &self.engine.topo)?;
        if self.checkpoint_dir.as_deref() == Some(dir) {
            self.last_saved_step = Some(self.step);
        }
        Ok(info)
    }

    /// Capture the complete training state at the current step boundary.
    pub fn snapshot(&self) -> TrainState {
        self.engine.snapshot(self.step, self.data_shards)
    }

    /// The engine, read-only (dims, backend, expert chunks, shard maps).
    pub fn engine(&self) -> &FssdpEngine {
        &self.engine
    }

    /// Next iteration to run (0 on a fresh session; the checkpointed step
    /// right after a resume).
    pub fn step(&self) -> u64 {
        self.step
    }

    /// Logical data shards this run streams.
    pub fn data_shards(&self) -> usize {
        self.data_shards
    }

    /// The executor this session runs on.
    pub fn executor(&self) -> Executor {
        self.engine.executor()
    }

    /// The Algorithm 2 cadence in effect (0 = never).
    pub fn reshard_every(&self) -> usize {
        self.engine.reshard_every()
    }

    /// Experts migrated by in-run re-shards so far.
    pub fn reshards_moved(&self) -> usize {
        self.engine.reshards_moved()
    }

    /// Per-rank metrics merged over the most recent SPMD span.
    pub fn spmd_metrics(&self) -> Option<&Metrics> {
        self.engine.spmd_metrics()
    }

    /// All trace events recorded so far (cumulative), when telemetry is
    /// enabled via the config. `None` with tracing off.
    pub fn trace_events(&self) -> Option<&[crate::telemetry::Event]> {
        self.engine.trace_events()
    }

    /// The step meter (memory ledger + load samples) accumulated so far,
    /// when metrics are enabled via the config. `None` with metering off.
    pub fn meter_samples(&self) -> Option<&crate::metrics::meter::StepMeter> {
        self.engine.meter_samples()
    }

    /// The elastic-resume summary (None on fresh sessions).
    pub fn resume_report(&self) -> Option<&ResumeReport> {
        self.resume.as_ref()
    }

    /// The simulated cluster.
    pub fn topology(&self) -> &Topology {
        &self.engine.topo
    }
}

/// Observer printing the classic per-iteration stat line and checkpoint
/// confirmations — the `hecate fssdp` console output, now composable.
#[derive(Debug, Default)]
pub struct PrintObserver;

impl StepObserver for PrintObserver {
    fn on_step(&mut self, step: u64, s: &EngineStats) {
        println!(
            "iter {step:>3}  loss {:.5}  λ={:.2}  replicas {}  remote_tokens {}  straggler {:.2}",
            s.loss, s.spag_sparsity, s.replicas, s.remote_tokens, s.straggler
        );
    }

    fn on_checkpoint(&mut self, step: u64, info: &CheckpointInfo) {
        println!(
            "  checkpoint @ step {step}: {} files, {:.2} MB -> {}",
            info.files,
            info.total_bytes as f64 / 1e6,
            info.dir.display()
        );
    }
}

/// Observer accumulating everything a run reports — per-iteration stats,
/// re-shard and checkpoint events — for later analysis when the
/// return value of [`Session::run`] (per-iteration stats only) is not
/// enough.
#[derive(Debug, Default)]
pub struct StatsCollector {
    /// `(step, stats)` per iteration, in order (each [`EngineStats`]
    /// carries that iteration's fresh workspace allocations in
    /// `ws_allocs`).
    pub steps: Vec<(u64, EngineStats)>,
    /// `(boundary_step, moved_experts)` per in-run re-shard.
    pub reshards: Vec<(u64, usize)>,
    /// Steps at which checkpoints were written.
    pub checkpoints: Vec<u64>,
    /// `(boundary_step, counters)` per committed span — the cumulative
    /// workspace pool counters at each span end.
    pub workspace: Vec<(u64, WorkspaceStats)>,
}

impl StepObserver for StatsCollector {
    fn on_step(&mut self, step: u64, stats: &EngineStats) {
        self.steps.push((step, stats.clone()));
    }

    fn on_reshard(&mut self, step: u64, moved: usize) {
        self.reshards.push((step, moved));
    }

    fn on_checkpoint(&mut self, step: u64, _info: &CheckpointInfo) {
        self.checkpoints.push(step);
    }

    fn on_span_end(&mut self, ctx: &SpanCtx<'_>) {
        self.workspace.push((ctx.step(), ctx.workspace_stats()));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fssdp::reference_dims;
    use crate::testing::all_chunks;

    fn cfg() -> crate::fssdp::SessionConfigBuilder {
        SessionConfig::builder().reference().topology(Topology::cluster_a(2, 2)).seed(13)
    }

    #[test]
    fn fresh_session_matches_direct_engine_trajectory_bitwise() {
        // The facade must not perturb the math: Session::fresh + run ==
        // the crate-private constructor + run_span at the same seed.
        let mut s = Session::fresh(cfg().data_shards(4).build().unwrap()).unwrap();
        s.run(3).unwrap();
        let mut e =
            FssdpEngine::new_reference_layers(reference_dims(), 1, Topology::cluster_a(2, 2), 13);
        e.run_span(0, 3, 4).unwrap();
        assert_eq!(all_chunks(s.engine()), all_chunks(&e));
        assert_eq!(s.step(), 3);
    }

    #[test]
    fn observers_see_every_step_reshard_and_checkpoint() {
        let dir = std::env::temp_dir().join(format!("hecate-session-obs-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut s = Session::fresh(
            cfg()
                .layers(2)
                .data_shards(4)
                .reshard_every(2)
                .checkpoint_every(3)
                .checkpoint_dir(&dir)
                .build()
                .unwrap(),
        )
        .unwrap();
        let mut col = StatsCollector::default();
        let stats = s.run_observed(6, &mut [&mut col]).unwrap();
        assert_eq!(stats.len(), 6);
        assert_eq!(col.steps.len(), 6);
        assert_eq!(
            col.steps.iter().map(|(i, _)| *i).collect::<Vec<_>>(),
            vec![0, 1, 2, 3, 4, 5]
        );
        // re-shards at absolute boundaries 2, 4, 6; checkpoints at 3, 6
        assert_eq!(col.reshards.iter().map(|(i, _)| *i).collect::<Vec<_>>(), vec![2, 4, 6]);
        assert_eq!(col.checkpoints, vec![3, 6]);
        // the boundary snapshot already covered step 6 — finish is a no-op
        assert!(s.finish(&mut [&mut col]).unwrap().is_none());
        assert_eq!(col.checkpoints, vec![3, 6]);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn finish_writes_a_final_snapshot_off_cadence() {
        let dir =
            std::env::temp_dir().join(format!("hecate-session-fin-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut s =
            Session::fresh(cfg().data_shards(4).checkpoint_dir(&dir).build().unwrap()).unwrap();
        s.run(2).unwrap();
        let info = s.finish(&mut []).unwrap().expect("no cadence: final snapshot required");
        assert!(info.files >= 2);
        assert!(dir.join("manifest.json").exists());
        // a second finish at the same step is a no-op
        assert!(s.finish(&mut []).unwrap().is_none());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn resume_restores_step_shards_and_cadence() {
        let dir =
            std::env::temp_dir().join(format!("hecate-session-res-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut s = Session::fresh(
            cfg().layers(2).data_shards(4).reshard_every(4).build().unwrap(),
        )
        .unwrap();
        s.run(2).unwrap();
        s.checkpoint_to(&dir).unwrap();

        let r = Session::resume(cfg().build().unwrap(), &dir).unwrap();
        assert_eq!(r.step(), 2);
        assert_eq!(r.data_shards(), 4);
        assert_eq!(r.reshard_every(), 4, "cadence is durable run config");
        let rep = r.resume_report().unwrap();
        assert!(rep.kept_saved_layout);
        assert_eq!(rep.old_world, 4);
        assert_eq!(rep.new_world, 4);
        assert_eq!(rep.layers, 2);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn finish_after_resume_writes_to_a_fresh_destination() {
        // Resume from A with checkpoint destination B and run nothing: B
        // must still receive the final snapshot (A's copy does not make
        // the state durable in B).
        let a = std::env::temp_dir().join(format!("hecate-session-rsa-{}", std::process::id()));
        let b = std::env::temp_dir().join(format!("hecate-session-rsb-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&a);
        let _ = std::fs::remove_dir_all(&b);
        let mut s = Session::fresh(cfg().data_shards(4).build().unwrap()).unwrap();
        s.run(1).unwrap();
        s.checkpoint_to(&a).unwrap();

        let mut r = Session::resume(cfg().checkpoint_dir(&b).build().unwrap(), &a).unwrap();
        assert!(r.finish(&mut []).unwrap().is_some(), "fresh destination needs a snapshot");
        assert!(b.join("manifest.json").exists());

        // …but resuming with the destination set to the resume dir itself
        // skips the redundant rewrite of the snapshot just read.
        let mut same = Session::resume(cfg().checkpoint_dir(&a).build().unwrap(), &a).unwrap();
        assert!(same.finish(&mut []).unwrap().is_none());
        std::fs::remove_dir_all(&a).unwrap();
        std::fs::remove_dir_all(&b).unwrap();
    }

    #[test]
    fn compute_threads_reach_the_engine_and_stay_bitwise() {
        let mut a = Session::fresh(cfg().layers(2).data_shards(4).build().unwrap()).unwrap();
        let mut b = Session::fresh(
            cfg().layers(2).data_shards(4).compute_threads(3).build().unwrap(),
        )
        .unwrap();
        assert_eq!(a.engine().compute_threads(), 1);
        assert_eq!(b.engine().compute_threads(), 3);
        a.run(2).unwrap();
        b.run(2).unwrap();
        assert_eq!(
            all_chunks(a.engine()),
            all_chunks(b.engine()),
            "threaded expert loops must not change a single bit"
        );
    }

    #[test]
    fn compute_mode_reaches_the_engine_and_fast_stays_deterministic() {
        use crate::fssdp::ComputeMode;
        let run = || {
            let mut s = Session::fresh(
                cfg()
                    .layers(2)
                    .data_shards(4)
                    .compute_mode(ComputeMode::Fast)
                    .compute_threads(2)
                    .build()
                    .unwrap(),
            )
            .unwrap();
            assert_eq!(s.engine().compute_mode(), Some(ComputeMode::Fast));
            assert_eq!(s.engine().backend(), "fast");
            s.run(3).unwrap();
            all_chunks(s.engine())
        };
        assert_eq!(run(), run(), "Fast sessions must repeat bit-for-bit");

        let s = Session::fresh(cfg().build().unwrap()).unwrap();
        assert_eq!(
            s.engine().compute_mode(),
            Some(ComputeMode::Reference),
            "Reference is the default tier"
        );
    }

    #[test]
    fn collector_surfaces_workspace_counters() {
        let mut s = Session::fresh(cfg().data_shards(4).build().unwrap()).unwrap();
        let mut col = StatsCollector::default();
        s.run_observed(3, &mut [&mut col]).unwrap();
        assert_eq!(col.workspace.len(), 1, "one span, one counter snapshot");
        let (step, ws) = col.workspace[0];
        assert_eq!(step, 3);
        assert!(ws.pool_allocated > 0, "the pool served the gradient buffers");
        assert!(
            col.steps.iter().any(|(_, st)| st.ws_allocs > 0),
            "per-iteration allocation counts flow through on_step"
        );
    }

    #[test]
    fn metrics_config_installs_meter_and_stays_bitwise() {
        let mut plain = Session::fresh(cfg().layers(2).data_shards(4).build().unwrap()).unwrap();
        let mut metered =
            Session::fresh(cfg().layers(2).data_shards(4).metrics(true).build().unwrap())
                .unwrap();
        assert!(plain.meter_samples().is_none(), "metering is off by default");
        plain.run(3).unwrap();
        metered.run(3).unwrap();
        assert_eq!(
            all_chunks(plain.engine()),
            all_chunks(metered.engine()),
            "the ledger is observational: metered == unmetered bitwise"
        );
        let m = metered.meter_samples().expect("metrics(true) installs the meter");
        // 3 iters x 2 layers x 4 devices memory rows; 3 x 2 load rows.
        assert_eq!(m.mem_samples().len(), 3 * 2 * 4);
        assert_eq!(m.load_samples().len(), 3 * 2);
        assert!(m.mem_samples().iter().all(|s| s.resident_bytes > 0));
    }

    #[test]
    fn span_ctx_exposes_meter_samples() {
        struct Peek {
            mem_rows: usize,
        }
        impl StepObserver for Peek {
            fn on_span_end(&mut self, ctx: &SpanCtx<'_>) {
                self.mem_rows = ctx.meter_samples().map(|m| m.mem_samples().len()).unwrap_or(0);
            }
        }
        let mut s =
            Session::fresh(cfg().data_shards(4).metrics(true).build().unwrap()).unwrap();
        let mut peek = Peek { mem_rows: 0 };
        s.run_observed(2, &mut [&mut peek]).unwrap();
        assert_eq!(peek.mem_rows, 2 * 4, "2 iters x 1 layer x 4 devices");
    }

    #[test]
    fn resume_rejects_conflicting_layer_count() {
        let dir =
            std::env::temp_dir().join(format!("hecate-session-lay-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut s = Session::fresh(cfg().layers(3).data_shards(4).build().unwrap()).unwrap();
        s.run(1).unwrap();
        s.checkpoint_to(&dir).unwrap();
        let err = match Session::resume(cfg().layers(2).build().unwrap(), &dir) {
            Ok(_) => panic!("layer mismatch must be rejected"),
            Err(e) => e.to_string(),
        };
        assert_eq!(
            err,
            "--layers 2 conflicts with the checkpoint's 3 layers (omit --layers when resuming)"
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
