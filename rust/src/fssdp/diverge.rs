//! Fast-vs-Reference divergence harness: run the same seeded training
//! span under two compute tiers and report how far the parameters drift.
//!
//! [`ComputeMode::Fast`](super::compute::ComputeMode) trades the
//! Reference tier's fixed accumulation order and libm activations for
//! autovectorizer-friendly kernels: split-lane dot products, a clamped
//! rational `tanh` (max abs error ≈ 1.2e-4), and fused bias+GeLU passes.
//! Each kernel stays within ~1e-5 relative of the transcribed-naive
//! oracle (locked per-kernel in `compute::tests`), but a *training run*
//! compounds three effects:
//!
//! 1. per-step kernel error feeds back through Adam into the weights,
//! 2. perturbed gate logits can flip a token's top-2 expert on a near
//!    tie, moving one whole token's gradient between experts, and
//! 3. the plan/placement layer then sees slightly different realized
//!    loads (control flow is integer, so this only shifts *which* floats
//!    are added, never the schedule's correctness).
//!
//! Because of (2), worst-case per-step divergence is bounded by the
//! update scale, not the kernel error — so the contract this module
//! locks is an **∞-norm ratio**: `max |p_fast − p_ref|` over all
//! parameters, divided by `max |p_ref|`, reported per step and locked to
//! [`FAST_REL_BOUND`] at the end of the span. The harness is what
//! `hecate bench step --json` embeds and what the CI divergence artifact
//! is generated from.

use super::compute::ComputeMode;
use super::{FssdpEngine, LayerDims};
use crate::topology::Topology;

/// Locked ∞-norm relative divergence bound for a Fast-tier training span
/// (8 iterations on the bench shape and the smaller test shapes). The
/// observed ratio sits around 1e-3..1e-2 — kernel error alone would be
/// ~1e-4, occasional near-tie routing flips account for the rest — so
/// 0.05 leaves margin without hiding a broken kernel (a wrong sign or a
/// dropped term lands orders of magnitude above it).
pub const FAST_REL_BOUND: f64 = 0.05;

/// Parameter drift after one more training step under the candidate tier.
#[derive(Debug, Clone, Copy)]
pub struct StepDivergence {
    /// Step index within the measured span (0-based).
    pub step: usize,
    /// `max |p_cand − p_ref|` over every parameter of every layer.
    pub max_abs: f64,
    /// `max_abs / max |p_ref|` — the ∞-norm ratio the bound locks.
    pub max_rel: f64,
    /// `|loss_cand − loss_ref| / max(|loss_ref|, 1)` at this step.
    pub loss_rel: f64,
}

/// A measured Fast-vs-Reference span: per-step drift plus span maxima.
#[derive(Debug, Clone)]
pub struct DivergenceReport {
    pub per_step: Vec<StepDivergence>,
    /// Largest per-step `max_abs` over the span.
    pub max_abs: f64,
    /// Largest per-step `max_rel` over the span — compare against
    /// [`FAST_REL_BOUND`].
    pub max_rel: f64,
}

/// Compare two parameter snapshots (layer-major chunk lists from
/// [`crate::testing::all_chunks`]) in the ∞ norm.
fn chunk_divergence(reference: &[Vec<f32>], candidate: &[Vec<f32>]) -> (f64, f64) {
    debug_assert_eq!(reference.len(), candidate.len());
    let mut max_abs = 0f64;
    let mut ref_inf = 0f64;
    for (cr, cc) in reference.iter().zip(candidate.iter()) {
        debug_assert_eq!(cr.len(), cc.len());
        for (a, b) in cr.iter().zip(cc.iter()) {
            max_abs = max_abs.max((*a as f64 - *b as f64).abs());
            ref_inf = ref_inf.max((*a as f64).abs());
        }
    }
    let max_rel = if ref_inf > 0.0 { max_abs / ref_inf } else { 0.0 };
    (max_abs, max_rel)
}

/// Train two engines in lockstep — the Reference oracle and a candidate
/// tier `mode` — for `iters` steps at the given shape/seed, snapshotting
/// the parameter divergence after every step. With
/// `mode == ComputeMode::Reference` the report is exactly zero (the
/// harness's own sanity check); with `ComputeMode::Fast` it measures the
/// bound the tests and the bench JSON report.
pub fn measure(
    dims: LayerDims,
    layers: usize,
    topo: Topology,
    seed: u64,
    iters: usize,
    sources: usize,
    mode: ComputeMode,
) -> anyhow::Result<DivergenceReport> {
    let mut oracle = FssdpEngine::new_reference_layers(dims, layers, topo.clone(), seed);
    let mut cand = FssdpEngine::new_reference_layers(dims, layers, topo, seed);
    cand.set_compute_mode(mode);

    let mut per_step = Vec::with_capacity(iters);
    let mut max_abs = 0f64;
    let mut max_rel = 0f64;
    for step in 0..iters {
        let rs = oracle.run_span(step as u64, 1, sources)?;
        let cs = cand.run_span(step as u64, 1, sources)?;
        let (sa, sr) = chunk_divergence(
            &crate::testing::all_chunks(&oracle),
            &crate::testing::all_chunks(&cand),
        );
        let rl = rs.first().map(|s| s.loss).unwrap_or(0.0);
        let cl = cs.first().map(|s| s.loss).unwrap_or(0.0);
        let loss_rel = (rl - cl).abs() / rl.abs().max(1.0);
        max_abs = max_abs.max(sa);
        max_rel = max_rel.max(sr);
        per_step.push(StepDivergence { step, max_abs: sa, max_rel: sr, loss_rel });
    }
    Ok(DivergenceReport { per_step, max_abs, max_rel })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fssdp::reference_dims;

    #[test]
    fn reference_candidate_diverges_by_exactly_zero() {
        let r = measure(
            reference_dims(),
            2,
            Topology::cluster_a(2, 2),
            11,
            4,
            4,
            ComputeMode::Reference,
        )
        .unwrap();
        assert_eq!(r.per_step.len(), 4);
        assert_eq!(r.max_abs, 0.0, "same tier, same seed: bit-identical");
        assert_eq!(r.max_rel, 0.0);
        for s in &r.per_step {
            assert_eq!(s.max_abs, 0.0);
            assert_eq!(s.loss_rel, 0.0);
        }
    }

    #[test]
    fn fast_divergence_is_nonzero_but_stays_under_the_locked_bound() {
        let r = measure(
            reference_dims(),
            2,
            Topology::cluster_a(2, 2),
            11,
            8,
            4,
            ComputeMode::Fast,
        )
        .unwrap();
        assert_eq!(r.per_step.len(), 8);
        assert!(
            r.max_abs > 0.0,
            "the rational tanh guarantees Fast differs from Reference"
        );
        assert!(r.max_rel.is_finite());
        assert!(
            r.max_rel <= FAST_REL_BOUND,
            "∞-norm ratio {} exceeds the locked bound {FAST_REL_BOUND}",
            r.max_rel
        );
        for s in &r.per_step {
            assert!(s.loss_rel.is_finite());
            assert!(s.loss_rel <= FAST_REL_BOUND, "loss drift {} at step {}", s.loss_rel, s.step);
        }
    }

    #[test]
    fn divergence_bound_holds_across_seeds_and_shapes() {
        // A coarse property sweep: different seeds shuffle the routing
        // near-ties, single-source spans stress the empty-key case.
        for (seed, layers, sources) in [(1u64, 1usize, 1usize), (7, 2, 4), (29, 3, 2)] {
            let r = measure(
                reference_dims(),
                layers,
                Topology::cluster_a(2, 2),
                seed,
                6,
                sources,
                ComputeMode::Fast,
            )
            .unwrap();
            assert!(
                r.max_rel <= FAST_REL_BOUND,
                "seed {seed}, {layers} layers, {sources} sources: {} > {FAST_REL_BOUND}",
                r.max_rel
            );
        }
    }
}
