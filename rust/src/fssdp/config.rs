//! Typed, validated configuration of a training [`Session`].
//!
//! Every knob the engine exposes — backend, layer-stack shape, topology,
//! executor, α–β link pacing, re-shard cadence, checkpoint cadence, and the
//! Algorithm 1 budgets — funnels through one [`SessionConfig`] builder.
//! [`SessionConfigBuilder::build`] is the single validation point shared by
//! the `hecate` CLI and library callers: every misconfiguration maps to a
//! typed [`ConfigError`] whose `Display` is the exact message CLI users see
//! (asserted by the regression tests below), replacing the `ensure!`
//! checks formerly scattered across `run_demo_with` and the coordinator.
//!
//! A validated [`SessionConfig`] is also the input to the static schedule
//! verifier: `hecate analyze schedule` builds one with the same builder
//! (mirroring the `fssdp` flags) and enumerates the SPMD communication
//! plan it implies without running a kernel
//! ([`crate::analysis::analyze_config`]).
//!
//! [`Session`]: crate::fssdp::Session

use std::fmt;
use std::path::PathBuf;
use std::time::Duration;

use crate::spmd::comm::Pacing;
use crate::spmd::transport::TransportKind;
use crate::telemetry::TelemetryConfig;
use crate::topology::Topology;

use super::compute::ComputeMode;
use super::{reference_dims, Executor, LayerDims};

/// Which compute backend executes the kernels.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Backend {
    /// AOT-compiled HLO artifacts through the PJRT runtime.
    Pjrt {
        /// Directory holding `manifest.json` and the compiled executables.
        artifact_dir: String,
    },
    /// The hermetic pure-Rust reference kernels (no artifacts required;
    /// the only backend the SPMD executor accepts — PJRT client handles
    /// cannot be shared across rank threads).
    Reference,
}

/// A misconfigured [`SessionConfig`]. The `Display` strings are the
/// contract with CLI users: `tests` below pin them verbatim.
#[derive(Debug, Clone, PartialEq)]
pub enum ConfigError {
    /// Zero nodes or devices.
    NoDevices,
    /// `devices % nodes != 0`.
    UnevenDevices,
    /// `layers == 0`.
    ZeroLayers,
    /// Zero logical data shards.
    ZeroDataShards,
    /// A checkpoint cadence without a destination directory.
    CheckpointEveryWithoutDir,
    /// An explicit thread count on the sequential executor.
    ThreadsWithoutParallel,
    /// α–β link pacing on the sequential executor (nothing consumes it).
    PacingWithoutParallel,
    /// SPMD thread count differs from the device count.
    ThreadCountMismatch { threads: usize, devices: usize },
    /// The SPMD executor on the PJRT backend.
    ParallelNeedsReference,
    /// An unparseable `--pacing` value.
    BadPacing { given: String },
    /// Resume with an explicit layer count that contradicts the checkpoint.
    LayerCountMismatch { requested: usize, checkpoint: usize },
    /// `compute_threads == 0`.
    ZeroComputeThreads,
    /// `--trace-out` with an empty/blank directory path.
    TraceOutEmpty,
    /// `--metrics-out` with an empty/blank directory path.
    MetricsOutEmpty,
    /// An unparseable `--transport` value.
    BadTransport { given: String },
    /// The socket transport on the sequential executor.
    SocketNeedsParallel,
    /// α–β link pacing combined with the socket transport (socket wire
    /// time is real wall clock; pacing only models the in-proc fabric).
    PacingWithSocket,
    /// Both `--pacing` and `--pacing-topo` given.
    PacingTopoConflict,
    /// Topology-derived pacing on the sequential executor.
    PacingTopoWithoutParallel,
    /// An unparseable `--pacing-topo` value.
    BadPacingScale { given: String },
    /// `--racks 0`.
    ZeroRacks,
    /// A rack count that does not evenly divide the nodes.
    RacksDontDivide { racks: usize, nodes: usize },
    /// An unparseable `--recv-timeout` value.
    BadRecvTimeout { given: String },
    /// A receive timeout without the socket transport.
    RecvTimeoutWithoutSocket,
    /// An unparseable `--compute-mode` value.
    BadComputeMode { given: String },
    /// More kernel worker threads than the host has cores to run them.
    ComputeThreadsExceedCores { threads: usize, cores: usize },
}

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ConfigError::NoDevices => write!(f, "need at least one node and device"),
            ConfigError::UnevenDevices => {
                write!(f, "devices must divide evenly into nodes")
            }
            ConfigError::ZeroLayers => write!(f, "--layers must be at least 1"),
            ConfigError::ZeroDataShards => {
                write!(f, "data_shards must be at least 1")
            }
            ConfigError::CheckpointEveryWithoutDir => {
                write!(f, "--checkpoint-every needs --checkpoint-dir")
            }
            ConfigError::ThreadsWithoutParallel => write!(
                f,
                "--threads requires --parallel (the SPMD executor runs one thread per rank; \
                 without --parallel the engine is single-threaded)"
            ),
            ConfigError::PacingWithoutParallel => write!(
                f,
                "--pacing requires --parallel (link pacing paces the SPMD communicator; \
                 the sequential engine has no wire time to pace)"
            ),
            ConfigError::ThreadCountMismatch { threads, devices } => write!(
                f,
                "--threads {threads} must equal --devices {devices}: the SPMD executor runs \
                 one OS thread per rank"
            ),
            ConfigError::ParallelNeedsReference => write!(
                f,
                "--parallel requires the hermetic backend (add --reference): \
                 PJRT runtime handles cannot be shared across rank threads"
            ),
            ConfigError::BadPacing { given } => write!(
                f,
                "--pacing expects `alpha,beta` (link latency in seconds, seconds per byte; \
                 e.g. `2e-5,1e-9`), got `{given}`"
            ),
            ConfigError::LayerCountMismatch { requested, checkpoint } => write!(
                f,
                "--layers {requested} conflicts with the checkpoint's {checkpoint} layers \
                 (omit --layers when resuming)"
            ),
            ConfigError::ZeroComputeThreads => {
                write!(f, "--compute-threads must be at least 1")
            }
            ConfigError::TraceOutEmpty => {
                write!(f, "--trace-out expects a non-empty directory path")
            }
            ConfigError::MetricsOutEmpty => {
                write!(f, "--metrics-out expects a non-empty directory path")
            }
            ConfigError::BadTransport { given } => {
                write!(f, "--transport expects `inproc` or `socket`, got `{given}`")
            }
            ConfigError::SocketNeedsParallel => write!(
                f,
                "--transport socket requires --parallel (the transport moves SPMD rank \
                 traffic; the sequential engine has none)"
            ),
            ConfigError::PacingWithSocket => write!(
                f,
                "--pacing/--pacing-topo require --transport inproc (socket wire time is \
                 real wall clock; pacing models links for the in-process fabric only)"
            ),
            ConfigError::PacingTopoConflict => {
                write!(f, "--pacing and --pacing-topo are mutually exclusive")
            }
            ConfigError::PacingTopoWithoutParallel => write!(
                f,
                "--pacing-topo requires --parallel (link pacing paces the SPMD \
                 communicator; the sequential engine has no wire time to pace)"
            ),
            ConfigError::BadPacingScale { given } => write!(
                f,
                "--pacing-topo expects a positive time-scale factor (e.g. `1e3`), \
                 got `{given}`"
            ),
            ConfigError::ZeroRacks => write!(f, "--racks must be at least 1"),
            ConfigError::RacksDontDivide { racks, nodes } => {
                write!(f, "--racks {racks} must evenly divide --nodes {nodes}")
            }
            ConfigError::BadRecvTimeout { given } => write!(
                f,
                "--recv-timeout expects a positive number of seconds, got `{given}`"
            ),
            ConfigError::RecvTimeoutWithoutSocket => write!(
                f,
                "--recv-timeout requires --transport socket (only the socket backend \
                 polls receives against a deadline)"
            ),
            ConfigError::BadComputeMode { given } => {
                write!(f, "--compute-mode expects `ref` or `fast`, got `{given}`")
            }
            ConfigError::ComputeThreadsExceedCores { threads, cores } => write!(
                f,
                "--compute-threads {threads} exceeds the {cores} available cores \
                 (the kernel worker pool is CPU-bound; oversubscribing only adds \
                 scheduling noise)"
            ),
        }
    }
}

impl std::error::Error for ConfigError {}

/// Parse the CLI's `--pacing alpha,beta` value into a uniform α–β
/// [`Pacing`]: `alpha` is the per-message link latency in seconds, `beta`
/// the inverse bandwidth in seconds per byte (a transfer of `n` bytes
/// occupies its ports for `alpha + n·beta` seconds of wall clock).
pub fn parse_pacing(s: &str) -> Result<Pacing, ConfigError> {
    let err = || ConfigError::BadPacing { given: s.to_string() };
    let (a, b) = s.split_once(',').ok_or_else(err)?;
    let alpha: f64 = a.trim().parse().map_err(|_| err())?;
    let beta: f64 = b.trim().parse().map_err(|_| err())?;
    if !alpha.is_finite() || !beta.is_finite() || alpha < 0.0 || beta <= 0.0 {
        return Err(err());
    }
    Ok(Pacing::uniform(1.0 / beta, alpha))
}

/// Parse the CLI's `--transport` value into a [`TransportKind`].
pub fn parse_transport(s: &str) -> Result<TransportKind, ConfigError> {
    TransportKind::parse(s).ok_or_else(|| ConfigError::BadTransport { given: s.to_string() })
}

/// Parse the CLI's `--pacing-topo` time-scale factor (simulated link
/// seconds per wall-clock second; `1e3` makes a modeled millisecond cost a
/// real microsecond).
pub fn parse_pacing_scale(s: &str) -> Result<f64, ConfigError> {
    let err = || ConfigError::BadPacingScale { given: s.to_string() };
    let scale: f64 = s.trim().parse().map_err(|_| err())?;
    if !scale.is_finite() || scale <= 0.0 {
        return Err(err());
    }
    Ok(scale)
}

/// Parse the CLI's `--compute-mode` value into a [`ComputeMode`]:
/// `ref`/`reference` selects the bitwise-reproducible oracle kernels,
/// `fast` the autovectorizer-friendly speed tier (see
/// [`crate::fssdp::compute`] for the determinism contract of each).
pub fn parse_compute_mode(s: &str) -> Result<ComputeMode, ConfigError> {
    match s.trim() {
        "ref" | "reference" => Ok(ComputeMode::Reference),
        "fast" => Ok(ComputeMode::Fast),
        other => Err(ConfigError::BadComputeMode { given: other.to_string() }),
    }
}

/// Parse the CLI's `--recv-timeout` value (seconds, fractional allowed).
pub fn parse_recv_timeout(s: &str) -> Result<Duration, ConfigError> {
    let err = || ConfigError::BadRecvTimeout { given: s.to_string() };
    let secs: f64 = s.trim().parse().map_err(|_| err())?;
    if !secs.is_finite() || secs <= 0.0 {
        return Err(err());
    }
    Ok(Duration::from_secs_f64(secs))
}

/// Validated session configuration — the only way to obtain one is
/// [`SessionConfig::builder`] + [`SessionConfigBuilder::build`], so holding
/// a `SessionConfig` is proof the invariants hold.
#[derive(Debug, Clone)]
pub struct SessionConfig {
    pub(crate) backend: Backend,
    pub(crate) dims: LayerDims,
    pub(crate) topo: Topology,
    /// `None` = default (1 fresh; the checkpoint's count on resume);
    /// `Some(n)` is an explicit request and must match on resume.
    pub(crate) layers: Option<usize>,
    pub(crate) seed: u64,
    /// Logical data shards. `None` = one per device on a fresh start; the
    /// checkpoint's count on resume (it must survive restarts unchanged).
    pub(crate) data_shards: Option<usize>,
    pub(crate) executor: Executor,
    pub(crate) pacing: Option<Pacing>,
    pub(crate) transport: TransportKind,
    pub(crate) recv_timeout: Option<Duration>,
    /// `Some(0)` explicitly disables in-run re-sharding (distinct from
    /// `None`, which keeps a resumed checkpoint's cadence).
    pub(crate) reshard_every: Option<usize>,
    pub(crate) checkpoint_every: usize,
    pub(crate) checkpoint_dir: Option<PathBuf>,
    pub(crate) mem_slots: Option<usize>,
    pub(crate) overlap_degree: Option<usize>,
    pub(crate) compute_threads: usize,
    pub(crate) compute_mode: ComputeMode,
    pub(crate) telemetry: TelemetryConfig,
}

impl SessionConfig {
    /// Start building a configuration (reference backend, 2 nodes × 4
    /// devices, 1 layer, seed 42, sequential executor).
    pub fn builder() -> SessionConfigBuilder {
        SessionConfigBuilder::default()
    }

    /// The resolved simulated cluster.
    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    /// The resolved executor.
    pub fn executor(&self) -> Executor {
        self.executor
    }

    /// The resolved SPMD transport backend.
    pub fn transport(&self) -> TransportKind {
        self.transport
    }

    /// Checkpoint destination, when configured.
    pub fn checkpoint_dir(&self) -> Option<&std::path::Path> {
        self.checkpoint_dir.as_deref()
    }

    /// Snapshot cadence in iterations (0 = off).
    pub fn checkpoint_every(&self) -> usize {
        self.checkpoint_every
    }

    /// The telemetry configuration (tracing off by default).
    pub fn telemetry(&self) -> &TelemetryConfig {
        &self.telemetry
    }

    /// The resolved compute mode (Reference unless `--compute-mode fast`).
    pub fn compute_mode(&self) -> ComputeMode {
        self.compute_mode
    }
}

/// Builder for [`SessionConfig`]; all validation happens in
/// [`SessionConfigBuilder::build`].
#[derive(Debug, Clone)]
pub struct SessionConfigBuilder {
    backend: Backend,
    dims: LayerDims,
    topology: Option<Topology>,
    nodes: usize,
    devices: usize,
    layers: Option<usize>,
    seed: u64,
    data_shards: Option<usize>,
    parallel: bool,
    threads: Option<usize>,
    overlap: bool,
    pacing: Option<Pacing>,
    pacing_topo: Option<f64>,
    transport: TransportKind,
    recv_timeout: Option<Duration>,
    racks: Option<usize>,
    reshard_every: Option<usize>,
    checkpoint_every: usize,
    checkpoint_dir: Option<PathBuf>,
    mem_slots: Option<usize>,
    overlap_degree: Option<usize>,
    compute_threads: usize,
    compute_mode: ComputeMode,
    cores_hint: Option<usize>,
    telemetry: TelemetryConfig,
}

impl Default for SessionConfigBuilder {
    fn default() -> Self {
        SessionConfigBuilder {
            backend: Backend::Reference,
            dims: reference_dims(),
            topology: None,
            nodes: 2,
            devices: 8,
            layers: None,
            seed: 42,
            data_shards: None,
            parallel: false,
            threads: None,
            overlap: true,
            pacing: None,
            pacing_topo: None,
            transport: TransportKind::InProc,
            recv_timeout: None,
            racks: None,
            reshard_every: None,
            checkpoint_every: 0,
            checkpoint_dir: None,
            mem_slots: None,
            overlap_degree: None,
            compute_threads: 1,
            compute_mode: ComputeMode::Reference,
            cores_hint: None,
            telemetry: TelemetryConfig::default(),
        }
    }
}

impl SessionConfigBuilder {
    /// Select the compute backend explicitly.
    pub fn backend(mut self, b: Backend) -> Self {
        self.backend = b;
        self
    }

    /// The hermetic pure-Rust reference backend (the default).
    pub fn reference(self) -> Self {
        self.backend(Backend::Reference)
    }

    /// The PJRT backend, loading artifacts from `artifact_dir`. Layer
    /// dimensions then come from the artifact manifest, not [`Self::dims`].
    pub fn pjrt(self, artifact_dir: &str) -> Self {
        self.backend(Backend::Pjrt { artifact_dir: artifact_dir.to_string() })
    }

    /// Layer dimensions of the reference backend (ignored under PJRT,
    /// where the artifacts dictate them). Default: [`reference_dims`].
    pub fn dims(mut self, d: LayerDims) -> Self {
        self.dims = d;
        self
    }

    /// Use this exact topology (libraries/tests). Overrides
    /// [`Self::cluster`].
    pub fn topology(mut self, t: Topology) -> Self {
        self.topology = Some(t);
        self
    }

    /// Build a Cluster-A topology of `devices` split evenly over `nodes`
    /// (the CLI path; validated at [`Self::build`]).
    pub fn cluster(mut self, nodes: usize, devices: usize) -> Self {
        self.nodes = nodes;
        self.devices = devices;
        self.topology = None;
        self
    }

    /// MoE layers in the stack. Fresh default is 1; on resume the
    /// checkpoint's count wins and an explicit value must match it.
    pub fn layers(mut self, l: usize) -> Self {
        self.layers = Some(l);
        self
    }

    /// Engine construction seed (recorded in checkpoints).
    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }

    /// Logical data-shard count (default: one per device; on resume the
    /// checkpoint's count always wins — elasticity changes the device
    /// count, never the data stream).
    pub fn data_shards(mut self, s: usize) -> Self {
        self.data_shards = Some(s);
        self
    }

    /// Run on the SPMD executor (one OS thread per rank).
    pub fn parallel(mut self, on: bool) -> Self {
        self.parallel = on;
        self
    }

    /// Explicit SPMD thread count; must equal the device count.
    pub fn threads(mut self, t: usize) -> Self {
        self.threads = Some(t);
        self
    }

    /// Toggle the SPMD overlap scheduler (§4.3 cross-layer pipeline);
    /// default on. Results are bit-identical either way.
    pub fn overlap(mut self, on: bool) -> Self {
        self.overlap = on;
        self
    }

    /// α–β link pacing for the SPMD communicator (see [`parse_pacing`] for
    /// the CLI form). Requires [`Self::parallel`] — nothing consumes
    /// pacing on the sequential executor. Never affects numerics.
    pub fn pacing(mut self, p: Pacing) -> Self {
        self.pacing = Some(p);
        self
    }

    /// Derive the α–β pacing from the resolved topology's link tiers,
    /// scaled by `scale` (simulated seconds per wall-clock second): every
    /// SPMD transfer then occupies wall clock per the tier it crosses
    /// (intra-node / inter-node / cross-rack). Mutually exclusive with
    /// [`Self::pacing`]; requires [`Self::parallel`] and the in-proc
    /// transport. Never affects numerics.
    pub fn pacing_topo(mut self, scale: f64) -> Self {
        self.pacing_topo = Some(scale);
        self
    }

    /// Which transport the SPMD ranks communicate over: the in-process
    /// mpsc fabric (default) or localhost sockets speaking the versioned
    /// wire codec. Results are bit-identical either way (locked by
    /// `rust/tests/socket_equivalence.rs`).
    pub fn transport(mut self, t: TransportKind) -> Self {
        self.transport = t;
        self
    }

    /// Receive timeout of the socket transport (default 30 s): a rank
    /// waiting longer than this on a peer fails with a typed timeout
    /// instead of hanging the span.
    pub fn recv_timeout(mut self, d: Duration) -> Self {
        self.recv_timeout = Some(d);
        self
    }

    /// Group the cluster's nodes into `n` racks (must divide the node
    /// count): cross-rack hops get their own α–β tier in the topology and
    /// in topology-derived pacing.
    pub fn racks(mut self, n: usize) -> Self {
        self.racks = Some(n);
        self
    }

    /// Re-run Algorithm 2 jointly over all layers every `k` iterations
    /// (0 disables; unset keeps a resumed checkpoint's cadence).
    pub fn reshard_every(mut self, k: usize) -> Self {
        self.reshard_every = Some(k);
        self
    }

    /// Snapshot every `n` iterations (0 = off; requires
    /// [`Self::checkpoint_dir`]).
    pub fn checkpoint_every(mut self, n: usize) -> Self {
        self.checkpoint_every = n;
        self
    }

    /// Where snapshots land. Setting a directory without a cadence still
    /// writes one final snapshot at [`Session::finish`].
    ///
    /// [`Session::finish`]: crate::fssdp::Session::finish
    pub fn checkpoint_dir(mut self, d: impl Into<PathBuf>) -> Self {
        self.checkpoint_dir = Some(d.into());
        self
    }

    /// Memory headroom per device for Algorithm 1, in expert slots
    /// (default 4; on resume the checkpoint's value wins unless set).
    pub fn mem_slots(mut self, n: usize) -> Self {
        self.mem_slots = Some(n);
        self
    }

    /// Overlap degree for Algorithms 1 and 2 (default 4; on resume the
    /// checkpoint's value wins unless set).
    pub fn overlap_degree(mut self, n: usize) -> Self {
        self.overlap_degree = Some(n);
        self
    }

    /// Worker threads for the expert-kernel loops (default 1 = in-line).
    /// On the sequential executor the engine's per-key loop fans out
    /// across this many scoped threads; under `--parallel` each SPMD rank
    /// runs its own pool of this size over its capacity groups. Takes
    /// effect on the hermetic backends only — PJRT runtime handles cannot
    /// be shared across threads, so PJRT engines always run the in-line
    /// loop. Per-key work is independent and merges in route order, so
    /// Reference mode stays bit-identical at any value and Fast mode is
    /// deterministic per thread count. Validated against
    /// [`Self::cores_hint`] at build time.
    pub fn compute_threads(mut self, n: usize) -> Self {
        self.compute_threads = n;
        self
    }

    /// Select the compute tier: [`ComputeMode::Reference`] (default, the
    /// bitwise-reproducible oracle) or [`ComputeMode::Fast`] (the
    /// autovectorizer-friendly speed tier; deterministic per thread count,
    /// divergence from Reference bounded by `fssdp::diverge`). Ignored by
    /// the PJRT backend, which brings its own kernels. Compute-only: the
    /// schedule verifier and every communication plan are unchanged by it.
    pub fn compute_mode(mut self, m: ComputeMode) -> Self {
        self.compute_mode = m;
        self
    }

    /// Cap [`Self::compute_threads`] at `n` cores (build-time check).
    /// Defaults to the host's available parallelism with a floor of 8, so
    /// portable configs with small pools build everywhere while gross
    /// oversubscription is still rejected; tests set it explicitly for a
    /// deterministic bound.
    pub fn cores_hint(mut self, n: usize) -> Self {
        self.cores_hint = Some(n);
        self
    }

    /// Enable telemetry without file export: spans accumulate in memory
    /// and are readable via `Session::trace_events`. Tracing is
    /// observational only — traced runs stay bit-identical to untraced
    /// ones on every executor.
    pub fn trace(mut self, on: bool) -> Self {
        self.telemetry.enabled = on;
        self
    }

    /// Enable telemetry and export the trace into `dir` (`--trace-out`):
    /// a Chrome-trace timeline plus a JSONL event stream, written at every
    /// span boundary. Implies [`Self::trace`]`(true)`.
    pub fn trace_out(mut self, dir: impl Into<String>) -> Self {
        self.telemetry.enabled = true;
        self.telemetry.trace_dir = Some(dir.into());
        self
    }

    /// Enable metering without file export: the engine's step meter
    /// records the memory ledger + load observatory in memory, readable
    /// via `Session::meter_samples`. Metering is observational only —
    /// metered runs stay bit-identical to unmetered ones on every
    /// executor.
    pub fn metrics(mut self, on: bool) -> Self {
        self.telemetry.metrics = on;
        self
    }

    /// Enable metering and export into `dir` (`--metrics-out`): a JSONL
    /// time series, a Prometheus-style text exposition, and a standalone
    /// Chrome-trace counter document, written at every span boundary.
    /// Implies [`Self::metrics`]`(true)`.
    pub fn metrics_out(mut self, dir: impl Into<String>) -> Self {
        self.telemetry.metrics = true;
        self.telemetry.metrics_dir = Some(dir.into());
        self
    }

    /// Validate and freeze the configuration. Validation order matches the
    /// legacy CLI so the first error reported is unchanged.
    pub fn build(self) -> Result<SessionConfig, ConfigError> {
        if self.threads.is_some() && !self.parallel {
            return Err(ConfigError::ThreadsWithoutParallel);
        }
        if self.pacing.is_some() && !self.parallel {
            return Err(ConfigError::PacingWithoutParallel);
        }
        if self.pacing_topo.is_some() && !self.parallel {
            return Err(ConfigError::PacingTopoWithoutParallel);
        }
        if self.pacing.is_some() && self.pacing_topo.is_some() {
            return Err(ConfigError::PacingTopoConflict);
        }
        if self.transport == TransportKind::Socket && !self.parallel {
            return Err(ConfigError::SocketNeedsParallel);
        }
        if self.transport == TransportKind::Socket
            && (self.pacing.is_some() || self.pacing_topo.is_some())
        {
            return Err(ConfigError::PacingWithSocket);
        }
        if self.recv_timeout.is_some() && self.transport != TransportKind::Socket {
            return Err(ConfigError::RecvTimeoutWithoutSocket);
        }
        if let Some(scale) = self.pacing_topo {
            if !scale.is_finite() || scale <= 0.0 {
                return Err(ConfigError::BadPacingScale { given: scale.to_string() });
            }
        }
        let topo = match self.topology {
            Some(t) => t,
            None => {
                if self.nodes == 0 || self.devices == 0 {
                    return Err(ConfigError::NoDevices);
                }
                if self.devices % self.nodes != 0 {
                    return Err(ConfigError::UnevenDevices);
                }
                Topology::cluster_a(self.nodes, self.devices / self.nodes)
            }
        };
        let topo = match self.racks {
            Some(0) => return Err(ConfigError::ZeroRacks),
            Some(r) if topo.nodes % r != 0 => {
                return Err(ConfigError::RacksDontDivide { racks: r, nodes: topo.nodes });
            }
            Some(r) => topo.with_racks(r),
            None => topo,
        };
        let devices = topo.num_devices();
        if devices == 0 {
            return Err(ConfigError::NoDevices);
        }
        if self.layers == Some(0) {
            return Err(ConfigError::ZeroLayers);
        }
        if self.data_shards == Some(0) {
            return Err(ConfigError::ZeroDataShards);
        }
        if self.checkpoint_every > 0 && self.checkpoint_dir.is_none() {
            return Err(ConfigError::CheckpointEveryWithoutDir);
        }
        if self.compute_threads == 0 {
            return Err(ConfigError::ZeroComputeThreads);
        }
        let cores = self.cores_hint.unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(8).max(8)
        });
        if self.compute_threads > cores {
            return Err(ConfigError::ComputeThreadsExceedCores {
                threads: self.compute_threads,
                cores,
            });
        }
        if let Some(d) = &self.telemetry.trace_dir {
            if d.trim().is_empty() {
                return Err(ConfigError::TraceOutEmpty);
            }
        }
        if let Some(d) = &self.telemetry.metrics_dir {
            if d.trim().is_empty() {
                return Err(ConfigError::MetricsOutEmpty);
            }
        }
        let executor = if self.parallel {
            let threads = self.threads.unwrap_or(devices);
            if threads != devices {
                return Err(ConfigError::ThreadCountMismatch { threads, devices });
            }
            if self.backend != Backend::Reference {
                return Err(ConfigError::ParallelNeedsReference);
            }
            Executor::Spmd { threads, overlap: self.overlap }
        } else {
            Executor::Sequential
        };
        let pacing = match self.pacing_topo {
            Some(scale) => Some(Pacing::from_topology(&topo, scale)),
            None => self.pacing,
        };
        Ok(SessionConfig {
            backend: self.backend,
            dims: self.dims,
            topo,
            layers: self.layers,
            seed: self.seed,
            data_shards: self.data_shards,
            executor,
            pacing,
            transport: self.transport,
            recv_timeout: self.recv_timeout,
            reshard_every: self.reshard_every,
            checkpoint_every: self.checkpoint_every,
            checkpoint_dir: self.checkpoint_dir,
            mem_slots: self.mem_slots,
            overlap_degree: self.overlap_degree,
            compute_threads: self.compute_threads,
            compute_mode: self.compute_mode,
            telemetry: self.telemetry,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> SessionConfigBuilder {
        SessionConfig::builder().reference()
    }

    // ---- exact error strings: the contract with today's CLI users ----

    #[test]
    fn zero_devices_error_string() {
        let err = base().cluster(0, 8).build().unwrap_err();
        assert_eq!(err.to_string(), "need at least one node and device");
        let err = base().cluster(2, 0).build().unwrap_err();
        assert_eq!(err.to_string(), "need at least one node and device");
    }

    #[test]
    fn uneven_devices_error_string() {
        let err = base().cluster(3, 8).build().unwrap_err();
        assert_eq!(err.to_string(), "devices must divide evenly into nodes");
    }

    #[test]
    fn zero_layers_error_string() {
        let err = base().cluster(2, 4).layers(0).build().unwrap_err();
        assert_eq!(err, ConfigError::ZeroLayers);
        assert_eq!(err.to_string(), "--layers must be at least 1");
    }

    #[test]
    fn checkpoint_every_without_dir_error_string() {
        let err = base().cluster(2, 4).checkpoint_every(5).build().unwrap_err();
        assert_eq!(err, ConfigError::CheckpointEveryWithoutDir);
        assert_eq!(err.to_string(), "--checkpoint-every needs --checkpoint-dir");
    }

    #[test]
    fn threads_without_parallel_error_string() {
        let err = base().cluster(2, 4).threads(4).build().unwrap_err();
        assert_eq!(err, ConfigError::ThreadsWithoutParallel);
        assert_eq!(
            err.to_string(),
            "--threads requires --parallel (the SPMD executor runs one thread per rank; \
             without --parallel the engine is single-threaded)"
        );
    }

    #[test]
    fn thread_mismatch_error_string() {
        let err = base().cluster(2, 4).parallel(true).threads(3).build().unwrap_err();
        assert_eq!(err, ConfigError::ThreadCountMismatch { threads: 3, devices: 4 });
        assert_eq!(
            err.to_string(),
            "--threads 3 must equal --devices 4: the SPMD executor runs one OS thread per rank"
        );
    }

    #[test]
    fn parallel_on_pjrt_error_string() {
        let err =
            SessionConfig::builder().pjrt("artifacts").cluster(2, 4).parallel(true).build();
        assert_eq!(err.clone().unwrap_err(), ConfigError::ParallelNeedsReference);
        assert_eq!(
            err.unwrap_err().to_string(),
            "--parallel requires the hermetic backend (add --reference): \
             PJRT runtime handles cannot be shared across rank threads"
        );
    }

    #[test]
    fn layer_mismatch_error_string() {
        let err = ConfigError::LayerCountMismatch { requested: 2, checkpoint: 3 };
        assert_eq!(
            err.to_string(),
            "--layers 2 conflicts with the checkpoint's 3 layers (omit --layers when resuming)"
        );
    }

    // ---- builder misconfigurations reachable only via CLI before ----

    #[test]
    fn zero_data_shards_is_rejected() {
        let err = base().cluster(2, 4).data_shards(0).build().unwrap_err();
        assert_eq!(err, ConfigError::ZeroDataShards);
    }

    #[test]
    fn pacing_without_parallel_is_rejected() {
        // pacing is only consumed by the SPMD communicator — accepting it
        // on the sequential executor would silently produce unpaced
        // timings the user believes are α–β modeled.
        let p = parse_pacing("2e-5,1e-9").unwrap();
        let err = base().cluster(2, 4).pacing(p).build().unwrap_err();
        assert_eq!(err, ConfigError::PacingWithoutParallel);
        assert!(err.to_string().contains("--pacing requires --parallel"), "{err}");
        assert!(base().cluster(2, 4).parallel(true).pacing(p).build().is_ok());
    }

    #[test]
    fn zero_compute_threads_is_rejected() {
        let err = base().cluster(2, 4).compute_threads(0).build().unwrap_err();
        assert_eq!(err, ConfigError::ZeroComputeThreads);
        assert_eq!(err.to_string(), "--compute-threads must be at least 1");
        let cfg = base().cluster(2, 4).compute_threads(4).build().unwrap();
        assert_eq!(cfg.compute_threads, 4);
    }

    #[test]
    fn compute_threads_are_accepted_with_parallel() {
        // regression for the old must-reject contract: SPMD ranks now run
        // per-rank kernel worker pools, so the combination is valid.
        let cfg = base().cluster(2, 4).parallel(true).compute_threads(2).build().unwrap();
        assert_eq!(cfg.compute_threads, 2);
        assert_eq!(cfg.executor(), Executor::Spmd { threads: 4, overlap: true });
    }

    #[test]
    fn compute_threads_beyond_cores_hint_error_string() {
        let err = base()
            .cluster(2, 4)
            .cores_hint(4)
            .compute_threads(9)
            .build()
            .unwrap_err();
        assert_eq!(err, ConfigError::ComputeThreadsExceedCores { threads: 9, cores: 4 });
        assert_eq!(
            err.to_string(),
            "--compute-threads 9 exceeds the 4 available cores (the kernel worker \
             pool is CPU-bound; oversubscribing only adds scheduling noise)"
        );
        assert!(base().cluster(2, 4).cores_hint(4).compute_threads(4).build().is_ok());
        // the default hint has a floor of 8, so portable small pools build
        // on any host
        assert!(base().cluster(2, 4).compute_threads(8).build().is_ok());
    }

    #[test]
    fn compute_mode_parses_and_reaches_the_config() {
        assert_eq!(parse_compute_mode("ref").unwrap(), ComputeMode::Reference);
        assert_eq!(parse_compute_mode("reference").unwrap(), ComputeMode::Reference);
        assert_eq!(parse_compute_mode("fast").unwrap(), ComputeMode::Fast);
        let err = parse_compute_mode("turbo").unwrap_err();
        assert_eq!(err, ConfigError::BadComputeMode { given: "turbo".to_string() });
        assert_eq!(err.to_string(), "--compute-mode expects `ref` or `fast`, got `turbo`");

        let cfg = base().cluster(2, 4).build().unwrap();
        assert_eq!(cfg.compute_mode(), ComputeMode::Reference, "Reference is the default");
        let cfg = base()
            .cluster(2, 4)
            .parallel(true)
            .compute_mode(ComputeMode::Fast)
            .compute_threads(2)
            .build()
            .unwrap();
        assert_eq!(cfg.compute_mode(), ComputeMode::Fast);
    }

    #[test]
    fn threads_default_to_device_count() {
        let cfg = base().cluster(2, 4).parallel(true).build().unwrap();
        assert_eq!(cfg.executor(), Executor::Spmd { threads: 4, overlap: true });
    }

    #[test]
    fn explicit_topology_skips_cluster_validation() {
        // an uneven `.cluster()` is overridden by a later `.topology()`
        let cfg = base()
            .cluster(3, 8)
            .topology(Topology::flat(1, 1e9))
            .build()
            .unwrap();
        assert_eq!(cfg.topology().num_devices(), 1);
    }

    #[test]
    fn overlap_toggle_reaches_the_executor() {
        let cfg = base().cluster(1, 2).parallel(true).overlap(false).build().unwrap();
        assert_eq!(cfg.executor(), Executor::Spmd { threads: 2, overlap: false });
    }

    #[test]
    fn empty_trace_out_error_string() {
        let err = base().cluster(2, 4).trace_out("   ").build().unwrap_err();
        assert_eq!(err, ConfigError::TraceOutEmpty);
        assert_eq!(err.to_string(), "--trace-out expects a non-empty directory path");
    }

    #[test]
    fn telemetry_flags_reach_the_config() {
        let cfg = base().cluster(2, 4).build().unwrap();
        assert!(!cfg.telemetry().enabled, "tracing is off by default");
        let cfg = base().cluster(2, 4).trace(true).build().unwrap();
        assert!(cfg.telemetry().enabled);
        assert_eq!(cfg.telemetry().trace_dir, None);
        let cfg = base().cluster(2, 4).trace_out("/tmp/trace").build().unwrap();
        assert!(cfg.telemetry().enabled, "trace_out implies enabled");
        assert_eq!(cfg.telemetry().trace_dir.as_deref(), Some("/tmp/trace"));
    }

    #[test]
    fn empty_metrics_out_error_string() {
        let err = base().cluster(2, 4).metrics_out("   ").build().unwrap_err();
        assert_eq!(err, ConfigError::MetricsOutEmpty);
        assert_eq!(err.to_string(), "--metrics-out expects a non-empty directory path");
    }

    #[test]
    fn metrics_flags_reach_the_config() {
        let cfg = base().cluster(2, 4).build().unwrap();
        assert!(!cfg.telemetry().metrics, "metering is off by default");
        let cfg = base().cluster(2, 4).metrics(true).build().unwrap();
        assert!(cfg.telemetry().metrics);
        assert_eq!(cfg.telemetry().metrics_dir, None);
        let cfg = base().cluster(2, 4).metrics_out("/tmp/metrics").build().unwrap();
        assert!(cfg.telemetry().metrics, "metrics_out implies enabled");
        assert_eq!(cfg.telemetry().metrics_dir.as_deref(), Some("/tmp/metrics"));
    }

    // ---- transport / rack knobs ----

    #[test]
    fn transport_parse_errors_name_the_value() {
        assert_eq!(parse_transport("socket").unwrap(), TransportKind::Socket);
        assert_eq!(parse_transport("inproc").unwrap(), TransportKind::InProc);
        let err = parse_transport("carrier-pigeon").unwrap_err();
        assert_eq!(err, ConfigError::BadTransport { given: "carrier-pigeon".to_string() });
        assert_eq!(
            err.to_string(),
            "--transport expects `inproc` or `socket`, got `carrier-pigeon`"
        );
    }

    #[test]
    fn socket_transport_requires_parallel() {
        let err = base().cluster(2, 4).transport(TransportKind::Socket).build().unwrap_err();
        assert_eq!(err, ConfigError::SocketNeedsParallel);
        assert!(err.to_string().contains("--transport socket requires --parallel"), "{err}");
        let cfg = base()
            .cluster(2, 4)
            .parallel(true)
            .transport(TransportKind::Socket)
            .build()
            .unwrap();
        assert_eq!(cfg.transport(), TransportKind::Socket);
    }

    #[test]
    fn pacing_is_rejected_on_the_socket_transport() {
        let p = parse_pacing("2e-5,1e-9").unwrap();
        let err = base()
            .cluster(2, 4)
            .parallel(true)
            .transport(TransportKind::Socket)
            .pacing(p)
            .build()
            .unwrap_err();
        assert_eq!(err, ConfigError::PacingWithSocket);
        let err = base()
            .cluster(2, 4)
            .parallel(true)
            .transport(TransportKind::Socket)
            .pacing_topo(1e3)
            .build()
            .unwrap_err();
        assert_eq!(err, ConfigError::PacingWithSocket);
    }

    #[test]
    fn pacing_topo_derives_tiered_pacing_from_the_topology() {
        let err = base().cluster(2, 4).pacing_topo(1e3).build().unwrap_err();
        assert_eq!(err, ConfigError::PacingTopoWithoutParallel);
        let p = parse_pacing("2e-5,1e-9").unwrap();
        let err = base()
            .cluster(2, 4)
            .parallel(true)
            .pacing(p)
            .pacing_topo(1e3)
            .build()
            .unwrap_err();
        assert_eq!(err, ConfigError::PacingTopoConflict);
        let cfg =
            base().cluster(4, 8).racks(2).parallel(true).pacing_topo(1e3).build().unwrap();
        let pc = cfg.pacing.expect("pacing derived");
        assert_eq!(pc.devices_per_node, 2);
        assert_eq!(pc.nodes_per_rack, 2);
        assert_eq!(pc.rack_bw, cfg.topology().rack_bw);
        assert_eq!(pc.time_scale, 1e3);
    }

    #[test]
    fn pacing_scale_parse_rejects_garbage() {
        assert_eq!(parse_pacing_scale("1e3").unwrap(), 1e3);
        for bad in ["nope", "0", "-5", "inf", ""] {
            let err = parse_pacing_scale(bad).unwrap_err();
            assert_eq!(err, ConfigError::BadPacingScale { given: bad.to_string() }, "{bad}");
        }
    }

    #[test]
    fn rack_knob_validates_and_reaches_the_topology() {
        let err = base().cluster(4, 8).racks(0).build().unwrap_err();
        assert_eq!(err, ConfigError::ZeroRacks);
        assert_eq!(err.to_string(), "--racks must be at least 1");
        let err = base().cluster(4, 8).racks(3).build().unwrap_err();
        assert_eq!(err, ConfigError::RacksDontDivide { racks: 3, nodes: 4 });
        assert_eq!(err.to_string(), "--racks 3 must evenly divide --nodes 4");
        let cfg = base().cluster(4, 8).racks(2).build().unwrap();
        assert_eq!(cfg.topology().racks, 2);
        assert_eq!(cfg.topology().rack_bw, cfg.topology().inter_bw / 2.0);
    }

    #[test]
    fn recv_timeout_parses_and_requires_socket() {
        assert_eq!(parse_recv_timeout("1.5").unwrap(), Duration::from_millis(1500));
        for bad in ["never", "0", "-1", "nan"] {
            let err = parse_recv_timeout(bad).unwrap_err();
            assert_eq!(err, ConfigError::BadRecvTimeout { given: bad.to_string() }, "{bad}");
        }
        let err = base()
            .cluster(2, 4)
            .parallel(true)
            .recv_timeout(Duration::from_secs(5))
            .build()
            .unwrap_err();
        assert_eq!(err, ConfigError::RecvTimeoutWithoutSocket);
        let cfg = base()
            .cluster(2, 4)
            .parallel(true)
            .transport(TransportKind::Socket)
            .recv_timeout(Duration::from_secs(5))
            .build()
            .unwrap();
        assert_eq!(cfg.recv_timeout, Some(Duration::from_secs(5)));
    }

    // ---- pacing parse ----

    #[test]
    fn pacing_parses_alpha_beta() {
        let p = parse_pacing("2e-5,1e-9").unwrap();
        assert!((p.intra_lat - 2e-5).abs() < 1e-12);
        assert!((p.intra_bw - 1e9).abs() / 1e9 < 1e-9);
    }

    #[test]
    fn pacing_parse_errors_are_typed_and_name_the_value() {
        for bad in ["nope", "1,", ",2", "1;2", "1,2,3", "-1e-5,1e-9", "1e-5,0", "nan,1e-9"] {
            let err = parse_pacing(bad).unwrap_err();
            assert_eq!(err, ConfigError::BadPacing { given: bad.to_string() }, "{bad}");
            assert!(err.to_string().contains(&format!("got `{bad}`")), "{err}");
        }
    }
}
