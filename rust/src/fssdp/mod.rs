//! The numeric FSSDP engine: real FSSDP training of an MoE layer across N
//! simulated devices inside one process.
//!
//! Everything the paper's Figure 5 shows actually happens here, with real
//! numbers:
//!
//! 1. **Sharding phase** — expert parameters + Adam states are partitioned
//!    into per-expert chunks owned by distinct devices.
//! 2. **Materialization phase** — each iteration the scheduler predicts
//!    loads (sliding window, w=5), runs Algorithm 1, and executes
//!    `spAG(P, P')` on the real parameter buffers
//!    ([`crate::collectives::exec`]).
//! 3. The **gate** runs as an AOT-compiled HLO executable per device
//!    (logits → softmax → Pallas top-2); the L3 **dispatcher** routes each
//!    token to a materialized replica (topology-aware, §4.4).
//! 4. **Expert compute** runs through the `expert_ffn_fwd`/`_bwd` HLO
//!    executables (Pallas kernels under PJRT), capacity-tiled.
//! 5. **Gradient reduction** executes `spRS(P', P)` on the real gradient
//!    buffers; shard owners apply Adam.
//!
//! The equivalence test (`examples/fssdp_numeric`, `rust/tests/`) runs the
//! same workload on 1 device (all experts local — no collectives, no
//! dispatch) and asserts the final parameters match: FSSDP's placement
//! freedom does not change the math.

pub mod adam;
pub mod compute;

use std::collections::BTreeMap;

use crate::checkpoint::{self, ExpertState, ReshardPlan, TrainState};
use crate::collectives::exec::{run_spag, run_sprs, ClusterMem};
use crate::collectives::sparse::{build_spag, build_sprs, SparsePlan};
use crate::dispatch::dispatch;
use crate::loadsim::LoadPredictor;
use crate::materialize::{sparse_materialize, MatConstraints};
use crate::metrics::Metrics;
use crate::placement::Placement;
use crate::runtime::{HostTensor, Runtime};
use crate::topology::{DeviceId, Topology};
use crate::util::rng::Rng;

use adam::{AdamCfg, AdamState};
use compute::Compute;

/// How the engine executes an iteration span: the sequential oracle (one
/// thread steps every simulated device in turn) or the SPMD runtime
/// ([`crate::spmd`] — one OS thread per rank over an in-process
/// communicator, with overlapped sparse collectives).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Executor {
    /// Single-threaded reference execution ([`FssdpEngine::step`]).
    Sequential,
    /// One OS thread per rank. `threads` must equal the topology's device
    /// count (SPMD = the program *is* the rank). `overlap` enables the
    /// re-materialization overlap scheduler (§4.3); results are
    /// bit-identical either way.
    Spmd { threads: usize, overlap: bool },
}

impl Executor {
    /// The SPMD executor sized for `topo` (one thread per device,
    /// overlap scheduler on).
    pub fn spmd_for(topo: &Topology) -> Executor {
        Executor::Spmd { threads: topo.num_devices(), overlap: true }
    }
}

/// Static dimensions of the engine's MoE layer (from the artifact manifest,
/// or chosen explicitly for the hermetic reference backend).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LayerDims {
    pub tokens: usize,
    pub d_model: usize,
    pub d_ffn: usize,
    pub experts: usize,
    pub cap: usize,
}

impl LayerDims {
    /// Floats in one expert's packed chunk: w1 ++ b1 ++ w2 ++ b2.
    pub fn chunk_len(&self) -> usize {
        self.d_model * self.d_ffn + self.d_ffn + self.d_ffn * self.d_model + self.d_model
    }

    fn from_runtime(rt: &Runtime) -> anyhow::Result<LayerDims> {
        let gate = rt.entry("gate_fwd")?;
        let ffn = rt.entry("expert_ffn_fwd")?;
        Ok(LayerDims {
            tokens: gate.extra_usize("tokens").unwrap_or(gate.inputs[0].shape[0]),
            d_model: gate.extra_usize("d_model").unwrap_or(gate.inputs[0].shape[1]),
            d_ffn: ffn.extra_usize("d_ffn").unwrap_or(ffn.inputs[1].shape[1]),
            experts: gate.inputs[1].shape[1],
            cap: ffn.extra_usize("cap").unwrap_or(ffn.inputs[0].shape[0]),
        })
    }
}

/// Unpack a chunk into (w1, b1, w2, b2) host tensors.
fn unpack_chunk(dims: &LayerDims, chunk: &[f32]) -> (HostTensor, HostTensor, HostTensor, HostTensor) {
    let (dm, dff) = (dims.d_model, dims.d_ffn);
    let mut off = 0;
    let w1 = HostTensor::f32(vec![dm, dff], chunk[off..off + dm * dff].to_vec());
    off += dm * dff;
    let b1 = HostTensor::f32(vec![dff], chunk[off..off + dff].to_vec());
    off += dff;
    let w2 = HostTensor::f32(vec![dff, dm], chunk[off..off + dff * dm].to_vec());
    off += dff * dm;
    let b2 = HostTensor::f32(vec![dm], chunk[off..off + dm].to_vec());
    (w1, b1, w2, b2)
}

/// Pack (gw1, gb1, gw2, gb2) into a gradient chunk, accumulating.
fn accumulate_grad_chunk(acc: &mut [f32], parts: &[HostTensor]) -> anyhow::Result<()> {
    let mut off = 0;
    for p in parts {
        let data = p.as_f32()?;
        for (a, &g) in acc[off..off + data.len()].iter_mut().zip(data.iter()) {
            *a += g;
        }
        off += data.len();
    }
    anyhow::ensure!(off == acc.len(), "grad pack length mismatch");
    Ok(())
}

/// Generate one logical data shard's token batch for iteration `iter`
/// (deterministic in (iter, source) only — the FSSDP run, the 1-device
/// reference, and every SPMD rank regenerate identical data locally, so
/// token payloads never need to cross the wire).
pub(crate) fn batch_for(dims: &LayerDims, iter: u64, source: usize) -> Vec<f32> {
    let mut r = Rng::new(0xDA7A ^ (iter.wrapping_mul(0x9E3779B97F4A7C15)) ^ (source as u64) << 32);
    // drift the token distribution over iterations so expert loads
    // fluctuate (the Figure 3 dynamic the predictor must track)
    let phase = iter as f64 * 0.05;
    (0..dims.tokens * dims.d_model)
        .map(|i| {
            let base = r.normal() as f32;
            let drift = ((i % dims.d_model) as f64 * 0.1 + phase).sin() as f32;
            base + 0.8 * drift
        })
        .collect()
}

/// The deterministic control-plane decisions of one iteration: predicted
/// placement (Algorithm 1) and the two compiled sparse collectives. Every
/// SPMD rank computes this redundantly from replicated state and gets the
/// same plan — the SPMD determinism contract (see DESIGN.md) hinges on it.
#[derive(Debug, Clone)]
pub(crate) struct IterPlan {
    pub placement: Placement,
    pub spag: SparsePlan,
    pub sprs: SparsePlan,
}

pub(crate) fn build_iter_plan(
    topo: &Topology,
    shards: &Placement,
    predicted: &[f64],
    cons: MatConstraints,
) -> anyhow::Result<IterPlan> {
    let placement = sparse_materialize(topo, shards, predicted, cons);
    let spag = build_spag(topo, shards, &placement)?;
    let sprs = build_sprs(topo, &placement, shards)?;
    Ok(IterPlan { placement, spag, sprs })
}

/// Realized load fractions from the gathered gate decisions (feeds the
/// predictor for the next iteration).
pub(crate) fn realized_loads(experts: usize, gate_idx: &[Vec<i32>]) -> Vec<f64> {
    let mut load_counts = vec![0usize; experts];
    for idx in gate_idx {
        for &e in idx {
            load_counts[e as usize] += 1;
        }
    }
    let total: usize = load_counts.iter().sum();
    load_counts.iter().map(|&c| c as f64 / total.max(1) as f64).collect()
}

/// `assignments[src_device][expert]` — sources map round-robin onto
/// devices (all on device 0 in the 1-device reference).
pub(crate) fn assignment_matrix(nd: usize, experts: usize, gate_idx: &[Vec<i32>]) -> Vec<Vec<usize>> {
    let mut asg = vec![vec![0usize; experts]; nd];
    for (s, idx) in gate_idx.iter().enumerate() {
        let dev = s % nd;
        for &e in idx {
            asg[dev][e as usize] += 1;
        }
    }
    asg
}

/// Physical token routing: per `(dst_device, expert)` → list of
/// `(source, token_row, gate_weight)`. Routing must follow the dispatch
/// plan: we re-derive each token's destination with the same rule
/// (local → same-node → any; round-robin among candidates). Deterministic
/// in its inputs, so SPMD ranks compute it redundantly and agree.
pub(crate) type Routes = BTreeMap<(usize, usize), Vec<(usize, usize, f32)>>;

pub(crate) fn routes_from_gates(
    topo: &Topology,
    placement: &Placement,
    nd: usize,
    experts: usize,
    gate_idx: &[Vec<i32>],
    gate_w_out: &[Vec<f32>],
) -> Routes {
    let mut routes: Routes = BTreeMap::new();
    let mut cursors = vec![0usize; experts];
    for (s, idx) in gate_idx.iter().enumerate() {
        let src = DeviceId(s % nd);
        for (t, pair) in idx.chunks(2).enumerate() {
            for (slot, &e) in pair.iter().enumerate() {
                let e = e as usize;
                let w = gate_w_out[s][t * 2 + slot];
                let dst = if placement.contains(e, src) {
                    src
                } else {
                    let local = placement.holders_on_node(topo, e, topo.node_of(src));
                    let cands: Vec<DeviceId> = if local.is_empty() {
                        placement.holders(e).collect()
                    } else {
                        local
                    };
                    let d = cands[cursors[e] % cands.len()];
                    cursors[e] += 1;
                    d
                };
                routes.entry((dst.0, e)).or_default().push((s, t, w));
            }
        }
    }
    routes
}

/// Expert forward + combine + loss + backward for every token routed to
/// one `(device, expert)` pair, accumulating parameter gradients into
/// `acc` (capacity-tiled, group order — the accumulation order is part of
/// the bit-exactness contract between executors). Returns the loss
/// contribution.
pub(crate) fn compute_expert_key(
    compute: &mut Compute,
    dims: &LayerDims,
    chunk: &[f32],
    toks: &[(usize, usize, f32)],
    batches: &[Vec<f32>],
    inv_t: f32,
    acc: &mut [f32],
) -> anyhow::Result<f64> {
    let (w1, b1, w2, b2) = unpack_chunk(dims, chunk);
    let mut loss = 0.0f64;
    for group in toks.chunks(dims.cap) {
        // pack token rows (zero-padded to cap)
        let mut xin = vec![0.0f32; dims.cap * dims.d_model];
        for (row, &(s, t, _w)) in group.iter().enumerate() {
            let src = &batches[s][t * dims.d_model..(t + 1) * dims.d_model];
            xin[row * dims.d_model..(row + 1) * dims.d_model].copy_from_slice(src);
        }
        let xt = HostTensor::f32(vec![dims.cap, dims.d_model], xin);
        let y = compute.execute(
            "expert_ffn_fwd",
            &[xt.clone(), w1.clone(), b1.clone(), w2.clone(), b2.clone()],
        )?;
        let yv = y[0].as_f32()?;
        // combine + loss + cotangent: target 0 ⇒ L = ½‖w·y‖²/T,
        // gy_row = w²·y·(1/T) (chain through the combine weight)
        let mut gy = vec![0.0f32; dims.cap * dims.d_model];
        for (row, &(_s, _t, w)) in group.iter().enumerate() {
            for c in 0..dims.d_model {
                let o = w * yv[row * dims.d_model + c];
                loss += 0.5 * (o as f64) * (o as f64) * inv_t as f64;
                gy[row * dims.d_model + c] = w * o * inv_t;
            }
        }
        let gyt = HostTensor::f32(vec![dims.cap, dims.d_model], gy);
        let out = compute.execute(
            "expert_ffn_bwd",
            &[xt, w1.clone(), b1.clone(), w2.clone(), b2.clone(), gyt],
        )?;
        // out = (gx, gw1, gb1, gw2, gb2); gx unused (gate frozen)
        accumulate_grad_chunk(acc, &out[1..5])?;
    }
    Ok(loss)
}

/// Per-iteration statistics of the engine.
#[derive(Debug, Clone, Default)]
pub struct EngineStats {
    pub loss: f64,
    /// λ of the spAG this iteration.
    pub spag_sparsity: f64,
    /// Materialized (chunk, device) pairs beyond the shards.
    pub replicas: usize,
    /// Tokens that crossed devices.
    pub remote_tokens: usize,
    /// Straggler factor of per-device expert tokens.
    pub straggler: f64,
}

/// The engine itself.
pub struct FssdpEngine {
    pub topo: Topology,
    pub dims: LayerDims,
    /// Which executor [`FssdpEngine::run_span`] uses.
    pub executor: Executor,
    pub(crate) compute: Compute,
    /// Engine construction seed (recorded in checkpoints).
    seed: u64,
    /// Expert parameter chunks, placed per `shards`.
    pub(crate) params: ClusterMem,
    pub(crate) shards: Placement,
    /// Adam state on shard owners only (the single global copy).
    pub(crate) opt: BTreeMap<usize, AdamState>,
    pub(crate) adam: AdamCfg,
    /// Gate weights, replicated on every device (dense DP part; frozen in
    /// the engine — the gate's drift is exogenous, from the data stream).
    pub(crate) gate_w: Vec<f32>,
    pub(crate) predictor: LoadPredictor,
    /// Memory headroom per device for Algorithm 1, in expert slots.
    pub mem_slots: usize,
    /// Overlap degree for Algorithm 1.
    pub overlap_degree: usize,
    rng: Rng,
    /// Per-rank metrics merged after the last SPMD span (None before the
    /// first parallel run).
    pub(crate) spmd_metrics: Option<Metrics>,
}

impl FssdpEngine {
    /// Build the engine on the PJRT backend: load artifacts, shard experts
    /// round-robin, init parameters deterministically from `seed`.
    pub fn new(artifact_dir: &str, topo: Topology, seed: u64) -> anyhow::Result<FssdpEngine> {
        let rt = Runtime::open(artifact_dir)?;
        let dims = LayerDims::from_runtime(&rt)?;
        Ok(Self::init(Compute::Pjrt(rt), dims, topo, seed))
    }

    /// Build the engine on the hermetic pure-Rust reference backend (no
    /// artifacts / PJRT required) — same math, explicit dimensions.
    pub fn new_reference(dims: LayerDims, topo: Topology, seed: u64) -> FssdpEngine {
        Self::init(Compute::Reference(compute::Reference), dims, topo, seed)
    }

    fn init(compute: Compute, dims: LayerDims, topo: Topology, seed: u64) -> FssdpEngine {
        let nd = topo.num_devices();
        let shards = Placement::round_robin(dims.experts, nd);
        let mut rng = Rng::new(seed);

        // deterministic init: chunk e seeded on (seed, e) only, so the
        // device count / placement cannot affect initial values.
        let mut params = ClusterMem::new(nd);
        let mut opt = BTreeMap::new();
        for e in 0..dims.experts {
            let mut er = Rng::new(seed ^ (0x9E37 + e as u64 * 0x1000193));
            let scale = (dims.d_model as f64).powf(-0.5);
            let chunk: Vec<f32> =
                (0..dims.chunk_len()).map(|_| (er.normal() * scale) as f32).collect();
            let owner = shards.holders(e).next().unwrap();
            params.dev_mut(owner).insert(e, chunk);
            opt.insert(e, AdamState::new(dims.chunk_len()));
        }
        let gate_scale = (dims.d_model as f64).powf(-0.5);
        let gate_w: Vec<f32> = (0..dims.d_model * dims.experts)
            .map(|_| (rng.normal() * gate_scale * 3.0) as f32)
            .collect();
        let predictor = LoadPredictor::new(dims.experts, 5);
        FssdpEngine {
            topo,
            dims,
            executor: Executor::Sequential,
            compute,
            seed,
            params,
            shards,
            opt,
            adam: AdamCfg::default(),
            gate_w,
            predictor,
            mem_slots: 4,
            overlap_degree: 4,
            rng,
            spmd_metrics: None,
        }
    }

    /// Owner device of expert `e`.
    pub fn owner(&self, e: usize) -> DeviceId {
        self.shards.holders(e).next().unwrap()
    }

    /// The current owner partition.
    pub fn shards(&self) -> &Placement {
        &self.shards
    }

    /// Which backend executes the kernels (`"pjrt"` / `"reference"`).
    pub fn backend(&self) -> &'static str {
        self.compute.backend_name()
    }

    /// Read back an expert's parameter chunk (from its owner).
    pub fn expert_chunk(&self, e: usize) -> &Vec<f32> {
        self.params.dev(self.owner(e)).get(e).expect("owner holds its shard")
    }

    /// Run one FSSDP training iteration over `sources` logical data shards
    /// (== devices in the distributed run; all mapped to device 0 in the
    /// reference run). Returns iteration statistics.
    pub fn step(&mut self, iter: u64, sources: usize) -> anyhow::Result<EngineStats> {
        let nd = self.topo.num_devices();
        let dims = self.dims;
        let mut stats = EngineStats::default();

        // ---- materialization phase: predict → Algorithm 1 → spAG ----
        let predicted = self.predictor.predict();
        let plan = build_iter_plan(
            &self.topo,
            &self.shards,
            &predicted,
            MatConstraints { overlap_degree: self.overlap_degree, mem_slots: self.mem_slots },
        )?;
        let placement = &plan.placement;
        stats.spag_sparsity = plan.spag.sparsity;
        stats.replicas = placement.len() - self.shards.len();
        run_spag(&mut self.params, &plan.spag)?;

        // ---- gate (HLO) per source batch ----
        let gate_wt = HostTensor::f32(vec![dims.d_model, dims.experts], self.gate_w.clone());
        let mut batches: Vec<Vec<f32>> = Vec::with_capacity(sources);
        let mut gate_w_out: Vec<Vec<f32>> = Vec::with_capacity(sources);
        let mut gate_idx: Vec<Vec<i32>> = Vec::with_capacity(sources);
        for s in 0..sources {
            let x = batch_for(&dims, iter, s);
            let xt = HostTensor::f32(vec![dims.tokens, dims.d_model], x.clone());
            let out = self.compute.execute("gate_fwd", &[xt, gate_wt.clone()])?;
            gate_w_out.push(out[1].as_f32()?.to_vec());
            gate_idx.push(out[2].as_i32()?.to_vec());
            batches.push(x);
        }

        // realized loads feed the predictor for the NEXT iteration
        let realized = realized_loads(dims.experts, &gate_idx);

        // ---- dispatch (L3) ----
        let asg = assignment_matrix(nd, dims.experts, &gate_idx);
        let dplan = dispatch(&self.topo, placement, &asg);
        stats.remote_tokens = dplan.remote_tokens();
        stats.straggler = crate::util::stats::straggler_factor(
            &dplan.device_compute_tokens().iter().map(|&t| t as f64).collect::<Vec<_>>(),
        );

        let routes =
            routes_from_gates(&self.topo, placement, nd, dims.experts, &gate_idx, &gate_w_out);

        // ---- expert forward (HLO), combine, loss, backward (HLO) ----
        // grads cluster-mem mirrors the materialized placement with zeros
        let mut grads = ClusterMem::new(nd);
        for e in 0..dims.experts {
            for d in placement.holders(e) {
                grads.dev_mut(d).insert(e, vec![0.0f32; dims.chunk_len()]);
            }
        }
        let mut loss = 0.0f64;
        let inv_t = 1.0f32 / (dims.tokens * sources) as f32;
        for (&(dev, e), toks) in &routes {
            let chunk = self
                .params
                .dev(DeviceId(dev))
                .get(e)
                .ok_or_else(|| anyhow::anyhow!("device {dev} lacks expert {e}"))?
                .clone();
            let acc = grads.dev_mut(DeviceId(dev)).get_mut(e).unwrap();
            loss +=
                compute_expert_key(&mut self.compute, &dims, &chunk, toks, &batches, inv_t, acc)?;
        }
        stats.loss = loss;

        // ---- spRS: reduce gradients to the shard owners ----
        run_sprs(&mut grads, &plan.sprs, &self.shards)?;

        // ---- optimizer step on owners; release materialized replicas ----
        for e in 0..dims.experts {
            let owner = self.owner(e);
            let g = grads
                .dev(owner)
                .get(e)
                .ok_or_else(|| anyhow::anyhow!("owner of {e} lost its gradient"))?
                .clone();
            let p = self.params.dev_mut(owner).get_mut(e).unwrap();
            self.opt.get_mut(&e).unwrap().update(&self.adam, p, &g);
        }
        // re-materialization: drop non-shard replicas (memory reuse, §4)
        for d in 0..nd {
            let dev = DeviceId(d);
            let resident: Vec<usize> = self.params.dev(dev).chunks().collect();
            for e in resident {
                if !self.shards.contains(e, dev) {
                    self.params.dev_mut(dev).remove(e);
                }
            }
        }

        self.predictor.observe(&realized);
        let _ = &self.rng; // reserved for stochastic extensions
        Ok(stats)
    }

    /// Run `iters` consecutive iterations starting at `start` on the
    /// configured [`Executor`], returning per-iteration statistics.
    ///
    /// `Executor::Sequential` loops [`FssdpEngine::step`];
    /// `Executor::Spmd` hands the whole span to the parallel runtime
    /// ([`crate::spmd::run_span`]) — one OS thread per rank, state split
    /// out per-rank at span entry and merged back at span exit, so
    /// checkpointing, [`FssdpEngine::snapshot`], and `expert_chunk` work
    /// identically under both executors.
    pub fn run_span(
        &mut self,
        start: u64,
        iters: usize,
        sources: usize,
    ) -> anyhow::Result<Vec<EngineStats>> {
        match self.executor {
            Executor::Sequential => {
                let mut out = Vec::with_capacity(iters);
                for k in 0..iters {
                    out.push(self.step(start + k as u64, sources)?);
                }
                Ok(out)
            }
            Executor::Spmd { threads, overlap } => {
                crate::spmd::run_span(self, start, iters, sources, threads, overlap)
            }
        }
    }

    /// Per-rank metrics merged over the most recent SPMD span (None if the
    /// engine has only run sequentially).
    pub fn spmd_metrics(&self) -> Option<&Metrics> {
        self.spmd_metrics.as_ref()
    }

    // ---- checkpointing (the durable state is exactly the shard set) ----

    /// Capture the complete training state at a step boundary: every
    /// expert's parameter chunk + Adam moments (read from their owners),
    /// the gate weights, the load-predictor sliding window, the RNG stream,
    /// and `step` (the next iteration to run). `data_shards` is the logical
    /// data-shard count of the run (`sources` at the `step` call sites) —
    /// it must survive elastic restarts unchanged.
    pub fn snapshot(&self, step: u64, data_shards: usize) -> TrainState {
        let experts: Vec<ExpertState> = (0..self.dims.experts)
            .map(|e| {
                let chunk = self.expert_chunk(e).clone();
                let o = self.opt.get(&e).expect("every expert has optimizer state");
                ExpertState { chunk, m: o.m.clone(), v: o.v.clone(), t: o.t }
            })
            .collect();
        TrainState {
            step,
            dims: self.dims,
            seed: self.seed,
            data_shards,
            owners: (0..self.dims.experts).map(|e| self.owner(e).0).collect(),
            experts,
            gate_w: self.gate_w.clone(),
            predictor_window: self.predictor.window(),
            predictor_history: self.predictor.history(),
            rng_state: self.rng.state(),
            mem_slots: self.mem_slots,
            overlap_degree: self.overlap_degree,
        }
    }

    /// Rebuild an engine from a restored [`TrainState`] on `topo`, which
    /// may have a *different* device count than the `old_world` that wrote
    /// the checkpoint (elastic resume). Same world size reuses the saved
    /// owner layout (bit-identical resume); a different world size re-runs
    /// the heterogeneous sharding planner over the restored load window —
    /// FSSDP placement freedom guarantees the training math is unchanged.
    pub fn resume_with(
        compute: Compute,
        topo: Topology,
        state: &TrainState,
        old_world: usize,
    ) -> anyhow::Result<(FssdpEngine, ReshardPlan)> {
        let dims = state.dims;
        anyhow::ensure!(
            state.experts.len() == dims.experts,
            "state holds {} experts, dims say {}",
            state.experts.len(),
            dims.experts
        );
        let plan = checkpoint::reshard::plan(state, old_world, &topo)?;
        let nd = topo.num_devices();
        let mut params = ClusterMem::new(nd);
        let mut opt = BTreeMap::new();
        for (e, st) in state.experts.iter().enumerate() {
            anyhow::ensure!(
                st.chunk.len() == dims.chunk_len(),
                "expert {e}: chunk has {} floats, dims imply {}",
                st.chunk.len(),
                dims.chunk_len()
            );
            let owner = plan.shards.holders(e).next().expect("partition has a holder");
            params.dev_mut(owner).insert(e, st.chunk.clone());
            opt.insert(e, AdamState { m: st.m.clone(), v: st.v.clone(), t: st.t });
        }
        anyhow::ensure!(
            state.gate_w.len() == dims.d_model * dims.experts,
            "gate_w has {} floats, dims imply {}",
            state.gate_w.len(),
            dims.d_model * dims.experts
        );
        let engine = FssdpEngine {
            topo,
            dims,
            executor: Executor::Sequential,
            compute,
            seed: state.seed,
            params,
            shards: plan.shards.clone(),
            opt,
            adam: AdamCfg::default(),
            gate_w: state.gate_w.clone(),
            predictor: LoadPredictor::restore(
                dims.experts,
                state.predictor_window,
                state.predictor_history.clone(),
            ),
            mem_slots: state.mem_slots,
            overlap_degree: state.overlap_degree,
            rng: Rng::from_state(state.rng_state),
            spmd_metrics: None,
        };
        Ok((engine, plan))
    }

    /// [`FssdpEngine::resume_with`] on the reference backend (hermetic).
    pub fn resume_reference(
        topo: Topology,
        state: &TrainState,
        old_world: usize,
    ) -> anyhow::Result<(FssdpEngine, ReshardPlan)> {
        Self::resume_with(Compute::Reference(compute::Reference), topo, state, old_world)
    }

    /// [`FssdpEngine::resume_with`] on the PJRT backend. The artifact
    /// dimensions must match the checkpoint's.
    pub fn resume(
        artifact_dir: &str,
        topo: Topology,
        state: &TrainState,
        old_world: usize,
    ) -> anyhow::Result<(FssdpEngine, ReshardPlan)> {
        let rt = Runtime::open(artifact_dir)?;
        let dims = LayerDims::from_runtime(&rt)?;
        anyhow::ensure!(
            dims == state.dims,
            "artifact dims {dims:?} do not match checkpoint dims {:?}",
            state.dims
        );
        Self::resume_with(Compute::Pjrt(rt), topo, state, old_world)
    }
}

/// Options of the `hecate fssdp` / `hecate checkpoint` / `hecate resume`
/// CLI flows.
#[derive(Debug, Clone)]
pub struct RunOpts {
    pub nodes: usize,
    pub devices: usize,
    pub iters: usize,
    pub seed: u64,
    /// Snapshot every N iterations into `checkpoint_dir` (0 = off).
    pub checkpoint_every: usize,
    pub checkpoint_dir: Option<String>,
    /// Resume from this checkpoint directory instead of a fresh init.
    pub resume: Option<String>,
    /// Use the hermetic reference backend instead of PJRT artifacts.
    pub reference: bool,
    /// Run on the SPMD executor (one OS thread per rank).
    pub parallel: bool,
    /// Optional explicit thread count; must equal `devices` when given
    /// (SPMD runs exactly one thread per rank).
    pub threads: Option<usize>,
}

impl Default for RunOpts {
    fn default() -> Self {
        RunOpts {
            nodes: 2,
            devices: 8,
            iters: 10,
            seed: 42,
            checkpoint_every: 0,
            checkpoint_dir: None,
            resume: None,
            reference: false,
            parallel: false,
            threads: None,
        }
    }
}

/// Reference-backend dimensions used when no artifacts are available
/// (small enough for CLI demos and CI).
pub fn reference_dims() -> LayerDims {
    LayerDims { tokens: 16, d_model: 8, d_ffn: 16, experts: 8, cap: 16 }
}

/// CLI driver: run the engine and print per-iteration stats (legacy entry,
/// no checkpointing).
pub fn run_demo(
    artifact_dir: &str,
    nodes: usize,
    devices: usize,
    iters: usize,
    seed: u64,
) -> anyhow::Result<()> {
    run_demo_with(
        artifact_dir,
        &RunOpts { nodes, devices, iters, seed, ..Default::default() },
    )
}

/// CLI driver with checkpoint/resume flows.
pub fn run_demo_with(artifact_dir: &str, opts: &RunOpts) -> anyhow::Result<()> {
    anyhow::ensure!(opts.nodes > 0 && opts.devices > 0, "need at least one node and device");
    anyhow::ensure!(
        opts.devices % opts.nodes == 0,
        "devices must divide evenly into nodes"
    );
    let topo = Topology::cluster_a(opts.nodes, opts.devices / opts.nodes);
    println!("FSSDP numeric engine on {} ({} devices)", topo.name, opts.devices);

    anyhow::ensure!(
        opts.checkpoint_every == 0 || opts.checkpoint_dir.is_some(),
        "--checkpoint-every needs --checkpoint-dir"
    );

    // SPMD flag validation, before any engine is built: one thread per
    // rank, and only the hermetic backend (PJRT client handles are
    // single-threaded).
    if opts.parallel {
        let threads = opts.threads.unwrap_or(opts.devices);
        anyhow::ensure!(
            threads == opts.devices,
            "--threads {} must equal --devices {}: the SPMD executor runs one OS thread per rank",
            threads,
            opts.devices
        );
        anyhow::ensure!(
            opts.reference,
            "--parallel requires the hermetic backend (add --reference): \
             PJRT runtime handles cannot be shared across rank threads"
        );
    }

    // Fresh start or elastic resume.
    let (mut engine, mut step, sources) = match &opts.resume {
        None => {
            let engine = if opts.reference {
                FssdpEngine::new_reference(reference_dims(), topo, opts.seed)
            } else {
                FssdpEngine::new(artifact_dir, topo, opts.seed)?
            };
            (engine, 0u64, opts.devices)
        }
        Some(dir) => {
            let (state, saved) = checkpoint::load(std::path::Path::new(dir))?;
            // The PJRT arm goes through `resume`, which validates the
            // artifact dims against the checkpoint before building.
            let (engine, plan) = if opts.reference {
                FssdpEngine::resume_reference(topo, &state, saved.world())?
            } else {
                FssdpEngine::resume(artifact_dir, topo, &state, saved.world())?
            };
            println!(
                "resumed step {} from {dir}: {} -> {} devices, {} experts moved ({:.2} MB), {}",
                state.step,
                saved.world(),
                opts.devices,
                plan.moved_experts.len(),
                plan.bytes_moved as f64 / 1e6,
                if plan.kept_saved_layout { "layout kept" } else { "re-sharded (Algorithm 2)" },
            );
            (engine, state.step, state.data_shards)
        }
    };

    if opts.parallel {
        engine.executor = Executor::spmd_for(&engine.topo);
    }

    println!(
        "layer: {} experts, d_model {}, d_ffn {}, {} tokens/source, cap {} (backend: {}, {})",
        engine.dims.experts,
        engine.dims.d_model,
        engine.dims.d_ffn,
        engine.dims.tokens,
        engine.dims.cap,
        engine.backend(),
        match engine.executor {
            Executor::Sequential => "sequential".to_string(),
            Executor::Spmd { threads, .. } => format!("spmd x{threads}"),
        }
    );

    // Spans run between checkpoint boundaries so both executors share one
    // driver loop (the SPMD executor keeps its rank threads alive for the
    // whole span and syncs state back at span exit).
    let end = step + opts.iters as u64;
    while step < end {
        let span = if opts.checkpoint_every > 0 {
            let ce = opts.checkpoint_every as u64;
            let next_ckpt = (step / ce + 1) * ce;
            (end.min(next_ckpt) - step) as usize
        } else {
            (end - step) as usize
        };
        let stats = engine.run_span(step, span, sources)?;
        for (k, s) in stats.iter().enumerate() {
            let it = step + k as u64;
            println!(
                "iter {it:>3}  loss {:.5}  λ={:.2}  replicas {}  remote_tokens {}  straggler {:.2}",
                s.loss, s.spag_sparsity, s.replicas, s.remote_tokens, s.straggler
            );
        }
        step += span as u64;
        if opts.checkpoint_every > 0 && step % opts.checkpoint_every as u64 == 0 {
            let dir = opts.checkpoint_dir.as_deref().expect("validated at entry");
            let info = checkpoint::save(
                std::path::Path::new(dir),
                &engine.snapshot(step, sources),
                &engine.topo,
            )?;
            println!(
                "  checkpoint @ step {step}: {} files, {:.2} MB -> {dir}",
                info.files,
                info.total_bytes as f64 / 1e6
            );
        }
    }
    if let Some(m) = engine.spmd_metrics() {
        println!(
            "spmd: compute {:?} | spag wait {:?} | gate+exchange {:?} | sprs {:?} (summed over ranks)",
            m.timer("spmd.compute"),
            m.timer("spmd.spag_wait"),
            m.timer("spmd.gate"),
            m.timer("spmd.sprs")
        );
    }
    // Final snapshot when a checkpoint dir is configured.
    if let Some(dir) = &opts.checkpoint_dir {
        if opts.checkpoint_every == 0 || step % opts.checkpoint_every as u64 != 0 {
            checkpoint::save(
                std::path::Path::new(dir),
                &engine.snapshot(step, sources),
                &engine.topo,
            )?;
            println!("final checkpoint @ step {step} -> {dir}");
        }
    }
    println!("done — parameters live on their shard owners (one global copy).");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::max_rel_err;

    #[test]
    fn reference_engine_trains_and_matches_single_device() {
        // Hermetic version of tests/fssdp_equivalence.rs: the reference
        // backend across 4 devices equals the 1-device run on the same data.
        let sources = 4;
        let dims = reference_dims();
        let run = |topo: Topology| -> Vec<Vec<f32>> {
            let mut e = FssdpEngine::new_reference(dims, topo, 7);
            for i in 0..3 {
                e.step(i, sources).unwrap();
            }
            (0..e.dims.experts).map(|x| e.expert_chunk(x).clone()).collect()
        };
        let dist = run(Topology::cluster_a(2, 2));
        let refr = run(Topology::flat(1, 1e9));
        for (e, (d, r)) in dist.iter().zip(refr.iter()).enumerate() {
            let err = max_rel_err(d, r);
            assert!(err < 2e-3, "expert {e}: max rel err {err}");
        }
    }

    #[test]
    fn reference_engine_loss_decreases() {
        let mut e = FssdpEngine::new_reference(reference_dims(), Topology::cluster_a(2, 2), 11);
        let first = e.step(0, 4).unwrap().loss;
        let mut last = first;
        for i in 1..6 {
            last = e.step(i, 4).unwrap().loss;
        }
        assert!(last < first, "loss {first} -> {last}");
        assert_eq!(e.backend(), "reference");
    }

    #[test]
    fn snapshot_captures_owner_layout() {
        let mut e = FssdpEngine::new_reference(reference_dims(), Topology::cluster_a(2, 2), 5);
        e.step(0, 4).unwrap();
        let s = e.snapshot(1, 4);
        assert_eq!(s.step, 1);
        assert_eq!(s.data_shards, 4);
        assert_eq!(s.experts.len(), e.dims.experts);
        for (x, &o) in s.owners.iter().enumerate() {
            assert_eq!(o, e.owner(x).0);
            assert_eq!(s.experts[x].chunk, *e.expert_chunk(x));
        }
    }
}
